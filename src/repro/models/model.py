"""Model assembly: grouped layer-stack scan, caches, chunked LM loss.

The layer stack is partitioned into homogeneous *groups* (see
``schema.layer_groups``): a uniform arch is one group scanned ``n_layers``
times; RecurrentGemma is ``(rglru, rglru, local) x 8`` plus a remainder
group; xLSTM is ``(mlstm x3, slstm) x 6``. Scanning keeps the HLO (and
compile time) independent of depth — essential when dry-running 80-layer
models for 512 devices.

Caches mirror the group structure with a leading ``repeats`` dim and flow
through the same scans.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import recurrent as rec
from repro.models.attention import KVCache, attn_block
from repro.models.config import ModelConfig
from repro.models.layers import (apply_mlp, apply_norm, cdt, cross_entropy,
                                 embed_tokens, linear, unembed)
from repro.models.moe import apply_moe
from repro.models.schema import layer_groups
from repro.sharding import shard_hint


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _attn_cache_init(cfg: ModelConfig, kind: str, b: int, cap: int):
    window = cfg.window if kind in ("swa", "local") else 0
    c = min(window, cap) if window else cap
    shape = (b, c, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, cdt(cfg)), jnp.zeros(shape, cdt(cfg)))


def _mixer_cache_init(cfg: ModelConfig, kind: str, b: int, cap: int):
    d = cfg.d_model
    if kind in ("attn", "swa", "local"):
        return _attn_cache_init(cfg, kind, b, cap)
    if kind == "mlstm":
        de = 2 * d
        return rec.mlstm_state_init(b, cfg.n_heads, de // cfg.n_heads, de)
    if kind == "slstm":
        return rec.slstm_state_init(b, d)
    if kind == "rglru":
        return rec.rglru_state_init(b, cfg.lru_d)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cap: int):
    """Decode cache pytree matching the params group structure."""
    groups = {}
    for gi, (unit, reps) in enumerate(layer_groups(cfg)):
        g = {str(i): _mixer_cache_init(cfg, kind, batch, cap)
             for i, kind in enumerate(unit)}
        groups[str(gi)] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (reps, *x.shape)).copy(), g)
    return groups


# ---------------------------------------------------------------------------
# one unit of blocks (the scan body)
# ---------------------------------------------------------------------------

def _apply_unit(unit, p_unit, x, cfg: ModelConfig, caches, positions,
                cache_pos, mode: str, prefill_pad: int = 0):
    """Apply the blocks of one pattern unit. Returns (x, new_caches, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for idx, kind in enumerate(unit):
        bp = p_unit[str(idx)]
        ci = caches.get(str(idx)) if caches is not None else None
        if kind in ("attn", "swa", "local"):
            out, c_new = attn_block(bp["mixer"], x, cfg, kind,
                                    positions=positions, cache=ci,
                                    cache_pos=cache_pos)
            if mode == "train":
                c_new = None
            elif mode == "prefill":
                c_new = _prefill_attn_cache(cfg, kind, c_new, prefill_pad)
        elif kind == "mlstm":
            out, c_new = rec.mlstm_block(bp["mixer"], x, cfg, ci)
        elif kind == "slstm":
            out, c_new = rec.slstm_block(bp["mixer"], x, cfg, ci)
        elif kind == "rglru":
            out, c_new = rec.rglru_block(bp["mixer"], x, cfg, ci)
        else:
            raise ValueError(kind)
        x = shard_hint(x + out, "acts")
        if "mlp" in bp:
            if cfg.n_experts:
                mo, a = apply_moe(bp["mlp"], x, cfg)
                aux = aux + a
            else:
                mo = apply_mlp(bp["mlp"], x, cfg)
            x = shard_hint(x + mo, "acts")
        if c_new is not None:
            new_caches[str(idx)] = c_new
    return x, (new_caches or None), aux


def _prefill_attn_cache(cfg: ModelConfig, kind: str, kv: KVCache,
                        pad_to: int = 0) -> KVCache:
    """Convert prefill-computed (k, v) into a decode cache (window tail,
    ring-buffer aligned; full-attn caches padded to ``pad_to`` capacity)."""
    window = cfg.window if kind in ("swa", "local") else 0
    k, v = kv.k, kv.v
    s = k.shape[1]
    if window and s > window:
        k, v = k[:, -window:], v[:, -window:]
        shift = s % window
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
    elif window and s < window:
        # ring decode indexes slots mod window: pad short prefills to the
        # full window (slot i == position i while the buffer first fills)
        pad = ((0, 0), (0, window - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    elif not window and pad_to > s:
        pad = ((0, 0), (0, pad_to - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return KVCache(k.astype(jnp.dtype(cfg.compute_dtype)),
                   v.astype(jnp.dtype(cfg.compute_dtype)))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _group_k(cfg: ModelConfig) -> int:
    """remat='group:k' -> k (0 = plain per-layer remat)."""
    if cfg.remat.startswith("group:"):
        return int(cfg.remat.split(":")[1])
    return 0


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, cache=None, cache_pos=None, mode: str = "train",
            prefill_pad: int = 0):
    """Run the stack. Returns (x_final, new_cache, aux_loss).

    mode: train (no caches) | prefill (produce caches) | decode (consume).
    """
    if embeds is not None:
        x = linear(params["frontend_proj"], embeds.astype(cdt(cfg)), cfg)
    else:
        x = embed_tokens(params["embed"], tokens, cfg)
    x = shard_hint(x, "acts")
    if positions is None:
        base = jnp.arange(x.shape[1])[None, :]
        if mode == "decode":
            base = base + cache_pos
        positions = jnp.broadcast_to(base, (3, *x.shape[:2])) if cfg.mrope \
            else jnp.broadcast_to(base, x.shape[:2])

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    for gi, (unit, reps) in enumerate(layer_groups(cfg)):
        gp = params["groups"][str(gi)]
        gcache = cache[str(gi)] if cache is not None else None

        if mode == "train":
            def body(carry, p_unit, _unit=unit):
                xc, auxc = carry
                xo, _, a = _apply_unit(_unit, p_unit, xc, cfg, None,
                                       positions, cache_pos, mode)
                return (xo, auxc + a), None
            k = _group_k(cfg)
            if k > 1 and reps % k == 0 and reps > k:
                # sqrt(L)-style recursive checkpointing: the outer scan
                # saves x once per k layers (residual stack / k); the
                # backward recomputes each group's k layers transiently.
                # See EXPERIMENTS.md §Perf (qwen2-vl-72b iteration 3).
                grouped = jax.tree.map(
                    lambda t: t.reshape(reps // k, k, *t.shape[1:]), gp)

                def group_body(carry, p_group, _unit=unit):
                    def inner(c, p_u):
                        xc, auxc = c
                        xo, _, a = _apply_unit(_unit, p_u, xc, cfg, None,
                                               positions, cache_pos, mode)
                        return (xo, auxc + a), None
                    # recursive: the inner layers are checkpointed too,
                    # else the group recompute saves k layers of internals
                    c2, _ = jax.lax.scan(
                        jax.checkpoint(
                            inner,
                            policy=jax.checkpoint_policies.nothing_saveable),
                        carry, p_group)
                    return c2, None
                (x, aux_total), _ = jax.lax.scan(
                    jax.checkpoint(
                        group_body,
                        policy=jax.checkpoint_policies.nothing_saveable),
                    (x, aux_total), grouped)
            else:
                (x, aux_total), _ = jax.lax.scan(
                    _remat(body, cfg), (x, aux_total), gp)
        else:
            def body(carry, xs, _unit=unit):
                xc, auxc = carry
                p_unit, caches = xs
                xo, c_new, a = _apply_unit(_unit, p_unit, xc, cfg, caches,
                                           positions, cache_pos, mode)
                return (xo, auxc + a), c_new
            if mode == "prefill":
                # caches are produced, not consumed: xs carries params only
                def body(carry, p_unit, _unit=unit):
                    xc, auxc = carry
                    xo, c_new, a = _apply_unit(_unit, p_unit, xc, cfg, None,
                                               positions, cache_pos, mode,
                                               prefill_pad)
                    return (xo, auxc + a), c_new
                (x, aux_total), c_out = jax.lax.scan(body, (x, aux_total), gp)
            else:
                (x, aux_total), c_out = jax.lax.scan(
                    body, (x, aux_total), (gp, gcache))
            new_cache[str(gi)] = c_out

    x = apply_norm(params["final_norm"], x, cfg)
    return x, (new_cache or None), aux_total


# ---------------------------------------------------------------------------
# losses / logits
# ---------------------------------------------------------------------------

def chunked_lm_loss(params, cfg: ModelConfig, x, labels, chunk: int = 1024):
    """Cross-entropy without materializing (B, S, V): scan over S chunks,
    rematerializing logits in the backward pass."""
    b, s, d = x.shape
    ck = min(chunk, s)
    n = s // ck
    xs = jnp.moveaxis(x.reshape(b, n, ck, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n, ck), 1, 0)

    @jax.checkpoint
    def body(tot, inp):
        xc, lc = inp
        logits = unembed(params, xc, cfg)
        logits = shard_hint(logits, "logits")
        nll = cross_entropy(logits, lc)
        return tot + nll, None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot / n


def lm_logits(params, cfg: ModelConfig, x):
    return shard_hint(unembed(params, x, cfg), "logits")


# ---------------------------------------------------------------------------
# public entry points (what the steps / dry-run lower)
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch):
    """batch: {tokens | embeds, labels?, positions?}."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    positions = batch.get("positions")
    if "labels" in batch:                   # pipeline provides shifted labels
        labels = batch["labels"]
        inputs = tokens
    else:                                   # causal LM fallback: shift here
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        if positions is not None:
            positions = positions[..., :-1]
    x, _, aux = forward(params, cfg, tokens=inputs, embeds=embeds,
                        positions=positions, mode="train")
    return chunked_lm_loss(params, cfg, x, labels) + aux


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, pad_to: int = 0):
    """Returns (last_token_logits, cache)."""
    x, cache, _ = forward(params, cfg, tokens=tokens, embeds=embeds,
                          positions=positions, mode="prefill",
                          prefill_pad=pad_to)
    logits = lm_logits(params, cfg, x[:, -1:, :])
    return logits[:, 0, :], cache


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (write slot).
    Returns (logits (B, V), new_cache)."""
    x, new_cache, _ = forward(params, cfg, tokens=token, cache=cache,
                              cache_pos=pos, mode="decode")
    logits = lm_logits(params, cfg, x)
    return logits[:, 0, :], new_cache


def encode(params, cfg: ModelConfig, embeds):
    """Encoder-only forward (HuBERT): full-sequence logits."""
    x, _, _ = forward(params, cfg, embeds=embeds, mode="train")
    return lm_logits(params, cfg, x)
