"""Mixture-of-Experts FFN with grouped capacity-based scatter dispatch.

Token-choice top-k routing (Mixtral: k=2 of 8; Llama4-Scout: k=1 of 16).

Dispatch is *grouped by batch row*: each row computes its own
position-in-expert cumsum and scatters into an (E, C_row, d) slice. This
keeps the cumsum and scatter local to the data shard — a global cumsum over
the flattened token stream creates a cross-shard sequential dependency that
XLA resolves by all-gathering every token onto every device (measured:
215 GiB/device and a 5x collective blow-up on mixtral train_4k; see
EXPERIMENTS.md §Perf iteration 1). With experts sharded on the model axis
the (B, E, C, d) buffer reshard lowers to an all-to-all, as in production
MoE stacks.

Returns ``(out, aux_loss)`` where aux is the standard load-balancing loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, cdt
from repro.sharding import shard_hint


def apply_moe(p, x, cfg: ModelConfig):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.topk
    cap = int(max(1, (s * k / e) * cfg.capacity_factor))   # per batch row
    cap = ((cap + 3) // 4) * 4

    hx = apply_norm(p["norm"], x, cfg)                     # (B, S, d)

    # --- routing (f32) ---
    logits = jnp.einsum("bsd,de->bse", hx.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                # (B, S, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (B, S, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- per-row capacity assignment ---
    flat_e = expert_idx.reshape(b, s * k)                  # (B, S*K)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)    # (B, S*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1              # row-local count
    position = jnp.take_along_axis(pos_in_e, flat_e[..., None],
                                   axis=2)[..., 0]         # (B, S*K)
    keep = position < cap
    slot = jnp.where(keep, flat_e * cap + position, e * cap)

    # --- dispatch: per-row scatter into (B, E*C+1, d) ---
    src = jnp.repeat(hx, k, axis=1)                        # (B, S*K, d)
    buf = jnp.zeros((b, e * cap + 1, d), cdt(cfg))
    buf = jax.vmap(lambda bf, sl, sr: bf.at[sl].add(sr))(
        buf, slot, src * keep[..., None].astype(cdt(cfg)))
    buf = buf[:, : e * cap].reshape(b, e, cap, d)
    buf = shard_hint(buf, "expert_buf4")                   # -> all-to-all

    # --- expert FFN (swiglu); experts sharded on the model axis ---
    wi = p["wi"].astype(cdt(cfg))
    wo = p["wo"].astype(cdt(cfg))
    gu = jnp.einsum("becd,edf->becf", buf, wi)
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, wo)          # (B, E, C, d)
    out_buf = shard_hint(out_buf, "expert_buf4")

    # --- combine: gather each (token, slot)'s row, weight, sum over K ---
    flat = jnp.concatenate(
        [out_buf.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), cdt(cfg))], axis=1)
    gathered = jax.vmap(lambda fl, sl: fl[sl])(flat, slot)  # (B, S*K, d)
    w = (gate_vals.reshape(b, s * k) * keep).astype(cdt(cfg))
    out = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)

    # --- load balancing aux (Switch/Mixtral form) ---
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs) * cfg.router_aux_coef

    return out, aux
