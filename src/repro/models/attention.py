"""Attention: GQA with RoPE/M-RoPE, full/sliding-window/local variants.

Two execution paths:
  * pure-JAX *blocked* attention (``lax.scan`` over q/kv chunks with online
    softmax) — O(S·chunk) memory, compiles on any backend; this is what the
    dry-run lowers. Used as the oracle for the Pallas kernel.
  * Pallas TPU flash kernel (``repro.kernels.flash_attention``) selected by
    ``cfg.use_pallas`` — the TPU hot path, validated in interpret mode.

Shapes: q (B,S,H,hd); k,v (B,Skv,Hkv,hd); GQA folds H = Hkv * G.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_mrope, apply_norm, apply_rope, cdt, linear
from repro.sharding import shard_hint


class KVCache(NamedTuple):
    k: jax.Array          # (B, Smax, Hkv, hd)
    v: jax.Array


NEG_INF = -1e30


def _fold_gqa(q, n_kv: int):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _chunked(x, chunk: int, axis: int):
    """Reshape axis into (n_chunks, chunk)."""
    n = x.shape[axis] // chunk
    new_shape = x.shape[:axis] + (n, chunk) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def blocked_attention(q, k, v, *, causal: bool, window: int, q_offset: int,
                      chunk_q: int, chunk_kv: int, scale: float):
    """Online-softmax blocked attention (flash-style, pure JAX).

    Scans q chunks (outer) and kv chunks (inner) carrying (m, l, acc); memory
    is O(B·H·chunk_q·hd) instead of O(S²).
    """
    b, sq, hkv, g, hd = q.shape[0], q.shape[1], k.shape[2], q.shape[2] // k.shape[2], q.shape[3]
    skv_real = k.shape[1]
    cq = min(chunk_q, sq)
    ck = min(chunk_kv, skv_real)
    pq, pk = (-sq) % cq, (-skv_real) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    q = _fold_gqa(q, hkv)                                     # (B,Sq,Hkv,G,hd)
    nq, nk = (sq + pq) // cq, (skv_real + pk) // ck

    qc = jnp.moveaxis(_chunked(q, cq, 1), 1, 0)               # (nq,B,cq,Hkv,G,hd)
    kc = jnp.moveaxis(_chunked(k, ck, 1), 1, 0)               # (nk,B,ck,Hkv,hd)
    vc = jnp.moveaxis(_chunked(v, ck, 1), 1, 0)

    qpos_base = jnp.arange(cq)
    kpos_base = jnp.arange(ck)

    # Each chunk body is checkpointed: without this, reverse-mode stacks
    # every (q,kv) chunk pair's f32 scores for the backward pass (measured
    # 16 GiB per layer at 4k/72B — EXPERIMENTS.md §Perf). With it, the
    # backward recomputes scores chunk-by-chunk: the remat analogue of
    # flash attention's O(S) memory.
    def q_step(_, qi):
        qblk, qidx = qi                                       # (B,cq,Hkv,G,hd)
        qpos = q_offset + qidx * cq + qpos_base               # (cq,)

        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            kpos = kidx * ck + kpos_base                      # (ck,)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale  # (B,Hkv,G,cq,ck)
            # additive (cq, ck) mask, added pre-broadcast: XLA hoists the
            # loop-invariant per-chunk-pair table out of the scan, so keep
            # it tiny (a post-broadcast boolean select materializes a
            # (nq*nk*B*H*cq*ck) monster — gigabytes at 4k, terabytes at 32k).
            mask = jnp.broadcast_to(kpos[None, :] < skv_real, (cq, ck))
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = s + jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,cq,hd)
        return None, jnp.moveaxis(out, 3, 1)                  # (B,cq,Hkv,G,hd)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq + pq, hkv * g, hd)
    return out[:, :sq]                                        # (B,Sq,H,hd)


def windowed_attention(q, k, v, *, window: int, chunk_q: int, scale: float):
    """Local/SWA attention with per-q-chunk KV slicing — O(S·window) FLOPs.

    For each q chunk starting at t, attends keys in [t - window, t + cq).
    KV is padded on the left by ``window`` so slices are static-size.
    """
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    cq = min(chunk_q, sq)
    pq = (-sq) % cq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    nq = (sq + pq) // cq
    span = window + cq
    q = _fold_gqa(q, hkv)
    kp = jnp.pad(k, ((0, 0), (window, pq), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, pq), (0, 0), (0, 0)))
    qc = jnp.moveaxis(_chunked(q, cq, 1), 1, 0)               # (nq,B,cq,Hkv,G,hd)

    qpos_base = jnp.arange(cq)
    kpos_base = jnp.arange(span)

    @jax.checkpoint
    def q_step(_, qi):
        qblk, qidx = qi
        start = qidx * cq                                     # kv slice start in padded coords
        kblk = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        qpos = start + qpos_base                              # unpadded q position
        kpos = start + kpos_base - window                     # unpadded key position
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk.astype(jnp.float32),
                       kblk.astype(jnp.float32)) * scale
        mask = (kpos[None, :] <= qpos[:, None]) \
            & (kpos[None, :] > qpos[:, None] - window) \
            & (kpos[None, :] >= 0) & (kpos[None, :] < sq)
        s = s + jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)  # pre-broadcast
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
        return None, jnp.moveaxis(out, 3, 1)                  # (B,cq,Hkv,G,hd)

    _, outs = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq + pq, h, hd)[:, :sq]


def decode_attention(q, cache: KVCache, pos, *, window: int, scale: float):
    """Single-token attention against a cache. q: (B,1,H,hd); pos: scalar
    current position (number of valid cache entries is pos+1 after insert)."""
    b, _, h, hd = q.shape
    hkv = cache.k.shape[2]
    smax = cache.k.shape[1]
    qf = _fold_gqa(q, hkv).astype(jnp.float32)                # (B,1,Hkv,G,hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, cache.k.astype(jnp.float32)) * scale
    kpos = jnp.arange(smax)
    mask = kpos <= pos
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, cache.v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# full attention block (projections + rope + attention + out proj)
# ---------------------------------------------------------------------------

def attn_block(p, x, cfg: ModelConfig, kind: str, *,
               positions=None, cache: Optional[KVCache] = None,
               cache_pos=None, layer_window: int = 0):
    """Returns (out, new_cache). kind: attn | swa | local.

    Train/prefill: cache is None (prefill callers build the cache from the
    returned k/v via ``make_cache``); decode: cache given, x is (B,1,d).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    window = layer_window or (cfg.window if kind in ("swa", "local") else 0)
    scale = hd ** -0.5

    hx = apply_norm(p["norm"], x, cfg)
    # GQA tensor-parallel attention: q heads shard over the model axis
    # whenever divisible; kv heads replicate when below the axis size
    # (kv=8 on a 16-way axis would otherwise force the WHOLE attention to
    # replicate — measured as the per-layer transient floor on 72B).
    q = shard_hint(linear(p["wq"], hx, cfg).reshape(b, s, h, hd), "heads")
    k = shard_hint(linear(p["wk"], hx, cfg).reshape(b, s, hkv, hd), "heads")
    v = shard_hint(linear(p["wv"], hx, cfg).reshape(b, s, hkv, hd), "heads")

    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.mrope:
        pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(
            positions, (3, *positions.shape))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:                                     # decode
        slot = cache_pos if window == 0 else cache_pos % cache.k.shape[1]
        nk = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
        nv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
        new_cache = KVCache(nk, nv)
        if window == 0:
            out = decode_attention(q, new_cache, cache_pos, window=0, scale=scale)
        else:
            # ring-buffer cache of size window: every live entry is in range
            out = _decode_ring(q, new_cache, cache_pos, window, scale)
        out = out.reshape(b, s, h * hd)
        return linear(p["wo"], out.astype(cdt(cfg)), cfg), new_cache

    if cfg.use_pallas:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window,
                                   scale=scale)
    elif window > 0:
        out = windowed_attention(q, k, v, window=window,
                                 chunk_q=cfg.attn_q_chunk, scale=scale)
    else:
        out = blocked_attention(q, k, v, causal=cfg.causal, window=0, q_offset=0,
                                chunk_q=cfg.attn_q_chunk,
                                chunk_kv=cfg.attn_kv_chunk, scale=scale)
    out = out.reshape(b, s, h * hd).astype(cdt(cfg))
    kv = KVCache(k, v)                                        # for prefill cache build
    return linear(p["wo"], out, cfg), kv


def _decode_ring(q, cache: KVCache, pos, window: int, scale: float):
    """Decode attention over a ring-buffer window cache (size == window)."""
    b, _, h, hd = q.shape
    hkv = cache.k.shape[2]
    qf = _fold_gqa(q, hkv).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, cache.k.astype(jnp.float32)) * scale
    # slot i holds absolute position p_i with p_i ≡ i (mod window); valid iff
    # p_i in (pos - window, pos]; since buffer is overwritten mod window, a
    # slot is stale only before the buffer first fills.
    idx = jnp.arange(window)
    age = (pos - idx) % window                                # distance back
    valid = (pos - age) >= 0
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, cache.v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(b, 1, h, hd)
