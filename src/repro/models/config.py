"""Model + input-shape configuration schema.

Single source of truth for every selectable architecture (``--arch``) and
every assigned input shape. Configs are frozen dataclasses so they hash and
can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- attention options ---
    attn_bias: bool = False        # Qwen-style QKV bias
    window: int = 0                # 0 = full attention; >0 = sliding window
    causal: bool = True            # False for encoder-only (HuBERT)
    rope_theta: float = 10_000.0
    mrope: bool = False            # Qwen2-VL multimodal RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # --- MoE options ---
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- layer pattern ---
    # Unit of block kinds repeated down the stack; remainder handled
    # explicitly. Kinds: attn | swa | local | mlstm | slstm | rglru
    pattern_unit: Tuple[str, ...] = ("attn",)

    # --- recurrent widths ---
    lru_width: int = 0             # RG-LRU width (0 -> d_model)
    conv_width: int = 4

    # --- MLP / norm ---
    mlp: str = "swiglu"            # swiglu | gelu | none
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- modality frontend stub ---
    frontend: Optional[str] = None  # None | audio_frames | vision_patches
    d_frontend: int = 0

    # --- numerics ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # --- runtime knobs (overridable by the MeshPlanner) ---
    remat: str = "full"            # none | dots | full
    scan_layers: bool = True
    use_pallas: bool = False       # TPU hot path; CPU CI uses the jnp path
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    mlstm_chunk: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is bounded (window, recurrence) -> long_500k ok."""
        kinds = set(self.pattern_unit)
        if kinds & {"mlstm", "slstm", "rglru"}:
            # fine unless some layer is *full* attention
            return "attn" not in kinds or self.window > 0
        return self.window > 0 or all(k in ("swa", "local") for k in kinds)

    @property
    def lru_d(self) -> int:
        return self.lru_width or self.d_model

    def pattern(self) -> Tuple[str, ...]:
        """Full per-layer kind list of length n_layers."""
        unit = self.pattern_unit
        reps = self.n_layers // len(unit)
        rem = self.n_layers % len(unit)
        return unit * reps + unit[:rem]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (matches the schema; used for roofline)."""
        from repro.models.schema import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only topk experts active)."""
        from repro.models.schema import count_params
        total = count_params(self)
        if self.n_experts and self.topk:
            # expert FFN params per layer: 3*d*ff each (fused gate|up = 2, down = 1)
            n_moe_layers = sum(1 for k in self.pattern() if k in ("attn", "swa", "local"))
            inactive = (self.n_experts - self.topk) * 3 * self.d_model * self.d_ff
            return total - inactive * n_moe_layers
        return total


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell is runnable; reason if not."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
