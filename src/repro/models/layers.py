"""Shared layer math: norms, MLPs, rotary embeddings, embedding/unembedding.

All functions are pure; params are plain dict subtrees produced by
``repro.models.schema``. Params are stored in ``cfg.param_dtype`` (f32) and
cast to ``cfg.compute_dtype`` (bf16) at the point of use — master weights
stay full precision for the optimizer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def apply_norm(p, x, cfg: ModelConfig):
    """RMSNorm or LayerNorm, computed in f32, returned in compute dtype."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + cfg.norm_eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(cdt(cfg))


def rms_head_norm(scale, x, eps=1e-6):
    """Per-head RMS norm used by mLSTM output (f32 in/out preserved)."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# linear / mlp
# ---------------------------------------------------------------------------

def linear(p, x, cfg: ModelConfig):
    y = x @ p["w"].astype(cdt(cfg))
    if "b" in p:
        y = y + p["b"].astype(cdt(cfg))
    return y


def apply_mlp(p, x, cfg: ModelConfig):
    h = apply_norm(p["norm"], x, cfg)
    if cfg.mlp == "swiglu":
        gu = linear(p["wi"], h, cfg)
        g, u = jnp.split(gu, 2, axis=-1)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(linear(p["wi"], h, cfg))
    return linear(p["wo"], h, cfg)


# ---------------------------------------------------------------------------
# rotary position embeddings (RoPE and Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = _rope_freqs(hd, theta)                              # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv      # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE. positions3: (3, ..., S) for (t, h, w) streams;
    head dim is split into ``sections`` (summing to hd/2), each rotated by its
    own position stream."""
    hd = x.shape[-1]
    inv = _rope_freqs(hd, theta)                              # (hd/2,)
    # build a per-frequency position by selecting the stream for its section
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=hd // 2)          # (hd/2,)
    pos = jnp.take(positions3, sec_id, axis=0)                # (hd/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                            # (..., S, hd/2)
    ang = pos.astype(jnp.float32) * inv
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_tokens(p, tokens, cfg: ModelConfig):
    return jnp.take(p["w"], tokens, axis=0).astype(cdt(cfg))


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"]["w"].astype(cdt(cfg)).T
        return x @ w
    return linear(params["lm_head"], x, cfg)


def cross_entropy(logits, labels, mask=None):
    """Mean token CE in f32. logits: (..., V), labels: (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
