"""Recurrent mixers: xLSTM (mLSTM, sLSTM) and Griffin's RG-LRU.

Training paths:
  * mLSTM — chunkwise-parallel form (lax.scan over chunks; quadratic inside a
    chunk, matrix-memory state across chunks) with log-space stabilizers,
    following the xLSTM formulation.
  * sLSTM — strictly sequential (recurrent h_{t-1} -> gates), lax.scan over
    time; this non-parallelizable form is intrinsic to sLSTM.
  * RG-LRU — diagonal linear recurrence via associative scan (or the Pallas
    blocked kernel on TPU).

Decode paths are single-step state updates; states live in the layer cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_norm, cdt, linear, rms_head_norm
from repro.sharding import shard_hint

LOG_EPS = -30.0
C_LRU = 8.0


# ---------------------------------------------------------------------------
# causal conv1d (width-4) with decode state
# ---------------------------------------------------------------------------

class ConvState(NamedTuple):
    buf: jax.Array                      # (B, W-1, D) trailing inputs


def causal_conv(p, x, state: ConvState | None):
    """Depthwise causal conv. x: (B,S,D). Returns (y, new_state)."""
    w, b = p["w"], p["b"]                                     # (W, D), (D,)
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.buf.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(width))
    y = y + b.astype(x.dtype)
    new_state = ConvState(xp[:, -(width - 1):, :].astype(jnp.float32))
    return y, new_state


def conv_state_init(b, d):
    return ConvState(jnp.zeros((b, 3, d), jnp.float32))


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array                        # (B, H, hd, hd) matrix memory (m-scaled)
    n: jax.Array                        # (B, H, hd)
    m: jax.Array                        # (B, H) log-space stabilizer
    conv: ConvState


def mlstm_state_init(b, h, hd, de):
    return MLSTMState(jnp.zeros((b, h, hd, hd), jnp.float32),
                      jnp.zeros((b, h, hd), jnp.float32),
                      jnp.full((b, h), LOG_EPS, jnp.float32),
                      conv_state_init(b, de))


def _mlstm_chunk(carry, inp):
    """One chunk of the chunkwise-parallel stabilized mLSTM.

    carry: (C, n, m) with C (B,H,hd,hd); inp: q,k,v (B,c,H,hd) with k
    pre-scaled by hd^-0.5, logi/logf (B,c,H). All f32.
    """
    C_p, n_p, m_p = carry
    q, k, v, logi, logf = inp
    c = q.shape[1]
    F = jnp.cumsum(logf, axis=1)                               # (B,c,H) inclusive
    Ftot = F[:, -1]                                            # (B,H)
    G = jax.lax.cummax(logi - F, axis=1)                       # (B,c,H)
    m_t = F + jnp.maximum(m_p[:, None], G)                     # (B,c,H)

    # decay matrix D[t,s] = exp(F_t - F_s + logi_s - m_t), s <= t
    logD = (F[:, :, None] - F[:, None, :] + logi[:, None, :]
            - m_t[:, :, None])                                 # (B,t,s,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri[None, :, :, None], jnp.exp(logD), 0.0)

    S_qk = jnp.einsum("bthd,bshd->btsh", q, k)                 # (B,t,s,H)
    intra = jnp.einsum("btsh,bshd->bthd", S_qk * D, v)
    w_inter = jnp.exp(F + m_p[:, None] - m_t)                  # (B,c,H)
    inter = jnp.einsum("bthd,bhde->bthe", q, C_p) * w_inter[..., None]
    n_t = (w_inter[..., None] * n_p[:, None]
           + jnp.einsum("btsh,bshd->bthd", D, k))
    qn = jnp.abs(jnp.einsum("bthd,bthd->bth", q, n_t))
    denom = jnp.maximum(qn, jnp.exp(-m_t))
    h = (intra + inter) / denom[..., None]                     # (B,c,H,hd)

    # chunk-end state
    m_new = m_t[:, -1]                                         # (B,H)
    w_c = jnp.exp(Ftot[:, None] - F + logi - m_new[:, None])   # (B,s,H)
    C_new = (jnp.exp(Ftot + m_p - m_new)[..., None, None] * C_p
             + jnp.einsum("bsh,bshd,bshe->bhde", w_c, k, v))
    n_new = (jnp.exp(Ftot + m_p - m_new)[..., None] * n_p
             + jnp.einsum("bsh,bshd->bhd", w_c, k))
    return (C_new, n_new, m_new), h


def mlstm_scan(q, k, v, logi, logf, state: MLSTMState, chunk: int):
    """q,k,v: (B,S,H,hd) f32; logi/logf: (B,S,H) f32. Returns (h, new_state).

    S is padded to a chunk multiple with i-gate = -inf (no state contribution)
    and f-gate = 1 (state preserved); padded outputs are sliced off."""
    b, s, h, hd = q.shape
    ck = min(chunk, s)
    pad = (-s) % ck
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(x, padq) for x in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)),
                       constant_values=2 * LOG_EPS)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    n = sp // ck
    shp = lambda x: jnp.moveaxis(x.reshape(b, n, ck, *x.shape[2:]), 1, 0)
    carry = (state.c, state.n, state.m)
    # checkpointed per chunk: the backward otherwise stacks every chunk's
    # (c,c) decay/score matrices (measured 29 GiB on xlstm train_4k)
    (c_f, n_f, m_f), hs = jax.lax.scan(
        jax.checkpoint(_mlstm_chunk,
                       policy=jax.checkpoint_policies.nothing_saveable),
        carry, (shp(q), shp(k), shp(v), shp(logi), shp(logf)))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, sp, h, hd)[:, :s]
    return hs, (c_f, n_f, m_f)


def mlstm_block(p, x, cfg: ModelConfig, state: MLSTMState | None):
    """Full mLSTM block. x: (B,S,d). Returns (out, new_state)."""
    b, s, d = x.shape
    de = 2 * d
    h = cfg.n_heads
    hd = de // h
    hx = apply_norm(p["norm"], x, cfg)
    u, g = jnp.split(linear(p["wup"], hx, cfg), 2, axis=-1)    # (B,S,de) x2
    u, conv_state = causal_conv(p["conv"], u,
                                state.conv if state is not None else None)
    u = jax.nn.silu(u)
    q = linear(p["wq"], u, cfg).reshape(b, s, h, hd).astype(jnp.float32)
    k = linear(p["wk"], u, cfg).reshape(b, s, h, hd).astype(jnp.float32)
    v = linear(p["wv"], u, cfg).reshape(b, s, h, hd).astype(jnp.float32)
    k = k * hd ** -0.5
    gates = linear(p["wif"], u, cfg).astype(jnp.float32)       # (B,S,2H)
    logi, f_pre = gates[..., :h], gates[..., h:]
    logf = -jax.nn.softplus(-f_pre)                            # log sigmoid

    st = state if state is not None else mlstm_state_init(b, h, hd, de)
    hs, (c_f, n_f, m_f) = mlstm_scan(q, k, v, logi, logf, st, cfg.mlstm_chunk)
    hs = rms_head_norm(p["onorm"]["scale"].reshape(h, hd), hs.astype(cdt(cfg)))
    out = hs.reshape(b, s, de) * jax.nn.silu(g)
    out = linear(p["wdown"], out, cfg)
    return out, MLSTMState(c_f, n_f, m_f, conv_state)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array                        # (B, d)
    n: jax.Array
    h: jax.Array
    m: jax.Array


def slstm_state_init(b, d):
    z = jnp.zeros((b, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((b, d), LOG_EPS, jnp.float32))


def slstm_block(p, x, cfg: ModelConfig, state: SLSTMState | None):
    """Sequential sLSTM with per-head block-diagonal recurrence."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    hx = apply_norm(p["norm"], x, cfg)
    gx = (linear(p["wg"], hx, cfg) + p["bg"].astype(cdt(cfg))).astype(jnp.float32)
    st = state if state is not None else slstm_state_init(b, d)
    rg = p["rg"].astype(jnp.float32)                           # (H, hd, 4hd)

    def step(carry, g_t):
        c, n, hprev, m = carry                                 # (B,d) each
        hh = hprev.reshape(b, h, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, rg).reshape(b, 4 * d)
        g = g_t + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)                        # exp f-gate form
        ip = jnp.exp(gi - m_new)
        fp = jnp.exp(gf + m - m_new)
        c_new = fp * c + ip * jnp.tanh(gz)
        n_new = fp * n + ip
        h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    gx_t = jnp.moveaxis(gx, 1, 0)                              # (S,B,4d)
    # two-level sqrt(T) checkpointing: a flat T-step scan saves 4 state
    # vectors per step for the backward (4 GiB/layer at 4k x B16); the
    # outer scan saves states every `blk` steps and recomputes inside.
    blk = max(1, int(s ** 0.5))
    nb, rem = divmod(s, blk)
    if rem:
        nb += 1
        gx_t = jnp.pad(gx_t, ((0, nb * blk - s), (0, 0), (0, 0)))

    def block(carry, g_blk):
        return jax.lax.scan(step, carry, g_blk)

    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(
        jax.checkpoint(block,
                       policy=jax.checkpoint_policies.nothing_saveable),
        (st.c, st.n, st.h, st.m),
        gx_t.reshape(nb, blk, b, 4 * d))
    hs = hs.reshape(nb * blk, b, d)[:s]
    hs = jnp.moveaxis(hs, 0, 1).astype(cdt(cfg))               # (B,S,d)
    out = linear(p["wo"], hs, cfg)
    return out, SLSTMState(c_f, n_f, h_f, m_f)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

class RGLRUState(NamedTuple):
    h: jax.Array                        # (B, dr)
    conv: ConvState


def rglru_state_init(b, dr):
    return RGLRUState(jnp.zeros((b, dr), jnp.float32), conv_state_init(b, dr))


def rglru_block(p, x, cfg: ModelConfig, state: RGLRUState | None):
    b, s, d = x.shape
    dr = cfg.lru_d
    hx = apply_norm(p["norm"], x, cfg)
    xr = linear(p["wx"], hx, cfg)                              # (B,S,dr)
    xg = linear(p["wg"], hx, cfg)
    xr, conv_state = causal_conv(p["conv"], xr,
                                 state.conv if state is not None else None)
    xr32 = xr.astype(jnp.float32)
    lru = p["lru"]
    r = jax.nn.sigmoid(xr32 @ lru["wa"]["w"].astype(jnp.float32)
                       + lru["ba"].astype(jnp.float32))        # recurrence gate
    i = jax.nn.sigmoid(xr32 @ lru["wi"]["w"].astype(jnp.float32)
                       + lru["bi"].astype(jnp.float32))        # input gate
    log_a = C_LRU * r * jax.nn.log_sigmoid(lru["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)                                         # (B,S,dr) in (0,1)
    gx = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xr32)

    h0 = (state.h if state is not None else jnp.zeros((b, dr), jnp.float32))
    if cfg.use_pallas and s > 1:
        from repro.kernels import ops as kops
        hs, h_f = kops.rglru_scan(a, gx, h0)
    else:
        hs, h_f = linear_scan(a, gx, h0)
    hs = shard_hint(hs, "acts_ffn")
    out = hs.astype(cdt(cfg)) * jax.nn.gelu(xg)
    out = linear(p["wo"], out, cfg)
    return out, RGLRUState(h_f, conv_state)


def linear_scan(a, b_in, h0):
    """h_t = a_t * h_{t-1} + b_t via associative scan. a,b: (B,S,D), h0: (B,D).
    Returns (h (B,S,D), h_final (B,D))."""
    # fold h0 into the first element: b_1' = a_1 * h0 + b_1
    b0 = b_in.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b0), axis=1)
    return hh, hh[:, -1, :]
