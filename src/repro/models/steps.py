"""Step factories: the functions the launcher / dry-run actually lowers.

``make_train_step(cfg, hp, microbatches)`` returns
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.
Microbatching (gradient accumulation) scans over batch slices so XLA can
overlap each microbatch's reduce-scatter with the next one's compute —
the paper's "on-demand pipeline insertion" adapted to collectives.

``make_prefill_step`` / ``make_decode_step`` are the serving entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_loss_fn(cfg: ModelConfig):
    def loss(params, batch):
        return M.loss_fn(params, cfg, batch)
    return loss


def make_train_step(cfg: ModelConfig, hp: adamw.AdamWConfig,
                    microbatches: int = 1):
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def slice_mb(i, key, x):
                # positions for M-RoPE are (3, B, S): batch is axis 1
                ax = 1 if (key == "positions" and x.ndim == 3
                           and x.shape[0] == 3) else 0
                mb = x.shape[ax] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=ax)

            def mb_body(carry, i):
                acc, ls = carry
                mbatch = {k: slice_mb(i, k, v) for k, v in batch.items()}
                l, g = grads_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, ls + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                mb_body, (zero, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            loss = lsum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        else:
            loss, grads = grads_of(params, batch)

        params, opt_state, metrics = adamw.update(grads, opt_state, params, hp)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return M.prefill(params, cfg, tokens=batch.get("tokens"),
                         embeds=batch.get("embeds"),
                         positions=batch.get("positions"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        return M.decode_step(params, cfg, cache, token, pos)
    return decode_step


def make_encode_step(cfg: ModelConfig):
    def encode_step(params, batch):
        return M.encode(params, cfg, embeds=batch["embeds"])
    return encode_step
