"""Parameter schema: the single source of truth for every architecture.

``schema(cfg)`` returns a nested dict whose leaves are :class:`ParamSpec`
(shape, logical axes, init scale). From it we derive — with zero drift —
  * ``abstract_params``  : ShapeDtypeStruct tree (dry-run, no allocation)
  * ``init_params``      : materialized random tree (smoke tests / training)
  * ``param_axes``       : logical-axis tree consumed by the sharding rules
  * ``count_params``     : analytic parameter count for roofline MODEL_FLOPS

Layer stacks are stored *stacked*: each repeated group has params with a
leading ``repeats`` dim and is executed with ``lax.scan``. Attention
projections are stored 2-D ``(d, H*hd)`` so the flattened output dim shards
evenly on the model axis regardless of head count (heads like 40, 20, 15, 10
do not divide a 16-way axis; 5120, 2560, … do).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[object, ...]          # logical axis name (str) or None per dim
    init: str = "normal"              # normal | zeros | ones | lambda_lru
    scale: float = 1.0


def _dense(d_in: int, d_out: int, ax_in: str, ax_out: str, *, bias: bool = False,
           init: str = "normal", scale: float | None = None) -> Dict[str, ParamSpec]:
    scale = (1.0 / math.sqrt(d_in)) if scale is None else scale
    out = {"w": ParamSpec((d_in, d_out), (ax_in, ax_out), init, scale)}
    if bias:
        out["b"] = ParamSpec((d_out,), (ax_out,), "zeros")
    return out


def _norm(d: int, kind: str) -> Dict[str, ParamSpec]:
    out = {"scale": ParamSpec((d,), ("embed",), "ones")}
    if kind == "layernorm":
        out["bias"] = ParamSpec((d,), ("embed",), "zeros")
    return out


# ---------------------------------------------------------------------------
# per-block-kind schemas
# ---------------------------------------------------------------------------

def _attn_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    d, hd = cfg.d_model, cfg.hd
    q_dim, kv_dim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    s: Dict[str, ParamSpec | dict] = {"norm": _norm(d, cfg.norm)}
    s["wq"] = _dense(d, q_dim, "embed", "qkv", bias=cfg.attn_bias)
    s["wk"] = _dense(d, kv_dim, "embed", "kv", bias=cfg.attn_bias)
    s["wv"] = _dense(d, kv_dim, "embed", "kv", bias=cfg.attn_bias)
    s["wo"] = _dense(q_dim, d, "qkv", "embed", bias=(cfg.norm == "layernorm"))
    return s


def _mlp_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    d, ff = cfg.d_model, cfg.d_ff
    s: Dict[str, ParamSpec | dict] = {"norm": _norm(d, cfg.norm)}
    if cfg.mlp == "swiglu":
        s["wi"] = _dense(d, 2 * ff, "embed", "ffn")           # fused gate|up
        s["wo"] = _dense(ff, d, "ffn", "embed")
    else:                                                     # gelu (HuBERT)
        s["wi"] = _dense(d, ff, "embed", "ffn", bias=True)
        s["wo"] = _dense(ff, d, "ffn", "embed", bias=True)
    return s


def _moe_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "norm": _norm(d, cfg.norm),
        "router": {"w": ParamSpec((d, e), ("embed", None), "normal", 1.0 / math.sqrt(d))},
        "wi": ParamSpec((e, d, 2 * ff), ("experts", "embed", "ffn"),
                        "normal", 1.0 / math.sqrt(d)),
        "wo": ParamSpec((e, ff, d), ("experts", "ffn", "embed"),
                        "normal", 1.0 / math.sqrt(ff)),
    }


def _rglru_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    """Griffin recurrent block: x -> [conv4 -> RG-LRU] * gelu(gate) -> out."""
    d, dr = cfg.d_model, cfg.lru_d
    return {
        "norm": _norm(d, cfg.norm),
        "wx": _dense(d, dr, "embed", "ffn"),                   # recurrent branch in
        "wg": _dense(d, dr, "embed", "ffn"),                   # gate branch
        "conv": {"w": ParamSpec((cfg.conv_width, dr), (None, "ffn"), "normal", 0.1),
                 "b": ParamSpec((dr,), ("ffn",), "zeros")},
        "lru": {
            "lam": ParamSpec((dr,), ("ffn",), "lambda_lru"),   # Λ, a = σ(Λ)^(c·r)
            "wa": _dense(dr, dr, "ffn", None, scale=1.0 / math.sqrt(dr)),
            "ba": ParamSpec((dr,), (None,), "zeros"),
            "wi": _dense(dr, dr, "ffn", None, scale=1.0 / math.sqrt(dr)),
            "bi": ParamSpec((dr,), (None,), "zeros"),
        },
        "wo": _dense(dr, d, "ffn", "embed"),
    }


def _mlstm_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    """xLSTM mLSTM block (up-proj x2, conv, per-head matrix memory)."""
    d = cfg.d_model
    de = 2 * d                        # expansion 2 (xLSTM paper)
    h = cfg.n_heads
    return {
        "norm": _norm(d, cfg.norm),
        "wup": _dense(d, 2 * de, "embed", "ffn"),              # fused x|gate
        "conv": {"w": ParamSpec((cfg.conv_width, de), (None, "ffn"), "normal", 0.1),
                 "b": ParamSpec((de,), ("ffn",), "zeros")},
        "wq": _dense(de, de, "ffn", None),
        "wk": _dense(de, de, "ffn", None),
        "wv": _dense(de, de, "ffn", None),
        "wif": _dense(de, 2 * h, "ffn", None),                 # i/f gate pre-acts
        "onorm": {"scale": ParamSpec((de,), ("ffn",), "ones")},
        "wdown": _dense(de, d, "ffn", "embed"),
    }


def _slstm_schema(cfg: ModelConfig) -> Dict[str, ParamSpec | dict]:
    """xLSTM sLSTM block: 4 gates, per-head block-diagonal recurrence."""
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    return {
        "norm": _norm(d, cfg.norm),
        "wg": _dense(d, 4 * d, "embed", "ffn"),                # i|f|z|o from x_t
        "rg": ParamSpec((h, hd, 4 * hd), (None, None, None), "normal",
                        1.0 / math.sqrt(hd)),                  # recurrent, per head
        "bg": ParamSpec((4 * d,), ("ffn",), "zeros"),
        "wo": _dense(d, d, "embed", "qkv"),
    }


_KIND_SCHEMA = {
    "attn": _attn_schema, "swa": _attn_schema, "local": _attn_schema,
    "rglru": _rglru_schema, "mlstm": _mlstm_schema, "slstm": _slstm_schema,
}


def _block_schema(cfg: ModelConfig, kind: str) -> Dict[str, ParamSpec | dict]:
    s = {"mixer": _KIND_SCHEMA[kind](cfg)}
    if cfg.d_ff > 0 and kind in ("attn", "swa", "local"):
        s["mlp"] = _moe_schema(cfg) if cfg.n_experts else _mlp_schema(cfg)
    return s


# ---------------------------------------------------------------------------
# whole-model schema
# ---------------------------------------------------------------------------

def layer_groups(cfg: ModelConfig):
    """[(unit_kinds, repeats), ...] covering all n_layers in order."""
    unit = cfg.pattern_unit
    reps, rem = divmod(cfg.n_layers, len(unit))
    groups = []
    if reps:
        groups.append((unit, reps))
    if rem:
        groups.append((unit[:rem], 1))
    return groups


def _stack(tree, n: int):
    """Prepend a stacked layer dim (axis name None) to every ParamSpec."""
    if isinstance(tree, ParamSpec):
        return ParamSpec((n, *tree.shape), (None, *tree.axes), tree.init, tree.scale)
    return {k: _stack(v, n) for k, v in tree.items()}


def schema(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    s: Dict = {}
    if cfg.frontend:
        s["frontend_proj"] = _dense(cfg.d_frontend, d, None, "embed")
    if cfg.frontend != "audio_frames":          # HuBERT: no token embedding
        s["embed"] = {"w": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"),
                                     "normal", 0.02)}
    groups = []
    for unit, reps in layer_groups(cfg):
        g = {str(i): _block_schema(cfg, kind) for i, kind in enumerate(unit)}
        groups.append(_stack(g, reps) if cfg.scan_layers else _unroll(g, reps))
    s["groups"] = {str(i): g for i, g in enumerate(groups)}
    s["final_norm"] = _norm(d, cfg.norm)
    if not cfg.tie_embeddings:
        s["lm_head"] = _dense(d, cfg.vocab_size, "embed", "vocab")
    return s


def _unroll(g, reps):
    return {f"L{r}": g for r in range(reps)} if reps > 1 else g


# ---------------------------------------------------------------------------
# derivations
# ---------------------------------------------------------------------------

def _is_spec(x):
    return isinstance(x, ParamSpec)


def tree_map_schema(fn, sch):
    if _is_spec(sch):
        return fn(sch)
    return {k: tree_map_schema(fn, v) for k, v in sch.items()}


def abstract_params(cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    return tree_map_schema(lambda s: jax.ShapeDtypeStruct(s.shape, dt), schema(cfg))


def param_axes(cfg: ModelConfig):
    return tree_map_schema(lambda s: s.axes, schema(cfg))


def count_params(cfg: ModelConfig) -> int:
    total = [0]
    tree_map_schema(lambda s: total.__setitem__(0, total[0] + int(np.prod(s.shape))),
                    schema(cfg))
    return total[0]


def init_params(cfg: ModelConfig, rng: jax.Array):
    """Materialize parameters (smoke tests / real training only)."""
    dt = jnp.dtype(cfg.param_dtype)
    sch = schema(cfg)
    leaves: list[ParamSpec] = []
    tree_map_schema(lambda s: leaves.append(s), sch)
    keys = iter(jax.random.split(rng, max(len(leaves), 1)))

    def mk(s: ParamSpec):
        k = next(keys)
        if s.init == "zeros":
            return jnp.zeros(s.shape, dt)
        if s.init == "ones":
            return jnp.ones(s.shape, dt)
        if s.init == "lambda_lru":
            # a = sigmoid(lam) uniformly in [0.9, 0.999] (Griffin init)
            u = jax.random.uniform(k, s.shape, dt, 0.9, 0.999)
            return jnp.log(u / (1 - u))
        return (jax.random.normal(k, s.shape, dt) * s.scale).astype(dt)

    return tree_map_schema(mk, sch)
