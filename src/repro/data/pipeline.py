"""Deterministic, stateless-seekable token pipeline.

``batch_at(step)`` is a pure function of (seed, step): restart/resume never
replays or skips data, any host can compute exactly its shard, and
stragglers can be re-dispatched deterministically — the data-side half of
the fault-tolerance story (trainer checkpoints carry only the step number).

Two sources:
  * ``SyntheticLM`` — a mixture of Zipfian unigrams and copy/induction
    motifs (so small models have learnable structure; loss decreases).
  * ``BinCorpus``  — memory-mapped pre-tokenized .bin shards (production
    path); documents are sliced by absolute token offset = f(step).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # host sharding (process i of n feeds rows [i*b/n, (i+1)*b/n))
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Zipf unigrams + injected copy motifs, fully deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._host_rows = cfg.global_batch // cfg.host_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = self._host_rows
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        # Zipf over the vocab (clip to range)
        toks = rng.zipf(1.3, size=(rows, cfg.seq_len + 1)).astype(np.int64)
        toks = (toks - 1) % cfg.vocab_size
        # copy motif: repeat a short window later in the sequence
        span = min(32, cfg.seq_len // 4)
        if span >= 4:
            src = rng.integers(0, cfg.seq_len // 2 - span, rows)
            dst = rng.integers(cfg.seq_len // 2, cfg.seq_len - span, rows)
            for r in range(rows):
                toks[r, dst[r]:dst[r] + span] = toks[r, src[r]:src[r] + span]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


class BinCorpus:
    """Memory-mapped token shards: files of int32 tokens, concatenated."""

    def __init__(self, cfg: DataConfig, paths):
        self.cfg = cfg
        self._maps = [np.memmap(p, dtype=np.int32, mode="r") for p in paths]
        self._sizes = np.array([m.shape[0] for m in self._maps])
        self._total = int(self._sizes.sum())
        self._host_rows = cfg.global_batch // cfg.host_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        need = cfg.seq_len + 1
        rows = self._host_rows
        out = np.empty((rows, need), np.int32)
        for r in range(rows):
            gr = cfg.host_index * rows + r
            # absolute offset is a pure function of (step, row)
            off = ((step * cfg.global_batch + gr) * cfg.seq_len) \
                % max(self._total - need, 1)
            out[r] = self._gather(off, need)
        return {"tokens": out[:, :-1] % cfg.vocab_size,
                "labels": out[:, 1:] % cfg.vocab_size}

    def _gather(self, off: int, n: int) -> np.ndarray:
        chunks = []
        fi = 0
        csum = 0
        for m, sz in zip(self._maps, self._sizes):
            if off < csum + sz:
                local = off - csum
                take = min(n - sum(len(c) for c in chunks), sz - local)
                chunks.append(np.asarray(m[local:local + take]))
                off += take
            csum += sz
            if sum(len(c) for c in chunks) == n:
                break
        return np.concatenate(chunks)


def make_source(cfg: DataConfig, paths=None):
    return BinCorpus(cfg, paths) if paths else SyntheticLM(cfg)


def device_put_batch(batch: Dict[str, np.ndarray], shardings=None):
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(jnp.asarray(v), shardings[k])
            for k, v in batch.items()}
