"""AdamW with decoupled weight decay — pure JAX, pytree-native.

State is the same pytree structure as params (plus a step counter), so the
sharding rules shard optimizer moments identically to their params (ZeRO-1
falls out of the FSDP param sharding for free).

Optional gradient compression hook (int8 with error feedback) lives in
``repro.optim.compress`` and wraps the grads before the update.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def schedule(hp: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - hp.warmup_steps)
                    / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * warm * (hp.min_lr_frac + (1 - hp.min_lr_frac) * cos)


def init(params) -> AdamWState:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return AdamWState(zeros(params), zeros(params), jnp.zeros((), jnp.int32))


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: AdamWState, params, hp: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if hp.grad_clip > 0 else 1.0
    lr = schedule(hp, step)
    b1c = 1 - hp.b1 ** step.astype(jnp.float32)
    b2c = 1 - hp.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = hp.b1 * m + (1 - hp.b1) * g
        v_new = hp.b2 * v + (1 - hp.b2) * jnp.square(g)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + hp.eps)
        if p.ndim >= 2:                          # decay matrices only
            delta = delta + hp.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}
