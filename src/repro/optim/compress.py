"""Int8 gradient compression with error feedback.

For cross-pod (DCN) gradient reduction, 4x fewer bytes on the wire directly
scales the collective roofline term down. Per-tensor symmetric int8
quantization; the quantization error is carried in an accumulator and added
back next step (error feedback keeps SGD/Adam convergence — Karimireddy et
al. 2019). The wire format (int8 payload + f32 scale) is what a production
all-reduce would ship; here compress/decompress wraps the grads around the
all-reduce that jit inserts.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressState(NamedTuple):
    error: dict           # residual per param, same structure/dtype f32


def init(params) -> CompressState:
    return CompressState(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressState
                   ) -> Tuple[dict, CompressState, dict]:
    """Returns (decompressed grads as the optimizer sees them, new error
    state, wire stats). grads/state leaves must align."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = _quantize(g)
        deq = _dequantize(q, scale)
        return deq, g - deq, q.size  # int8 bytes on the wire

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = treedef.unflatten([o[0] for o in outs])
    err = treedef.unflatten([o[1] for o in outs])
    wire_bytes = sum(o[2] for o in outs)           # int8: 1 byte/elem
    raw_bytes = sum(g.size * 4 for g in flat_g)
    return deq, CompressState(err), {
        "wire_bytes": wire_bytes, "raw_bytes": raw_bytes,
        "ratio": raw_bytes / max(wire_bytes, 1)}
