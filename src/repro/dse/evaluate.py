"""Cycle-accurate evaluation of design points over the paper's benches.

The evaluator is where the DSE gets cheap enough to search: candidate
design points are grouped by their *engine-visible* configuration (the
frozen ``GGPUConfig`` — frequency targets that plan to the same pipeline
depth share one simulation), every uncached (config, bench) pair is
submitted to one ``serve.Scheduler`` drain per config, and the scheduler's
chunk planner folds same-shape launches through ``run_kernel_cohort`` /
``run_kernel_batch`` so a whole bench suite costs one or two compiled
stepper dispatches instead of N. Cycle results are memoized on the
process-wide shared executor (``serve.executors.get_executor``) keyed by
the bench content, so sweeps, repeat evaluators, and serving fleets that
touch the same configuration share both the compiled steppers and the
cached cycles.

Each point is also evaluated under the **free-pipelining assumption**
(the same config at ``pipeline_depth=0``) — the cycles the analytic map
believes in. ``search.search`` uses the pair to show which analytic picks
the cycle-accurate model excludes.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.dse.point import DesignPoint
from repro.ggpu.engine import GGPUConfig

DEFAULT_BENCHES = ("xcorr",)
DEFAULT_SIZES: Dict[str, Tuple[int, int]] = {}   # empty: bench defaults


@dataclass
class BenchMetrics:
    """Per-bench outcome of one design point."""
    bench: str
    cycles: int                 # cycle-accurate (pipeline-depth-aware)
    analytic_cycles: int        # free-pipelining (depth-0) cycles
    time_us: float              # cycles / fmax
    analytic_time_us: float
    sim_wall_s: float           # simulator wall-clock share (amortized)
    info: dict = field(repr=False, default_factory=dict)


@dataclass
class EvaluatedPoint:
    """A design point with its end-to-end metrics.

    Aggregates are geometric means over the evaluated benches (the paper's
    Fig. 6 convention); energy = power x time."""
    point: DesignPoint
    per_bench: Dict[str, BenchMetrics]
    time_us: float
    analytic_time_us: float
    area_mm2: float
    power_w: float
    energy_uj: float
    perf_per_area: float        # (1 / time_us) / area_mm2
    sim_wall_s: float

    def label(self) -> str:
        return self.point.label()

    def report(self) -> dict:
        return {
            "label": self.label(),
            "n_cus": self.point.spec.n_cus,
            "freq_target_mhz": self.point.spec.freq_target_mhz,
            "fmax_mhz": self.point.freq_mhz,
            "memsys": self.point.spec.memsys,
            "fuse": self.point.config.fuse,
            "pipeline_depth": self.point.config.pipeline_depth,
            "achieved": self.point.plan.achieved,
            "time_us": round(self.time_us, 3),
            "analytic_time_us": round(self.analytic_time_us, 3),
            "area_mm2": round(self.area_mm2, 2),
            "power_w": round(self.power_w, 2),
            "energy_uj": round(self.energy_uj, 3),
            "perf_per_area": self.perf_per_area,
            "sim_wall_s": round(self.sim_wall_s, 4),
        }


def _geomean(vals: Sequence[float]) -> float:
    return float(math.exp(sum(math.log(max(v, 1e-12)) for v in vals)
                          / len(vals)))


class Evaluator:
    """Simulates benches for design points with config-level batching and a
    persistent cycle cache.

    ``benches`` are names from ``repro.ggpu.programs`` (``_<name>``
    builders); ``sizes`` optionally maps a bench name to the builder's
    (scalar, gpu) input sizes — reduced sizes keep a sweep interactive,
    ``None``/missing uses the paper's Table III defaults.

    ``workloads`` maps extra names to pre-built ``Bench``-shaped records
    — e.g. compiled kernels from the tensor-expression DSL
    (``repro.compiler.CompiledKernel.as_bench()`` or
    ``compiler.dsl_benches()``) — so the DSE sweeps arbitrary generated
    workloads alongside (or instead of) the fixed list. A workload needs
    ``gpu_prog``/``gpu_mem``/``gpu_items``/``gpu_out``/``gpu_n``/``ref``;
    its name may also appear in ``benches`` to pin the evaluation order."""

    def __init__(self, benches: Sequence[str] = DEFAULT_BENCHES,
                 sizes: Optional[Dict[str, Tuple[int, int]]] = None,
                 check: bool = False,
                 workloads: Optional[Dict[str, object]] = None):
        import hashlib

        from repro.ggpu import programs
        workloads = dict(workloads or {})
        self.bench_names = tuple(benches) + tuple(
            n for n in workloads if n not in benches)
        sizes = dict(sizes or DEFAULT_SIZES)
        self._benches = {}
        self._keys: Dict[str, tuple] = {}
        for name in self.bench_names:
            if name in workloads:
                b = workloads[name]
            else:
                build = getattr(programs, f"_{name}")
                sz = sizes.get(name)
                b = build(*sz) if sz is not None else build()
            self._benches[name] = b
            # content-addressed memo key: safe to share across evaluators
            # with different bench sizes on the same executor
            self._keys[name] = (
                "bench", name, b.gpu_items,
                hashlib.sha1(b.gpu_prog.tobytes()).hexdigest(),
                hashlib.sha1(b.gpu_mem.tobytes()).hexdigest())
        self.check = check
        # (sim config, bench key) pairs THIS evaluator has verified; with
        # check=True a bench memoized by another (unchecked) evaluator is
        # re-simulated so the requested verification actually runs
        self._verified: set = set()

    # -- simulation ---------------------------------------------------------

    @staticmethod
    def _sim_key(cfg: GGPUConfig) -> GGPUConfig:
        """``freq_mhz`` never enters the traced cycle computation, so it is
        normalized out of the simulation/cache key (see
        ``serve.executors.sim_key``): frequency targets that plan to the
        same pipeline depth share one compiled stepper and one simulation
        (the config is a static jit argument — without this, every
        distinct frequency would recompile)."""
        from repro.serve.executors import sim_key
        return sim_key(cfg)

    def _simulate_config(self, cfg: GGPUConfig, names: Sequence[str]) -> None:
        """Run every unmemoized bench for one engine config as a single
        pipelined Scheduler drain (cohort/batch-folded where shapes allow)
        on the process-wide shared executor for that config. The evaluator
        needs cycles only, so each launch declares an empty ``out_region``
        and the final memory images are never downloaded from the device —
        except under ``check=True``, which pulls the full image to verify
        it against the bench's numpy reference."""
        from repro.serve.executors import get_executor
        from repro.serve.scheduler import Scheduler
        ex = get_executor(cfg)
        todo = [n for n in names
                if self._keys[n] not in ex.memo
                or (self.check
                    and (ex.cfg, self._keys[n]) not in self._verified)]
        if not todo:
            return
        sched = Scheduler(executor=ex)
        for n in todo:
            b = self._benches[n]
            sched.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, tag=n,
                         out_region=None if self.check else (0, 0))
        t0 = time.perf_counter()
        results = sched.drain()
        wall = (time.perf_counter() - t0) / len(todo)
        if sched.quarantined:
            from repro.ggpu.engine import KernelLaunchError
            bad = "; ".join(f"{q.request.tag}: {q.error}"
                            for q in sched.quarantined.values())
            raise KernelLaunchError(
                f"bench simulation did not halt under {cfg}: {bad}")
        for mem, info in results:
            n = info["tag"]          # align by tag, not submission order
            if self.check:
                b = self._benches[n]
                np.testing.assert_array_equal(
                    mem[b.gpu_out], b.ref(b.gpu_mem, b.gpu_n))
                self._verified.add((ex.cfg, self._keys[n]))
            ex.memo[self._keys[n]] = (info, wall)

    def _lookup(self, cfg: GGPUConfig, bench: str) -> Tuple[dict, float]:
        from repro.serve.executors import get_executor
        return get_executor(cfg).memo[self._keys[bench]]

    def cache_size(self) -> int:
        """Memoized (config, bench) entries for this evaluator's bench set
        across the shared executor registry."""
        from repro.serve.executors import _EXECUTORS
        keys = set(self._keys.values())
        return sum(1 for ex in _EXECUTORS.values()
                   for k in ex.memo if k in keys)

    def simulate(self, cfg: GGPUConfig,
                 names: Optional[Sequence[str]] = None) -> None:
        """Ensure every named bench (default: all) is simulated/memoized
        under ``cfg`` — one pipelined Scheduler drain for all misses. The
        autotuner uses this to cost a whole candidate-schedule set in one
        batched dispatch; subsequent ``cycles`` calls are cache hits."""
        self._simulate_config(cfg, self.bench_names if names is None
                              else tuple(names))

    def cycles(self, cfg: GGPUConfig, bench: str) -> Tuple[dict, float]:
        self._simulate_config(cfg, [bench])
        info, wall = self._lookup(cfg, bench)
        # restate frequency-derived fields for the caller's actual config
        info = dict(info)
        info["time_us"] = info["cycles"] / cfg.freq_mhz
        return info, wall

    # -- evaluation ---------------------------------------------------------

    def evaluate(self, points: Sequence[DesignPoint]
                 ) -> List[EvaluatedPoint]:
        """Evaluate candidates; simulation order is grouped by config so
        identical configs (and their depth-0 analytic twins) are simulated
        exactly once across the whole sweep."""
        # collect the needed (config, bench) work, preserving first-seen
        # config order for determinism
        wanted: Dict[GGPUConfig, None] = {}
        for p in points:
            wanted.setdefault(p.config)
            wanted.setdefault(dataclasses.replace(p.config, pipeline_depth=0))
        for cfg in wanted:
            self._simulate_config(cfg, self.bench_names)
        out = []
        for p in points:
            cfg0 = dataclasses.replace(p.config, pipeline_depth=0)
            per_bench: Dict[str, BenchMetrics] = {}
            for n in self.bench_names:
                info, wall = self._lookup(p.config, n)
                info0, _ = self._lookup(cfg0, n)
                cyc, cyc0 = info["cycles"], info0["cycles"]
                info = dict(info)
                info["time_us"] = cyc / p.freq_mhz
                per_bench[n] = BenchMetrics(
                    bench=n, cycles=cyc, analytic_cycles=cyc0,
                    time_us=cyc / p.freq_mhz,
                    analytic_time_us=cyc0 / p.freq_mhz,
                    sim_wall_s=wall, info=info)
            t = _geomean([m.time_us for m in per_bench.values()])
            t0 = _geomean([m.analytic_time_us for m in per_bench.values()])
            area = p.area_mm2
            power = p.power_w
            out.append(EvaluatedPoint(
                point=p, per_bench=per_bench, time_us=t,
                analytic_time_us=t0, area_mm2=area, power_w=power,
                energy_uj=power * t,
                perf_per_area=(1.0 / t) / area,
                sim_wall_s=sum(m.sim_wall_s for m in per_bench.values())))
        return out
