"""Unified design-space exploration over G-GPU design points.

This package joins the repo's two evaluation layers — GPUPlanner's analytic
fmax/PPA map (``repro.core.planner`` / ``repro.core.ppa``) and the
cycle-accurate execution engine (``repro.ggpu.engine``) — into one searchable
space, the way the paper's generator flow intends (and full-stack evaluators
like Gemmini and AutoDNNchip practice):

  * ``point``    — ``DesignSpec`` / ``DesignPoint``: a candidate composes a
    planned ``GGPUVersion`` (fmax, area, power) with the ``GGPUConfig`` the
    engine simulates, including the pipeline-latency feedback knob
    (``pipeline_depth``) the analytic map cannot see.
  * ``evaluate`` — ``Evaluator``: end-to-end metrics (wall-clock =
    cycles/fmax, energy, perf/area) per bench, with config-grouped batched
    simulation (``run_kernel_cohort``/``run_kernel_batch`` via
    ``LaunchQueue``) and a persistent cycle cache.
  * ``search``   — Pareto-frontier search over {n_cus, frequency target,
    memsys, fuse, pipeline depth}; reports the analytic-only picks the
    cycle-accurate evaluation excludes.
  * ``artifact`` — the standardized ``BENCH_dse.json`` emitter.
"""
from repro.dse.artifact import bench_map, dse_artifact, write_artifact
from repro.dse.evaluate import BenchMetrics, EvaluatedPoint, Evaluator
from repro.dse.point import (DesignPoint, DesignSpec, design_point,
                             memsys_inventory)
from repro.dse.search import (JointPoint, JointResult, SearchResult,
                              analytic_objective, cycle_objective, dominates,
                              enumerate_specs, joint_frontier,
                              pareto_frontier, search, sweep_memsys)

__all__ = [
    "DesignSpec", "DesignPoint", "design_point", "memsys_inventory",
    "BenchMetrics", "EvaluatedPoint", "Evaluator",
    "SearchResult", "search", "enumerate_specs", "sweep_memsys",
    "pareto_frontier", "dominates", "cycle_objective", "analytic_objective",
    "JointPoint", "JointResult", "joint_frontier",
    "bench_map", "dse_artifact", "write_artifact",
]
