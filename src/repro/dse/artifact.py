"""Standardized machine-readable DSE artifact (``BENCH_dse.json``).

One schema shared by ``python -m benchmarks.run --engine`` and the DSE
sweep, so the perf trajectory is comparable across PRs:

    {
      "schema": "ggpu-dse/1",
      "reference": "<label of the design point the bench map describes>",
      "benches": { "<bench>": { "cycles": int,
                                "sim_wall_s": float,
                                "fmax_mhz": float,
                                "area_mm2": float,
                                "perf_per_area": float,
                                "time_us": float } },
      "points": [ per-point report rows ... ],     # present for sweeps
      "frontier": [ labels ... ],
      "analytic_frontier": [ labels ... ],
      "excluded_analytic": [ labels ... ]
    }
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Union

from repro.dse.evaluate import EvaluatedPoint

SCHEMA = "ggpu-dse/1"


def bench_map(point: EvaluatedPoint) -> dict:
    """The satellite schema: bench -> {cycles, sim wall-clock, fmax, area,
    perf/area} for one evaluated design point."""
    out = {}
    for name, m in point.per_bench.items():
        t = m.time_us
        out[name] = {
            "cycles": int(m.cycles),
            "sim_wall_s": float(m.sim_wall_s),
            "fmax_mhz": float(point.point.freq_mhz),
            "area_mm2": float(point.area_mm2),
            "perf_per_area": (1.0 / t) / point.area_mm2,
            "time_us": float(t),
        }
    return out


def dse_artifact(reference: EvaluatedPoint,
                 result: Optional["SearchResult"] = None) -> dict:
    """Build the artifact dict: the reference point's bench map, plus the
    full sweep/frontier when a ``SearchResult`` is given."""
    art = {
        "schema": SCHEMA,
        "reference": reference.label(),
        "benches": bench_map(reference),
    }
    if result is not None:
        art["points"] = result.report()
        art["frontier"] = [p.label() for p in result.frontier]
        art["analytic_frontier"] = [p.label()
                                    for p in result.analytic_frontier]
        art["excluded_analytic"] = [p.label()
                                    for p in result.excluded_analytic]
    return art


def write_artifact(path: Union[str, Path], reference: EvaluatedPoint,
                   result: Optional["SearchResult"] = None) -> Path:
    path = Path(path)
    path.write_text(json.dumps(dse_artifact(reference, result), indent=2,
                               sort_keys=True) + "\n")
    return path
