"""DesignPoint: one G-GPU design candidate, joining both evaluation layers.

A design point composes the two halves the repo used to evaluate in silos:

  * the **physical version** (``repro.core.ppa.GGPUVersion``) — the planner's
    analytic map output: divided memory inventory, inserted pipeline stages,
    achieved fmax, area, power;
  * the **engine config** (``repro.ggpu.engine.GGPUConfig``) — what the
    cycle-accurate simulator runs: CU count, cache organization, fused
    dispatch width, and (new) the ``pipeline_depth`` feedback knob.

``design_point`` closes the loop: it runs GPUPlanner's map for the spec's
(CU count, frequency target) over a memory inventory rewritten for the
spec's cache organization, then builds the engine config *from the planned
version* — in particular ``pipeline_depth = version.pipelines``, so the
simulator charges the CPI cost of every stage the map inserted to close
timing. Wall-clock = cycles(depth) / fmax(depth) is then a real trade-off
instead of the analytic map's free-pipelining assumption.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional

from repro.core.planner import Plan, plan
from repro.core.ppa import GGPUVersion, baseline_inventory
from repro.core.sram import MIN_WORDS, Macro
from repro.ggpu.engine import GGPUConfig


def memsys_inventory(memsys: str, n_cus: int,
                     inventory: Optional[List[Macro]] = None) -> List[Macro]:
    """Rewrite the baseline memory inventory for a cache organization, so
    the analytic map prices what the engine simulates:

      * ``shared``     — the paper's central multi-port cache (unchanged);
      * ``banked``     — the data cache + tag store replicate per CU at full
        size (aggregate capacity and area grow with CU count);
      * ``banked-iso`` — per-CU banks splitting the shared capacity
        (word count divided by CU count; the per-block periphery overhead
        makes this slightly larger than shared, exactly the paper's
        division trade-off).
    """
    inv = list(inventory if inventory is not None else baseline_inventory())
    if memsys == "shared":
        return inv
    if memsys not in ("banked", "banked-iso"):
        raise KeyError(f"no inventory rule for memsys {memsys!r}")
    out = []
    for m in inv:
        if m.name.startswith("dcache"):
            if memsys == "banked":
                m = replace(m, per_cu=True)
            else:
                m = replace(m, per_cu=True,
                            words=max(MIN_WORDS, m.words // n_cus))
        out.append(m)
    return out


@dataclass(frozen=True)
class DesignSpec:
    """The searchable knobs of one candidate design."""
    n_cus: int = 1
    freq_target_mhz: float = 500.0
    memsys: str = "shared"
    fuse: int = 4
    # None: take the planner's inserted stage count (the closed loop).
    # An explicit value overrides it — depth 0 reproduces the analytic
    # map's free-pipelining assumption as its own sweepable point.
    pipeline_depth: Optional[int] = None

    def label(self) -> str:
        d = "plan" if self.pipeline_depth is None else self.pipeline_depth
        return (f"{self.n_cus}cu@{self.freq_target_mhz:.0f}"
                f"/{self.memsys}/d{d}")


@dataclass
class DesignPoint:
    """A planned candidate: spec + the map's version + the engine config."""
    spec: DesignSpec
    plan: Plan
    config: GGPUConfig

    @property
    def version(self) -> GGPUVersion:
        return self.plan.version

    @property
    def freq_mhz(self) -> float:
        """Achieved frequency: the target when the map closed, the map's
        best achievable fmax otherwise (the paper's 8CU@667 -> 600)."""
        return self.config.freq_mhz

    @property
    def area_mm2(self) -> float:
        return self.version.total_area_mm2()

    @property
    def power_w(self) -> float:
        return self.version.total_w()

    def label(self) -> str:
        """Unique per sweep point: a derated design keeps its target in the
        label (``8cu@667~601``), since distinct targets can derate to the
        same achieved frequency; an explicitly overridden pipeline depth is
        marked ``!`` (a forced depth can coincide with the planned one);
        a non-default fuse width is appended."""
        freq = (f"{self.spec.freq_target_mhz:.0f}" if self.plan.achieved
                else f"{self.spec.freq_target_mhz:.0f}~{self.freq_mhz:.0f}")
        forced = "" if self.spec.pipeline_depth is None else "!"
        fuse = "" if self.spec.fuse == 4 else f"/f{self.spec.fuse}"
        return (f"{self.spec.n_cus}cu@{freq}/{self.spec.memsys}"
                f"/d{self.config.pipeline_depth}{forced}{fuse}")


def design_point(spec: DesignSpec, **cfg_kw) -> DesignPoint:
    """Plan one candidate end to end: memsys-aware inventory -> analytic
    map -> engine config carrying the map's pipeline depth. Extra keyword
    arguments become ``GGPUConfig`` fields (e.g. ``cache_lines=128``)."""
    inv = memsys_inventory(spec.memsys, spec.n_cus)
    p = plan(spec.n_cus, spec.freq_target_mhz, inventory=inv)
    if p.achieved:
        freq = spec.freq_target_mhz
    else:
        # the paper keeps the layout at its best achievable frequency
        freq = round(p.version.fmax_mhz(), 0)
    p.version.freq_mhz = freq
    depth = (p.version.pipelines if spec.pipeline_depth is None
             else spec.pipeline_depth)
    cfg = GGPUConfig(n_cus=spec.n_cus, memsys=spec.memsys, fuse=spec.fuse,
                     pipeline_depth=depth, freq_mhz=freq, **cfg_kw)
    return DesignPoint(spec=spec, plan=p, config=cfg)
