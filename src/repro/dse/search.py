"""Pareto-frontier search over the joint G-GPU design space.

The search enumerates ``DesignSpec`` candidates over {CU count, frequency
target, cache organization, fused-dispatch width, pipeline depth}, plans
each one analytically (``dse.point.design_point``), evaluates all of them
cycle-accurately through one shared ``Evaluator`` (config-grouped, batched,
cached), and returns the Pareto frontier under minimize-(wall-clock, area)
— the paper's Fig. 5 (raw performance) and Fig. 6 (performance derated by
area) axes joined into one dominance relation.

Every point is also ranked under the **free-pipelining assumption** the
analytic map makes (depth-0 cycles at the planned frequency). The points
on that analytic frontier that the cycle-accurate evaluation dominates are
reported in ``SearchResult.excluded_analytic`` — the designs a
spreadsheet-only flow would have picked and the simulator rejects.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.dse.evaluate import EvaluatedPoint, Evaluator
from repro.dse.point import DesignSpec, design_point

Objective = Callable[[EvaluatedPoint], Tuple[float, ...]]


def cycle_objective(p: EvaluatedPoint) -> Tuple[float, float]:
    """Minimize (cycle-accurate wall-clock, area)."""
    return (p.time_us, p.area_mm2)


def analytic_objective(p: EvaluatedPoint) -> Tuple[float, float]:
    """Minimize (free-pipelining wall-clock, area) — what the map sees."""
    return (p.analytic_time_us, p.area_mm2)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Pareto dominance for minimization: a is no worse everywhere and
    strictly better somewhere."""
    if len(a) != len(b):
        raise ValueError("objective vectors must have equal length")
    return all(x <= y for x, y in zip(a, b)) \
        and any(x < y for x, y in zip(a, b))


def pareto_frontier(items: Sequence, key: Callable[[object], Sequence[float]]
                    ) -> List:
    """Non-dominated subset of ``items`` under minimization of ``key``,
    in stable input order (ties — equal vectors — are all kept)."""
    vecs = [tuple(key(it)) for it in items]
    return [it for it, v in zip(items, vecs)
            if not any(dominates(w, v) for w in vecs)]


@dataclass
class SearchResult:
    points: List[EvaluatedPoint]
    frontier: List[EvaluatedPoint]            # cycle-accurate Pareto set
    analytic_frontier: List[EvaluatedPoint]   # free-pipelining Pareto set
    excluded_analytic: List[EvaluatedPoint]   # analytic picks the cycle
    #                                           model dominates
    objective: Objective = field(repr=False, default=cycle_objective)

    def report(self) -> List[dict]:
        front = {id(p) for p in self.frontier}
        afront = {id(p) for p in self.analytic_frontier}
        rows = []
        for p in self.points:
            r = p.report()
            r["on_frontier"] = id(p) in front
            r["on_analytic_frontier"] = id(p) in afront
            rows.append(r)
        return rows


@dataclass
class JointPoint:
    """One co-designed candidate: a hardware design point evaluated under
    one compiler schedule variant (``variant`` is the schedule label)."""
    variant: str
    point: EvaluatedPoint

    def label(self) -> str:
        return f"{self.point.label()}|{self.variant}"

    def report(self) -> dict:
        r = self.point.report()
        r["schedule"] = self.variant
        return r


@dataclass
class JointResult:
    """The (DesignPoint, Schedule) product ranked under one dominance
    relation — the co-designed Pareto frontier."""
    points: List[JointPoint]
    frontier: List[JointPoint]
    objective: Objective = field(repr=False, default=cycle_objective)

    def report(self) -> List[dict]:
        front = {id(p) for p in self.frontier}
        rows = []
        for p in self.points:
            r = p.report()
            r["on_frontier"] = id(p) in front
            rows.append(r)
        return rows


def joint_frontier(variants: Dict[str, SearchResult],
                   objective: Objective = cycle_objective) -> JointResult:
    """Rank the union of several per-variant search results (e.g. one
    ``search`` per candidate compiler schedule) as a single population of
    ``(DesignPoint, variant)`` pairs. A hardware point survives only if no
    (point, schedule) pair dominates it — so a schedule that makes a
    smaller design fast enough can evict a bigger design entirely."""
    pts = [JointPoint(v, p)
           for v, res in variants.items() for p in res.points]
    frontier = pareto_frontier(pts, lambda jp: objective(jp.point))
    return JointResult(points=pts, frontier=frontier, objective=objective)


def enumerate_specs(cus: Sequence[int] = (1, 2, 4, 8),
                    freq_targets: Sequence[float] = (500.0, 590.0, 667.0,
                                                     750.0),
                    memsys: Sequence[str] = ("shared",),
                    fuse: Sequence[int] = (4,),
                    pipeline_depths: Sequence[Optional[int]] = (None,)
                    ) -> List[DesignSpec]:
    """The candidate grid. ``pipeline_depths=(None,)`` takes each plan's
    own inserted-stage count (the closed loop); explicit integers add
    override points (0 = the free-pipelining analytic assumption run as a
    real — optimistic — design)."""
    return [DesignSpec(n_cus=c, freq_target_mhz=f, memsys=ms, fuse=fu,
                       pipeline_depth=d)
            for c in cus for f in freq_targets for ms in memsys
            for fu in fuse for d in pipeline_depths]


def search(specs: Optional[Sequence[DesignSpec]] = None,
           evaluator: Optional[Evaluator] = None,
           objective: Objective = cycle_objective,
           analytic: Objective = analytic_objective,
           **grid_kw) -> SearchResult:
    """Plan + evaluate + rank the design space.

    ``specs`` overrides the grid; otherwise ``grid_kw`` is forwarded to
    ``enumerate_specs``. ``evaluator`` defaults to a reduced-size xcorr
    evaluator (the paper's cache-pressure kernel) so a full sweep stays
    interactive; pass a configured ``Evaluator`` for the Table III suite.
    """
    if specs is None:
        specs = enumerate_specs(**grid_kw)
    elif grid_kw:
        raise ValueError("pass either specs or grid keywords, not both")
    if evaluator is None:
        evaluator = Evaluator(benches=("xcorr",), sizes={"xcorr": (32, 256)})
    points = [design_point(s) for s in specs]
    evaluated = evaluator.evaluate(points)
    frontier = pareto_frontier(evaluated, objective)
    analytic_frontier = pareto_frontier(evaluated, analytic)
    front_ids = {id(p) for p in frontier}
    excluded = [p for p in analytic_frontier if id(p) not in front_ids]
    return SearchResult(points=evaluated, frontier=frontier,
                        analytic_frontier=analytic_frontier,
                        excluded_analytic=excluded, objective=objective)


def sweep_memsys(bench: str = "xcorr",
                 n_cus: Sequence[int] = (1, 8),
                 memsys: Optional[Sequence[str]] = None,
                 sizes: Optional[Tuple[int, int]] = (64, 1024),
                 **cfg_kw) -> Dict[Tuple[int, str], dict]:
    """Cache-organization DSE: cycle-simulate ``bench`` on every
    (CU count, memory system) point; returns ``{(n_cus, memsys): info}``
    with the simulator's cycles/hits/misses per point.

    ``memsys`` defaults to every organization registered with the engine.
    ``sizes`` are the bench constructor's (scalar, gpu) input sizes — the
    default is a reduced xcorr so a sweep stays interactive; pass ``None``
    for the paper's Table III sizes. Extra keyword arguments become
    ``GGPUConfig`` fields (e.g. ``cache_lines=128``)."""
    from repro.ggpu.engine import GGPUConfig
    from repro.registry import MEMSYS

    if memsys is None:
        memsys = tuple(MEMSYS.names())
    ev = Evaluator(benches=(bench,),
                   sizes=None if sizes is None else {bench: sizes})
    out: Dict[Tuple[int, str], dict] = {}
    for c in n_cus:
        for ms in memsys:
            cfg = GGPUConfig(n_cus=c, memsys=ms, **cfg_kw)
            info, _ = ev.cycles(cfg, bench)
            out[(c, ms)] = info
    return out
