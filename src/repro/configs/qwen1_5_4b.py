"""Qwen1.5-4B: dense, QKV bias. [hf:Qwen/Qwen1.5-4B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab_size=151_936, attn_bias=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, attn_bias=True, rope_theta=1_000_000.0,
)
