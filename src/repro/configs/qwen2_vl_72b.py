"""Qwen2-VL-72B backbone: M-RoPE, dynamic-resolution vision stub.
The ViT frontend is a stub: input_specs() provides precomputed patch
embeddings (d_frontend=1176 = 14x14x2x3 patchified pixels).
[arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152_064, mrope=True, mrope_sections=(16, 24, 24),
    frontend="vision_patches", d_frontend=1176, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, mrope=True, mrope_sections=(2, 3, 3),
    frontend="vision_patches", d_frontend=48,
)
