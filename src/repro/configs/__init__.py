"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke(arch_id)``.

Each module defines ``CONFIG`` (the exact published full-size config — only
exercised abstractly via the dry-run) and ``SMOKE`` (a reduced same-family
config that runs a real step on CPU)."""
from __future__ import annotations

import importlib

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "mixtral-8x7b": "mixtral_8x7b",
    "xlstm-350m": "xlstm_350m",
    "qwen1.5-4b": "qwen1_5_4b",
    "granite-8b": "granite_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "smollm-360m": "smollm_360m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-72b": "qwen2_vl_72b",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _mod(arch).CONFIG


def get_smoke(arch: str):
    return _mod(arch).SMOKE
