"""Granite-8B (code): llama-arch dense GQA. [arXiv:2405.04324; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=49_152, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256,
)
