"""SmolLM-360M: llama-arch small, tied embeddings.
[hf:HuggingFaceTB/SmolLM-360M; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab_size=49_152, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128,
    vocab_size=256, tie_embeddings=True,
)
