"""RecurrentGemma-2B (Griffin): RG-LRU + local attention, pattern 2:1.
head_dim=256 (10 heads x 256 = 2560), 1 KV head, local window 2048.
[arXiv:2402.19427; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256_000, window=2048, lru_width=2560,
    pattern_unit=("rglru", "rglru", "local"),
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=256, window=16, lru_width=64,
    pattern_unit=("rglru", "rglru", "local"),
)
