"""Mixtral-8x7B: MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32_000, n_experts=8, topk=2, window=4096,
    pattern_unit=("swa",), rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_experts=4, topk=2, window=16,
    pattern_unit=("swa",), rope_theta=1_000_000.0,
)
