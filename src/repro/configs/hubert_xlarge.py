"""HuBERT-XLarge: encoder-only audio transformer (w2v2 arch). The conv
feature-extractor frontend is a stub: input_specs() provides precomputed
frame embeddings (d_frontend=512). vocab=504 is the target-unit inventory.
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, causal=False, norm="layernorm", mlp="gelu",
    frontend="audio_frames", d_frontend=512,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=64, causal=False, norm="layernorm", mlp="gelu",
    frontend="audio_frames", d_frontend=32,
)
