"""Llama-4-Scout-17B-16E: MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202_048, n_experts=16, topk=1, rope_theta=500_000.0,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, n_experts=4, topk=1, rope_theta=500_000.0,
)
