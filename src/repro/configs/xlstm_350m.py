"""xLSTM-350M: sLSTM + mLSTM blocks, attention-free. d_ff=0: xLSTM blocks
carry their own up/down projections, no separate FFN. Block ratio choice
(3 mLSTM : 1 sLSTM) follows the xLSTM paper's mixed configs.
[arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50_304, mlp="none",
    pattern_unit=("mlstm", "mlstm", "mlstm", "slstm"),
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, d_ff=0,
    vocab_size=256, mlp="none",
    pattern_unit=("mlstm", "mlstm", "mlstm", "slstm"), mlstm_chunk=8,
)
