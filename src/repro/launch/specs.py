"""ShapeDtypeStruct stand-ins for every dry-run cell — no device allocation.

``input_specs(cfg, shape)`` returns the abstract inputs for the step kind:
  train   -> (params, opt_state, batch)
  prefill -> (params, batch)
  decode  -> (params, cache, token, pos)

Every struct carries its NamedSharding so ``jax.jit(...).lower(...)`` sees
the full distribution plan.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig, ShapeSpec
from repro.models.schema import abstract_params
from repro.optim.adamw import AdamWState
from repro.sharding.rules import (ShardingRules, cache_shardings,
                                  input_shardings, opt_state_shardings,
                                  param_shardings)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(tree, shardings):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), tree, shardings)


def abstract_batch(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract train/prefill batch (tokens or frontend embeds)."""
    b, s = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.frontend:
        batch["embeds"] = _sds((b, s, cfg.d_frontend), jnp.bfloat16)
        batch["labels"] = _sds((b, s), jnp.int32)
        if cfg.mrope:
            batch["positions"] = _sds((3, b, s), jnp.int32)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        if shape.kind == "train":
            # labels provided explicitly (shifted by the data pipeline) so
            # the model sees the full power-of-two seq_len — an off-by-one
            # S-1 breaks sequence sharding (4095 % 16 != 0) and pads every
            # attention chunk scan.
            batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract decode cache with capacity seq_len."""
    def ab(x):
        return _sds(x.shape, x.dtype)
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    return jax.tree.map(ab, cache)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, rules: ShardingRules
                ) -> Tuple[Any, ...]:
    params = _with_shardings(abstract_params(cfg), param_shardings(rules, cfg))
    if shape.kind == "train":
        batch = abstract_batch(cfg, shape)
        batch = _with_shardings(batch, input_shardings(rules, batch))
        opt = AdamWState(
            m=abstract_params(cfg), v=abstract_params(cfg),
            step=_sds((), jnp.int32))
        opt = _with_shardings(opt, opt_state_shardings(rules, cfg))
        return params, opt, batch
    if shape.kind == "prefill":
        batch = abstract_batch(cfg, shape)
        batch = _with_shardings(batch, input_shardings(rules, batch))
        return params, batch
    if shape.kind == "decode":
        cache = abstract_cache(cfg, shape)
        cache = _with_shardings(cache, cache_shardings(rules, cache))
        token = _sds((shape.global_batch, 1), jnp.int32,
                     rules.named(rules.activation_spec(
                         "tokens", (shape.global_batch, 1))))
        pos = _sds((), jnp.int32, rules.named(jax.sharding.PartitionSpec()))
        return params, cache, token, pos
    raise ValueError(shape.kind)
