import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and emit roofline terms.

The two lines above MUST stay the first statements in this file: jax locks
the device count on first init, and smoke tests / benches elsewhere must
keep seeing 1 CPU device (this env var is set only in this process).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Per cell this prints ``compiled.memory_analysis()`` (proves the program
fits per-device HBM) and ``compiled.cost_analysis()`` (FLOPs/bytes for
EXPERIMENTS.md §Roofline), and writes a JSON record.
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.config import SHAPES, cell_supported
from repro.models.steps import (make_decode_step, make_encode_step,
                                make_prefill_step, make_train_step)
from repro.optim.adamw import AdamWConfig
from repro.roofline import analysis as RL
from repro.sharding import set_rules
from repro.sharding.rules import make_rules, opt_state_shardings, param_shardings


def build_step(cfg, shape):
    """Returns (fn, donate_argnums)."""
    if shape.kind == "train":
        return make_train_step(cfg, AdamWConfig()), (0, 1)
    if shape.kind == "prefill":
        if cfg.is_encoder_only:
            return make_encode_step(cfg), ()
        return make_prefill_step(cfg), ()
    return make_decode_step(cfg), (1,)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             remat: str = None, microbatches: int = 1, fsdp: bool = True,
             seq_shard: bool = True, seq_attn_min_s: int = 16384,
             out_dir: Path = None, verbose: bool = True):
    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "supported": ok, "reason": reason}
    if not ok:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(mesh, fsdp=fsdp, seq_shard=seq_shard,
                       seq_attn_min_s=seq_attn_min_s)
    step, donate = build_step(cfg, shape)
    if shape.kind == "train" and microbatches > 1:
        step = make_train_step(cfg, AdamWConfig(), microbatches=microbatches)

    t0 = time.time()
    with set_rules(rules), mesh:
        args = input_specs(cfg, shape, rules)
        out_sh = None
        if shape.kind == "train":
            psh = param_shardings(rules, cfg)
            osh = opt_state_shardings(rules, cfg)
            scalar = rules.named(jax.sharding.PartitionSpec())
            out_sh = (psh, osh,
                      {"grad_norm": scalar, "lr": scalar, "loss": scalar})
        elif shape.kind == "decode":
            # cache round-trips with identical shardings so donation aliases
            # (otherwise XLA reshards the output and doubles decode memory)
            from repro.sharding.rules import cache_shardings
            logits_sh = rules.named(rules.activation_spec(
                "logits", (shape.global_batch, cfg.vocab_size)))
            out_sh = (logits_sh, cache_shardings(rules, args[1]))
        jitted = jax.jit(step, donate_argnums=donate, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mf = RL.model_flops_estimate(cfg, shape)
    roof = RL.analyze(compiled, model_flops_total=mf,
                      n_devices=mesh.devices.size)
    ma = compiled.memory_analysis()
    fits = (roof.arg_bytes + roof.temp_bytes + roof.out_bytes) <= RL.HBM_PER_CHIP
    rec.update(roof.asdict(), lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), n_devices=int(mesh.devices.size),
               fits_hbm=bool(fits),
               total_dev_bytes=int(roof.arg_bytes + roof.temp_bytes
                                   + roof.out_bytes))
    if verbose:
        print(f"[ok] {arch} x {shape_name} ({rec['mesh']}): "
              f"compute={roof.compute_s*1e3:.2f}ms memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms bound={roof.bound} "
              f"useful={roof.useful_ratio:.2f} "
              f"mem/dev={(rec['total_dev_bytes'])/2**30:.2f}GiB fits={fits} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"     memory_analysis: {ma}")
        print(f"     cost_analysis: flops={roof.flops:.3e} bytes={roof.bytes_hbm:.3e}")
        print(f"     collectives: {dict(roof.collectives.counts)}")
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}".replace("/", "-")
        (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat")  # none | dots | full | group:<k>
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--seq-attn-min", type=int, default=16384)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    out_dir = Path(args.out)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for mp in meshes:
        for arch, shp in cells:
            try:
                run_cell(arch, shp, multi_pod=mp, remat=args.remat,
                         microbatches=args.microbatches,
                         fsdp=not args.no_fsdp,
                         seq_shard=not args.no_seq_shard,
                         seq_attn_min_s=args.seq_attn_min,
                         out_dir=out_dir)
            except Exception:
                failures += 1
                print(f"[FAIL] {arch} x {shp} multi_pod={mp}")
                traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
