"""Distributed training launcher: mesh + sharding rules + fault-tolerant
trainer, end to end.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --ckpt-dir /tmp/launch_ckpt

On a TPU slice the same command shards over the real device mesh; on this
CPU box it runs the identical code path on a 1x1 mesh (the sharding rules
degrade to replication via their divisibility fallbacks). MeshPlanner
picks remat/microbatch knobs for the configured shape before the first
step — spec -> map -> run, the GPUPlanner flow.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.meshplanner import Knobs, plan
from repro.data.pipeline import DataConfig
from repro.models.config import SHAPES, ShapeSpec
from repro.optim import adamw
from repro.sharding.rules import make_rules
from repro.train.trainer import Trainer, TrainConfig


def build_mesh():
    devs = jax.devices()
    n = len(devs)
    # squarest (data, model) factorization of the available devices
    model = 1
    for m in range(int(n ** 0.5), 0, -1):
        if n % m == 0:
            model = m
            break
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(n // model, model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--full-config", action="store_true",
                    help="use the published size (needs a real pod)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = let MeshPlanner decide")
    ap.add_argument("--ckpt-dir", default="/tmp/launch_train_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_smoke(args.arch)
    mesh = build_mesh()
    rules = make_rules(mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices)")

    # plan the launch like the dry-run plans a cell
    shape = ShapeSpec("launch", args.seq_len, args.batch, "train")
    mp = plan(cfg, shape, n_devices=mesh.devices.size,
              tp=mesh.devices.shape[-1])
    mb = args.microbatches or mp.knobs.microbatches
    cfg = mp.knobs.apply(cfg)
    print(f"plan: remat={cfg.remat} microbatches={mb} "
          f"est={mp.estimate.total_bytes/2**30:.2f} GiB/dev "
          f"bound={mp.estimate.bound()}")

    hp = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                           total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, save_every=max(10, args.steps // 4),
                     log_every=10, ckpt_dir=args.ckpt_dir, microbatches=mb)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)
    result = Trainer(cfg, hp, tc, dc, rules=rules).run()
    print(f"final loss: {result['final_loss']:.4f}")


if __name__ == "__main__":
    main()
