"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run entry point sets
``--xla_force_host_platform_device_count=512`` *before* importing jax; real
TPU launches get the same shapes from the actual pod slice.

Mesh axes:
  pod   — across-pod data parallelism (DCN in practice; 2 pods here)
  data  — within-pod data parallelism + FSDP shard axis (16-way)
  model — tensor/expert/sequence parallelism (16-way)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """1-device mesh for smoke tests: same axis names, trivial sizes."""
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))


def make_launch_mesh(n_devices=None):
    """1-D ``("data",)`` mesh over the available devices for data-parallel
    G-GPU launch sharding (``repro.ggpu.engine`` ``mesh=`` entry points,
    ``repro.serve`` executors/fleet). Uses every device by default; with
    one device the mesh is a valid 1-extent mesh and every sharded entry
    point falls back to the single-device path. CPU CI simulates devices
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set
    *before* importing jax."""
    import numpy as np
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not (1 <= n <= len(devices)):
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))
