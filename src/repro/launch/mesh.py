"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run entry point sets
``--xla_force_host_platform_device_count=512`` *before* importing jax; real
TPU launches get the same shapes from the actual pod slice.

Mesh axes:
  pod   — across-pod data parallelism (DCN in practice; 2 pods here)
  data  — within-pod data parallelism + FSDP shard axis (16-way)
  model — tensor/expert/sequence parallelism (16-way)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """1-device mesh for smoke tests: same axis names, trivial sizes."""
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
