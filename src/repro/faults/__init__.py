"""Deterministic fault injection for the G-GPU serving stack.

Three layers (DESIGN.md §Fault injection & self-healing fleet):

  * :mod:`repro.faults.plan` — ``FaultPlan``: a seed-keyed, stateless
    chaos description whose every decision is a pure hash of
    ``(seed, kind, ticket, attempt)`` — reproducible anywhere and
    independent of chunk grouping or retry interleaving.
  * :mod:`repro.faults.inject` — ``FaultInjector``: the transparent
    executor wrapper that applies a plan at the dispatch boundary
    (SEU bit flips via the engine's fused XOR patch path, straggler
    holds, wedged devices surfacing as ``DeviceTimeout``).
  * :mod:`repro.faults.scenarios` — the ``FAULTS`` registry axis
    built-ins (``none``/``seu``/``straggler``/``device-loss``): named
    ``FaultScenario`` bundles of a plan plus the serve-side resilience
    knobs that answer it, pluggable into CI sweeps and chaos benches.

Injection is strictly opt-in: nothing in this package touches the
serving path unless an injector is interposed, and an inactive plan
injects nothing — committed baselines stay byte-identical.
"""
from repro.faults.inject import FaultInjector
from repro.faults.plan import FaultPlan
from repro.faults.scenarios import (FaultScenario, device_loss, no_faults,
                                    seu, straggler)

__all__ = [
    "FaultInjector", "FaultPlan", "FaultScenario",
    "device_loss", "no_faults", "seu", "straggler",
]
