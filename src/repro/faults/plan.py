"""Deterministic fault plans: seed-reproducible chaos for the serving
stack.

A ``FaultPlan`` is a pure *description* of the faults to inject — it
holds no state and draws every decision from a counter-mode hash keyed
by ``(seed, kind, ticket, attempt)``. That keying is the whole design:

  * **Reproducible** — the same seed and plan produce byte-identical
    fault decisions on any machine, any JAX backend, any run.
  * **Schedule-independent** — a launch's fate depends on *its own*
    ticket and attempt number, never on which chunk the scheduler folded
    it into, how deep the dispatch pipeline ran, or how retries
    interleaved across devices. Re-planning a chunk after a quarantine
    cannot silently reshuffle who gets hit.
  * **Attempt-aware** — a retry is a fresh draw (the ``attempt`` term),
    so a transiently-corrupted launch normally succeeds on re-dispatch,
    exactly like a real SEU; a plan with rate 1.0 models a hard fault.

The fault taxonomy (DESIGN.md §Fault injection & self-healing fleet):

  * ``seu_rate`` — single-event upset *before* compute: one bit of the
    launch's staged memory image is flipped pre-dispatch (via the
    engine's fused ``XorBlockPatch``, one XLA dispatch, off the hot path
    entirely when the rate is 0). The kernel then computes over the
    corrupted input.
  * ``seu_post_rate`` — silent data corruption *after* compute: one bit
    of the collected result is flipped. Invisible unless the request
    carries an output-checksum ``audit`` — the failure mode the
    scheduler's ChecksumError machinery exists for.
  * ``straggler_rate`` / ``straggler_delay_s`` — a dispatched chunk's
    completion is withheld for ``straggler_delay_s`` wall-clock seconds
    (the tail-latency fault hedging exists for).
  * ``stuck_devices`` / ``stuck_after`` — the named devices wedge
    permanently after ``stuck_after`` dispatches: their chunks never
    resolve, surfacing as ``DeviceTimeout`` once the executor's
    ``timeout_s`` expires (the device-loss fault eviction exists for).

All rates are probabilities in [0, 1]; a default-constructed plan (all
rates 0, no stuck devices) injects nothing and adds nothing to the
dispatch path — bit-exact-off-by-default.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

# result words are int32; bit 31 would need an unsigned view to mask, so
# flips draw from the 31 value bits — one flipped bit is one flipped bit
_BITS = 31


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic, seed-keyed chaos description (module doc)."""
    seed: int = 0
    seu_rate: float = 0.0           # pre-dispatch staged-memory bit flips
    seu_post_rate: float = 0.0      # post-collect result bit flips (SDC)
    straggler_rate: float = 0.0     # per-chunk completion-hold probability
    straggler_delay_s: float = 0.0  # how long a straggling chunk is held
    stuck_devices: Tuple[str, ...] = ()  # device names that wedge...
    stuck_after: int = 0            # ...after this many dispatches

    @property
    def active(self) -> bool:
        """Does this plan ever inject anything?"""
        return bool(self.seu_rate or self.seu_post_rate
                    or self.straggler_rate or self.stuck_devices)

    # -- the draw primitive --------------------------------------------------

    def _digest(self, kind: str, *key) -> bytes:
        return hashlib.sha256(
            repr((self.seed, kind) + key).encode()).digest()

    def _unit(self, kind: str, *key) -> float:
        """One uniform draw in [0, 1), a pure function of (seed, kind,
        key) — the counter-mode primitive every decision reduces to."""
        return int.from_bytes(self._digest(kind, *key)[:8], "big") / 2.0**64

    def _pick(self, kind: str, n: int, *key) -> int:
        """One uniform draw in [0, n)."""
        return int.from_bytes(self._digest(kind, *key)[8:16], "big") % n

    # -- decisions (keyed per launch attempt / per dispatch) -----------------

    def seu_hit(self, ticket: int, attempt: int) -> bool:
        """Does attempt ``attempt`` of launch ``ticket`` take a
        pre-dispatch staged-memory upset?"""
        return self._unit("seu", ticket, attempt) < self.seu_rate

    def seu_flip(self, ticket: int, attempt: int,
                 msize: int) -> Tuple[int, int]:
        """The (word, bit) the upset flips, uniform over the image."""
        return (self._pick("seu-word", msize, ticket, attempt),
                self._pick("seu-bit", _BITS, ticket, attempt))

    def post_hit(self, ticket: int, attempt: int) -> bool:
        """Does this attempt's *result* take a silent corruption?"""
        return self._unit("sdc", ticket, attempt) < self.seu_post_rate

    def post_flip(self, ticket: int, attempt: int,
                  msize: int) -> Tuple[int, int]:
        return (self._pick("sdc-word", msize, ticket, attempt),
                self._pick("sdc-bit", _BITS, ticket, attempt))

    def straggler_hit(self, ticket: int, attempt: int) -> bool:
        """Is the chunk whose *first member* is (ticket, attempt) held as
        a straggler? Chunk-level on purpose: a real straggling device
        delays everything it was running, not one launch of it."""
        return self._unit("straggler", ticket, attempt) \
            < self.straggler_rate

    def stuck(self, device: str, dispatch_ordinal: int) -> bool:
        """Has ``device`` wedged by its ``dispatch_ordinal``-th dispatch?"""
        return device in self.stuck_devices \
            and dispatch_ordinal >= self.stuck_after
