"""The fault injector: a transparent ``Executor`` wrapper that applies a
:class:`~repro.faults.plan.FaultPlan` at the dispatch boundary.

The injector sits where a fleet's ``executor_wrap`` hook puts it —
between each device's ``Scheduler`` and its real ``Executor`` — and
implements the executor protocol (``submit`` / ``chunk_ready`` /
``collect``; everything else delegates). Faults enter at exactly three
points:

  * **submit** — launches drawn for a pre-dispatch SEU get an
    ``XorBlockPatch`` merged into the chunk's staged-memory patches: the
    bit flip rides the engine's existing fused patch path, so injection
    costs one XLA dispatch and is *in* the staged buffer the kernel
    reads — not a host-side fiction. Chunks drawn as stragglers (or
    dispatched on a wedged device) are recorded in ``_holds``.
  * **chunk_ready** — held chunks report not-ready until their hold
    expires (never, for a stuck device). The scheduler's readiness-
    ordered collection and the fleet's hedging both key off this.
  * **collect** — a held chunk past the executor ``timeout_s`` raises
    ``DeviceTimeout`` exactly as the real executor would; an expired
    straggler hold sleeps out its remainder and resolves normally.
    Collected results drawn for post-compute corruption get one bit
    flipped — the silent-data-corruption path only a checksum audit can
    catch.

Every decision is appended to ``injected`` — ``(kind, device, ticket,
attempt, ...)`` tuples — which is the determinism surface the fault
tests compare across runs: same seed, same plan, byte-identical log.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from repro.faults.plan import FaultPlan
from repro.ggpu.engine import BlockPatch, XorBlockPatch
from repro.serve.executors import DeviceTimeout, Executor, PendingChunk
from repro.serve.request import Request, Result


class FaultInjector:
    """Wraps one device's executor with a deterministic fault plan
    (module doc). With an inactive plan every call is pure delegation
    plus one dict lookup — and ``submit`` adds nothing at all."""

    def __init__(self, name: str, executor: Executor, plan: FaultPlan):
        self.name = name
        self.inner = executor
        self.plan = plan
        self._holds: dict = {}      # id(pending) -> None (stuck) | ready-at
        self._dispatches = 0
        self.injected: List[tuple] = []   # the decision log (module doc)

    def __getattr__(self, attr):
        # transparent protocol passthrough: cfg, stats, shards, memo,
        # timeout_s, run, ... — the scheduler never knows we're here
        return getattr(self.inner, attr)

    # -- submit: pre-dispatch SEUs + hold decisions --------------------------

    def submit(self, kind: str, reqs: Sequence[Request],
               patches=None) -> PendingChunk:
        ordinal = self._dispatches
        self._dispatches += 1
        if self.plan.seu_rate:
            patches = self._merge_seu(kind, list(reqs), patches)
        pending = self.inner.submit(kind, reqs, patches)
        first = reqs[0]
        if self.plan.stuck(self.name, ordinal):
            self._holds[id(pending)] = None
            self.injected.append(("stuck", self.name, first.ticket,
                                  first.attempts, ordinal))
        elif self.plan.straggler_rate \
                and self.plan.straggler_hit(first.ticket, first.attempts):
            self._holds[id(pending)] = time.monotonic() \
                + self.plan.straggler_delay_s
            self.injected.append(("straggler", self.name, first.ticket,
                                  first.attempts, ordinal))
        return pending

    def _merge_seu(self, kind: str, reqs: List[Request], patches):
        """Fold this chunk's drawn bit flips into its staged-memory
        patches. A patch-free cohort gets one fused ``XorBlockPatch``
        (rows of zeros are no-ops for the unhit launches — one device op
        covers the chunk); everything else degrades to per-launch
        ``(lo, hi, mask, "xor")`` entries merged with whatever dependency
        patches the chunk already carries."""
        hits = {}
        for i, r in enumerate(reqs):
            if self.plan.seu_hit(r.ticket, r.attempts):
                word, bit = self.plan.seu_flip(r.ticket, r.attempts,
                                               int(r.mem0.shape[0]))
                hits[i] = (word, bit)
                self.injected.append(("seu", self.name, r.ticket,
                                      r.attempts, word, bit))
        if not hits:
            return patches
        if patches is None and kind == "cohort" and len(reqs) > 1:
            # full-width zero block with only the drawn bits set: the
            # (0, msize) envelope is stable regardless of which words
            # were hit, so repeated injection reuses one compiled patch
            # path instead of re-tracing per drawn (lo, hi) span
            msize = int(reqs[0].mem0.shape[0])
            block = np.zeros((len(reqs), msize), np.int32)
            for i, (word, bit) in hits.items():
                block[i, word] = np.int32(1) << bit
            return XorBlockPatch(0, msize, block)
        per = self._per_launch(kind, reqs, patches)
        for i, (word, bit) in hits.items():
            # same stable-envelope trick as the fused path: a full-width
            # mask keeps the patch span at (0, msize) for every draw
            mask = np.zeros(int(reqs[i].mem0.shape[0]), np.int32)
            mask[word] = np.int32(1) << bit
            entry = (0, mask.shape[0], mask, "xor")
            per[i] = (list(per[i]) + [entry]) if per[i] else [entry]
        return per

    @staticmethod
    def _per_launch(kind: str, reqs: List[Request], patches) -> list:
        """Normalize any chunk-level patch form down to one mutable
        per-launch list (the form XOR entries can always merge into)."""
        if patches is None:
            return [None] * len(reqs)
        if isinstance(patches, (BlockPatch, XorBlockPatch)):
            op = ("xor",) if isinstance(patches, XorBlockPatch) else ()
            return [[(patches.lo, patches.hi, patches.block[i]) + op]
                    for i in range(len(reqs))]
        return [list(p) if p else None for p in patches]

    # -- readiness + collection: holds, timeouts, post-compute SDC -----------

    def chunk_ready(self, pending: PendingChunk) -> bool:
        hold = self._holds.get(id(pending), False)
        if hold is None:                      # stuck: never ready
            return False
        if hold is not False and time.monotonic() < hold:
            return False                      # straggler: not yet
        return self.inner.chunk_ready(pending)

    def collect(self, pending: PendingChunk) -> List[Result]:
        key = id(pending)
        if key in self._holds:
            hold = self._holds.pop(key)
            deadline = None if self.inner.timeout_s is None \
                else pending.t_dispatch + self.inner.timeout_s
            if hold is None:
                # a wedged device only ever resolves via the timeout
                if deadline is not None:
                    time.sleep(max(0.0, deadline - time.monotonic()))
                raise DeviceTimeout(
                    f"device {self.name} stuck: chunk of "
                    f"{len(pending.reqs)} launch(es) never resolved")
            if deadline is not None and hold >= deadline:
                time.sleep(max(0.0, deadline - time.monotonic()))
                raise DeviceTimeout(
                    f"device {self.name} straggled past its "
                    f"{self.inner.timeout_s}s timeout")
            time.sleep(max(0.0, hold - time.monotonic()))
        results = self.inner.collect(pending)
        if self.plan.seu_post_rate:
            results = [self._corrupt(r, res)
                       for r, res in zip(pending.reqs, results)]
        return results

    def _corrupt(self, req: Request, res: Result) -> Result:
        """Post-compute silent corruption: flip one drawn bit of the
        collected words (no-op for cycles-only results)."""
        msize = int(np.asarray(res.mem).shape[0])
        if not msize or not self.plan.post_hit(req.ticket, req.attempts):
            return res
        word, bit = self.plan.post_flip(req.ticket, req.attempts, msize)
        mem = np.array(res.mem, np.int32, copy=True)
        mem[word] ^= np.int32(1) << bit
        self.injected.append(("sdc", self.name, req.ticket, req.attempts,
                              word, bit))
        return Result(mem, res.info)
