"""The ``FAULTS`` registry axis built-ins: named chaos scenarios.

A registered fault plugin is a factory ``(seed=0, **kw) ->
FaultScenario``: a :class:`~repro.faults.plan.FaultPlan` bundled with
the serve-side resilience knobs that *answer* that fault class — the
retry policy that re-runs corrupted launches, the executor timeout that
surfaces a wedged device, the fleet resilience/hedging policy that
routes around it, and whether requests should carry output-checksum
audits (without audits a post-compute SEU is silent). The bundle is what
makes a scenario one registry lookup for CI: ``FAULTS.get("seu")(seed)``
hands a chaos bench everything it needs to build a fleet that should
*survive* the trace, and the gates then check that it did.

``FaultScenario.fleet_kwargs()`` plugs straight into ``Fleet(...)``;
``executor_wrap`` is the fleet hook that interposes one
:class:`~repro.faults.inject.FaultInjector` per device. Injectors are
recorded on the scenario, so ``decision_log()`` is the merged, ordered
fault-decision record — the byte-comparable determinism surface.

Built-ins:

  * ``none`` — the control: no injection, no resilience machinery. A
    fleet built from it is the bit-exact baseline the chaos results are
    compared against.
  * ``seu`` — pre- and post-compute single-event upsets with checksum
    audits and bounded retries (corruption is caught and re-run, never
    served).
  * ``straggler`` — held completions with an executor timeout and
    deadline-aware hedging (the p99 insurance case).
  * ``device-loss`` — one device wedges permanently; the timeout +
    eviction machinery must re-route its backlog to the survivors.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.faults.inject import FaultInjector
from repro.faults.plan import FaultPlan
from repro.registry import FAULTS
from repro.serve.fleet import FleetResilience, HedgePolicy
from repro.serve.scheduler import RetryPolicy


@dataclasses.dataclass
class FaultScenario:
    """One named chaos scenario: the fault plan plus the resilience
    configuration that answers it (module doc). ``audit`` asks the
    driver to stamp ``result_checksum`` audits on every request — the
    only way a post-compute SEU is detectable."""
    plan: FaultPlan
    retry: Optional[RetryPolicy] = None
    timeout_s: Optional[float] = None
    resilience: Optional[FleetResilience] = None
    audit: bool = False
    injectors: List[FaultInjector] = dataclasses.field(
        default_factory=list, repr=False)

    def executor_wrap(self, name: str, executor) -> FaultInjector:
        """The ``Fleet(executor_wrap=...)`` hook: interpose one injector
        per device (recorded here for ``decision_log``)."""
        inj = FaultInjector(name, executor, self.plan)
        self.injectors.append(inj)
        return inj

    def fleet_kwargs(self) -> dict:
        """Keyword arguments that configure a ``Fleet`` for this
        scenario — injection *and* the machinery expected to absorb it."""
        return dict(resilience=self.resilience, retry=self.retry,
                    timeout_s=self.timeout_s,
                    executor_wrap=self.executor_wrap)

    def decision_log(self) -> Tuple[tuple, ...]:
        """Every injection decision taken so far, merged across devices
        and canonically ordered — byte-identical across two runs with
        the same seed, plan, and trace (the determinism tests' surface)."""
        return tuple(sorted(
            entry for inj in self.injectors for entry in inj.injected))


@FAULTS.register("none")
def no_faults(seed: int = 0) -> FaultScenario:
    """The control scenario: nothing injected, nothing interposed."""
    return FaultScenario(FaultPlan(seed=seed))


@FAULTS.register("seu")
def seu(seed: int = 0, rate: float = 0.08,
        max_retries: int = 3) -> FaultScenario:
    """Single-event upsets on both sides of compute, with the audit +
    retry machinery that turns silent corruption into re-runs."""
    return FaultScenario(
        FaultPlan(seed=seed, seu_rate=rate / 2, seu_post_rate=rate),
        retry=RetryPolicy(max_retries=max_retries),
        resilience=FleetResilience(),
        audit=True)


@FAULTS.register("straggler")
def straggler(seed: int = 0, rate: float = 0.15,
              delay_s: float = 0.25,
              hedge_after_s: float = 0.05) -> FaultScenario:
    """Held completions: a fraction of chunks straggle by ``delay_s``;
    hedging duplicates their members onto healthy idle devices."""
    return FaultScenario(
        FaultPlan(seed=seed, straggler_rate=rate,
                  straggler_delay_s=delay_s),
        timeout_s=max(4 * delay_s, 1.0),
        resilience=FleetResilience(
            hedge=HedgePolicy(after_s=hedge_after_s)))


@FAULTS.register("device-loss")
def device_loss(seed: int = 0, device: str = "dev0",
                timeout_s: float = 0.25,
                stuck_after: int = 1) -> FaultScenario:
    """A device wedges permanently after ``stuck_after`` dispatches; the
    executor timeout surfaces it, retries exhaust, eviction re-routes
    its backlog to the survivors."""
    return FaultScenario(
        FaultPlan(seed=seed, stuck_devices=(device,),
                  stuck_after=stuck_after),
        retry=RetryPolicy(max_retries=1),
        timeout_s=timeout_s,
        resilience=FleetResilience(evict_after=2))
