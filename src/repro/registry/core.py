"""Registry core: named axes of decorator-registered plugins.

The idiom (Volatility3's interfaces + automagic discovery, DESIGN.md
§Scenario registry): an :class:`Axis` is one extension dimension of the
system — benches, memory systems, chunk-planning policies, fleet
routers, traffic generators, bench sections. Each axis knows the
*provider modules* whose import registers the built-in plugins, plus the
``repro.registry.plugins`` drop-in package that is scanned
automatically, so a brand-new scenario is **one new file** that appears
in every enumeration (including the CI matrices ``python -m
repro.registry --json`` emits) without touching any core module.

Rules every axis enforces:

  * **decorator or direct registration** — ``@AXIS.register("name")``
    on a class/function, or ``AXIS.register("name", obj)`` for
    pre-built instances;
  * **duplicate-name rejection** — a second registration of a taken
    name raises :class:`DuplicateNameError` (silent shadowing is how
    two plugins corrupt each other's CI legs);
  * **lazy discovery** — provider modules import only when the axis is
    first queried, so ``import repro.registry`` stays light and the
    engine/serve modules can register themselves without import cycles;
  * **deterministic enumeration** — ``names()``/``items()`` are sorted
    by name, so the order never depends on which axis was queried first
    or which module happened to import earlier.

Lookup failures raise :class:`UnknownPluginError`, a ``KeyError``
subclass so pre-registry call sites (``get_memsys``) keep their
contract, with the same ``choices:`` message shape.
"""
from __future__ import annotations

import importlib
import pkgutil
from typing import Callable, Dict, Iterator, List, Optional, Tuple

__all__ = ["Axis", "DuplicateNameError", "RegistryError",
           "UnknownPluginError", "resolve", "scan_package"]


class RegistryError(Exception):
    """Base class for registry failures."""


class DuplicateNameError(RegistryError):
    """Two plugins claimed the same name on one axis."""


class UnknownPluginError(RegistryError, KeyError):
    """Lookup of a name no plugin registered (``KeyError`` for
    compatibility with the pre-registry dict-based call sites)."""

    def __str__(self) -> str:  # KeyError would repr() the args tuple
        return self.args[0]


_MISSING = object()


class Axis:
    """One pluggable dimension: a name -> plugin mapping with lazy
    provider discovery (see module doc).

    ``providers`` are module paths imported on first query; their import
    side effect is the ``register`` calls for the built-ins. The shared
    ``repro.registry.plugins`` drop-in package is appended to every
    axis's provider list by default (``scan_plugins=False`` opts out —
    used by unit tests that build throwaway axes)."""

    def __init__(self, name: str, doc: str = "",
                 providers: Tuple[str, ...] = (),
                 scan_plugins: bool = True):
        self.name = name
        self.doc = doc
        self._providers = tuple(providers)
        if scan_plugins:
            self._providers += ("repro.registry.plugins",)
        self._entries: Dict[str, object] = {}
        self._discovered = False

    # -- registration -------------------------------------------------------

    def register(self, name: str, obj: object = _MISSING):
        """Register ``obj`` under ``name``; with ``obj`` omitted,
        returns a decorator. The decorated object is returned unchanged,
        so ``@AXIS.register("x")`` stacks freely with ``@dataclass``."""
        if obj is _MISSING:
            def deco(target):
                self._add(name, target)
                return target
            return deco
        self._add(name, obj)
        return obj

    def _add(self, name: str, obj: object) -> None:
        if not isinstance(name, str) or not name:
            raise RegistryError(
                f"{self.name} plugin name must be a non-empty string, "
                f"got {name!r}")
        if name in self._entries:
            raise DuplicateNameError(
                f"{self.name} plugin {name!r} is already registered "
                f"({self._entries[name]!r}); plugin names must be unique "
                f"per axis")
        self._entries[name] = obj

    # -- discovery ----------------------------------------------------------

    def discover(self) -> None:
        """Import every provider module once (their import registers the
        built-ins). Import errors propagate — a broken plugin must fail
        the ``registry-smoke`` CI job loudly, not vanish from the
        matrix."""
        if self._discovered:
            return
        # flip first: a provider that queries its own axis mid-import
        # (e.g. to extend an existing entry) must not recurse
        self._discovered = True
        try:
            for mod in self._providers:
                importlib.import_module(mod)
        except BaseException:
            self._discovered = False
            raise

    # -- lookup / enumeration -----------------------------------------------

    def get(self, name: str) -> object:
        self.discover()
        try:
            return self._entries[name]
        except KeyError:
            raise UnknownPluginError(
                f"unknown {self.name} {name!r}; choices: "
                f"{sorted(self._entries)}") from None

    def names(self) -> List[str]:
        """Registered names, sorted — the deterministic enumeration
        order every CI matrix is generated from."""
        self.discover()
        return sorted(self._entries)

    def items(self) -> List[Tuple[str, object]]:
        self.discover()
        return [(n, self._entries[n]) for n in sorted(self._entries)]

    def __contains__(self, name: str) -> bool:
        self.discover()
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        self.discover()
        return len(self._entries)

    def __repr__(self) -> str:
        state = sorted(self._entries) if self._discovered \
            else f"undiscovered, providers={list(self._providers)}"
        return f"Axis({self.name!r}: {state})"


def scan_package(package) -> List[str]:
    """Import every module inside ``package`` (sorted by name — the
    drop-in directory's automagic). Returns the imported module names."""
    out = []
    for info in sorted(pkgutil.iter_modules(package.__path__),
                       key=lambda m: m.name):
        importlib.import_module(f"{package.__name__}.{info.name}")
        out.append(info.name)
    return out


def resolve(spec: str, default_attr: Optional[str] = None) -> Callable:
    """Resolve a ``"module:attr"`` runner spec to the callable it names
    (the indirection bench sections use so the registry never imports the
    ``benchmarks`` package itself)."""
    mod, _, attr = spec.partition(":")
    target = importlib.import_module(mod)
    attr = attr or default_attr
    try:
        return getattr(target, attr)
    except (AttributeError, TypeError) as exc:
        raise RegistryError(
            f"spec {spec!r}: module {mod!r} has no attribute {attr!r}"
        ) from exc
