"""Bench axis built-ins: the eight suite benches as registry plugins.

A :class:`BenchSpec` is the plugin contract of the ``BENCHES`` axis —
the registered form of what used to be hard-wired in two places
(``programs.all_benches()`` and ``compiler.suite._DEFS``):

  * ``build(*sizes)`` constructs the ``programs.Bench`` record (ISA
    programs, memory images, NumPy reference); no arguments means the
    paper's Table III sizes.
  * ``kernel_def(*sizes)`` (optional) is the traceable tensor-DSL
    ``(fn, shapes)`` definition the compiler/autotuner re-lowers under
    candidate schedules; ``None`` marks an ISA-only bench the compiler
    sections skip.
  * ``smoke_sizes`` are the (scalar, gpu[, extra]) build arguments the
    ``registry-smoke`` CI job uses for its one-minimal-launch check —
    small enough that every registered bench simulates in well under a
    second.
  * ``paper`` marks the seven benches the paper's tables report.

``ordered_names()`` preserves the legacy ``all_benches()`` ordering
(paper order, then extensions, then any plugin benches sorted) so the
benchmark tables keep their historical row order while the axis itself
enumerates sorted like every other axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.registry import BENCHES

#: the pre-registry ``all_benches()`` insertion order, kept so bench
#: tables/CSV rows don't reshuffle under the registry refactor
LEGACY_ORDER = ("mat_mul", "copy", "vec_mul", "fir", "div_int", "xcorr",
                "parallel_sel", "reduction")


@dataclass(frozen=True)
class BenchSpec:
    """One registered workload (see module doc)."""
    name: str
    build: Callable          # (*sizes) -> programs.Bench
    kernel_def: Optional[Callable] = None  # (*sizes) -> (fn, shapes)
    smoke_sizes: Tuple[int, ...] = ()
    paper: bool = False

    def describe(self) -> dict:
        return {
            "paper": self.paper,
            "has_kernel_def": self.kernel_def is not None,
            "smoke_sizes": list(self.smoke_sizes),
        }


def _register_builtins() -> None:
    # lazy domain imports: the registry package itself must stay light,
    # and ``programs``/``suite`` both reach back into the registry
    from repro.compiler import suite
    from repro.ggpu import programs

    smoke = {
        "mat_mul": (4, 8),
        "copy": (32, 128),
        "vec_mul": (32, 128),
        "fir": (16, 64),
        "div_int": (32, 64),
        "xcorr": (16, 32),
        "parallel_sel": (16, 32),
        "reduction": (64, 128),
    }
    for name in LEGACY_ORDER:
        BENCHES.register(name, BenchSpec(
            name=name,
            build=getattr(programs, f"_{name}"),
            kernel_def=suite._DEFS.get(name),
            smoke_sizes=smoke[name],
            paper=name in programs.PAPER_CYCLES))


_register_builtins()


def ordered_names() -> list:
    """Bench names in legacy table order, plugin extras (sorted) last."""
    names = BENCHES.names()
    legacy = [n for n in LEGACY_ORDER if n in names]
    return legacy + [n for n in names if n not in LEGACY_ORDER]
