"""Section axis built-ins: the benchmark-harness sections and their CI legs.

A :class:`BenchSection` describes one ``benchmarks.run`` section — the
unit CI smokes per-PR. ``benchmarks/run.py`` dispatches its CLI flags
through this axis, and ``python -m repro.registry --json`` emits the
``bench-smoke`` matrix from the sections with ``ci_smoke=True``, so a
new bench section (one registration here or in a drop-in plugin, plus
its runner) gets a CI smoke leg with **no workflow edit**: the matrix
entry carries the run arguments, artifact/baseline paths, extra
``check_bench`` arguments, and the leg's ``XLA_FLAGS``.

``runner`` is a ``"module:function"`` spec resolved lazily by
``benchmarks/run.py`` — the registry never imports the ``benchmarks``
package (which lives outside ``src/``), it only names entry points. The
runner contract is ``runner(emit, fast) -> list_of_problem_strings``
(empty list = section healthy).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.registry import SECTIONS


@dataclass(frozen=True)
class BenchSection:
    """One benchmark section + its CI smoke-leg metadata."""
    name: str
    runner: str                      # "module:function" (emit, fast) spec
    flag: Optional[str] = None       # benchmarks.run CLI flag, if any
    description: str = ""
    ci_smoke: bool = True            # gets a per-PR bench-smoke leg
    run_args: str = ""               # benchmarks.run args for the CI leg
    artifact: str = ""               # file the section writes
    artifact_name: str = ""          # CI upload-artifact name
    baseline: str = ""               # committed baseline check_bench gates on
    check_args: Tuple[str, ...] = () # extra check_bench arguments
    xla_flags: str = ""              # XLA_FLAGS for the CI leg
    timeout_minutes: int = 20
    gate_sections: Tuple[str, ...] = field(default=())  # check_bench
    #                                 --section values this section accepts

    def matrix_entry(self) -> dict:
        """The ``bench-smoke`` matrix row for this section (strings only:
        GitHub Actions matrix values interpolate into shell commands)."""
        return {
            "section": self.name,
            "run_args": self.run_args,
            "artifact": self.artifact,
            "artifact_name": self.artifact_name or self.name,
            "baseline": self.baseline,
            "check_args": " ".join(self.check_args),
            "xla_flags": self.xla_flags,
        }

    def describe(self) -> dict:
        d = self.matrix_entry()
        d.update(ci_smoke=self.ci_smoke, flag=self.flag or "",
                 runner=self.runner, description=self.description)
        del d["section"]
        return d


_BASELINES = "benchmarks/baselines"

SECTIONS.register("dse", BenchSection(
    name="dse", flag="--dse",
    runner="benchmarks.engine_bench:run_dse_section",
    description="unified DSE Pareto sweep + BENCH_dse.json artifact",
    run_args="--dse --fast",
    artifact="BENCH_dse.json", artifact_name="BENCH_dse",
    baseline=f"{_BASELINES}/BENCH_dse.json"))

SECTIONS.register("serve", BenchSection(
    name="serve", flag="--serve",
    runner="benchmarks.serve_bench:run_serve_section",
    description="serving throughput, sharding, open-loop latency, fleet "
                "routing, kernel graphs + BENCH_serve.json artifact",
    run_args="--serve --fast",
    artifact="BENCH_serve.json", artifact_name="BENCH_serve",
    baseline=f"{_BASELINES}/BENCH_serve.json"))

SECTIONS.register("compiler", BenchSection(
    name="compiler", flag="--compiler",
    runner="benchmarks.compiler_bench:run_compiler_section",
    description="tensor-DSL suite parity + autotune + codesign sweep "
                "+ BENCH_compiler.json artifact",
    run_args="--compiler --fast",
    artifact="BENCH_compiler.json", artifact_name="BENCH_compiler",
    baseline=f"{_BASELINES}/BENCH_compiler.json"))

SECTIONS.register("graph", BenchSection(
    name="graph", flag="--graph",
    runner="benchmarks.serve_bench:run_graph_section",
    description="device-resident kernel-graph path vs host-staged chains "
                "(partial serve artifact, gated with --section graph)",
    run_args="--graph --fast",
    artifact="BENCH_graph.json", artifact_name="BENCH_graph",
    baseline=f"{_BASELINES}/BENCH_serve.json",
    check_args=("--section", "graph"),
    gate_sections=("graph",)))

# chaos gates: the PR-blocking resilience-smoke job — SEU audits +
# retry, device eviction + re-route, hedged-vs-unhedged straggler p99 —
# gated against its own committed baseline (fault decisions are
# deterministic at the committed seed, so counts compare exactly)
SECTIONS.register("resilience", BenchSection(
    name="resilience", flag="--resilience",
    runner="benchmarks.resilience_bench:run_resilience_section",
    description="fault-injection chaos gates: SEU audit+retry, device "
                "eviction, hedged straggler p99 (BENCH_resilience.json)",
    run_args="--resilience --fast",
    artifact="BENCH_resilience.json", artifact_name="BENCH_resilience",
    baseline=f"{_BASELINES}/BENCH_resilience.json",
    check_args=("--section", "resilience"),
    gate_sections=("resilience",)))

# the serve section again under 8 simulated host devices: the leg that
# exercises real mesh sharding and the >= 1.5x sharded throughput gate
SECTIONS.register("fleet", BenchSection(
    name="fleet", flag=None,
    runner="benchmarks.serve_bench:run_serve_section",
    description="8-simulated-device sharded serve (mesh shard_map leg of "
                "the serve section)",
    run_args="--serve --fast",
    artifact="BENCH_serve.json", artifact_name="BENCH_serve-sharded",
    baseline=f"{_BASELINES}/BENCH_serve.json",
    xla_flags="--xla_force_host_platform_device_count=8"))

# engine micro-benchmarks: a local section with no CI smoke leg (the
# engine paths are covered by tier-1 tests and the dse section's gate)
SECTIONS.register("engine", BenchSection(
    name="engine", flag="--engine",
    runner="benchmarks.engine_bench:run_engine_section",
    description="simulator-engine micro-benchmarks (fused dispatch, "
                "batched queue, memsys sweep)",
    ci_smoke=False))
