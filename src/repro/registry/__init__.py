"""Unified scenario registry: every pluggable axis of the system.

One introspectable surface (DESIGN.md §Scenario registry) spanning seven
axes, each an :class:`~repro.registry.core.Axis` whose built-ins
register themselves from the named provider modules on first query:

  ==============  =======================================  ==================
  axis            plugin contract                          built-ins from
  ==============  =======================================  ==================
  ``BENCHES``     :class:`~repro.registry.benches.         repro.registry.
                  BenchSpec` (build a ``programs.Bench``   benches
                  at given sizes; optional DSL
                  ``kernel_def`` for the autotuner)
  ``MEMSYS``      ``engine.memsys.MemorySystem``           repro.ggpu.engine.
                  instance (cycle model of a cache         memsys
                  organization)
  ``SCHEDULERS``  chunk-planning policy:                   repro.serve.
                  ``(requests, cfg, max_batch) ->          policies
                  List[Chunk]``
  ``ROUTERS``     fleet routing strategy *class*:          repro.serve.
                  instances expose ``pick(fleet, req)      routing
                  -> FleetDevice``
  ``TRAFFIC``     arrival-trace generator:                 repro.serve.
                  ``(n, seed=0) -> np.ndarray`` of         loadgen
                  seconds-from-start times
  ``FAULTS``      chaos-scenario factory:                  repro.faults.
                  ``(seed=0, **kw) -> FaultScenario``      scenarios
                  (a FaultPlan + the serve-side
                  resilience knobs that answer it)
  ``SECTIONS``    :class:`~repro.registry.sections.        repro.registry.
                  BenchSection` (a benchmark-harness       sections
                  section + its CI smoke leg metadata)
  ==============  =======================================  ==================

Every axis also scans the ``repro.registry.plugins`` drop-in package, so
a new scenario on any axis is one new file there — it resolves by name
everywhere (``GGPUConfig(memsys=...)``, ``Scheduler(policy=...)``,
``Fleet(router=...)``), and ``python -m repro.registry --json`` makes it
appear in the CI smoke and nightly cross-product matrices with no
workflow edit (README "Add a scenario in one file").

``AXES`` maps axis name -> axis for generic enumeration (the CLI and the
``registry-smoke`` job iterate it).
"""
from repro.registry.core import (Axis, DuplicateNameError, RegistryError,
                                 UnknownPluginError)

BENCHES = Axis(
    "bench",
    doc="workloads: ISA benches with optional DSL kernel definitions",
    providers=("repro.registry.benches",))

MEMSYS = Axis(
    "memsys",
    doc="memory-system cycle models (cache organizations)",
    providers=("repro.ggpu.engine.memsys",))

SCHEDULERS = Axis(
    "scheduler",
    doc="chunk-planning policies for the continuous-batching core",
    providers=("repro.serve.policies",))

ROUTERS = Axis(
    "router",
    doc="fleet placement strategies (router classes)",
    providers=("repro.serve.routing",))

TRAFFIC = Axis(
    "traffic",
    doc="open-loop arrival-trace generators",
    providers=("repro.serve.loadgen",))

FAULTS = Axis(
    "fault",
    doc="deterministic chaos scenarios (FaultScenario factories)",
    providers=("repro.faults.scenarios",))

SECTIONS = Axis(
    "section",
    doc="benchmark-harness sections and their CI smoke legs",
    providers=("repro.registry.sections",))

#: axis name -> axis; the generic enumeration surface. ``sections`` is
#: CI plumbing rather than a scenario dimension, so the scenario
#: cross-product (nightly sweeps) uses ``SCENARIO_AXES``.
AXES = {
    "benches": BENCHES,
    "memsys": MEMSYS,
    "schedulers": SCHEDULERS,
    "routers": ROUTERS,
    "traffic": TRAFFIC,
    "faults": FAULTS,
    "sections": SECTIONS,
}

SCENARIO_AXES = {k: AXES[k] for k in
                 ("benches", "memsys", "schedulers", "routers", "traffic",
                  "faults")}

__all__ = [
    "AXES", "BENCHES", "FAULTS", "MEMSYS", "ROUTERS", "SCENARIO_AXES",
    "SCHEDULERS",
    "SECTIONS", "TRAFFIC", "Axis", "DuplicateNameError", "RegistryError",
    "UnknownPluginError",
]
