"""Registry CLI — the machine-readable enumeration surface CI consumes.

Modes (exactly one):

  ``--json``
      Full enumeration: every axis's plugin names (plus per-plugin
      detail where the spec provides ``describe()``) and the generated
      CI matrices. Schema ``ggpu-registry/1``.
  ``--ci-matrix {smoke,nightly}``
      One matrix as compact JSON on a single line, ready for
      ``$GITHUB_OUTPUT`` + ``fromJSON``:
        * ``smoke``   — the per-PR ``bench-smoke`` job: one leg per
          registered bench section with ``ci_smoke=True`` (run args,
          artifact/baseline paths, gate args, XLA flags).
        * ``nightly`` — the scenario cross-product: one cell per
          (memsys, policy, router, fault) combination (each cell
          replays every registered traffic pattern over every bench,
          under the named chaos scenario), plus one full-sweep leg per
          artifact section (``run_args`` with ``--fast`` stripped).
  ``--selfcheck``
      Discover every axis; exit non-zero on import errors, duplicate
      names (both raise), or an empty axis.
  ``--smoke``
      ``--selfcheck`` plus one minimal launch per registered scenario
      (the PR-blocking ``registry-smoke`` CI job).
  ``--run-cell MEMSYS POLICY ROUTER [FAULT]``
      Execute one nightly cross-product cell (``FAULT`` names a
      ``FAULTS`` scenario; default ``none``).

Adding a scenario in a drop-in file under ``repro/registry/plugins/``
changes these outputs — and therefore the CI matrices — with no
workflow edit.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.registry import AXES, SECTIONS

SCHEMA = "ggpu-registry/1"


def _sections(ci_only: bool = True):
    secs = [SECTIONS.get(n) for n in SECTIONS.names()]
    return [s for s in secs if s.ci_smoke] if ci_only else secs


def smoke_matrix() -> dict:
    """The ``bench-smoke`` strategy matrix (include-list form)."""
    return {"include": [s.matrix_entry() for s in _sections()]}


def nightly_matrix() -> dict:
    """The nightly matrix: scenario cross-product cells + full sweeps."""
    include = []
    for ms in AXES["memsys"].names():
        for pol in AXES["schedulers"].names():
            for rt in AXES["routers"].names():
                for ft in AXES["faults"].names():
                    include.append({
                        "kind": "cell",
                        "memsys": ms, "policy": pol, "router": rt,
                        "fault": ft,
                        "xla_flags": "",
                        "name": f"cell-{ms}-{pol}-{rt}-{ft}",
                    })
    seen = set()
    for s in _sections():
        # one full (non --fast) sweep per distinct run; the fleet
        # section re-runs --serve but under 8 sharded devices, so the
        # dedupe key includes the XLA flags
        full = " ".join(a for a in s.run_args.split() if a != "--fast")
        if not full or (full, s.xla_flags) in seen:
            continue
        seen.add((full, s.xla_flags))
        include.append({
            "kind": "sweep", "section": s.name, "run_args": full,
            "artifact": s.artifact,
            "artifact_name": f"{s.artifact_name or s.name}-nightly",
            "xla_flags": s.xla_flags,
            "name": f"sweep-{s.name}",
        })
    return {"include": include}


def full_enumeration() -> dict:
    axes = {}
    for axis_name, axis in AXES.items():
        entries = {}
        for name, obj in axis.items():
            detail = obj.describe() if hasattr(obj, "describe") else {}
            entries[name] = detail
        axes[axis_name] = {"names": axis.names(), "detail": entries}
    return {
        "schema": SCHEMA,
        "axes": axes,
        "ci": {"smoke": smoke_matrix(), "nightly": nightly_matrix()},
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.registry",
        description="Enumerate, self-check, and smoke the scenario "
                    "registry (see module doc).")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--json", action="store_true",
                      help="full enumeration + CI matrices as JSON")
    mode.add_argument("--ci-matrix", choices=("smoke", "nightly"),
                      help="one CI matrix as single-line JSON")
    mode.add_argument("--selfcheck", action="store_true",
                      help="fail on empty axes / duplicate names / "
                           "import errors")
    mode.add_argument("--smoke", action="store_true",
                      help="selfcheck + one minimal launch per "
                           "registered scenario")
    mode.add_argument("--run-cell", nargs="+",
                      metavar="MEMSYS POLICY ROUTER [FAULT]",
                      help="run one nightly cross-product cell "
                           "(FAULT defaults to 'none')")
    args = ap.parse_args(argv)
    if args.run_cell is not None and len(args.run_cell) not in (3, 4):
        ap.error("--run-cell takes MEMSYS POLICY ROUTER [FAULT]")

    def emit(line: str) -> None:
        print(line)

    if args.json:
        json.dump(full_enumeration(), sys.stdout, indent=2, sort_keys=True)
        print()
        return 0
    if args.ci_matrix:
        matrix = smoke_matrix() if args.ci_matrix == "smoke" \
            else nightly_matrix()
        print(json.dumps(matrix, sort_keys=True))
        return 0

    from repro.registry import smoke as smoke_mod
    if args.selfcheck or args.smoke:
        problems = smoke_mod.selfcheck(emit)
        if args.smoke and not problems:
            problems += smoke_mod.smoke_all(emit)
    else:
        ms, pol, rt = args.run_cell[:3]
        fault = args.run_cell[3] if len(args.run_cell) > 3 else "none"
        problems = smoke_mod.run_cell(ms, pol, rt, emit, fault=fault)
    for p in problems:
        print(f"REGISTRY PROBLEM: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
