"""Preemptive deadline-drop scheduling policy — a drop-in plugin.

``deadline-drop`` is the admission-control variant of the cohort
planner: before planning, every pending request whose *wall-clock*
latency budget has already expired is planned into a leading ``"drop"``
chunk — the scheduler quarantines those members with
``DeadlineExceeded`` instead of spending batch slots computing results
nobody will accept — and the survivors are planned exactly as the
default ``cohort`` policy would plan them.

The budget is ``Request.deadline_us`` interpreted as *microseconds of
wall clock since admission* (the ``arrival_s`` stamp the scheduler
writes at ``submit_request``). Requests with no deadline (``inf``, the
default) or no admission stamp are never dropped, so traffic that
doesn't opt in is planned identically to ``cohort`` — including the
(priority, deadline, ticket) chunk ordering, which still sees the
deadline as its EDF tie-break.

Registered here, the policy resolves everywhere a policy name does
(``Scheduler(policy="deadline-drop")``, ``Fleet(policy=...)``) and
joins the ``registry-smoke`` leg and the nightly scenario cross-product
with no workflow edit.
"""
from __future__ import annotations

import math
import time
from typing import List, Sequence

from repro.registry import SCHEDULERS
from repro.serve.policies import plan_chunks
from repro.serve.scheduler import Chunk


@SCHEDULERS.register("deadline-drop")
def plan_deadline_drop(requests: Sequence, cfg,
                       max_batch: int = 64) -> List[Chunk]:
    """Cohort planning with preemptive expiry (module doc): expired
    requests lead in one ``"drop"`` chunk, survivors get the default
    plan (member indices remapped back into ``requests``)."""
    now = time.monotonic()
    expired, alive = [], []
    for i, r in enumerate(requests):
        if r.deadline_us != math.inf and r.arrival_s is not None \
                and (now - r.arrival_s) * 1e6 > r.deadline_us:
            expired.append(i)
        else:
            alive.append(i)
    chunks: List[Chunk] = []
    if expired:
        chunks.append(Chunk("drop", tuple(expired)))
    for c in plan_chunks([requests[i] for i in alive], cfg, max_batch):
        chunks.append(Chunk(c.kind, tuple(alive[j] for j in c.members)))
    return chunks
