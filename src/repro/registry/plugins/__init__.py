"""Drop-in scenario plugins — the one-file extension point.

Every module in this package is imported (sorted by file name) the first
time any registry axis is queried; a module registers its scenarios with
the axis decorators::

    from repro.registry import TRAFFIC

    @TRAFFIC.register("my-pattern")
    def my_pattern(n, seed=0):
        ...

Nothing else is required: the new name resolves everywhere the axis is
consumed, and ``python -m repro.registry --json`` puts it in the CI
smoke and nightly cross-product matrices automatically (README "Add a
scenario in one file")."""
import sys

from repro.registry.core import scan_package

#: module names discovered in this package, in import order
DISCOVERED = scan_package(sys.modules[__name__])
