"""Heavy-tailed arrival pattern — a drop-in traffic plugin.

The worked example for README "Add a scenario in one file": lognormal
inter-arrival gaps (sigma ~ 1.3) produce occasional very long idle
stretches followed by tight clumps — heavier-tailed than ``poisson``
but, unlike ``bursty``, never exactly simultaneous, so the
continuous-batching scheduler sees ragged partial cohorts instead of
clean full ones. Registered here, it appears in the ``registry-smoke``
CI leg and the nightly scenario cross-product with no workflow edit.
"""
import numpy as np

from repro.registry import TRAFFIC


@TRAFFIC.register("heavy-tail")
def heavy_tail_arrivals(n: int, seed: int = 0,
                        median_gap_s: float = 0.004,
                        sigma: float = 1.3) -> np.ndarray:
    """``n`` arrival times with lognormal inter-arrival gaps (median
    ``median_gap_s``, shape ``sigma``). Deterministic per seed."""
    if median_gap_s <= 0:
        raise ValueError("median_gap_s must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.lognormal(mean=np.log(median_gap_s), sigma=sigma,
                         size=int(n))
    return np.cumsum(gaps)
