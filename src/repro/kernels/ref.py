"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive O(S^2)/sequential implementations — no
blocking, no online softmax — so a kernel bug cannot be masked by shared
structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool, window: int, scale: float):
    """q: (BH, Sq, hd); k, v: (BHkv, Skv, hd) with BH = BHkv * G.
    Naive full-matrix masked softmax attention, f32."""
    bh, sq, hd = q.shape
    bhkv, skv, _ = k.shape
    g = bh // bhkv
    qf = q.reshape(bhkv, g, sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bgqd,bkd->bgqk", qf, kf) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, vf)
    return o.reshape(bh, sq, hd).astype(q.dtype)


def rglru_scan_ref(a, b, h0):
    """Sequential h_t = a_t * h_{t-1} + b_t. a, b: (B, S, D) f32; h0: (B, D).
    Returns (h (B,S,D), h_final)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.moveaxis(a, 1, 0)
    b_t = jnp.moveaxis(b, 1, 0)
    h_f, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1), h_f


def pe_alu_ref(op, a, b, imm):
    """Reference G-GPU PE ALU (one opcode per wavefront row).
    op: (W, 1) int32; a, b: (W, L); imm: (W, 1). Mirrors isa semantics."""
    from repro.ggpu import isa
    sh = jnp.clip(b, 0, 31)
    shi = jnp.clip(imm, 0, 31)
    au = a.astype(jnp.uint32)
    b_safe = jnp.where(b == 0, 1, b)
    a_lo, a_hi = a & 0xFFFF, a >> 16
    b_lo, b_hi = b & 0xFFFF, b >> 16
    t1 = (a_lo * b_lo).astype(jnp.uint32) >> 16
    t2 = a_hi * b_lo + t1.astype(jnp.int32)
    t3 = a_lo * b_hi + (t2 & 0xFFFF)
    mulh = a_hi * b_hi + (t2 >> 16) + (t3 >> 16)
    out = jnp.zeros_like(a)
    table = {
        isa.ADD: a + b, isa.SUB: a - b, isa.MUL: a * b, isa.MULH: mulh,
        isa.DIV: jnp.where(b == 0, 0, a // b_safe),
        isa.REM: jnp.where(b == 0, 0, a % b_safe),
        isa.AND: a & b, isa.OR: a | b, isa.XOR: a ^ b,
        isa.SLL: a << sh,
        isa.SRL: (au >> sh.astype(jnp.uint32)).astype(jnp.int32),
        isa.SRA: a >> sh,
        isa.SLT: (a < b).astype(jnp.int32),
        isa.ADDI: a + imm, isa.ANDI: a & imm, isa.ORI: a | imm,
        isa.XORI: a ^ imm, isa.SLLI: a << shi,
        isa.SRLI: (au >> shi.astype(jnp.uint32)).astype(jnp.int32),
        isa.SRAI: a >> shi, isa.SLTI: (a < imm).astype(jnp.int32),
        isa.LUI: jnp.broadcast_to(imm << 12, a.shape),
    }
    for code, val in table.items():
        out = jnp.where(op == code, val, out)
    return out
