"""Flash attention Pallas TPU kernel (blocked online softmax).

TPU adaptation of the memory-division insight: the (Sq x Skv) score matrix
is the "monolithic memory" — it never touches HBM. The grid tiles
(batch*head, q block, kv block); q/k/v tiles stream HBM->VMEM via
BlockSpecs, scores/softmax state live in VMEM scratch, and the MXU sees
(block_q x hd) @ (hd x block_k) matmuls with 128-aligned tiles.

Supports causal, sliding-window and bidirectional masking, and GQA (the
kv BlockSpec index map folds the query-head group onto its kv head).

Grid semantics: ("parallel", "parallel", "arbitrary") — the kv dimension is
innermost and sequential, so the scratch accumulators carry across kv steps
(standard TPU flash pattern).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, nk: int, sq: int, skv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    # skip fully-masked blocks (the causal-waste fix vs the jnp path)
    need = kpos[0, 0] < skv
    if causal:
        need &= (ki * block_k) <= (qi * block_q + block_q - 1)
    if window > 0:
        need &= (ki * block_k + block_k) > (qi * block_q - window)

    @pl.when(need)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                   # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (kpos < skv) & (qpos < sq)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = 0.0, block_q: int = 128,
                    block_k: int = 512, interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BHkv, Skv, hd), BH = BHkv * G.
    Returns (BH, Sq, hd) in q's dtype. Sq/Skv are padded to block multiples
    internally; hd should be 128-aligned for MXU efficiency (any hd works
    functionally)."""
    bh, sq, hd = q.shape
    bhkv, skv, _ = k.shape
    g = bh // bhkv
    scale = scale or (1.0 / math.sqrt(hd))
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 8))
    pq = (-sq) % bq
    pk = (-skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = (sq + pq) // bq
    nk = (skv + pk) // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, nk=nk, sq=sq, skv=skv)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g_=g: (b // g_, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g_=g: (b // g_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
