"""G-GPU PE execute stage as a Pallas TPU kernel.

The CU's 8 Processing Elements executing one instruction per wavefront is a
classic SIMD select-tree: every lane computes all candidate ALU results and
the per-wavefront opcode selects one. On TPU this maps onto the VPU: lanes
tile the (wavefront, lane) plane in VMEM blocks; the opcode/immediate
stream sits in SMEM-like narrow blocks. This is the hot inner loop of the
cycle simulator (`repro.ggpu.machine.exec_alu` is the jnp twin used on CPU
and as the oracle).

Integer division (the paper's weak spot) is implemented as a bounded
Newton/long-division loop to stay VPU-friendly — mirroring FGPU's
soft-divide microkernel, see ISA cost table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.ggpu import isa


def _mulh32(a, b):
    """Signed 32x32 -> high 32 bits with pure int32 ops (no int64 needed).
    Standard decomposition a = a_hi*2^16 + a_lo (a_lo unsigned); all
    partial products fit int32."""
    a_lo = a & 0xFFFF
    a_hi = a >> 16                      # arithmetic
    b_lo = b & 0xFFFF
    b_hi = b >> 16
    t1 = (a_lo * b_lo).astype(jnp.uint32) >> 16
    t2 = a_hi * b_lo + t1.astype(jnp.int32)
    t3 = a_lo * b_hi + (t2 & 0xFFFF)
    return a_hi * b_hi + (t2 >> 16) + (t3 >> 16)


def _pe_kernel(op_ref, imm_ref, a_ref, b_ref, out_ref):
    op = op_ref[...]                                       # (bw, 1) int32
    imm = imm_ref[...]
    a = a_ref[...]                                         # (bw, L) int32
    b = b_ref[...]
    sh = jnp.clip(b, 0, 31)
    shi = jnp.clip(imm, 0, 31)
    au = a.astype(jnp.uint32)
    b_safe = jnp.where(b == 0, 1, b)
    cases = [
        (isa.ADD, a + b), (isa.SUB, a - b), (isa.MUL, a * b),
        (isa.MULH, _mulh32(a, b)),
        (isa.DIV, jnp.where(b == 0, 0, a // b_safe)),
        (isa.REM, jnp.where(b == 0, 0, a % b_safe)),
        (isa.AND, a & b), (isa.OR, a | b), (isa.XOR, a ^ b),
        (isa.SLL, a << sh),
        (isa.SRL, (au >> sh.astype(jnp.uint32)).astype(jnp.int32)),
        (isa.SRA, a >> sh),
        (isa.SLT, (a < b).astype(jnp.int32)),
        (isa.ADDI, a + imm), (isa.ANDI, a & imm), (isa.ORI, a | imm),
        (isa.XORI, a ^ imm), (isa.SLLI, a << shi),
        (isa.SRLI, (au >> shi.astype(jnp.uint32)).astype(jnp.int32)),
        (isa.SRAI, a >> shi), (isa.SLTI, (a < imm).astype(jnp.int32)),
        (isa.LUI, jnp.broadcast_to(imm << 12, a.shape)),
    ]
    out = jnp.zeros_like(a)
    for code, val in cases:
        out = jnp.where(op == code, val, out)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def pe_execute(op, imm, a, b, *, block_w: int = 8, interpret: bool = True):
    """op, imm: (W, 1) int32; a, b: (W, L) int32 -> (W, L) results.
    Grid tiles wavefronts; a block of 8 wavefronts x 64 lanes = one CU's
    PE array across 8 issue beats."""
    w, l = a.shape
    bw = min(block_w, w)
    pad = (-w) % bw
    if pad:
        op = jnp.pad(op, ((0, pad), (0, 0)))
        imm = jnp.pad(imm, ((0, pad), (0, 0)))
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    wp = w + pad
    out = pl.pallas_call(
        _pe_kernel,
        grid=(wp // bw,),
        in_specs=[
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
            pl.BlockSpec((bw, l), lambda i: (i, 0)),
            pl.BlockSpec((bw, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bw, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, l), jnp.int32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(op, imm, a, b)
    return out[:w]
