"""G-GPU PE execute stage as a Pallas TPU kernel.

The CU's 8 Processing Elements executing one instruction per wavefront is a
classic SIMD select-tree: every lane computes all candidate ALU results and
the per-wavefront opcode selects one. On TPU this maps onto the VPU: lanes
tile the (wavefront, lane) plane in VMEM blocks; the opcode/immediate
stream sits in SMEM-like narrow blocks. This is the hot inner loop of the
cycle simulator (`repro.ggpu.engine.alu.select_alu` is the shared datapath:
the same case table traces here inside the Pallas kernel and inside the
engine's `lax.while_loop` stepper, so the two can never drift).

Integer division (the paper's weak spot) is implemented as a bounded
Newton/long-division loop to stay VPU-friendly — mirroring FGPU's
soft-divide microkernel, see ISA cost table.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS

from repro.ggpu.engine.alu import select_alu


def _pe_kernel(op_ref, imm_ref, a_ref, b_ref, out_ref):
    op = op_ref[...]                                       # (bw, 1) int32
    imm = imm_ref[...]
    a = a_ref[...]                                         # (bw, L) int32
    b = b_ref[...]
    out_ref[...] = select_alu(op, a, b, imm)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def pe_execute(op, imm, a, b, *, block_w: int = 8, interpret: bool = True):
    """op, imm: (W, 1) int32; a, b: (W, L) int32 -> (W, L) results.
    Grid tiles wavefronts; a block of 8 wavefronts x 64 lanes = one CU's
    PE array across 8 issue beats."""
    w, l = a.shape
    bw = min(block_w, w)
    pad = (-w) % bw
    if pad:
        op = jnp.pad(op, ((0, pad), (0, 0)))
        imm = jnp.pad(imm, ((0, pad), (0, 0)))
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    wp = w + pad
    out = pl.pallas_call(
        _pe_kernel,
        grid=(wp // bw,),
        in_specs=[
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
            pl.BlockSpec((bw, 1), lambda i: (i, 0)),
            pl.BlockSpec((bw, l), lambda i: (i, 0)),
            pl.BlockSpec((bw, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bw, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, l), jnp.int32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(op, imm, a, b)
    return out[:w]
