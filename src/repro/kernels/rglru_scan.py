"""RG-LRU diagonal linear recurrence, blocked Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, elementwise over the channel dim. The TPU
adaptation: channels tile across the grid (VPU lanes, 128-aligned blocks);
the sequence dim is walked in VMEM-resident chunks inside the kernel with
the carried state h in scratch — HBM traffic is exactly one read of (a, b)
and one write of h (the associative-scan jnp path re-materializes
log-depth intermediates instead).

Grid: (B, D/block_d) parallel; S is looped inside the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import COMPILER_PARAMS as _COMPILER_PARAMS


def _rglru_kernel(a_ref, b_ref, h0_ref, h_ref, hf_ref, carry, *, seq: int,
                  chunk: int):
    carry[...] = h0_ref[...].astype(jnp.float32)           # (1, bd)
    n = seq // chunk

    def step(i, _):
        h = carry[...]
        a = a_ref[0, pl.dslice(i * chunk, chunk), :].astype(jnp.float32)
        b = b_ref[0, pl.dslice(i * chunk, chunk), :].astype(jnp.float32)

        def inner(t, hh):
            hh = a[t][None, :] * hh + b[t][None, :]
            h_ref[0, i * chunk + t, :] = hh[0].astype(h_ref.dtype)
            return hh
        h = jax.lax.fori_loop(0, chunk, inner, h)
        carry[...] = h
        return 0

    jax.lax.fori_loop(0, n, step, 0)
    hf_ref[...] = carry[...].astype(hf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def rglru_scan(a, b, h0, *, block_d: int = 128, chunk: int = 128,
               interpret: bool = True):
    """a, b: (B, S, D) f32; h0: (B, D) f32 -> (h (B,S,D), h_final (B,D))."""
    bsz, seq, d = a.shape
    bd = min(block_d, d)
    pad_d = (-d) % bd
    if pad_d:
        pw = ((0, 0), (0, 0), (0, pad_d))
        a = jnp.pad(a, pw)
        b = jnp.pad(b, pw)
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d)))
    ck = min(chunk, seq)
    pad_s = (-seq) % ck
    if pad_s:
        # padded steps: a=1 (keep state), b=0 (no input)
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    sp = seq + pad_s
    dp = d + pad_d

    kernel = functools.partial(_rglru_kernel, seq=sp, chunk=ck)
    h, hf = pl.pallas_call(
        kernel,
        grid=(bsz, dp // bd),
        in_specs=[
            pl.BlockSpec((1, sp, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, sp, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, sp, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, sp, dp), a.dtype),
            jax.ShapeDtypeStruct((bsz, dp), a.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a, b, h0)
    return h[:, :seq, :d], hf[:, :d]
