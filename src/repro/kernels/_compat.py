"""Version compatibility for the Pallas TPU toolchain.

jax renamed ``TPUCompilerParams`` -> ``CompilerParams`` in newer releases;
every kernel module imports the resolved class from here so the pin can
move in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
