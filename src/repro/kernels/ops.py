"""Jitted public wrappers for the Pallas kernels.

Model code calls these; each dispatches to the Pallas kernel (interpret
mode on CPU, compiled on TPU) and handles the model-side layout
((B, S, H, hd) <-> the kernels' (BH, S, hd) folding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import pe_simd as _pe
from repro.kernels import rglru_scan as _rg

_INTERPRET = jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float = 0.0):
    """q: (B, S, H, hd); k, v: (B, Skv, Hkv, hd) -> (B, S, H, hd)."""
    bsz, sq, h, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    # fold (B, Hkv, G) so consecutive q heads share a kv head block
    qf = (q.transpose(0, 2, 1, 3)
           .reshape(bsz, hkv, g, sq, hd)
           .reshape(bsz * hkv * g, sq, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(bsz * hkv, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(bsz * hkv, skv, hd)
    o = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                            scale=scale, interpret=_INTERPRET)
    return (o.reshape(bsz, hkv * g, sq, hd).transpose(0, 2, 1, 3))


def rglru_scan(a, b, h0):
    """(B, S, D) recurrence; see rglru_scan.py."""
    return _rg.rglru_scan(a, b, h0, interpret=_INTERPRET)


def pe_execute(op, imm, a, b):
    return _pe.pe_execute(op, imm, a, b, interpret=_INTERPRET)
