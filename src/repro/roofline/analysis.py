"""Three-term roofline from a compiled SPMD executable.

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = ring-model ICI bytes per device / ICI_BW

``cost_analysis()`` FLOPs/bytes are per-device post-partitioning (verified
against analytic counts). Collective bytes are parsed from the
post-optimization HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the *result* buffer
bytes per device and apply the standard ring cost along its replica group
(all-reduce 2(n-1)/n on the full value, all-gather (n-1)/n of the gathered
value, reduce-scatter (n-1)/n of the reduced value, all-to-all (n-1)/n,
permute 1x). TPU v5e constants; override for other targets.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, Optional

# --- TPU v5e (per chip) -----------------------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (assume 1 active link direction)
HBM_PER_CHIP = 16 * 1024**3  # 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2  # permutes etc.: conservative


@dataclasses.dataclass
class CollectiveStats:
    ring_bytes: float = 0.0            # per-device ICI bytes (ring model)
    raw_bytes: float = 0.0             # sum of result buffer bytes
    counts: Counter = dataclasses.field(default_factory=Counter)
    by_kind_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":               # counted at -start
            continue
        n = _group_size(line)
        if n <= 1:
            continue
        b = _shape_bytes(shape_txt)
        if kind == "all-reduce":
            ring = 2.0 * (n - 1) / n * b
        elif kind in ("all-gather", "all-to-all"):
            ring = (n - 1) / n * b         # result = full value
        elif kind == "reduce-scatter":
            ring = (n - 1) * b             # result = 1/n of reduced value
        else:                              # collective-permute
            ring = float(b)
        st.ring_bytes += ring
        st.raw_bytes += b
        st.counts[kind] += 1
        st.by_kind_bytes[kind] = st.by_kind_bytes.get(kind, 0.0) + ring
    return st


@dataclasses.dataclass
class Roofline:
    flops: float                        # per device
    bytes_hbm: float                    # per device
    collectives: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0
    model_flops: float = 0.0            # 6*N*D style, per device
    useful_ratio: float = 0.0           # model_flops / hlo flops
    raw_flops: float = 0.0              # builtin cost_analysis (scan-undercounted)
    raw_bytes: float = 0.0

    def asdict(self):
        d = dataclasses.asdict(self)
        d["collectives"] = {
            "ring_bytes": self.collectives.ring_bytes,
            "raw_bytes": self.collectives.raw_bytes,
            "counts": dict(self.collectives.counts),
            "by_kind_bytes": self.collectives.by_kind_bytes,
        }
        return d


def analyze(compiled, *, model_flops_total: float = 0.0,
            n_devices: int = 1) -> Roofline:
    """Trip-count-aware roofline. ``cost_analysis()`` counts while bodies
    once (scan under-reporting), so FLOPs/bytes/collectives come from the
    HLO-text walker in ``hlo_parse``; the builtin numbers are kept in
    ``raw_*`` fields for comparison."""
    from repro.roofline.hlo_parse import HloCost
    text = compiled.as_text()
    cost = HloCost(text).entry_cost()
    flops, bts = cost.flops, cost.bytes
    coll = CollectiveStats(
        ring_bytes=cost.coll_ring, raw_bytes=cost.coll_ring,
        counts=cost.coll_counts, by_kind_bytes=cost.coll_bytes_by_kind)
    ca = compiled.cost_analysis() or {}
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    coll_s = coll.ring_bytes / ICI_BW
    bound = max((("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)), key=lambda kv: kv[1])[0]
    ma = compiled.memory_analysis()
    mf_dev = model_flops_total / max(n_devices, 1)
    return Roofline(
        flops=flops, bytes_hbm=bts, collectives=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bound=bound,
        arg_bytes=getattr(ma, "argument_size_in_bytes", 0),
        out_bytes=getattr(ma, "output_size_in_bytes", 0),
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        model_flops=mf_dev,
        useful_ratio=(mf_dev / flops) if flops else 0.0,
        raw_flops=float(ca.get("flops", 0.0)),
        raw_bytes=float(ca.get("bytes accessed", 0.0)),
    )


def model_flops_estimate(cfg, shape) -> float:
    """Total step MODEL_FLOPS: 6*N_active*D for train, 2*N_active*B for
    decode (one token/seq), 2*N_active*D for prefill."""
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        d_tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * d_tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch          # decode: one token each
