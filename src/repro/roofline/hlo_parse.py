"""Trip-count-aware cost analysis parsed from post-optimization HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
regardless of trip count (verified empirically — a scan of 10 matmuls
reports the FLOPs of one). Every model here scans its layer stack, so the
built-in numbers under-report by ~n_layers x. XLA writes the trip count
into the while op's ``backend_config={"known_trip_count":{"n":...}}``, so we
walk the computation graph ourselves and multiply.

Accounting model (documented in EXPERIMENTS.md):
  * FLOPs   — ``dot`` ops only: 2 * prod(result_dims) * prod(contract_dims).
              Elementwise/reduce FLOPs are ignored (same convention as the
              6*N*D MODEL_FLOPS yardstick).
  * bytes   — per top-level op in each computation: result bytes + resolved
              operand bytes (≈ XLA's "bytes accessed" fusion-boundary
              model). Ops inside fusion bodies don't touch HBM and are
              excluded; parameter/tuple/gte/bitcast/constant cost nothing.
  * colls   — ring-model ICI bytes (see analysis.py), multiplied through
              enclosing loops like everything else.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OP_LINE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[": {]+n[": ]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\))?[^()]*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_NO_TRAFFIC = {"parameter", "tuple", "get-tuple-element", "bitcast", "constant",
               "while", "conditional", "call", "after-all", "partition-id",
               "replica-id", "iota"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_ring: float = 0.0
    coll_counts: Counter = dataclasses.field(default_factory=Counter)
    coll_bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "OpCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_ring += o.coll_ring
        self.coll_counts.update(o.coll_counts)
        for k, v in o.coll_bytes_by_kind.items():
            self.coll_bytes_by_kind[k] = self.coll_bytes_by_kind.get(k, 0) + v
        return self

    def scaled(self, m: float) -> "OpCost":
        return OpCost(self.flops * m, self.bytes * m, self.coll_ring * m,
                      Counter({k: v * int(m) for k, v in self.coll_counts.items()}),
                      {k: v * m for k, v in self.coll_bytes_by_kind.items()})


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_txt: str
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    ops: List[_Op]
    defs: Dict[str, str]                 # op name -> result type text


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def split_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry_name = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_START.match(line)
            if m:
                cur = _Comp(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry_name = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            name, rtxt, kind = m.group(1), m.group(2), m.group(3)
            cur.ops.append(_Op(name, kind, rtxt, line.strip()))
            cur.defs[name] = rtxt
    if cur is not None:
        comps[cur.name] = cur
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _dot_flops(op: _Op) -> float:
    res = 1
    for d in _shape_dims(op.result_txt):
        res *= d
    m = _CONTRACT_RE.search(op.line)
    contract = 1
    if m and m.group(1):
        # operand shapes: first two shapes inside dot(...) are %refs without
        # inline types post-opt; contraction dims resolved via defs later.
        pass
    return 2.0 * res  # multiplied by contract size by caller (needs defs)


def _operands(op: _Op) -> List[str]:
    # take text inside the op's call parens: kind(...)
    i = op.line.find(op.kind + "(")
    if i < 0:
        return []
    depth = 0
    args = []
    buf = ""
    for ch in op.line[i + len(op.kind):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            args.append(buf.strip())
            buf = ""
        else:
            buf += ch
    if buf.strip():
        args.append(buf.strip())
    return [a.lstrip("%") for a in args if a.strip().startswith("%")]


class HloCost:
    def __init__(self, text: str):
        self.comps = split_computations(text)
        self.fused: set = set()
        self.trip: Dict[str, int] = {}    # while op name -> trip count
        for comp in self.comps.values():
            for op in comp.ops:
                if op.kind == "fusion":
                    m = _CALLS_RE.search(op.line)
                    if m:
                        self.fused.add(m.group(1))
        self._memo: Dict[str, OpCost] = {}

    def _resolve_bytes(self, comp: _Comp, names: List[str]) -> float:
        total = 0.0
        for n in names:
            t = comp.defs.get(n)
            if t:
                total += _shape_list_bytes(t)
        return total

    def _fusion_bytes(self, comp: _Comp, op: _Op, body: _Comp) -> float:
        """Bytes-accessed for a fusion call site, slice-aware: an operand
        consumed only via dynamic-slice/gather inside the body is charged
        the sliced bytes, not the whole buffer (the stacked-layer params of
        a scanned stack would otherwise be charged n_layers^2 x). A
        dynamic-update-slice root writes only the update region."""
        # consumers of each body parameter
        param_name_by_idx: Dict[int, str] = {}
        for o in body.ops:
            if o.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.line)
                if m:
                    param_name_by_idx[int(m.group(1))] = o.name
        consumers: Dict[str, List[_Op]] = {}
        for o in body.ops:
            for a in _operands(o):
                consumers.setdefault(a, []).append(o)

        operand_names = _operands(op)
        total = 0.0
        for i, n in enumerate(operand_names):
            full = _shape_list_bytes(comp.defs.get(n, ""))
            pname = param_name_by_idx.get(i)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(c.kind in ("dynamic-slice", "gather") for c in cons):
                total += sum(_shape_list_bytes(c.result_txt) for c in cons)
            else:
                total += full
        # result side
        root = body.ops[-1] if body.ops else None
        for o in body.ops:
            if "ROOT" in o.line or o.name == "root":
                root = o
        if root is not None and root.kind == "dynamic-update-slice":
            ops_r = _operands(root)
            upd = _shape_list_bytes(body.defs.get(ops_r[1], "")) if len(ops_r) > 1 else 0
            total += upd or _shape_list_bytes(op.result_txt)
        else:
            total += _shape_list_bytes(op.result_txt)
        return total

    def comp_cost(self, name: str) -> OpCost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = OpCost()       # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = OpCost()
        in_fusion_body = name in self.fused
        for op in comp.ops:
            k = op.kind
            if k == "while":
                m = _TRIP_RE.search(op.line)
                trips = int(m.group(1)) if m else 1
                cb = _COND_BODY_RE.search(op.line)
                if cb:
                    sub = OpCost()
                    sub += self.comp_cost(cb.group(2))
                    sub += self.comp_cost(cb.group(1))
                    total += sub.scaled(trips)
                continue
            if k in ("call", "fusion"):
                m = _CALLS_RE.search(op.line)
                if m:
                    total += self.comp_cost(m.group(1))
                if k == "fusion" and not in_fusion_body:
                    body = self.comps.get(m.group(1)) if m else None
                    if body is not None:
                        total += OpCost(bytes=self._fusion_bytes(comp, op, body))
                    else:
                        total += OpCost(bytes=_shape_list_bytes(op.result_txt)
                                        + self._resolve_bytes(comp, _operands(op)))
                continue
            if k in ("dynamic-slice", "gather") and not in_fusion_body:
                total += OpCost(bytes=2.0 * _shape_list_bytes(op.result_txt))
                continue
            if k in ("dynamic-update-slice", "scatter") and not in_fusion_body:
                ops_list = _operands(op)
                upd_idx = 1 if k == "dynamic-update-slice" else 2
                upd = (_shape_list_bytes(comp.defs.get(ops_list[upd_idx], ""))
                       if len(ops_list) > upd_idx else 0)
                total += OpCost(bytes=2.0 * upd)
                continue
            if k == "dot":
                res = 1
                for d in _shape_dims(op.result_txt):
                    res *= d
                contract = 1
                m = _CONTRACT_RE.search(op.line)
                ops_list = _operands(op)
                if m and ops_list:
                    lhs_t = comp.defs.get(ops_list[0], "")
                    dims = _shape_dims(lhs_t)
                    if m.group(1):
                        for ci in m.group(1).split(","):
                            ci = int(ci)
                            if ci < len(dims):
                                contract *= dims[ci]
                total += OpCost(flops=2.0 * res * contract)
            if k.startswith(_COLLECTIVES):
                base = k.replace("-start", "").replace("-done", "")
                if k.endswith("-done"):
                    continue
                n = _group_size(op.line)
                if n > 1:
                    b = _shape_list_bytes(op.result_txt)
                    if base == "all-reduce":
                        ring = 2.0 * (n - 1) / n * b
                    elif base in ("all-gather", "all-to-all"):
                        ring = (n - 1) / n * b
                    elif base == "reduce-scatter":
                        ring = (n - 1.0) * b
                    else:
                        ring = float(b)
                    c = OpCost(coll_ring=ring)
                    c.coll_counts[base] += 1
                    c.coll_bytes_by_kind[base] = ring
                    total += c
            if not in_fusion_body and k not in _NO_TRAFFIC:
                total += OpCost(bytes=_shape_list_bytes(op.result_txt)
                                + self._resolve_bytes(comp, _operands(op)))
        self._memo[name] = total
        return total

    def entry_cost(self) -> OpCost:
        return self.comp_cost("__entry__")


def analyze_text(text: str) -> OpCost:
    return HloCost(text).entry_cost()
