"""LLM serving engine: prefill + decode with slot-based continuous
batching, expressed on the serving core's slot accounting.

A fixed decode batch of ``slots``; finished sequences free their slot and
the next queued request is prefilled into it (its KV written into the
shared cache at the slot's batch row). Greedy or temperature sampling.
This is the serve-side driver the decode dry-run cells lower. Admission
uses the same FIFO slot-wave planner (``scheduler.plan_waves``) the kernel
scheduler exposes, and results return in ticket (submission) order — the
same contract as the kernel path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.steps import make_decode_step
from repro.serve.scheduler import plan_waves


@dataclasses.dataclass
class EngineConfig:
    max_len: int = 256
    slots: int = 4
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.decode_fn = jax.jit(make_decode_step(cfg))

    def _sample(self, logits, rng):
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.ecfg.temperature,
                                      axis=-1)

    def generate(self, prompts: List[List[int]], max_new: int
                 ) -> List[List[int]]:
        """Slot-batched generation. Prompts are queued; each batch wave
        prefills up to ``slots`` prompts padded to a common length."""
        ecfg = self.ecfg
        results: List[Optional[List[int]]] = [None] * len(prompts)
        rng = jax.random.PRNGKey(ecfg.seed)
        for wave in plan_waves(range(len(prompts)), ecfg.slots):
            plen = max(len(prompts[i]) for i in wave)
            batch = np.zeros((len(wave), plen), np.int32)
            for r, i in enumerate(wave):
                batch[r, plen - len(prompts[i]):] = prompts[i]  # left-pad
            cap = plen + max_new + 1
            logits, cache = M.prefill(self.params, self.cfg,
                                      tokens=jnp.asarray(batch), pad_to=cap)
            toks = [list(prompts[i]) for i in wave]
            last = self._sample(logits, rng)
            done = np.zeros(len(wave), bool)
            for r in range(len(wave)):
                tok = int(last[r])
                toks[r].append(tok)
                if tok == ecfg.eos_id:
                    done[r] = True       # EOS straight out of prefill
            for t in range(max_new - 1):
                if done.all():
                    break
                rng, sub = jax.random.split(rng)
                logits, cache = self.decode_fn(
                    self.params, cache, last[:, None],
                    jnp.asarray(plen + t, jnp.int32))
                last = self._sample(logits, sub)
                for r in range(len(wave)):
                    if not done[r]:
                        tok = int(last[r])
                        toks[r].append(tok)
                        if tok == ecfg.eos_id:
                            done[r] = True
            for r, i in enumerate(wave):
                results[i] = toks[r]
        return results  # type: ignore
