"""Fleet routing strategies — the ``ROUTERS`` registry axis built-ins.

A router is a *class*; ``Fleet(router="name")`` resolves the name
through the registry and instantiates one router per fleet (routers may
carry state — ``round-robin`` does). The instance contract is::

    pick(fleet, req) -> FleetDevice

called for every dependency-free submission (requests with ``deps`` are
always pinned to their producers' device, regardless of router — device
residency of graph edges is a correctness property, not a policy).
Routers read the fleet's public estimate surface (``finish_us``,
``estimate_us``, ``routable_devices``) and must not mutate fleet state:
the fleet itself charges the backlog after the pick. Under a
:class:`~repro.serve.fleet.FleetResilience` policy ``routable_devices``
excludes evicted devices (and probation devices out of admission
budget), so every router heals around a retired device for free.

Built-ins:

  * ``earliest-finish`` — the default greedy placement: minimize
    (shard-width-discounted backlog + estimated service time); the
    pre-registry behavior, placement-exact.
  * ``round-robin`` — cycle through devices in order, ignoring load and
    service estimates. The baseline that shows what the learned
    estimates buy; also the fairness floor when estimates are known to
    be garbage (e.g. adversarial traffic of never-seen kernels).
"""
from __future__ import annotations

from repro.registry import ROUTERS


@ROUTERS.register("earliest-finish")
class EarliestFinishRouter:
    """Greedy earliest-finish-time placement (see module doc)."""

    def pick(self, fleet, req):
        return min(fleet.routable_devices(),
                   key=lambda d: fleet.finish_us(d, req))


@ROUTERS.register("round-robin")
class RoundRobinRouter:
    """Stateful cyclic placement, blind to load and estimates."""

    def __init__(self):
        self._next = 0

    def pick(self, fleet, req):
        devices = fleet.routable_devices()
        dev = devices[self._next % len(devices)]
        self._next += 1
        return dev
