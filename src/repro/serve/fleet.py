"""Fleet router: serve one launch stream across *multiple* G-GPU configs.

This is the layer that connects the DSE output to the serving path: the
Pareto front ``repro.dse.search`` emits is a set of complementary designs
(e.g. a small high-clock 1-CU part and a wide derated 8-CU part), and a
mixed traffic trace is served fastest by placing each launch on the device
that finishes it earliest — small single-wavefront launches on the fast
small part, wide launches on the wide one.

Placement is greedy earliest-finish-time: for each request the router
estimates its service time on every device — from the learned per-kernel
cycle model once the device has served that kernel, from an analytic
occupancy proxy (wavefront rounds / CU parallelism, scaled by clock) on a
cold start — and picks the device minimizing (modeled queue backlog +
estimated service time), with both discounted by the device's physical
shard width: a sub-mesh-bound device dispatches same-shape launches
``shards`` abreast, so for a stream of launches (a large cohort) the wide
device finishes earlier and wins placement even when its per-launch
estimate ties. Modeled wall-clock of a fleet is the makespan:
the max over devices of the sum of served launch times (devices run in
parallel); ``pinned_makespan`` prices the whole trace on one config for
comparison. ``benchmarks/serve_bench.py`` records the routed-vs-pinned
comparison in ``BENCH_serve.json``.

**Kernel graphs.** A request carrying ``deps`` is not routed freely: its
producers' device-resident outputs feed it with no host hop, so it must
land on the device already holding every producer. The router looks the
producers up in its placement map, requires them to agree on one device
(a graph that spans devices would need a host round-trip — submit it to
one device or don't use deps), and translates the fleet-level producer
tickets into that device scheduler's local tickets before handing the
request down. The learned service-time model keys on *(kernel,
schedule)* — ``Request.schedule`` carries the lowering-schedule label —
because a tuned and a default lowering of one kernel are different
programs with different true cycle counts; folding them under one key
would let a fast tuned variant mask a slow default one (or vice versa)
and skew every later placement of either.

**Physical placement.** Passing a ``mesh`` (``make_launch_mesh``) binds
each simulated device to a contiguous slice of the mesh's physical JAX
devices: a slice of one pins that scheduler's dispatches to that device
(``Executor.device``), a wider slice becomes a sub-mesh so the
scheduler's chunks shard their launch axis across it
(``Executor.mesh``). Slices are proportional to remaining devices (every
simulated device gets at least one physical device while supply lasts;
with fewer physical than simulated devices the remainder runs unplaced
on the default device). Bit-exactness is unchanged — placement moves *where*
arrays live, never the traced computation.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ggpu.engine import GGPUConfig, KernelLaunchError
from repro.serve.executors import Executor
from repro.serve.request import Request, Result
from repro.serve.scheduler import (Quarantined, RetryPolicy, Scheduler,
                                   wavefronts)


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Deadline-aware hedged dispatch: once a dispatched chunk has been
    in flight longer than ``after_s`` wall-clock seconds, each of its
    dependency-free members is *duplicated* onto the healthiest idle
    routable device. First result wins the fleet ticket; the loser's
    result (or its eventual quarantine) is discarded at collect. At most
    one hedge per fleet ticket."""
    after_s: float = 0.05


@dataclasses.dataclass(frozen=True)
class FleetResilience:
    """Self-healing fleet policy (DESIGN.md §Fault injection &
    self-healing fleet). ``evict_after`` consecutive device faults
    (``DeviceTimeout``/``ChecksumError`` quarantines, i.e. failures blamed
    on the *device*, not the program) evict a device: its dependency-free
    backlog is re-routed to the survivors, everything else is quarantined,
    and the mesh effectively shrinks. After ``probation_after`` further
    drains the device is re-admitted **on probation** — routable for at
    most ``probation_budget`` requests — and promoted back to active after
    a clean drain, or re-evicted on its first new fault. ``hedge``
    optionally enables straggler hedging (:class:`HedgePolicy`)."""
    evict_after: int = 3
    probation_after: int = 2
    probation_budget: int = 4
    hedge: Optional[HedgePolicy] = None


@dataclasses.dataclass
class FleetDevice:
    """One config in the fleet, with its scheduler and load accounting.
    ``mesh``/``device`` record the physical binding (either or neither).
    The health fields move only under a :class:`FleetResilience` policy:
    ``state`` walks active -> evicted -> probation -> active, ``faults``
    counts device-blamed quarantines, ``served`` successful results."""
    name: str
    cfg: GGPUConfig
    scheduler: Scheduler
    eta_us: float = 0.0        # modeled backlog the router sees (estimates)
    busy_us: float = 0.0       # actual modeled service time after drain
    mesh: object = None        # sub-mesh when bound to >1 physical device
    device: object = None      # pinned jax.Device when bound to exactly 1
    state: str = "active"      # active | evicted | probation
    served: int = 0            # successful results (health numerator)
    faults: int = 0            # device-blamed quarantines (lifetime)
    consecutive_faults: int = 0  # reset by any successful result
    evicted_at: int = -1       # fleet drain counter at eviction
    probation_left: int = 0    # admission budget while on probation

    @property
    def health(self) -> float:
        """Smoothed success fraction in (0, 1]: ``(1 + served) /
        (1 + served + 4 * faults)`` — the +1 prior keeps a cold device
        routable, the 4x fault weight makes one fault cost four serves
        to win back (hedging and re-routing prefer high-health
        devices)."""
        return (1.0 + self.served) / (1.0 + self.served + 4.0 * self.faults)


def _mesh_slices(mesh, n: int) -> List[list]:
    """Partition a launch mesh's devices into ``n`` contiguous slices,
    proportionally (largest first). Empty slices mean the fleet outnumbers
    the physical devices; those simulated devices stay unplaced."""
    devices = list(np.ravel(mesh.devices))
    out, lo = [], 0
    for i in range(n):
        take = -((len(devices) - lo) // -(n - i))   # ceil of remaining/n
        out.append(devices[lo:lo + take])
        lo += take
    return out


class Fleet:
    """Routes submissions across devices; drains every device's scheduler.

    ``configs`` may be raw ``GGPUConfig``s or (name, config) pairs —
    e.g. ``[(p.label(), p.config) for p in search_result.frontier]``.
    ``mesh`` binds simulated devices to physical ones (see module doc).
    ``router`` picks the placement strategy by registered name (the
    ``ROUTERS`` registry axis; ``"earliest-finish"`` is the legacy
    greedy placement, see ``repro.serve.routing``) or as a router
    instance/class with a ``pick(fleet, req)`` method. ``policy`` is
    forwarded to every device scheduler (``SCHEDULERS`` axis).
    """

    def __init__(self, configs: Sequence, max_batch: int = 64, *,
                 mesh=None, router="earliest-finish", policy="cohort",
                 resilience: Optional[FleetResilience] = None,
                 retry: Optional[RetryPolicy] = None,
                 timeout_s: Optional[float] = None,
                 executor_wrap: Optional[Callable] = None):
        configs = list(configs)
        slices = _mesh_slices(mesh, len(configs)) if mesh is not None \
            else [[] for _ in configs]
        self.devices: List[FleetDevice] = []
        for i, c in enumerate(configs):
            name, cfg = c if isinstance(c, tuple) else (f"dev{i}", c)
            sub_mesh = sub_dev = None
            if len(slices[i]) > 1:
                import jax
                sub_mesh = jax.sharding.Mesh(np.asarray(slices[i]),
                                             ("data",))
            elif len(slices[i]) == 1:
                sub_dev = slices[i][0]
            # the scheduler's private executor is built here (identical
            # to what Scheduler(cfg, ...) would build) so a caller's
            # ``executor_wrap(name, executor)`` hook — e.g. a
            # ``repro.faults.FaultInjector`` — can interpose per device
            ex = Executor(cfg, mesh=sub_mesh, device=sub_dev,
                          timeout_s=timeout_s)
            if executor_wrap is not None:
                ex = executor_wrap(name, ex) or ex
            self.devices.append(FleetDevice(
                name, cfg,
                Scheduler(executor=ex, max_batch=max_batch, policy=policy,
                          retry=retry),
                mesh=sub_mesh, device=sub_dev))
        if len(self.devices) < 1:
            raise ValueError("fleet needs at least one device")
        self.resilience = resilience
        self._drains = 0                 # drain calls (probation clock)
        self._served_tickets: set = set()   # fleet tickets with a result
        self._hedged: set = set()           # fleet tickets hedged once
        self._reroutes: Dict[int, int] = {}  # fleet ticket -> re-routes
        # routing strategy: a registered name resolves to a router class
        # on the ROUTERS axis; classes are instantiated per fleet
        # (routers may carry state), prebuilt instances pass through
        if isinstance(router, str):
            from repro.registry import ROUTERS
            router = ROUTERS.get(router)
        self.router = router() if isinstance(router, type) else router
        names = [d.name for d in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"fleet device names must be unique: {names}"
                             " (names key the routing and result maps)")
        # learned service times: (device name, kernel key, schedule
        # label) -> time_us — the schedule is part of the identity
        # (module doc: a tuned lowering is a different program)
        self._learned: Dict[Tuple[str, tuple, str], float] = {}
        self.placement: Dict[int, str] = {}     # fleet ticket -> device name
        self._next_ticket = 0
        self._tickets: Dict[Tuple[str, int], int] = {}  # (dev, local) -> fleet
        self._local: Dict[int, int] = {}                # fleet -> local
        self._kernel_keys: Dict[int, tuple] = {}  # fleet -> (kernel, sched)
        self._eta_charged: Dict[int, float] = {}        # fleet -> estimate
        self.quarantined: Dict[int, Quarantined] = {}   # by fleet ticket

    # -- service-time model --------------------------------------------------

    def estimate_us(self, dev: FleetDevice, req: Request) -> float:
        """Expected service time of ``req`` on ``dev``: the learned value
        when this device has served this kernel, else an occupancy proxy —
        each of the kernel's ``W`` wavefronts issues its program once over
        ``n_cus``-way CU parallelism at the device's clock."""
        learned = self._learned.get(
            (dev.name, req.kernel_key(), req.schedule))
        if learned is not None:
            return learned
        W = wavefronts(req.n_items, dev.cfg)
        rounds = math.ceil(W / dev.cfg.n_cus) * req.prog.shape[0]
        return rounds * dev.cfg.issue_cycles / dev.cfg.freq_mhz

    @staticmethod
    def _shard_scale(dev: FleetDevice) -> float:
        """Backlog scale for a device's physical shard width: a sub-mesh-
        bound device dispatches same-shape launches ``shards`` abreast
        (``Executor.shards`` scales the scheduler's ``plan_batch``), so a
        stream of launches drains ~``shards``x faster in wall-clock even
        though each launch's modeled cycles are unchanged. The router
        weighs this into earliest-finish; ``busy_us``/``makespan_us``
        (modeled *compute*) are untouched."""
        return 1.0 / max(1, dev.scheduler.executor.shards)

    def finish_us(self, dev: FleetDevice, req: Request) -> float:
        """Modeled finish time of placing ``req`` on ``dev`` now: the
        shard-width-discounted backlog plus this launch's charge."""
        return dev.eta_us + self.estimate_us(dev, req) \
            * self._shard_scale(dev)

    # -- routing -------------------------------------------------------------

    def routable_devices(self) -> List[FleetDevice]:
        """The devices a router may place fresh work on: all of them
        without a resilience policy; otherwise the active ones plus
        probation devices with admission budget left. Falls back to
        not-evicted (then to everything) rather than going empty — a
        fully-degraded fleet still routes somewhere instead of
        crashing."""
        if self.resilience is None:
            return list(self.devices)
        out = [d for d in self.devices
               if d.state == "active"
               or (d.state == "probation" and d.probation_left > 0)]
        return out or [d for d in self.devices if d.state != "evicted"] \
            or list(self.devices)

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "", priority: int = 0,
               deadline_us: float = math.inf) -> int:
        """Route a launch to the device with the earliest modeled finish
        time; returns a fleet-level ticket."""
        return self.submit_request(
            Request(prog, mem0, n_items, tag, priority, deadline_us))

    def _dep_device(self, req: Request) -> FleetDevice:
        """The one device holding every producer of ``req`` (module doc:
        graph stages co-locate to preserve device residency)."""
        names = set()
        for d in req.deps:
            name = self.placement.get(d.producer)
            if name is None:
                raise ValueError(
                    f"dep producer ticket {d.producer} is unknown to "
                    f"this fleet")
            names.add(name)
        if len(names) > 1:
            raise ValueError(
                f"graph stages must co-locate on one device to stay "
                f"device-resident; producers span {sorted(names)}")
        (name,) = names
        return next(d for d in self.devices if d.name == name)

    def submit_request(self, req: Request) -> int:
        """Route a prebuilt ``Request`` (the ``loadgen.replay`` target
        protocol, shared with ``Scheduler.submit_request``). A request
        with ``deps`` is pinned to its producers' device, with the
        fleet-level producer tickets rewritten to that scheduler's local
        tickets on the way down."""
        if req.deps:
            dev = self._dep_device(req)
            req.deps = tuple(
                dataclasses.replace(d, producer=self._local[d.producer])
                for d in req.deps)
        else:
            dev = self.router.pick(self, req)
        if dev.state == "probation":
            dev.probation_left -= 1
        est = self.estimate_us(dev, req) * self._shard_scale(dev)
        local = dev.scheduler.submit_request(req)
        dev.eta_us += est
        ticket = self._next_ticket
        self._next_ticket += 1
        self.placement[ticket] = dev.name
        self._tickets[(dev.name, local)] = ticket
        self._local[ticket] = local
        self._kernel_keys[ticket] = (req.kernel_key(), req.schedule)
        self._eta_charged[ticket] = est
        return ticket

    def drain(self, budget: Optional[int] = None) -> List[Result]:
        """Drain every device (``budget`` applies per device); returns the
        completed results in fleet-ticket order, each stamped with
        ``info['device']`` and the fleet ``info['ticket']``. Every
        device's chunks are **dispatched before any device is collected**,
        so the whole fleet's work is in flight together and one device's
        download/collection never serializes another's compute. Actual
        service times update the device loads (replacing the estimate the
        router charged at submit time, so cold-start error never skews
        later placements) and the learned per-kernel model. Launches the
        device scheduler quarantined surface in ``Fleet.quarantined``
        under their fleet ticket — they produce no result.

        Under a :class:`FleetResilience` policy the drain switches to the
        readiness-ordered self-healing loop (``_drain_resilient``);
        without one this is the original dispatch-all-then-collect path,
        unchanged."""
        if self.resilience is not None:
            return self._drain_resilient(budget)
        for dev in self.devices:
            dev.scheduler.dispatch(budget)
        out: List[Result] = []
        for dev in self.devices:
            for res in dev.scheduler.collect():
                local = res.info["ticket"]
                t_us = res.info["cycles"] / dev.cfg.freq_mhz
                dev.busy_us += t_us
                res.info["device"] = dev.name
                ticket = self._tickets[(dev.name, local)]
                res.info["ticket"] = ticket
                kk, sched = self._kernel_keys[ticket]
                self._learned[(dev.name, kk, sched)] = t_us
                # reconcile the modeled backlog with the actual time
                # (shard-discounted the same way the submit charge was)
                scaled = t_us * self._shard_scale(dev)
                dev.eta_us += scaled - self._eta_charged.pop(ticket, scaled)
                out.append(res)
            for local, q in dev.scheduler.quarantined.items():
                ticket = self._tickets[(dev.name, local)]
                if ticket not in self.quarantined:
                    self.quarantined[ticket] = q
                    dev.eta_us -= self._eta_charged.pop(ticket, 0.0)
        out.sort(key=lambda r: r.info["ticket"])
        return out

    # -- self-healing drain (FleetResilience) --------------------------------

    def _drain_resilient(self, budget: Optional[int] = None) -> List[Result]:
        """The readiness-ordered drain loop: dispatch every live device,
        then settle whichever chunks are resolvable *anywhere* — a
        straggling device never serializes the others' collections. Each
        pass harvests device-blamed quarantines into the health counters,
        re-routes dependency-free failures to the healthiest survivor,
        evicts devices past ``evict_after`` consecutive faults (re-routing
        their backlog), and fires straggler hedges. ``budget`` applies per
        device per dispatch pass. The loop exits when every fleet ticket
        is settled or quarantined — NOT when every chunk has resolved: a
        hedge loser still in flight is *abandoned* here and discarded by
        a later drain's collect, so a straggling duplicate never holds
        the drain (and the caller's admission loop) hostage. Probation
        bookkeeping brackets the loop: eviction cooldowns expire on
        entry, clean probation devices are promoted on exit."""
        r = self.resilience
        self._drains += 1
        for dev in self.devices:
            if dev.state == "evicted" \
                    and self._drains - dev.evicted_at > r.probation_after:
                dev.state = "probation"
                dev.probation_left = r.probation_budget
                dev.consecutive_faults = 0
        start_served = {d.name: d.served for d in self.devices}
        out: List[Result] = []
        while True:
            live = [d for d in self.devices if d.state != "evicted"]
            for dev in live:
                dev.scheduler.dispatch(budget)
            progress = False
            for dev in live:
                if dev.state == "evicted":
                    continue  # evicted by an earlier harvest this pass
                got = dev.scheduler.collect_ready()
                if got:
                    progress = True
                self._settle(dev, got, out)
                self._harvest(dev, out)
            if not self._unsettled():
                break  # abandoned hedge losers may remain in flight
            if self._maybe_hedge():
                progress = True
            if not progress:
                live = [d for d in self.devices if d.state != "evicted"]
                if not any(d.scheduler.inflight_chunks
                           or len(d.scheduler) for d in live):
                    break  # unresolved tickets with nowhere left to run
                # nothing resolvable anywhere: poll rather than block on
                # one device, so a hedge winner elsewhere is settled the
                # moment it finishes (blocking on the oldest chunk would
                # hand the straggler the race by default)
                time.sleep(1e-3)
        for dev in self.devices:
            if dev.state == "probation" and dev.consecutive_faults == 0 \
                    and dev.served > start_served[dev.name]:
                dev.state = "active"
        out.sort(key=lambda r: r.info["ticket"])
        return out

    def _unsettled(self) -> bool:
        """Any fleet ticket not yet settled or finally quarantined? (The
        resilient drain's exit condition — a hedge loser's in-flight
        chunk does not count, so it cannot block the drain.)"""
        return any(t not in self._served_tickets
                   and t not in self.quarantined for t in self.placement)

    def _settle(self, dev: FleetDevice, results: List[Result],
                out: List[Result]) -> None:
        """Account device-local results into the fleet surface (the
        resilient-path twin of the default drain's collect loop). The
        first result for a fleet ticket wins; a hedge loser's result is
        discarded here — 'cancelled at collect'. Each winner is stamped
        with ``info['settled_s']`` (monotonic settle time) so an
        open-loop driver can measure when the result actually landed
        rather than when the whole drain returned."""
        for res in results:
            local = res.info["ticket"]
            ticket = self._tickets[(dev.name, local)]
            if ticket in self._served_tickets:
                continue  # hedge loser: the duplicate already won
            self._served_tickets.add(ticket)
            res.info["settled_s"] = time.monotonic()
            t_us = res.info["cycles"] / dev.cfg.freq_mhz
            dev.busy_us += t_us
            res.info["device"] = dev.name
            res.info["ticket"] = ticket
            kk, sched_label = self._kernel_keys[ticket]
            self._learned[(dev.name, kk, sched_label)] = t_us
            scaled = t_us * self._shard_scale(dev)
            dev.eta_us += scaled - self._eta_charged.pop(ticket, scaled)
            dev.served += 1
            dev.consecutive_faults = 0
            out.append(res)

    def _harvest(self, dev: FleetDevice, out: List[Result]) -> None:
        """Drain a device scheduler's quarantine surface into the fleet:
        device-blamed errors (``device_fault``) move the health counters
        and — for dependency-free requests with re-route budget left —
        send the request to the healthiest other device instead of a
        final quarantine. Ends with the eviction check: ``evict_after``
        consecutive faults (a single fault on probation) retire the
        device."""
        sched = dev.scheduler
        for local in list(sched.quarantined):
            q = sched.quarantined.pop(local)
            ticket = self._tickets[(dev.name, local)]
            fault = getattr(type(q.error), "device_fault", False)
            if fault:
                dev.faults += 1
                dev.consecutive_faults += 1
            dev.eta_us -= self._eta_charged.pop(ticket, 0.0)
            if ticket in self._served_tickets or ticket in self.quarantined:
                continue  # a hedge (or an earlier pass) already settled it
            target = None
            if fault and not q.request.deps and self._reroutes.get(
                    ticket, 0) < max(1, len(self.devices) - 1):
                target = self._healthiest(exclude=dev)
            if target is not None:
                self._resubmit(ticket, q.request, target)
            else:
                self.quarantined[ticket] = q
        if dev.state != "evicted" and dev.consecutive_faults >= \
                (1 if dev.state == "probation" else
                 self.resilience.evict_after):
            self._evict(dev, out)

    def _resubmit(self, ticket: int, req: Request,
                  target: FleetDevice) -> None:
        """Re-route a request to ``target`` under its existing fleet
        ticket (fresh local ticket, fresh retry budget; the admission
        stamp survives, so a deadline keeps counting)."""
        self._reroutes[ticket] = self._reroutes.get(ticket, 0) + 1
        req.ticket = -1
        req.attempts = 0
        local = target.scheduler.submit_request(req)
        if target.state == "probation":
            target.probation_left -= 1
        est = self.estimate_us(target, req) * self._shard_scale(target)
        target.eta_us += est
        self.placement[ticket] = target.name
        self._tickets[(target.name, local)] = ticket
        self._local[ticket] = local
        self._eta_charged[ticket] = est

    def _evict(self, dev: FleetDevice, out: List[Result]) -> None:
        """Retire a device: flush its in-flight chunks (without retrying
        on the dying device — stuck chunks resolve via ``DeviceTimeout``
        straight to quarantine), quarantine the backlog that cannot move
        (graph requests are pinned by device residency), and re-route the
        dependency-free rest to the survivors."""
        dev.state = "evicted"
        dev.evicted_at = self._drains
        sched = dev.scheduler
        saved, sched.retry = sched.retry, None
        try:
            self._settle(dev, sched.collect(), out)
        finally:
            sched.retry = saved
        for t in list(sched.pending_tickets):
            req = sched._pending.get(t)
            if req is not None and (req.deps or sched._dep_waiters.get(t)):
                # cascades to its pending consumers via dep poisoning
                sched._quarantine(req, KernelLaunchError(
                    f"device {dev.name} evicted"))
        for t in list(sched.pending_tickets):
            req = sched.cancel(t)
            ticket = self._tickets[(dev.name, t)]
            dev.eta_us -= self._eta_charged.pop(ticket, 0.0)
            target = self._healthiest(exclude=dev)
            if target is not None and ticket not in self._served_tickets:
                self._resubmit(ticket, req, target)
            else:
                self.quarantined.setdefault(ticket, Quarantined(
                    req, KernelLaunchError(f"device {dev.name} evicted")))
        self._harvest(dev, out)

    def _healthiest(self, exclude: Optional[FleetDevice] = None
                    ) -> Optional[FleetDevice]:
        """The routable device with the best health score, excluding
        ``exclude`` (the device being blamed); ``None`` when no other
        device is routable — the caller quarantines instead."""
        cands = [d for d in self.routable_devices() if d is not exclude]
        return max(cands, key=lambda d: d.health, default=None)

    def _healthiest_idle(self, exclude: Optional[FleetDevice] = None
                         ) -> Optional[FleetDevice]:
        """Hedge target: healthiest routable device with nothing pending
        and nothing in flight — a hedge must never queue behind real
        work, or the duplicate finishes after the straggler it insures."""
        cands = [d for d in self.routable_devices()
                 if d is not exclude and len(d.scheduler) == 0
                 and d.scheduler.inflight_chunks == 0]
        return max(cands, key=lambda d: d.health, default=None)

    def _maybe_hedge(self) -> int:
        """Fire straggler hedges: any dependency-free member of a chunk
        in flight longer than ``hedge.after_s`` is duplicated (once per
        fleet ticket) onto the healthiest idle device. First result wins
        in ``_settle``; the loser is discarded there. Returns how many
        hedges were fired this pass."""
        hedge = self.resilience.hedge
        if hedge is None:
            return 0
        fired = 0
        now = time.monotonic()
        for dev in self.devices:
            if dev.state == "evicted":
                continue
            for chunk in dev.scheduler.inflight:
                if now - chunk.t_dispatch < hedge.after_s:
                    continue
                for req in chunk.reqs:
                    if req.deps:
                        continue
                    ticket = self._tickets.get((dev.name, req.ticket))
                    if ticket is None or ticket in self._hedged \
                            or ticket in self._served_tickets:
                        continue
                    target = self._healthiest_idle(exclude=dev)
                    if target is None:
                        return fired
                    clone = Request(req.prog, req.mem0, req.n_items,
                                    req.tag, req.priority, req.deadline_us,
                                    out_region=req.out_region,
                                    schedule=req.schedule, audit=req.audit)
                    clone.arrival_s = req.arrival_s
                    self._hedged.add(ticket)
                    local = target.scheduler.submit_request(clone)
                    # the duplicate maps to the SAME fleet ticket; the
                    # placement/_local maps keep the original so graph
                    # lookups are unaffected
                    self._tickets[(target.name, local)] = ticket
                    target.scheduler.dispatch()
                    fired += 1
        return fired

    def makespan_us(self) -> float:
        """Modeled fleet wall-clock: devices serve in parallel, so the
        slowest device's total service time bounds the trace."""
        return max(d.busy_us for d in self.devices)

    def report(self) -> dict:
        """Fleet load report: besides placement counts and modeled busy
        time, each device exposes its **utilization** (busy_us over the
        fleet makespan — 1.0 on the critical-path device, lower on
        underused ones), its live **queue depth** (pending requests plus
        dispatched-but-uncollected chunks), the modeled backlog ``eta_us``
        the router currently sees, and its physical ``shards`` width."""
        counts: Dict[str, int] = {d.name: 0 for d in self.devices}
        for name in self.placement.values():
            counts[name] += 1
        makespan = self.makespan_us()
        rep = {
            "devices": [d.name for d in self.devices],
            "placement": counts,
            "busy_us": {d.name: round(d.busy_us, 3) for d in self.devices},
            "utilization": {
                d.name: round(d.busy_us / makespan, 3) if makespan else 0.0
                for d in self.devices},
            "queue_depth": {
                d.name: len(d.scheduler) + d.scheduler.inflight_chunks
                for d in self.devices},
            "eta_us": {d.name: round(d.eta_us, 3) for d in self.devices},
            "shards": {d.name: d.scheduler.executor.shards
                       for d in self.devices},
            "makespan_us": round(self.makespan_us(), 3),
            "quarantined": sorted(self.quarantined),
        }
        if self.resilience is not None:
            rep["health"] = {d.name: round(d.health, 3)
                             for d in self.devices}
            rep["device_state"] = {d.name: d.state for d in self.devices}
            rep["faults"] = {d.name: d.faults for d in self.devices}
            rep["served"] = {d.name: d.served for d in self.devices}
            rep["reroutes"] = sum(self._reroutes.values())
            rep["hedged"] = len(self._hedged)
        return rep


def pinned_makespan(cfg: GGPUConfig,
                    trace: Sequence[Tuple[np.ndarray, np.ndarray, int]],
                    max_batch: int = 64) -> float:
    """Modeled wall-clock of serving the whole ``trace`` (an iterable of
    (prog, mem0, n_items)) pinned to one config: the sum of per-launch
    service times on that device."""
    sched = Scheduler(cfg, max_batch=max_batch)
    for prog, mem0, n_items in trace:
        sched.submit(prog, mem0, n_items)
    results = sched.flush()
    return sum(r.info["cycles"] / cfg.freq_mhz for r in results)
