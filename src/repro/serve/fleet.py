"""Fleet router: serve one launch stream across *multiple* G-GPU configs.

This is the layer that connects the DSE output to the serving path: the
Pareto front ``repro.dse.search`` emits is a set of complementary designs
(e.g. a small high-clock 1-CU part and a wide derated 8-CU part), and a
mixed traffic trace is served fastest by placing each launch on the device
that finishes it earliest — small single-wavefront launches on the fast
small part, wide launches on the wide one.

Placement is greedy earliest-finish-time: for each request the router
estimates its service time on every device — from the learned per-kernel
cycle model once the device has served that kernel, from an analytic
occupancy proxy (wavefront rounds / CU parallelism, scaled by clock) on a
cold start — and picks the device minimizing (modeled queue backlog +
estimated service time), with both discounted by the device's physical
shard width: a sub-mesh-bound device dispatches same-shape launches
``shards`` abreast, so for a stream of launches (a large cohort) the wide
device finishes earlier and wins placement even when its per-launch
estimate ties. Modeled wall-clock of a fleet is the makespan:
the max over devices of the sum of served launch times (devices run in
parallel); ``pinned_makespan`` prices the whole trace on one config for
comparison. ``benchmarks/serve_bench.py`` records the routed-vs-pinned
comparison in ``BENCH_serve.json``.

**Kernel graphs.** A request carrying ``deps`` is not routed freely: its
producers' device-resident outputs feed it with no host hop, so it must
land on the device already holding every producer. The router looks the
producers up in its placement map, requires them to agree on one device
(a graph that spans devices would need a host round-trip — submit it to
one device or don't use deps), and translates the fleet-level producer
tickets into that device scheduler's local tickets before handing the
request down. The learned service-time model keys on *(kernel,
schedule)* — ``Request.schedule`` carries the lowering-schedule label —
because a tuned and a default lowering of one kernel are different
programs with different true cycle counts; folding them under one key
would let a fast tuned variant mask a slow default one (or vice versa)
and skew every later placement of either.

**Physical placement.** Passing a ``mesh`` (``make_launch_mesh``) binds
each simulated device to a contiguous slice of the mesh's physical JAX
devices: a slice of one pins that scheduler's dispatches to that device
(``Executor.device``), a wider slice becomes a sub-mesh so the
scheduler's chunks shard their launch axis across it
(``Executor.mesh``). Slices are proportional to remaining devices (every
simulated device gets at least one physical device while supply lasts;
with fewer physical than simulated devices the remainder runs unplaced
on the default device). Bit-exactness is unchanged — placement moves *where*
arrays live, never the traced computation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ggpu.engine import GGPUConfig
from repro.serve.request import Request, Result
from repro.serve.scheduler import Quarantined, Scheduler, wavefronts


@dataclasses.dataclass
class FleetDevice:
    """One config in the fleet, with its scheduler and load accounting.
    ``mesh``/``device`` record the physical binding (either or neither)."""
    name: str
    cfg: GGPUConfig
    scheduler: Scheduler
    eta_us: float = 0.0        # modeled backlog the router sees (estimates)
    busy_us: float = 0.0       # actual modeled service time after drain
    mesh: object = None        # sub-mesh when bound to >1 physical device
    device: object = None      # pinned jax.Device when bound to exactly 1


def _mesh_slices(mesh, n: int) -> List[list]:
    """Partition a launch mesh's devices into ``n`` contiguous slices,
    proportionally (largest first). Empty slices mean the fleet outnumbers
    the physical devices; those simulated devices stay unplaced."""
    devices = list(np.ravel(mesh.devices))
    out, lo = [], 0
    for i in range(n):
        take = -((len(devices) - lo) // -(n - i))   # ceil of remaining/n
        out.append(devices[lo:lo + take])
        lo += take
    return out


class Fleet:
    """Routes submissions across devices; drains every device's scheduler.

    ``configs`` may be raw ``GGPUConfig``s or (name, config) pairs —
    e.g. ``[(p.label(), p.config) for p in search_result.frontier]``.
    ``mesh`` binds simulated devices to physical ones (see module doc).
    ``router`` picks the placement strategy by registered name (the
    ``ROUTERS`` registry axis; ``"earliest-finish"`` is the legacy
    greedy placement, see ``repro.serve.routing``) or as a router
    instance/class with a ``pick(fleet, req)`` method. ``policy`` is
    forwarded to every device scheduler (``SCHEDULERS`` axis).
    """

    def __init__(self, configs: Sequence, max_batch: int = 64, *,
                 mesh=None, router="earliest-finish", policy="cohort"):
        configs = list(configs)
        slices = _mesh_slices(mesh, len(configs)) if mesh is not None \
            else [[] for _ in configs]
        self.devices: List[FleetDevice] = []
        for i, c in enumerate(configs):
            name, cfg = c if isinstance(c, tuple) else (f"dev{i}", c)
            sub_mesh = sub_dev = None
            if len(slices[i]) > 1:
                import jax
                sub_mesh = jax.sharding.Mesh(np.asarray(slices[i]),
                                             ("data",))
            elif len(slices[i]) == 1:
                sub_dev = slices[i][0]
            self.devices.append(FleetDevice(
                name, cfg,
                Scheduler(cfg, max_batch=max_batch, mesh=sub_mesh,
                          device=sub_dev, policy=policy),
                mesh=sub_mesh, device=sub_dev))
        if len(self.devices) < 1:
            raise ValueError("fleet needs at least one device")
        # routing strategy: a registered name resolves to a router class
        # on the ROUTERS axis; classes are instantiated per fleet
        # (routers may carry state), prebuilt instances pass through
        if isinstance(router, str):
            from repro.registry import ROUTERS
            router = ROUTERS.get(router)
        self.router = router() if isinstance(router, type) else router
        names = [d.name for d in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"fleet device names must be unique: {names}"
                             " (names key the routing and result maps)")
        # learned service times: (device name, kernel key, schedule
        # label) -> time_us — the schedule is part of the identity
        # (module doc: a tuned lowering is a different program)
        self._learned: Dict[Tuple[str, tuple, str], float] = {}
        self.placement: Dict[int, str] = {}     # fleet ticket -> device name
        self._next_ticket = 0
        self._tickets: Dict[Tuple[str, int], int] = {}  # (dev, local) -> fleet
        self._local: Dict[int, int] = {}                # fleet -> local
        self._kernel_keys: Dict[int, tuple] = {}  # fleet -> (kernel, sched)
        self._eta_charged: Dict[int, float] = {}        # fleet -> estimate
        self.quarantined: Dict[int, Quarantined] = {}   # by fleet ticket

    # -- service-time model --------------------------------------------------

    def estimate_us(self, dev: FleetDevice, req: Request) -> float:
        """Expected service time of ``req`` on ``dev``: the learned value
        when this device has served this kernel, else an occupancy proxy —
        each of the kernel's ``W`` wavefronts issues its program once over
        ``n_cus``-way CU parallelism at the device's clock."""
        learned = self._learned.get(
            (dev.name, req.kernel_key(), req.schedule))
        if learned is not None:
            return learned
        W = wavefronts(req.n_items, dev.cfg)
        rounds = math.ceil(W / dev.cfg.n_cus) * req.prog.shape[0]
        return rounds * dev.cfg.issue_cycles / dev.cfg.freq_mhz

    @staticmethod
    def _shard_scale(dev: FleetDevice) -> float:
        """Backlog scale for a device's physical shard width: a sub-mesh-
        bound device dispatches same-shape launches ``shards`` abreast
        (``Executor.shards`` scales the scheduler's ``plan_batch``), so a
        stream of launches drains ~``shards``x faster in wall-clock even
        though each launch's modeled cycles are unchanged. The router
        weighs this into earliest-finish; ``busy_us``/``makespan_us``
        (modeled *compute*) are untouched."""
        return 1.0 / max(1, dev.scheduler.executor.shards)

    def finish_us(self, dev: FleetDevice, req: Request) -> float:
        """Modeled finish time of placing ``req`` on ``dev`` now: the
        shard-width-discounted backlog plus this launch's charge."""
        return dev.eta_us + self.estimate_us(dev, req) \
            * self._shard_scale(dev)

    # -- routing -------------------------------------------------------------

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "", priority: int = 0,
               deadline_us: float = math.inf) -> int:
        """Route a launch to the device with the earliest modeled finish
        time; returns a fleet-level ticket."""
        return self.submit_request(
            Request(prog, mem0, n_items, tag, priority, deadline_us))

    def _dep_device(self, req: Request) -> FleetDevice:
        """The one device holding every producer of ``req`` (module doc:
        graph stages co-locate to preserve device residency)."""
        names = set()
        for d in req.deps:
            name = self.placement.get(d.producer)
            if name is None:
                raise ValueError(
                    f"dep producer ticket {d.producer} is unknown to "
                    f"this fleet")
            names.add(name)
        if len(names) > 1:
            raise ValueError(
                f"graph stages must co-locate on one device to stay "
                f"device-resident; producers span {sorted(names)}")
        (name,) = names
        return next(d for d in self.devices if d.name == name)

    def submit_request(self, req: Request) -> int:
        """Route a prebuilt ``Request`` (the ``loadgen.replay`` target
        protocol, shared with ``Scheduler.submit_request``). A request
        with ``deps`` is pinned to its producers' device, with the
        fleet-level producer tickets rewritten to that scheduler's local
        tickets on the way down."""
        if req.deps:
            dev = self._dep_device(req)
            req.deps = tuple(
                dataclasses.replace(d, producer=self._local[d.producer])
                for d in req.deps)
        else:
            dev = self.router.pick(self, req)
        est = self.estimate_us(dev, req) * self._shard_scale(dev)
        local = dev.scheduler.submit_request(req)
        dev.eta_us += est
        ticket = self._next_ticket
        self._next_ticket += 1
        self.placement[ticket] = dev.name
        self._tickets[(dev.name, local)] = ticket
        self._local[ticket] = local
        self._kernel_keys[ticket] = (req.kernel_key(), req.schedule)
        self._eta_charged[ticket] = est
        return ticket

    def drain(self, budget: Optional[int] = None) -> List[Result]:
        """Drain every device (``budget`` applies per device); returns the
        completed results in fleet-ticket order, each stamped with
        ``info['device']`` and the fleet ``info['ticket']``. Every
        device's chunks are **dispatched before any device is collected**,
        so the whole fleet's work is in flight together and one device's
        download/collection never serializes another's compute. Actual
        service times update the device loads (replacing the estimate the
        router charged at submit time, so cold-start error never skews
        later placements) and the learned per-kernel model. Launches the
        device scheduler quarantined surface in ``Fleet.quarantined``
        under their fleet ticket — they produce no result."""
        for dev in self.devices:
            dev.scheduler.dispatch(budget)
        out: List[Result] = []
        for dev in self.devices:
            for res in dev.scheduler.collect():
                local = res.info["ticket"]
                t_us = res.info["cycles"] / dev.cfg.freq_mhz
                dev.busy_us += t_us
                res.info["device"] = dev.name
                ticket = self._tickets[(dev.name, local)]
                res.info["ticket"] = ticket
                kk, sched = self._kernel_keys[ticket]
                self._learned[(dev.name, kk, sched)] = t_us
                # reconcile the modeled backlog with the actual time
                # (shard-discounted the same way the submit charge was)
                scaled = t_us * self._shard_scale(dev)
                dev.eta_us += scaled - self._eta_charged.pop(ticket, scaled)
                out.append(res)
            for local, q in dev.scheduler.quarantined.items():
                ticket = self._tickets[(dev.name, local)]
                if ticket not in self.quarantined:
                    self.quarantined[ticket] = q
                    dev.eta_us -= self._eta_charged.pop(ticket, 0.0)
        out.sort(key=lambda r: r.info["ticket"])
        return out

    def makespan_us(self) -> float:
        """Modeled fleet wall-clock: devices serve in parallel, so the
        slowest device's total service time bounds the trace."""
        return max(d.busy_us for d in self.devices)

    def report(self) -> dict:
        """Fleet load report: besides placement counts and modeled busy
        time, each device exposes its **utilization** (busy_us over the
        fleet makespan — 1.0 on the critical-path device, lower on
        underused ones), its live **queue depth** (pending requests plus
        dispatched-but-uncollected chunks), the modeled backlog ``eta_us``
        the router currently sees, and its physical ``shards`` width."""
        counts: Dict[str, int] = {d.name: 0 for d in self.devices}
        for name in self.placement.values():
            counts[name] += 1
        makespan = self.makespan_us()
        return {
            "devices": [d.name for d in self.devices],
            "placement": counts,
            "busy_us": {d.name: round(d.busy_us, 3) for d in self.devices},
            "utilization": {
                d.name: round(d.busy_us / makespan, 3) if makespan else 0.0
                for d in self.devices},
            "queue_depth": {
                d.name: len(d.scheduler) + d.scheduler.inflight_chunks
                for d in self.devices},
            "eta_us": {d.name: round(d.eta_us, 3) for d in self.devices},
            "shards": {d.name: d.scheduler.executor.shards
                       for d in self.devices},
            "makespan_us": round(self.makespan_us(), 3),
            "quarantined": sorted(self.quarantined),
        }


def pinned_makespan(cfg: GGPUConfig,
                    trace: Sequence[Tuple[np.ndarray, np.ndarray, int]],
                    max_batch: int = 64) -> float:
    """Modeled wall-clock of serving the whole ``trace`` (an iterable of
    (prog, mem0, n_items)) pinned to one config: the sum of per-launch
    service times on that device."""
    sched = Scheduler(cfg, max_batch=max_batch)
    for prog, mem0, n_items in trace:
        sched.submit(prog, mem0, n_items)
    results = sched.flush()
    return sum(r.info["cycles"] / cfg.freq_mhz for r in results)
