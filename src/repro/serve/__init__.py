"""Multi-tenant serving subsystem for the G-GPU reproduction.

One continuous-batching core with two tenants: the G-GPU kernel launch
path (submit/drain over the cycle-accurate simulator, with cohort/vmap
batching, failure quarantine, and a multi-config fleet router) and the
slot-batched LLM engine. See DESIGN.md §Serving subsystem.

``repro.serve.engine`` is the stable compatibility facade; the package
modules are the API for new code.
"""
from repro.serve.executors import (DeviceTimeout, Executor, ExecutorStats,
                                   PendingChunk, get_executor, sim_key)
from repro.serve.fleet import (Fleet, FleetDevice, FleetResilience,
                               HedgePolicy, pinned_makespan)
from repro.serve.graphs import (GraphTickets, extract_outputs,
                                run_chains_host_staged, run_program,
                                run_program_host_staged,
                                run_programs_host_staged, submit_program,
                                submit_programs)
from repro.serve.llm import Engine, EngineConfig
from repro.serve.loadgen import (LoadResult, bursty_arrivals,
                                 poisson_arrivals, replay)
from repro.serve.policies import plan_fifo
from repro.serve.request import (Dep, KernelLaunch, Request, Result,
                                 result_checksum)
from repro.serve.routing import EarliestFinishRouter, RoundRobinRouter
from repro.serve.scheduler import (AdmissionError, ChecksumError, Chunk,
                                   DeadlineExceeded, DependencyError,
                                   LaunchQueue, Quarantined, RetryPolicy,
                                   Scheduler,
                                   plan_chunks, plan_waves, wavefronts)

__all__ = [
    "AdmissionError", "ChecksumError", "Chunk", "DeadlineExceeded", "Dep",
    "DependencyError", "DeviceTimeout",
    "EarliestFinishRouter", "Engine",
    "EngineConfig", "Executor", "ExecutorStats", "Fleet", "FleetDevice",
    "FleetResilience",
    "GraphTickets", "HedgePolicy", "KernelLaunch", "LaunchQueue",
    "LoadResult",
    "PendingChunk", "Quarantined", "Request", "Result", "RetryPolicy",
    "RoundRobinRouter",
    "Scheduler",
    "bursty_arrivals", "extract_outputs", "get_executor",
    "pinned_makespan", "plan_chunks", "plan_fifo", "plan_waves",
    "poisson_arrivals",
    "replay", "result_checksum", "run_chains_host_staged", "run_program",
    "run_program_host_staged",
    "run_programs_host_staged", "sim_key", "submit_program",
    "submit_programs", "wavefronts",
]
