"""Compiled-executor layer: runs planned chunks on one device config.

An ``Executor`` owns the three engine entry points for one ``GGPUConfig``
and tracks the **envelope cache**: the set of compiled-stepper signatures
(chunk kind, batch size, wavefront count, program length, memory size,
opcode set) this process has already traced. The jit cache inside
``repro.ggpu.engine`` is keyed on exactly these statics, so a chunk whose
envelope has been seen re-uses the compiled stepper — repeat serving
traffic never re-traces — and the executor's hit/miss counters make that
visible (``BENCH_serve.json`` reports the hit rate).

``get_executor`` is a process-wide registry keyed by the **simulation
key** — the config with ``freq_mhz`` normalized out, since frequency never
enters the traced cycle computation but is a static jit argument (without
normalization every distinct frequency target would recompile). The
registry is shared with ``repro.dse.Evaluator``, whose cycle cache lives
on the executor (``Executor.memo``): a DSE sweep and a serving fleet that
touch the same config share both the compiled steppers and the memoized
bench results.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.ggpu.engine import GGPUConfig
from repro.ggpu.engine import run_kernel, run_kernel_batch, run_kernel_cohort
from repro.ggpu.engine.stepper import _n_wavefronts, _static_ops

from repro.serve.request import Request, Result


@dataclasses.dataclass
class ExecutorStats:
    """Counts *executed* work: a launch re-run after a failed chunk (the
    LaunchQueue restore-and-retry path, or quarantine survivors) counts
    each time it actually runs — these are simulator-activity stats, not
    unique-request stats. hits + misses == dispatches always holds."""
    launches: int = 0        # kernel launches executed
    dispatches: int = 0      # compiled-stepper calls issued
    trace_hits: int = 0      # dispatches whose envelope was already traced
    trace_misses: int = 0    # dispatches that paid a trace/compile

    @property
    def batch_occupancy(self) -> float:
        """Mean launches per dispatch — the continuous-batching win."""
        return self.launches / self.dispatches if self.dispatches else 0.0

    @property
    def hit_rate(self) -> float:
        return (self.trace_hits / self.dispatches) if self.dispatches else 0.0

    def report(self) -> dict:
        return {
            "launches": self.launches,
            "dispatches": self.dispatches,
            "batch_occupancy": round(self.batch_occupancy, 3),
            "trace_hits": self.trace_hits,
            "trace_misses": self.trace_misses,
            "hit_rate": round(self.hit_rate, 3),
        }


def sim_key(cfg: GGPUConfig) -> GGPUConfig:
    """Normalize ``freq_mhz`` out of the executor/compile key: frequency
    scales reported ``time_us`` but never the traced cycle computation."""
    return dataclasses.replace(cfg, freq_mhz=500.0)


class Executor:
    """Runs (kind, requests) chunks on one config, with envelope-cache
    accounting and a memo dict shared across its users (see module doc)."""

    def __init__(self, cfg: GGPUConfig):
        self.cfg = cfg
        self.stats = ExecutorStats()
        self.memo: Dict[tuple, object] = {}   # e.g. the DSE cycle cache
        self._envelopes: set = set()

    # -- envelope accounting ------------------------------------------------

    def _envelope(self, kind: str, reqs: Sequence[Request]) -> tuple:
        """The static signature the engine jit-caches on for this chunk."""
        cfg = self.cfg
        if kind == "cohort":
            r = reqs[0]
            return ("cohort", len(reqs), _n_wavefronts(r.n_items, cfg),
                    r.prog.shape[0], r.mem0.shape[0], _static_ops(r.prog))
        if kind == "batch":
            P = max(r.prog.shape[0] for r in reqs)
            M = max(r.mem0.shape[0] for r in reqs)
            W = max(_n_wavefronts(r.n_items, cfg) for r in reqs)
            ops = tuple(sorted(set().union(
                *(_static_ops(r.prog) for r in reqs))))
            return ("batch", len(reqs), W, P, M, ops)
        r = reqs[0]
        return ("single", _n_wavefronts(r.n_items, cfg), r.prog.shape[0],
                r.mem0.shape[0], _static_ops(r.prog))

    # -- execution ----------------------------------------------------------

    def run(self, kind: str, reqs: Sequence[Request]) -> List[Result]:
        """Execute one planned chunk; returns per-launch ``Result``s in the
        chunk's own order. Raises ``KernelLaunchError`` (with ``index``
        naming the failing position) when a launch does not halt."""
        if len(reqs) == 1:
            kind = "single"          # a degenerate chunk needs no folding
        env = self._envelope(kind, reqs)
        traced = env in self._envelopes
        if kind == "cohort":
            outs = run_kernel_cohort(reqs[0].prog, [r.mem0 for r in reqs],
                                     reqs[0].n_items, self.cfg)
        elif kind == "batch":
            outs = run_kernel_batch([r.prog for r in reqs],
                                    [r.mem0 for r in reqs],
                                    [r.n_items for r in reqs], self.cfg)
        else:
            mem, info = run_kernel(reqs[0].prog, reqs[0].mem0,
                                   reqs[0].n_items, self.cfg)
            info["batch_size"] = 1
            outs = [(mem, info)]
        # stats (including the hit/miss split) count successful dispatches
        # only: a chunk that raises is retried with fewer members (a
        # different envelope), so counting it would break the
        # hits + misses == dispatches invariant
        self._envelopes.add(env)
        if traced:
            self.stats.trace_hits += 1
        else:
            self.stats.trace_misses += 1
        self.stats.launches += len(reqs)
        self.stats.dispatches += 1
        return [Result(mem, info) for mem, info in outs]


# -- process-wide registry (shared with repro.dse.Evaluator) ----------------

_EXECUTORS: Dict[GGPUConfig, Executor] = {}


def get_executor(cfg: GGPUConfig) -> Executor:
    """The shared executor for ``cfg``'s simulation key. Callers that need
    frequency-faithful ``info['time_us']`` (e.g. fleet devices) should hold
    their own ``Executor(cfg)`` instead and restate nothing."""
    key = sim_key(cfg)
    if key not in _EXECUTORS:
        _EXECUTORS[key] = Executor(key)
    return _EXECUTORS[key]
