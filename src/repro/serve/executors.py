"""Compiled-executor layer: runs planned chunks on one device config.

An ``Executor`` owns the engine entry points for one ``GGPUConfig`` and
tracks the **envelope cache**: the set of compiled-stepper signatures
(chunk kind, batch size, wavefront count, program length, memory size,
opcode set) this process has already traced. The jit cache inside
``repro.ggpu.engine`` is keyed on exactly these statics, so a chunk whose
envelope has been seen re-uses the compiled stepper — repeat serving
traffic never re-traces — and the executor's hit/miss counters make that
visible (``BENCH_serve.json`` reports the hit rate).

Every executor separates its **simulation config** (``sim_cfg``:
``freq_mhz`` normalized out, the engine/compile key — frequency never
enters the traced cycle computation, and as a static jit argument every
distinct frequency target would otherwise recompile) from its
**reporting config** (``cfg``: the caller's true frequency).
``Result.info["time_us"]`` is always rescaled from cycles at the true
``freq_mhz``, so results are frequency-faithful even off the shared
registry — and executors at different frequency targets of the same
design share one compiled-stepper cache.

The launch path is **asynchronous**: ``submit`` stages and dispatches a
chunk, returning a ``PendingChunk`` immediately while the device runs;
``collect`` resolves it into ``Result``s (fetching only the small
cycles/stats arrays, plus each request's declared ``out_region`` slice of
memory — or the full image when none was declared). ``run`` is the
blocking composition of the two, so sync and async callers share one code
path and are bit-exact by construction.

``get_executor`` is a process-wide registry keyed by the simulation key;
callers with a non-default frequency get a lightweight view that shares
the envelope cache, stats, and memo with the canonical executor but
reports at the caller's true frequency. The registry is shared with
``repro.dse.Evaluator``, whose cycle cache lives on the executor
(``Executor.memo``): a DSE sweep and a serving fleet that touch the same
config share both the compiled steppers and the memoized bench results.

An executor also carries its **placement**: a ``mesh`` shards every
cohort/batch chunk's launch axis across the mesh's data-parallel devices
(``repro.ggpu.engine`` ``mesh=`` entry points — one dispatch, each
physical device stepping its own slice), and a ``device`` pins dispatch
to one ``jax.Device`` (how a fleet puts different simulated configs on
different physical devices so their compute genuinely overlaps). Both
default off; with one JAX device everything degrades to the PR-5
single-device behavior. ``shards`` reports the mesh's data-parallel
extent (1 = unsharded) — schedulers scale their chunk planning by it.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax

from repro.ggpu.engine import (BlockPatch, GGPUConfig, KernelLaunchError,
                               LaunchHandle, XorBlockPatch,
                               cohort_rows, launch_shards)
from repro.ggpu.engine import (run_kernel_async, run_kernel_batch_async,
                               run_kernel_cohort_async)
from repro.ggpu.engine.stepper import _n_wavefronts

from repro.serve.request import Request, Result


class DeviceTimeout(KernelLaunchError):
    """A dispatched chunk did not resolve within the executor's
    ``timeout_s`` — the stuck-device failure mode (DESIGN.md §Fault
    injection). ``index`` is ``None``: the whole chunk is suspect, every
    member is retried or quarantined by the scheduler. ``device_fault``
    marks it as the *device's* failure (not the program's), which is what
    a fleet counts toward eviction and re-routes to survivors."""

    device_fault = True

    def __init__(self, message: str, index: Optional[int] = None):
        super().__init__(message, 0 if index is None else index)
        self.index = index


@dataclasses.dataclass
class ExecutorStats:
    """Counts *executed* work: a launch re-run after a failed chunk (the
    LaunchQueue restore-and-retry path, or quarantine survivors) counts
    each time it actually runs — these are simulator-activity stats, not
    unique-request stats. hits + misses == dispatches always holds, and
    both are counted at *collection* (a dispatch that fails to halt is
    retried with fewer members, a different envelope)."""
    launches: int = 0        # kernel launches executed
    dispatches: int = 0      # compiled-stepper calls issued
    trace_hits: int = 0      # dispatches whose envelope was already traced
    trace_misses: int = 0    # dispatches that paid a trace/compile

    @property
    def batch_occupancy(self) -> float:
        """Mean launches per dispatch — the continuous-batching win."""
        return self.launches / self.dispatches if self.dispatches else 0.0

    @property
    def hit_rate(self) -> float:
        return (self.trace_hits / self.dispatches) if self.dispatches else 0.0

    def report(self) -> dict:
        return {
            "launches": self.launches,
            "dispatches": self.dispatches,
            "batch_occupancy": round(self.batch_occupancy, 3),
            "trace_hits": self.trace_hits,
            "trace_misses": self.trace_misses,
            "hit_rate": round(self.hit_rate, 3),
        }


def sim_key(cfg: GGPUConfig) -> GGPUConfig:
    """Normalize ``freq_mhz`` out of the executor/compile key: frequency
    scales reported ``time_us`` but never the traced cycle computation."""
    return dataclasses.replace(cfg, freq_mhz=500.0)


@dataclasses.dataclass
class PendingChunk:
    """One dispatched chunk in flight on the device, awaiting collection.
    ``t_dispatch`` is the wall clock at dispatch — the reference point for
    executor timeouts and fleet-level hedging."""
    handle: LaunchHandle
    kind: str
    reqs: List[Request]
    env: tuple
    traced: bool
    t_dispatch: float = 0.0


class Executor:
    """Runs (kind, requests) chunks on one config, with envelope-cache
    accounting and a memo dict shared across its users (see module doc).

    ``share`` hands this executor another one's mutable state (envelope
    cache, stats, memo) — how the registry builds frequency-faithful views
    over one canonical executor per simulation key. ``mesh`` and ``device``
    set placement (module doc): a mesh shards cohort/batch launch axes
    data-parallel, a device pins dispatch. Placement enters the envelope
    key, so differently-placed chunks never alias a compiled signature."""

    def __init__(self, cfg: GGPUConfig, *,
                 share: Optional["Executor"] = None,
                 mesh=None, device=None,
                 timeout_s: Optional[float] = None):
        self.cfg = cfg                    # reporting config (true freq)
        self.sim_cfg = sim_key(cfg)       # engine/compile config
        self.mesh = mesh
        self.device = device
        self.shards = launch_shards(mesh)
        # wall-clock budget a dispatched chunk gets before ``collect``
        # gives up with ``DeviceTimeout`` (None: wait forever — the
        # pre-fault-model behavior, and the default)
        self.timeout_s = timeout_s
        if share is None:
            self.stats = ExecutorStats()
            self.memo: Dict[tuple, object] = {}  # e.g. the DSE cycle cache
            self._envelopes: set = set()
        else:
            if share.sim_cfg != self.sim_cfg:
                raise ValueError("shared executors must agree on the "
                                 "simulation key")
            self.stats = share.stats
            self.memo = share.memo
            self._envelopes = share._envelopes

    # -- envelope accounting ------------------------------------------------

    def _envelope(self, kind: str, reqs: Sequence[Request]) -> tuple:
        """The static signature the engine jit-caches on for this chunk
        (opcode sets come from the requests' content-keyed cache), suffixed
        with this executor's placement — a sharded or pinned dispatch is a
        different compiled artifact than the plain one."""
        cfg = self.sim_cfg
        place = (self.shards, None if self.device is None else self.device.id)
        if kind == "cohort":
            # the engine buckets cohort sizes (cohort_rows), so the traced
            # envelope is the bucket, not the raw member count
            r = reqs[0]
            return ("cohort", cohort_rows(len(reqs), self.shards),
                    _n_wavefronts(r.n_items, cfg),
                    r.prog.shape[0], r.mem0.shape[0], r.static_ops(), place)
        if kind == "batch":
            P = max(r.prog.shape[0] for r in reqs)
            M = max(r.mem0.shape[0] for r in reqs)
            W = max(_n_wavefronts(r.n_items, cfg) for r in reqs)
            ops = tuple(sorted(set().union(
                *(r.static_ops() for r in reqs))))
            return ("batch", len(reqs), W, P, M, ops, place)
        r = reqs[0]
        return ("single", _n_wavefronts(r.n_items, cfg), r.prog.shape[0],
                r.mem0.shape[0], r.static_ops(), place)

    # -- execution ----------------------------------------------------------

    def submit(self, kind: str, reqs: Sequence[Request],
               patches=None) -> PendingChunk:
        """Stage and dispatch one planned chunk asynchronously; returns
        while the device still runs. Pair with ``collect``. ``patches``
        optionally overwrites regions of the chunk's staged memory with
        device arrays before dispatch — a ``repro.ggpu.engine.BlockPatch``
        or one ``[(lo, hi, src), ...]`` list per launch — the
        device-resident chaining path a dependency-aware scheduler uses to
        feed a producer's output into a consumer with no host transfer."""
        reqs = list(reqs)
        if len(reqs) == 1:
            kind = "single"          # a degenerate chunk needs no folding
        env = self._envelope(kind, reqs)
        traced = env in self._envelopes
        # the jit trace is paid HERE, at dispatch — record the envelope
        # now so identical-envelope chunks dispatched ahead in the same
        # pipeline window count as the hits they really are
        self._envelopes.add(env)
        regions = [r.out_region for r in reqs]
        if all(r is None for r in regions):
            regions = None
        cfg = self.sim_cfg
        place = (jax.default_device(self.device) if self.device is not None
                 else contextlib.nullcontext())
        with place:
            if kind == "cohort":
                h = run_kernel_cohort_async(
                    reqs[0].prog, [r.mem0 for r in reqs], reqs[0].n_items,
                    cfg, out_regions=regions, patches=patches,
                    mesh=self.mesh)
            elif kind == "batch":
                h = run_kernel_batch_async(
                    [r.prog for r in reqs], [r.mem0 for r in reqs],
                    [r.n_items for r in reqs], cfg, out_regions=regions,
                    patches=patches, mesh=self.mesh)
            else:
                # normalize the chunk-level patch forms down to the
                # single-launch flat list the engine entry point takes
                single = None
                if isinstance(patches, XorBlockPatch):
                    single = [(patches.lo, patches.hi, patches.block[0],
                               "xor")]
                elif isinstance(patches, BlockPatch):
                    single = [(patches.lo, patches.hi, patches.block[0])]
                elif patches is not None:
                    single = patches[0]
                h = run_kernel_async(
                    reqs[0].prog, reqs[0].mem0, reqs[0].n_items, cfg,
                    out_region=regions[0] if regions else None,
                    patches=single)
        return PendingChunk(h, kind, reqs, env, traced,
                            t_dispatch=time.monotonic())

    def chunk_ready(self, pending: PendingChunk) -> bool:
        """Non-blocking: has the device finished this chunk? (The hook a
        fault injector overrides to model stuck devices and stragglers.)"""
        return pending.handle.ready()

    def collect(self, pending: PendingChunk) -> List[Result]:
        """Resolve a dispatched chunk into per-launch ``Result``s in the
        chunk's own order, rescaling ``time_us`` to this executor's true
        frequency. Raises ``KernelLaunchError`` (with ``index`` naming the
        failing position) when a launch did not halt — stat counters move
        on successful collections only, preserving hits + misses ==
        dispatches (a failed chunk is retried with fewer members, a
        different envelope). With ``timeout_s`` set, a chunk still
        unresolved ``timeout_s`` after its dispatch raises
        ``DeviceTimeout`` (``index=None``: the whole chunk is suspect)."""
        if self.timeout_s is not None:
            deadline = pending.t_dispatch + self.timeout_s
            while not self.chunk_ready(pending):
                now = time.monotonic()
                if now >= deadline:
                    raise DeviceTimeout(
                        f"chunk of {len(pending.reqs)} launch(es) not "
                        f"resolved within {self.timeout_s}s of dispatch")
                time.sleep(min(1e-3, deadline - now))
        outs = pending.handle.results()
        if pending.traced:
            self.stats.trace_hits += 1
        else:
            self.stats.trace_misses += 1
        self.stats.launches += len(pending.reqs)
        self.stats.dispatches += 1
        results = []
        for mem, info in outs:
            info.setdefault("batch_size", 1)
            info["time_us"] = info["cycles"] / self.cfg.freq_mhz
            results.append(Result(mem, info))
        return results

    def run(self, kind: str, reqs: Sequence[Request]) -> List[Result]:
        """Execute one planned chunk synchronously (dispatch + collect)."""
        return self.collect(self.submit(kind, reqs))


# -- process-wide registry (shared with repro.dse.Evaluator) ----------------

_EXECUTORS: Dict[GGPUConfig, Executor] = {}       # canonical, by sim key
_VIEWS: Dict[tuple, Executor] = {}                # freq/placement views


def get_executor(cfg: GGPUConfig, *, mesh=None, device=None) -> Executor:
    """The shared executor for ``cfg``'s simulation key, reporting at
    ``cfg``'s true frequency: a non-default-frequency caller gets a view
    sharing the canonical executor's compiled-envelope cache, stats, and
    memo, with ``time_us`` rescaled from cycles at the caller's
    ``freq_mhz``. ``mesh``/``device`` placement likewise produces a view
    (keyed by placement) over the same canonical state — a sharded fleet
    and an unsharded DSE sweep of one config share one stats/memo pool."""
    key = sim_key(cfg)
    canon = _EXECUTORS.get(key)
    if canon is None:
        canon = _EXECUTORS.setdefault(key, Executor(key))
    if cfg == key and mesh is None and device is None:
        return canon
    vkey = (cfg, mesh, device)
    view = _VIEWS.get(vkey)
    if view is None:
        view = _VIEWS.setdefault(
            vkey, Executor(cfg, share=canon, mesh=mesh, device=device))
    return view
