"""Batched serving engine: prefill + decode with slot-based continuous
batching, plus the G-GPU kernel ``LaunchQueue``.

LLM side: a fixed decode batch of ``slots``; finished sequences free their
slot and the next queued request is prefilled into it (its KV written into
the shared cache at the slot's batch row). Greedy or temperature sampling.
This is the serve-side driver the decode dry-run cells lower.

G-GPU side: ``LaunchQueue`` batches simulator kernel launches the same way
the LLM engine batches decode requests — N same-shape (program, mem-image)
pairs are padded to a common envelope and ``jax.vmap``-ed over one compiled
stepper (``repro.ggpu.engine.run_kernel_batch``), so a traffic burst of
launches costs one dispatch instead of N.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ggpu.engine import GGPUConfig, KernelLaunchError
from repro.ggpu.engine import run_kernel as _ggpu_run_kernel
from repro.ggpu.engine import run_kernel_batch as _ggpu_run_kernel_batch
from repro.ggpu.engine import run_kernel_cohort as _ggpu_run_kernel_cohort
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.steps import make_decode_step


@dataclasses.dataclass
class EngineConfig:
    max_len: int = 256
    slots: int = 4
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        self.decode_fn = jax.jit(make_decode_step(cfg))

    def _sample(self, logits, rng):
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(rng, logits / self.ecfg.temperature,
                                      axis=-1)

    def generate(self, prompts: List[List[int]], max_new: int
                 ) -> List[List[int]]:
        """Slot-batched generation. Prompts are queued; each batch wave
        prefills up to ``slots`` prompts padded to a common length."""
        ecfg = self.ecfg
        results: List[Optional[List[int]]] = [None] * len(prompts)
        queue = list(range(len(prompts)))
        rng = jax.random.PRNGKey(ecfg.seed)
        while queue:
            wave = queue[:ecfg.slots]
            queue = queue[ecfg.slots:]
            plen = max(len(prompts[i]) for i in wave)
            batch = np.zeros((len(wave), plen), np.int32)
            for r, i in enumerate(wave):
                batch[r, plen - len(prompts[i]):] = prompts[i]  # left-pad
            cap = plen + max_new + 1
            logits, cache = M.prefill(self.params, self.cfg,
                                      tokens=jnp.asarray(batch), pad_to=cap)
            toks = [list(prompts[i]) for i in wave]
            last = self._sample(logits, rng)
            done = np.zeros(len(wave), bool)
            for r in range(len(wave)):
                toks[r].append(int(last[r]))
            for t in range(max_new - 1):
                rng, sub = jax.random.split(rng)
                logits, cache = self.decode_fn(
                    self.params, cache, last[:, None],
                    jnp.asarray(plen + t, jnp.int32))
                last = self._sample(logits, sub)
                for r in range(len(wave)):
                    if not done[r]:
                        tok = int(last[r])
                        toks[r].append(tok)
                        if tok == ecfg.eos_id:
                            done[r] = True
                if done.all():
                    break
            for r, i in enumerate(wave):
                results[i] = toks[r]
        return results  # type: ignore


@dataclasses.dataclass
class KernelLaunch:
    """One queued G-GPU kernel launch."""
    prog: np.ndarray
    mem0: np.ndarray
    n_items: int
    tag: str = ""


class LaunchQueue:
    """Multi-kernel launch queue for the G-GPU simulator.

    ``submit`` enqueues a (program, mem-image, n_items) launch and returns
    a ticket; ``flush`` executes everything queued and returns results in
    submission order. Launches of the *same kernel* (identical program,
    item count, and memory shape — the serving-traffic common case) are
    folded into one **cohort** stepper call, which amortizes the
    simulator's per-round fixed costs across the whole group; remaining
    launches with a matching wavefront count share one vmapped batch, and
    odd shapes fall back to the single-launch path. Groups are chunked at
    ``max_batch`` and drained deterministically in ticket order (each
    chunk executes in order of its earliest submission — never in dict or
    group-iteration order). All three paths are bit-exact per launch.
    """

    def __init__(self, cfg: GGPUConfig, max_batch: int = 64):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self._pending: List[KernelLaunch] = []

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "") -> int:
        """Queue a launch; returns its ticket (index into flush() order)."""
        self._pending.append(
            KernelLaunch(np.asarray(prog, np.int32),
                         np.asarray(mem0, np.int32), int(n_items), tag))
        return len(self._pending) - 1

    def discard(self, ticket: int) -> KernelLaunch:
        """Remove and return a pending launch by its current ticket (the
        recovery path after a failed flush: drop the poisoned launch,
        flush the rest). Later tickets shift down by one."""
        return self._pending.pop(ticket)

    def _wavefronts(self, n_items: int) -> int:
        L = self.cfg.wavefront
        return (n_items + L - 1) // L

    def flush(self) -> List[Tuple[np.ndarray, dict]]:
        """Run every queued launch; results come back in submission order
        with the queue's grouping recorded in ``info['batch_size']`` and
        the submission ``tag`` (if any) in ``info['tag']``. If any launch
        fails (e.g. hits ``max_steps``), the whole flush raises a
        ``KernelLaunchError`` naming the poisoned launch's ticket and tag,
        and every launch is restored to the queue so the caller can
        ``discard`` that ticket and retry the rest."""
        pending, self._pending = self._pending, []
        try:
            return self._run_all(pending)
        except BaseException:
            self._pending = pending + self._pending
            raise

    def _plan_chunks(self, pending: List[KernelLaunch]
                     ) -> List[Tuple[str, List[int]]]:
        """Grouping pass: (kind, tickets) chunks — same-kernel cohorts,
        same-wavefront vmap batches, singleton fallbacks — ordered by each
        chunk's first ticket. The drain order is a pure function of the
        submission order, never of dict/group iteration order."""
        cohorts: Dict[Tuple, List[int]] = {}
        for i, kl in enumerate(pending):
            key = (kl.prog.tobytes(), kl.n_items, kl.mem0.shape[0])
            cohorts.setdefault(key, []).append(i)
        chunks: List[Tuple[str, List[int]]] = []
        stragglers: List[int] = []
        for members in cohorts.values():
            if len(members) == 1:
                stragglers.append(members[0])
                continue
            for lo in range(0, len(members), self.max_batch):
                chunks.append(("cohort", members[lo:lo + self.max_batch]))
        # stragglers: vmap-batch per wavefront bucket, singles otherwise
        buckets: Dict[int, List[int]] = {}
        for i in sorted(stragglers):
            buckets.setdefault(self._wavefronts(pending[i].n_items),
                               []).append(i)
        for members in buckets.values():
            for lo in range(0, len(members), self.max_batch):
                chunk = members[lo:lo + self.max_batch]
                chunks.append(("single" if len(chunk) == 1 else "batch",
                               chunk))
        chunks.sort(key=lambda kc: kc[1][0])
        return chunks

    def _run_all(self, pending: List[KernelLaunch]
                 ) -> List[Tuple[np.ndarray, dict]]:
        results: List[Optional[Tuple[np.ndarray, dict]]] = \
            [None] * len(pending)

        def blame(chunk, exc: KernelLaunchError):
            """Re-raise a chunk failure naming the submission ticket."""
            ticket = chunk[exc.index]
            tag = pending[ticket].tag
            raise KernelLaunchError(
                f"launch ticket {ticket}" + (f" (tag {tag!r})" if tag
                                             else "")
                + f" hit max_steps without halting; discard({ticket}) "
                f"and flush() again to retry the rest", ticket) from exc

        for kind, chunk in self._plan_chunks(pending):
            try:
                if kind == "cohort":
                    i0 = chunk[0]
                    outs = _ggpu_run_kernel_cohort(
                        pending[i0].prog, [pending[i].mem0 for i in chunk],
                        pending[i0].n_items, self.cfg)
                elif kind == "batch":
                    outs = _ggpu_run_kernel_batch(
                        [pending[i].prog for i in chunk],
                        [pending[i].mem0 for i in chunk],
                        [pending[i].n_items for i in chunk], self.cfg)
                else:
                    i = chunk[0]
                    mem, info = _ggpu_run_kernel(
                        pending[i].prog, pending[i].mem0,
                        pending[i].n_items, self.cfg)
                    info["batch_size"] = 1
                    outs = [(mem, info)]
            except KernelLaunchError as exc:
                blame(chunk, exc)
            for i, out in zip(chunk, outs):
                results[i] = out
        for i, kl in enumerate(pending):
            if kl.tag:
                results[i][1]["tag"] = kl.tag
        return results  # type: ignore[return-value]
