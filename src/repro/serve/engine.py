"""Compatibility facade over the ``repro.serve`` package.

The serving subsystem used to be this single module; it is now a package
(DESIGN.md §Serving subsystem):

  * ``serve.request``   — ``Request``/``Result`` model (tickets, tags,
    priorities, deadlines); ``KernelLaunch`` is the legacy alias.
  * ``serve.scheduler`` — the continuous-batching core: chunk planner,
    incremental ``drain(budget)``, per-launch failure quarantine; plus the
    legacy strict-mode ``LaunchQueue``.
  * ``serve.executors`` — compiled-executor cache per config (envelope
    hit accounting, shared with ``repro.dse.Evaluator``).
  * ``serve.fleet``     — router over multiple ``GGPUConfig`` devices
    (e.g. a DSE Pareto front).
  * ``serve.llm``       — the slot-batched LLM ``Engine``.

Everything importable here before the split stays importable here
(including the ``GGPUConfig``/``KernelLaunchError`` engine re-exports this
module used to expose as attributes), with identical behavior:
``from repro.serve.engine import LaunchQueue, Engine`` works,
``LaunchQueue`` keeps its raise-and-restore flush semantics, and all
launch paths remain bit-exact per launch. The one deliberate behavior
change is the prefill-EOS bugfix: a sequence whose *first* generated
token is ``eos_id`` now stops immediately instead of decoding ``max_new``
post-EOS tokens.
"""
from repro.ggpu.engine import GGPUConfig, KernelLaunchError
from repro.serve.llm import Engine, EngineConfig
from repro.serve.request import KernelLaunch, Request, Result
from repro.serve.scheduler import LaunchQueue, Scheduler

__all__ = [
    "Engine", "EngineConfig", "GGPUConfig", "KernelLaunch",
    "KernelLaunchError", "LaunchQueue", "Request", "Result", "Scheduler",
]
