"""Continuous-batching scheduler: admission, chunk planning, incremental
drain, and per-launch failure quarantine.

The **chunk planner** (``plan_chunks``) is the grouping pass both tenants
of the serving core share: launches of the *same kernel* (identical
program, item count, memory shape) fold into one **cohort** stepper call;
remaining launches with a matching wavefront count share one vmapped
**batch**; odd shapes fall back to **single** dispatch. Groups are chunked
at ``max_batch`` and ordered by (priority desc, deadline asc, earliest
ticket) — with default metadata that is exactly the legacy first-ticket
order, a pure function of the submission sequence.

The ``Scheduler`` is the continuous-batching core. ``submit`` admits a
request (optionally bounded by ``max_pending``) and returns a monotonic
ticket; ``drain(budget)`` plans over *everything currently pending* and
executes chunks until ``budget`` launches have been served, so new
submissions interleave with in-flight work instead of waiting for a full
flush. A launch that fails (hits ``max_steps``) is moved to
``quarantined`` — its chunk's survivors are re-run and still complete in
the same drain; nothing is aborted and nothing must be manually discarded.

``drain`` is **pipelined**: it is ``dispatch(budget)`` (plan and
asynchronously stage + dispatch every budgeted chunk, so chunk *k+1* is
planned, padded, and uploaded while chunk *k* still runs on the device)
followed by ``collect()`` (resolve the in-flight queue in dispatch order,
quarantining failures per launch). ``max_inflight`` bounds how many
dispatched chunks may be outstanding before the oldest is collected —
the pipeline depth. Results are bit-exact with the serial path at any
depth; ``Fleet.drain`` uses the split API directly to dispatch to every
device before collecting from any.

``LaunchQueue`` remains the pre-package interface with its original
strict semantics (whole-flush raise + restore on failure); see the class
docstring. New code should use ``Scheduler``/``Fleet`` directly.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.ggpu.engine import GGPUConfig, KernelLaunchError
from repro.serve.executors import Executor, PendingChunk
from repro.serve.request import Request, Result


class AdmissionError(RuntimeError):
    """The scheduler's pending set is full (``max_pending`` reached)."""


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One planned dispatch: ``kind`` in {cohort, batch, single}, and the
    member positions into the planner's input sequence."""
    kind: str
    members: Tuple[int, ...]


def wavefronts(n_items: int, cfg: GGPUConfig) -> int:
    """Raw wavefront count — the planner's bucket key (and the fleet's
    occupancy proxy). Deliberately NOT the engine's ``_n_wavefronts``:
    that also rounds W up for ragged CU residency, which is a
    machine-shape concern — the executor's envelope keys use it — while
    grouping here must match the legacy plan exactly."""
    L = cfg.wavefront
    return (n_items + L - 1) // L


def plan_chunks(requests: Sequence[Request], cfg: GGPUConfig,
                max_batch: int = 64) -> List[Chunk]:
    """Grouping pass over a request sequence (see module doc). Member
    indices are positions into ``requests``; the chunk order is a pure
    function of the submission order and the requests' metadata, never of
    dict/group iteration order."""
    cohorts: Dict[tuple, List[int]] = {}
    for i, r in enumerate(requests):
        cohorts.setdefault(r.kernel_key(), []).append(i)
    chunks: List[Chunk] = []
    stragglers: List[int] = []
    for members in cohorts.values():
        if len(members) == 1:
            stragglers.append(members[0])
            continue
        for lo in range(0, len(members), max_batch):
            chunks.append(Chunk("cohort", tuple(members[lo:lo + max_batch])))
    # stragglers: vmap-batch per wavefront bucket, singles otherwise
    buckets: Dict[int, List[int]] = {}
    for i in sorted(stragglers):
        buckets.setdefault(wavefronts(requests[i].n_items, cfg), []).append(i)
    for members in buckets.values():
        for lo in range(0, len(members), max_batch):
            chunk = members[lo:lo + max_batch]
            chunks.append(Chunk("single" if len(chunk) == 1 else "batch",
                                tuple(chunk)))

    def order(c: Chunk):
        prio = max(requests[i].priority for i in c.members)
        deadline = min(requests[i].deadline_us for i in c.members)
        return (-prio, deadline, c.members[0])

    chunks.sort(key=order)
    return chunks


def plan_waves(tickets: Sequence[int], slots: int) -> List[List[int]]:
    """FIFO slot-wave admission: waves of at most ``slots`` tickets. The
    slot accounting shared by the LLM engine (decode slots) and callers
    that meter kernel submission."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    tickets = list(tickets)
    return [tickets[i:i + slots] for i in range(0, len(tickets), slots)]


@dataclasses.dataclass
class Quarantined:
    """A poisoned launch isolated by the scheduler, with its error."""
    request: Request
    error: KernelLaunchError


class Scheduler:
    """The continuous-batching core (see module doc).

    Construct from a config (the scheduler owns a private ``Executor``) or
    hand it a shared one (e.g. ``executors.get_executor`` — how the DSE
    evaluator and a serving fleet share compiled steppers). ``mesh`` and
    ``device`` set the private executor's placement; a sharded executor
    scales the planning width — chunks are planned at ``max_batch`` *per
    shard* (``max_batch * executor.shards`` launches folded into one
    dispatch), which is where the sharded throughput win comes from: one
    dispatch covers what would otherwise be ``shards`` pipelined ones."""

    def __init__(self, cfg: Optional[GGPUConfig] = None, *,
                 executor: Optional[Executor] = None, max_batch: int = 64,
                 max_pending: Optional[int] = None, max_inflight: int = 8,
                 mesh=None, device=None):
        if (cfg is None) == (executor is None):
            raise ValueError("pass exactly one of cfg or executor")
        if executor is not None and (mesh is not None or device is not None):
            raise ValueError("pass mesh/device only with cfg (placement "
                             "belongs to the executor)")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.executor = executor if executor is not None \
            else Executor(cfg, mesh=mesh, device=device)
        self.cfg = self.executor.cfg
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.max_inflight = max_inflight
        self._pending: Dict[int, Request] = {}   # ticket -> request (FIFO)
        self._next_ticket = 0
        self.quarantined: Dict[int, Quarantined] = {}
        self._completed: List[Result] = []       # buffered across failures
        self._inflight: Deque[PendingChunk] = deque()
        self._inflight_tickets: set = set()

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending_tickets(self) -> List[int]:
        return list(self._pending)

    @property
    def inflight_chunks(self) -> int:
        """Dispatched-but-uncollected chunks — the live pipeline depth
        (``Fleet.report`` surfaces it as per-device queue depth)."""
        return len(self._inflight)

    @property
    def plan_batch(self) -> int:
        """Effective planning width: ``max_batch`` launches per shard."""
        return self.max_batch * self.executor.shards

    # -- admission ----------------------------------------------------------

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "", priority: int = 0,
               deadline_us: float = math.inf,
               out_region: Optional[Tuple[int, int]] = None) -> int:
        """Admit a launch; returns its (monotonic) ticket. ``out_region``
        optionally declares the slice of the final memory image the caller
        wants back (``(0, 0)``: cycles-only, no download)."""
        return self.submit_request(Request(prog, mem0, n_items, tag,
                                           priority, deadline_us,
                                           out_region=out_region))

    def submit_request(self, req: Request) -> int:
        if self.max_pending is not None \
                and len(self._pending) >= self.max_pending:
            raise AdmissionError(
                f"scheduler full: {len(self._pending)} pending "
                f"(max_pending={self.max_pending})")
        req.ticket = self._next_ticket
        self._next_ticket += 1
        self._pending[req.ticket] = req
        return req.ticket

    def cancel(self, ticket: int) -> Request:
        """Remove a still-pending request by ticket."""
        return self._pending.pop(ticket)

    # -- drain --------------------------------------------------------------

    def dispatch(self, budget: Optional[int] = None) -> int:
        """Plan chunks over the pending-but-not-in-flight set and dispatch
        them asynchronously until ``budget`` launches have been staged
        (``None``: everything); returns how many launches were dispatched.
        Dispatch returns while the device still runs — staging/padding of
        chunk *k+1* overlaps chunk *k*'s compute. When more than
        ``max_inflight`` chunks are outstanding the oldest is collected
        (into the completed buffer) to bound the pipeline."""
        items = [r for r in self._pending.values()
                 if r.ticket not in self._inflight_tickets]
        chunks = plan_chunks(items, self.cfg, self.plan_batch)
        taken = 0
        for chunk in chunks:
            if budget is not None and taken >= budget:
                break
            reqs = [items[i] for i in chunk.members]
            taken += len(reqs)
            try:
                # shrink the window BEFORE dispatching so ``max_inflight``
                # bounds simultaneous in-flight chunks: 1 = strictly serial
                # (collect each chunk before the next is staged — the sync
                # reference), N = an N-deep dispatch-ahead pipeline
                while len(self._inflight) >= self.max_inflight:
                    self._collect_oldest()
                pending = self.executor.submit(chunk.kind, reqs)
                self._inflight.append(pending)
                self._inflight_tickets.update(r.ticket for r in reqs)
            except BaseException:
                self._abandon_inflight()
                raise
        return taken

    def collect(self) -> List[Result]:
        """Resolve every in-flight chunk (dispatch order) and return all
        results completed since the last collection, in ticket order;
        poisoned launches land in ``quarantined``."""
        try:
            while self._inflight:
                self._collect_oldest()
        except BaseException:
            self._abandon_inflight()
            raise
        out, self._completed = self._completed, []
        out.sort(key=lambda r: r.info["ticket"])
        return out

    def drain(self, budget: Optional[int] = None) -> List[Result]:
        """Serve pending work: plan chunks over the current pending set and
        execute them in planned order until ``budget`` launches have been
        taken off the queue (``None``: everything) — dispatching ahead of
        collection (see ``dispatch``/``collect``). Returns the completed
        ``Result``s of this call in ticket order; poisoned launches land in
        ``quarantined`` (they count against the budget but produce no
        result). Per-launch results are bit-exact with direct
        ``run_kernel`` regardless of how submissions interleave with
        drains or how deep the pipeline runs.

        Unexpected failures (anything other than a launch hitting
        ``max_steps``) propagate, but lose no work: requests leave
        ``_pending`` only when they complete or are quarantined, in-flight
        chunks are abandoned back to pending, and completed results are
        buffered on the scheduler until a drain returns — so after an
        interrupt or a malformed launch, the next ``drain`` resumes with
        everything still queued plus the results already computed."""
        self.dispatch(budget)
        return self.collect()

    def flush(self) -> List[Result]:
        """Monolithic drain of everything pending."""
        return self.drain()

    def _abandon_inflight(self) -> None:
        """Drop in-flight chunks after an unexpected failure: their
        requests are still pending, so the next dispatch re-plans them —
        no work is lost, nothing is double-served."""
        self._inflight.clear()
        self._inflight_tickets.clear()

    def _collect_oldest(self) -> None:
        pending = self._inflight.popleft()
        for r in pending.reqs:
            self._inflight_tickets.discard(r.ticket)
        self._completed.extend(self._collect_quarantining(pending))

    def _collect_quarantining(self, pending: PendingChunk) -> List[Result]:
        """Collect one chunk; on failure isolate the blamed launch into
        ``quarantined`` and re-dispatch the survivors until the chunk
        completes. Survivor results stay bit-exact: cohort/batch folding
        is per-launch exact at any membership."""
        out: List[Result] = []
        while True:
            reqs = pending.reqs
            try:
                results = self.executor.collect(pending)
            except KernelLaunchError as exc:
                bad = reqs[exc.index]
                survivors = reqs[:exc.index] + reqs[exc.index + 1:]
                del self._pending[bad.ticket]
                self.quarantined[bad.ticket] = Quarantined(bad, exc)
                if not survivors:
                    return out
                pending = self.executor.submit(pending.kind, survivors)
                continue
            for req, res in zip(reqs, results):
                res.info["ticket"] = req.ticket
                if req.tag:
                    res.info["tag"] = req.tag
                del self._pending[req.ticket]
                out.append(res)
            return out


class LaunchQueue:
    """Multi-kernel launch queue for the G-GPU simulator (the pre-package
    interface, bit-exact compatible).

    ``submit`` enqueues a (program, mem-image, n_items) launch and returns
    a ticket; ``flush`` executes everything queued and returns results in
    submission order. Launches of the *same kernel* (identical program,
    item count, and memory shape — the serving-traffic common case) are
    folded into one **cohort** stepper call, which amortizes the
    simulator's per-round fixed costs across the whole group; remaining
    launches with a matching wavefront count share one vmapped batch, and
    odd shapes fall back to the single-launch path. Groups are chunked at
    ``max_batch`` and drained deterministically in ticket order (each
    chunk executes in order of its earliest submission — never in dict or
    group-iteration order). All three paths are bit-exact per launch.

    Failure semantics are the legacy strict mode: if any launch fails
    (e.g. hits ``max_steps``), the whole flush raises a
    ``KernelLaunchError`` naming the poisoned launch's ticket and tag, and
    every launch is restored to the queue so the caller can ``discard``
    that ticket and retry the rest. ``Scheduler`` supersedes this with
    per-launch quarantine and incremental ``drain``.
    """

    def __init__(self, cfg: GGPUConfig, max_batch: int = 64):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self.executor = Executor(cfg)
        self._pending: List[Request] = []

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "") -> int:
        """Queue a launch; returns its ticket (index into flush() order)."""
        self._pending.append(Request(prog, mem0, n_items, tag))
        return len(self._pending) - 1

    def discard(self, ticket: int) -> Request:
        """Remove and return a pending launch by its current ticket (the
        recovery path after a failed flush: drop the poisoned launch,
        flush the rest). Later tickets shift down by one."""
        return self._pending.pop(ticket)

    def _plan_chunks(self, pending: List[Request]
                     ) -> List[Tuple[str, List[int]]]:
        """Legacy-shaped view of the shared planner (kind, tickets)."""
        return [(c.kind, list(c.members))
                for c in plan_chunks(pending, self.cfg, self.max_batch)]

    def flush(self) -> List[Result]:
        """Run every queued launch; results come back in submission order
        with the queue's grouping recorded in ``info['batch_size']`` and
        the submission ``tag`` (if any) in ``info['tag']``."""
        pending, self._pending = self._pending, []
        try:
            return self._run_all(pending)
        except BaseException:
            self._pending = pending + self._pending
            raise

    def _run_all(self, pending: List[Request]) -> List[Result]:
        results: List[Optional[Result]] = [None] * len(pending)

        def blame(chunk, exc: KernelLaunchError):
            """Re-raise a chunk failure naming the submission ticket."""
            ticket = chunk[exc.index]
            tag = pending[ticket].tag
            raise KernelLaunchError(
                f"launch ticket {ticket}" + (f" (tag {tag!r})" if tag
                                             else "")
                + f" hit max_steps without halting; discard({ticket}) "
                f"and flush() again to retry the rest", ticket) from exc

        for kind, chunk in self._plan_chunks(pending):
            try:
                outs = self.executor.run(kind, [pending[i] for i in chunk])
            except KernelLaunchError as exc:
                blame(chunk, exc)
            for i, out in zip(chunk, outs):
                results[i] = out
        for i, req in enumerate(pending):
            results[i].info["ticket"] = i
            if req.tag:
                results[i].info["tag"] = req.tag
        return results  # type: ignore[return-value]
