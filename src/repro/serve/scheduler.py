"""Continuous-batching scheduler: admission, chunk planning, incremental
drain, and per-launch failure quarantine.

The **chunk planner** (``plan_chunks``) is the grouping pass both tenants
of the serving core share: launches of the *same kernel* (identical
program, item count, memory shape) fold into one **cohort** stepper call;
remaining launches with a matching wavefront count share one vmapped
**batch**; odd shapes fall back to **single** dispatch. Groups are chunked
at ``max_batch`` and ordered by (priority desc, deadline asc, earliest
ticket) — with default metadata that is exactly the legacy first-ticket
order, a pure function of the submission sequence.

The ``Scheduler`` is the continuous-batching core. ``submit`` admits a
request (optionally bounded by ``max_pending``) and returns a monotonic
ticket; ``drain(budget)`` plans over *everything currently pending* and
executes chunks until ``budget`` launches have been served, so new
submissions interleave with in-flight work instead of waiting for a full
flush. A launch that fails (hits ``max_steps``) is moved to
``quarantined`` — its chunk's survivors are re-run and still complete in
the same drain; nothing is aborted and nothing must be manually discarded.

``drain`` is **pipelined**: it is ``dispatch(budget)`` (plan and
asynchronously stage + dispatch every budgeted chunk, so chunk *k+1* is
planned, padded, and uploaded while chunk *k* still runs on the device)
followed by ``collect()`` (resolve the in-flight queue in dispatch order,
quarantining failures per launch). ``max_inflight`` bounds how many
dispatched chunks may be outstanding before the oldest is collected —
the pipeline depth. Results are bit-exact with the serial path at any
depth; ``Fleet.drain`` uses the split API directly to dispatch to every
device before collecting from any.

The scheduler is **dependency-aware** (DESIGN.md §Kernel graphs): a
request may declare ``deps`` edges naming producer tickets whose final
memory feeds regions of its own image. Planning then works over the
topological *ready set* — a request is ready once every producer has
been **dispatched** (not collected: an in-flight producer feeds its
consumers without a collect barrier; XLA sequences the reads). Ready
consumers are dispatched with ``patches``: device-resident slices of
their producers' final memory (``LaunchHandle.device_mem`` /
``device_mem_block``) written into the consumer's staged buffer before
its own dispatch — a producer→consumer edge costs zero host round-trips.
A producer's handle stays **resident** (``_resident``) from its dispatch
until every consumer has been collected, so survivor re-dispatch after a
quarantine — and re-dispatch after an abandoned drain — can always
rebuild its patches. When a producer is quarantined, its consumers are
poisoned transitively: pending ones are quarantined immediately,
in-flight ones at their collection (``DependencyError`` names the failed
producer); their results are never returned.

``LaunchQueue`` remains the pre-package interface with its original
strict semantics (whole-flush raise + restore on failure); see the class
docstring. New code should use ``Scheduler``/``Fleet`` directly.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.ggpu.engine import BlockPatch, GGPUConfig, KernelLaunchError
from repro.registry import SCHEDULERS
from repro.serve.executors import Executor, PendingChunk
from repro.serve.request import Dep, Request, Result, result_checksum


class AdmissionError(RuntimeError):
    """The scheduler's pending set is full (``max_pending`` reached)."""


class DependencyError(KernelLaunchError):
    """A launch was quarantined because a producer it depends on was —
    its input region would have been the failed producer's garbage."""


class ChecksumError(KernelLaunchError):
    """A collected result failed its request's output-checksum audit
    (``Request.audit``): the launch ran to completion but produced
    corrupted words — the silent-data-corruption failure mode an SEU
    induces. ``device_fault`` marks the *device* as suspect (the program
    is fine; a re-run elsewhere, or even here, normally passes)."""

    device_fault = True


class DeadlineExceeded(KernelLaunchError):
    """A request's wall-clock latency budget (``deadline_us``, measured
    from its admission stamp ``arrival_s``) expired before it was
    dispatched; a preemptive deadline policy drops it to quarantine
    instead of spending batch slots on a result nobody will accept."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-with-backoff for failed or corrupted launches: a
    blamed launch is re-staged and re-dispatched (with its chunk's
    survivors) up to ``max_retries`` times before quarantine;
    ``backoff_s`` sleeps ``backoff_s * attempt`` before each re-dispatch
    (linear backoff — attempt 1 waits one unit, attempt 2 two). Retries
    apply to max-steps failures, ``DeviceTimeout``, and ``ChecksumError``
    audits alike; dependency poisoning is never retried (the producer's
    output is gone for good)."""
    max_retries: int = 2
    backoff_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One planned dispatch: ``kind`` in {cohort, batch, single}, and the
    member positions into the planner's input sequence."""
    kind: str
    members: Tuple[int, ...]


def wavefronts(n_items: int, cfg: GGPUConfig) -> int:
    """Raw wavefront count — the planner's bucket key (and the fleet's
    occupancy proxy). Deliberately NOT the engine's ``_n_wavefronts``:
    that also rounds W up for ragged CU residency, which is a
    machine-shape concern — the executor's envelope keys use it — while
    grouping here must match the legacy plan exactly."""
    L = cfg.wavefront
    return (n_items + L - 1) // L


def plan_chunks(requests: Sequence[Request], cfg: GGPUConfig,
                max_batch: int = 64) -> List[Chunk]:
    """Grouping pass over a request sequence (see module doc). Member
    indices are positions into ``requests``; the chunk order is a pure
    function of the submission order and the requests' metadata, never of
    dict/group iteration order."""
    cohorts: Dict[tuple, List[int]] = {}
    for i, r in enumerate(requests):
        cohorts.setdefault(r.kernel_key(), []).append(i)
    chunks: List[Chunk] = []
    stragglers: List[int] = []
    for members in cohorts.values():
        if len(members) == 1:
            stragglers.append(members[0])
            continue
        for lo in range(0, len(members), max_batch):
            chunks.append(Chunk("cohort", tuple(members[lo:lo + max_batch])))
    # stragglers: vmap-batch per wavefront bucket, singles otherwise
    buckets: Dict[int, List[int]] = {}
    for i in sorted(stragglers):
        buckets.setdefault(wavefronts(requests[i].n_items, cfg), []).append(i)
    for members in buckets.values():
        for lo in range(0, len(members), max_batch):
            chunk = members[lo:lo + max_batch]
            chunks.append(Chunk("single" if len(chunk) == 1 else "batch",
                                tuple(chunk)))

    def order(c: Chunk):
        prio = max(requests[i].priority for i in c.members)
        deadline = min(requests[i].deadline_us for i in c.members)
        return (-prio, deadline, c.members[0])

    chunks.sort(key=order)
    return chunks


def plan_waves(tickets: Sequence[int], slots: int) -> List[List[int]]:
    """FIFO slot-wave admission: waves of at most ``slots`` tickets. The
    slot accounting shared by the LLM engine (decode slots) and callers
    that meter kernel submission."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    tickets = list(tickets)
    return [tickets[i:i + slots] for i in range(0, len(tickets), slots)]


@dataclasses.dataclass
class Quarantined:
    """A poisoned launch isolated by the scheduler, with its error."""
    request: Request
    error: KernelLaunchError


class Scheduler:
    """The continuous-batching core (see module doc).

    Construct from a config (the scheduler owns a private ``Executor``) or
    hand it a shared one (e.g. ``executors.get_executor`` — how the DSE
    evaluator and a serving fleet share compiled steppers). ``mesh`` and
    ``device`` set the private executor's placement; a sharded executor
    scales the planning width — chunks are planned at ``max_batch`` *per
    shard* (``max_batch * executor.shards`` launches folded into one
    dispatch), which is where the sharded throughput win comes from: one
    dispatch covers what would otherwise be ``shards`` pipelined ones.
    ``policy`` selects the chunk-planning strategy by registered name
    (the ``SCHEDULERS`` registry axis; ``"cohort"`` is the legacy plan,
    see ``repro.serve.policies``) or as a direct callable with the
    ``plan_chunks`` contract."""

    def __init__(self, cfg: Optional[GGPUConfig] = None, *,
                 executor: Optional[Executor] = None, max_batch: int = 64,
                 max_pending: Optional[int] = None, max_inflight: int = 8,
                 mesh=None, device=None, policy="cohort",
                 retry: Optional[RetryPolicy] = None):
        if (cfg is None) == (executor is None):
            raise ValueError("pass exactly one of cfg or executor")
        if executor is not None and (mesh is not None or device is not None):
            raise ValueError("pass mesh/device only with cfg (placement "
                             "belongs to the executor)")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        self.executor = executor if executor is not None \
            else Executor(cfg, mesh=mesh, device=device)
        self.cfg = self.executor.cfg
        # chunk-planning policy: a registered name (SCHEDULERS axis —
        # "cohort" is the legacy plan) or a callable with the
        # ``plan_chunks`` contract
        self.policy = policy if isinstance(policy, str) else \
            getattr(policy, "__name__", str(policy))
        self._plan = SCHEDULERS.get(policy) if isinstance(policy, str) \
            else policy
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.max_inflight = max_inflight
        # bounded retry of failed/corrupted launches (None: quarantine on
        # first failure — the pre-fault-model behavior, and the default)
        self.retry = retry
        self._pending: Dict[int, Request] = {}   # ticket -> request (FIFO)
        self._next_ticket = 0
        self.quarantined: Dict[int, Quarantined] = {}
        self._completed: List[Result] = []       # buffered across failures
        self._inflight: Deque[PendingChunk] = deque()
        self._inflight_tickets: set = set()
        # dependency state (module doc): producer -> uncollected consumers,
        # producer -> (dispatched chunk, index) while any consumer waits,
        # in-flight consumer -> its quarantined producer
        self._dep_waiters: Dict[int, set] = {}
        self._resident: Dict[int, Tuple[PendingChunk, int]] = {}
        self._poisoned: Dict[int, int] = {}

    def __len__(self) -> int:
        return len(self._pending)

    @property
    def pending_tickets(self) -> List[int]:
        return list(self._pending)

    @property
    def inflight_chunks(self) -> int:
        """Dispatched-but-uncollected chunks — the live pipeline depth
        (``Fleet.report`` surfaces it as per-device queue depth)."""
        return len(self._inflight)

    @property
    def plan_batch(self) -> int:
        """Effective planning width: ``max_batch`` launches per shard."""
        return self.max_batch * self.executor.shards

    # -- admission ----------------------------------------------------------

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "", priority: int = 0,
               deadline_us: float = math.inf,
               out_region: Optional[Tuple[int, int]] = None,
               deps: Sequence[Dep] = ()) -> int:
        """Admit a launch; returns its (monotonic) ticket. ``out_region``
        optionally declares the slice of the final memory image the caller
        wants back (``(0, 0)``: cycles-only, no download); ``deps``
        declares producer edges (module doc)."""
        return self.submit_request(Request(prog, mem0, n_items, tag,
                                           priority, deadline_us,
                                           out_region=out_region,
                                           deps=tuple(deps)))

    def submit_request(self, req: Request) -> int:
        if self.max_pending is not None \
                and len(self._pending) >= self.max_pending:
            raise AdmissionError(
                f"scheduler full: {len(self._pending)} pending "
                f"(max_pending={self.max_pending})")
        if req.deps:
            req.deps = tuple(self._resolve_dep(d) for d in req.deps)
        if req.arrival_s is None:
            # admission stamp: deadline-drop policies measure the
            # wall-clock latency budget from here
            req.arrival_s = time.monotonic()
        req.ticket = self._next_ticket
        self._next_ticket += 1
        self._pending[req.ticket] = req
        for d in req.deps:
            self._dep_waiters.setdefault(d.producer, set()).add(req.ticket)
            if d.producer in self._inflight_tickets \
                    and d.producer not in self._resident:
                # producer dispatched before it had waiters: register its
                # residency now so this consumer can be planned at once
                for chunk in self._inflight:
                    for idx, r in enumerate(chunk.reqs):
                        if r.ticket == d.producer:
                            self._resident[d.producer] = (chunk, idx)
        return req.ticket

    def _resolve_dep(self, d: Dep) -> Dep:
        """Validate one edge at admission (malformed edges bounce the
        submit, they never poison a drain) and pin its ``src`` region:
        explicit > the producer's non-empty ``out_region`` > the full
        image when the producer declared no region at all."""
        producer = self._pending.get(d.producer)
        if producer is None and d.producer in self._resident:
            chunk, idx = self._resident[d.producer]
            producer = chunk.reqs[idx]
        if producer is None:
            state = ("quarantined" if d.producer in self.quarantined
                     else "unknown or already collected")
            raise ValueError(f"dep producer ticket {d.producer} is {state}")
        src = d.src
        if src is None:
            if producer.out_region is None:
                src = (0, producer.mem0.shape[0])
            elif producer.out_region[1] > producer.out_region[0]:
                src = producer.out_region
            else:
                raise ValueError(
                    f"dep on producer ticket {d.producer} needs an explicit "
                    "src: the producer declares the empty out_region (0, 0)")
        if not (0 <= src[0] <= src[1] <= producer.mem0.shape[0]):
            raise ValueError(f"dep src {src} outside producer ticket "
                             f"{d.producer}'s memory image "
                             f"[0, {producer.mem0.shape[0]})")
        if src[1] - src[0] != d.dst[1] - d.dst[0]:
            raise ValueError(f"dep src {src} and dst {d.dst} widths differ")
        return Dep(d.producer, d.dst, src)

    def cancel(self, ticket: int) -> Request:
        """Remove a still-pending request by ticket. A request that is in
        flight or has consumers waiting on it cannot be cancelled."""
        if ticket in self._inflight_tickets:
            raise ValueError(f"ticket {ticket} is in flight")
        if self._dep_waiters.get(ticket):
            raise ValueError(f"ticket {ticket} has waiting consumers")
        req = self._pending.pop(ticket)
        self._release_deps(req)
        return req

    # -- drain --------------------------------------------------------------

    def _ready(self) -> List[Request]:
        """The planner's input: pending, not in flight, every producer
        already dispatched (resident) — the topological ready set."""
        return [r for r in self._pending.values()
                if r.ticket not in self._inflight_tickets
                and all(d.producer in self._resident for d in r.deps)]

    def dispatch(self, budget: Optional[int] = None) -> int:
        """Plan chunks over the ready set (pending, not in flight, every
        producer dispatched) and dispatch them asynchronously until
        ``budget`` launches have been staged (``None``: everything);
        returns how many launches were dispatched. Dispatch returns while
        the device still runs — staging/padding of chunk *k+1* overlaps
        chunk *k*'s compute. When more than ``max_inflight`` chunks are
        outstanding the oldest is collected (into the completed buffer) to
        bound the pipeline. Dispatching a producer makes its consumers
        ready, so planning repeats until no progress — a whole DAG drains
        in one call, producers feeding in-flight consumers with no collect
        barrier in between."""
        taken = 0
        while budget is None or taken < budget:
            items = self._ready()
            chunks = self._plan(items, self.cfg, self.plan_batch)
            progress = False
            for chunk in chunks:
                if budget is not None and taken >= budget:
                    break
                if chunk.kind == "drop":
                    # a preemptive policy (e.g. "deadline-drop") planned
                    # these members out of the batch: quarantine them with
                    # DeadlineExceeded instead of dispatching — they count
                    # against the budget (taken off the queue) but never
                    # occupy a device
                    for r in (items[i] for i in chunk.members):
                        if r.ticket in self._pending \
                                and r.ticket not in self._inflight_tickets:
                            taken += 1
                            self._quarantine(r, DeadlineExceeded(
                                f"ticket {r.ticket} missed its "
                                f"{r.deadline_us}us deadline before "
                                f"dispatch"))
                            progress = True
                    continue
                try:
                    # shrink the window BEFORE dispatching so
                    # ``max_inflight`` bounds simultaneous in-flight
                    # chunks: 1 = strictly serial (collect each chunk
                    # before the next is staged — the sync reference),
                    # N = an N-deep dispatch-ahead pipeline
                    while len(self._inflight) >= self.max_inflight:
                        self._collect_oldest()
                    # the window collection above may have quarantined a
                    # planned-but-undispatched consumer (cascade): keep
                    # only members that are still live
                    reqs = [r for r in (items[i] for i in chunk.members)
                            if r.ticket in self._pending
                            and r.ticket not in self._inflight_tickets]
                    if not reqs:
                        continue
                    taken += len(reqs)
                    pending = self.executor.submit(
                        chunk.kind, reqs,
                        self._chunk_patches(reqs))
                    self._inflight.append(pending)
                    self._inflight_tickets.update(r.ticket for r in reqs)
                    self._note_dispatched(pending)
                    progress = True
                except BaseException:
                    self._abandon_inflight()
                    raise
            if not progress:
                break
        return taken

    def _note_dispatched(self, pending: PendingChunk) -> None:
        """Record residency for dispatched requests that have consumers
        waiting: the handle (and with it the device-side final memory)
        stays reachable until every consumer has been collected."""
        for idx, r in enumerate(pending.reqs):
            if self._dep_waiters.get(r.ticket):
                self._resident[r.ticket] = (pending, idx)

    def _chunk_patches(self, reqs: Sequence[Request]):
        """Build the device-resident patches for one planned chunk: the
        fused ``BlockPatch`` when every member draws the same region from
        producers co-located in one resident chunk (one device op feeds
        the whole chunk), per-launch patch lists otherwise, ``None`` when
        the chunk has no dependencies."""
        if not any(r.deps for r in reqs):
            return None
        fused = self._fused_patch(reqs)
        if fused is not None:
            return fused
        per = []
        for r in reqs:
            plist = []
            for d in r.deps:
                chunk, idx = self._resident[d.producer]
                plist.append((d.dst[0], d.dst[1],
                              chunk.handle.device_mem(idx, d.src)))
            per.append(plist or None)
        return per

    def _fused_patch(self, reqs: Sequence[Request]):
        """The chunk-to-chunk fast path: every member has exactly one dep,
        all with identical (dst, src) regions, and every producer lives in
        the same resident chunk — one fused slice of the producer chunk's
        memory feeds the whole consumer chunk."""
        if not all(len(r.deps) == 1 for r in reqs):
            return None
        d0 = reqs[0].deps[0]
        if not all(r.deps[0].dst == d0.dst and r.deps[0].src == d0.src
                   for r in reqs):
            return None
        entries = [self._resident[r.deps[0].producer] for r in reqs]
        chunk0 = entries[0][0]
        if any(e[0] is not chunk0 for e in entries):
            return None
        block = chunk0.handle.device_mem_block(*d0.src)
        idxs = [e[1] for e in entries]
        if idxs != list(range(len(chunk0.reqs))):
            block = jnp.take(block, jnp.asarray(np.asarray(idxs, np.int32)),
                             axis=0)
        return BlockPatch(d0.dst[0], d0.dst[1], block)

    def collect(self) -> List[Result]:
        """Resolve every in-flight chunk (dispatch order) and return all
        results completed since the last collection, in ticket order;
        poisoned launches land in ``quarantined``."""
        try:
            while self._inflight:
                self._collect_oldest()
        except BaseException:
            self._abandon_inflight()
            raise
        out, self._completed = self._completed, []
        out.sort(key=lambda r: r.info["ticket"])
        return out

    # -- incremental collection (the fleet resilience surface) --------------

    @property
    def inflight(self) -> Tuple[PendingChunk, ...]:
        """The dispatched-but-uncollected chunks, oldest first — the
        read-only view a fleet's hedging policy scans for stragglers."""
        return tuple(self._inflight)

    def oldest_dispatch(self) -> float:
        """Dispatch wall clock of the oldest in-flight chunk (``inf``
        when nothing is in flight)."""
        return self._inflight[0].t_dispatch if self._inflight \
            else math.inf

    def _resolvable(self, pending: PendingChunk) -> bool:
        """Would collecting this chunk return without waiting on the
        device? True when the device has finished it, or when it is
        already past the executor timeout (collecting then raises
        ``DeviceTimeout`` immediately — also no wait)."""
        if self.executor.chunk_ready(pending):
            return True
        t = getattr(self.executor, "timeout_s", None)
        return t is not None \
            and time.monotonic() - pending.t_dispatch >= t

    def collect_ready(self) -> List[Result]:
        """Resolve only the in-flight chunks that are already finished
        (or past the executor timeout), never blocking on the rest —
        the readiness-ordered collection a resilient fleet drains with,
        so one straggling device never serializes the others'
        collections. Returns the results completed by this call, ticket
        order; unfinished chunks keep their relative (dispatch) order."""
        try:
            for _ in range(len(self._inflight)):
                if self._resolvable(self._inflight[0]):
                    self._collect_oldest()
                else:
                    self._inflight.rotate(-1)
        except BaseException:
            self._abandon_inflight()
            raise
        out, self._completed = self._completed, []
        out.sort(key=lambda r: r.info["ticket"])
        return out

    def collect_step(self) -> List[Result]:
        """Blocking-collect the single oldest in-flight chunk — the
        guaranteed-progress move a resilient fleet makes when nothing is
        resolvable anywhere. Returns the results it completed."""
        if not self._inflight:
            return []
        try:
            self._collect_oldest()
        except BaseException:
            self._abandon_inflight()
            raise
        out, self._completed = self._completed, []
        out.sort(key=lambda r: r.info["ticket"])
        return out

    def drain(self, budget: Optional[int] = None) -> List[Result]:
        """Serve pending work: plan chunks over the current pending set and
        execute them in planned order until ``budget`` launches have been
        taken off the queue (``None``: everything) — dispatching ahead of
        collection (see ``dispatch``/``collect``). Returns the completed
        ``Result``s of this call in ticket order; poisoned launches land in
        ``quarantined`` (they count against the budget but produce no
        result). Per-launch results are bit-exact with direct
        ``run_kernel`` regardless of how submissions interleave with
        drains or how deep the pipeline runs.

        Unexpected failures (anything other than a launch hitting
        ``max_steps``) propagate, but lose no work: requests leave
        ``_pending`` only when they complete or are quarantined, in-flight
        chunks are abandoned back to pending, and completed results are
        buffered on the scheduler until a drain returns — so after an
        interrupt or a malformed launch, the next ``drain`` resumes with
        everything still queued plus the results already computed."""
        self.dispatch(budget)
        return self.collect()

    def flush(self) -> List[Result]:
        """Monolithic drain of everything pending."""
        return self.drain()

    def _abandon_inflight(self) -> None:
        """Drop in-flight chunks after an unexpected failure: their
        requests are still pending, so the next dispatch re-plans them —
        no work is lost, nothing is double-served. Residency entries
        pointing into the abandoned chunks are dropped with them (the
        producers re-dispatch and re-register); entries for
        already-collected producers survive, so abandoned consumers can
        rebuild their patches on re-dispatch. In-flight consumers of a
        quarantined producer go straight to quarantine — their producer's
        output is gone for good."""
        abandoned = {id(c) for c in self._inflight}
        self._inflight.clear()
        self._inflight_tickets.clear()
        self._resident = {t: e for t, e in self._resident.items()
                          if id(e[0]) not in abandoned}
        poisoned, self._poisoned = self._poisoned, {}
        for ticket, producer in poisoned.items():
            req = self._pending.get(ticket)
            if req is not None:
                self._quarantine(req, DependencyError(
                    f"producer ticket {producer} was quarantined"))

    def _collect_oldest(self) -> None:
        pending = self._inflight.popleft()
        for r in pending.reqs:
            self._inflight_tickets.discard(r.ticket)
        self._completed.extend(self._collect_quarantining(pending))

    def _release_deps(self, req: Request) -> None:
        """A consumer reached a terminal state: stop holding its
        producers' handles resident once no consumer still waits."""
        for d in req.deps:
            waiters = self._dep_waiters.get(d.producer)
            if waiters is None:
                continue
            waiters.discard(req.ticket)
            if not waiters:
                del self._dep_waiters[d.producer]
                self._resident.pop(d.producer, None)

    def _quarantine(self, req: Request,
                    exc: KernelLaunchError) -> None:
        """Isolate one launch and poison its consumers transitively:
        pending consumers are quarantined right here, in-flight ones at
        their own collection (their result is garbage — the patch read the
        failed producer's memory)."""
        self._pending.pop(req.ticket, None)
        self.quarantined[req.ticket] = Quarantined(req, exc)
        self._release_deps(req)
        waiters = self._dep_waiters.pop(req.ticket, set())
        self._resident.pop(req.ticket, None)
        for ticket in waiters:
            if ticket in self._poisoned:
                continue
            if ticket in self._inflight_tickets:
                self._poisoned[ticket] = req.ticket
            elif ticket in self._pending:
                self._quarantine(self._pending[ticket], DependencyError(
                    f"producer ticket {req.ticket} was quarantined"))

    def _retryable(self, req: Request, exc: KernelLaunchError) -> bool:
        """May this blamed launch be re-staged and re-dispatched? Only
        under a retry policy with budget left, never for dependency
        poisoning (the producer's output is gone), and only while every
        producer it needs is still resident (its patches can be
        rebuilt)."""
        if self.retry is None or req.attempts >= self.retry.max_retries:
            return False
        if isinstance(exc, DependencyError) or req.ticket in self._poisoned:
            return False
        return all(d.producer in self._resident for d in req.deps)

    def _backoff(self, attempt: int) -> None:
        if self.retry is not None and self.retry.backoff_s:
            time.sleep(self.retry.backoff_s * max(1, attempt))

    def _collect_quarantining(self, pending: PendingChunk) -> List[Result]:
        """Collect one chunk; on failure isolate the blamed launch(es)
        and re-dispatch the survivors until the chunk completes. Survivor
        results stay bit-exact: cohort/batch folding is per-launch exact
        at any membership, and survivors with dependencies rebuild their
        patches from the still-resident producer handles (a consumer in
        flight keeps its producers resident, so the rebuild always finds
        them).

        Under a ``RetryPolicy``, a blamed launch with retry budget left is
        *re-staged and re-dispatched with the survivors* instead of
        quarantined (its ``attempts`` counter moves) — this covers
        max-steps failures, whole-chunk ``DeviceTimeout``
        (``exc.index is None``: every member is blamed), and the
        per-result output-checksum audit: a result whose words fail
        ``Request.audit`` is never returned, it is retried or quarantined
        as a ``ChecksumError``. Without a policy the behavior is the
        original quarantine-on-first-failure, unchanged."""
        out: List[Result] = []
        while True:
            reqs = pending.reqs
            try:
                results = self.executor.collect(pending)
            except KernelLaunchError as exc:
                idx = getattr(exc, "index", 0)
                blamed = list(reqs) if idx is None else [reqs[idx]]
                keep = []
                for bad in blamed:
                    if self._retryable(bad, exc):
                        bad.attempts += 1
                        keep.append(bad)
                    else:
                        self._poisoned.pop(bad.ticket, None)
                        self._quarantine(bad, exc)
                if keep:
                    self._backoff(max(r.attempts for r in keep))
                survivors = [r for r in reqs
                             if r.ticket in self._pending
                             and r.ticket not in self.quarantined]
                if not survivors:
                    return out
                pending = self.executor.submit(
                    pending.kind, survivors, self._chunk_patches(survivors))
                self._note_dispatched(pending)
                continue
            redo: List[Request] = []
            for req, res in zip(reqs, results):
                producer = self._poisoned.pop(req.ticket, None)
                if producer is not None:
                    self._quarantine(req, DependencyError(
                        f"producer ticket {producer} was quarantined"))
                    continue
                if req.audit is not None \
                        and result_checksum(res.mem) != req.audit:
                    exc = ChecksumError(
                        f"ticket {req.ticket} failed its output-checksum "
                        f"audit (attempt {req.attempts + 1})")
                    if self._retryable(req, exc):
                        req.attempts += 1
                        redo.append(req)
                    else:
                        self._quarantine(req, exc)
                    continue
                res.info["ticket"] = req.ticket
                if req.tag:
                    res.info["tag"] = req.tag
                del self._pending[req.ticket]
                self._release_deps(req)
                out.append(res)
            if not redo:
                return out
            self._backoff(max(r.attempts for r in redo))
            pending = self.executor.submit(
                pending.kind if len(redo) > 1 else "single", redo,
                self._chunk_patches(redo))
            self._note_dispatched(pending)


class LaunchQueue:
    """Multi-kernel launch queue for the G-GPU simulator (the pre-package
    interface, bit-exact compatible).

    ``submit`` enqueues a (program, mem-image, n_items) launch and returns
    a ticket; ``flush`` executes everything queued and returns results in
    submission order. Launches of the *same kernel* (identical program,
    item count, and memory shape — the serving-traffic common case) are
    folded into one **cohort** stepper call, which amortizes the
    simulator's per-round fixed costs across the whole group; remaining
    launches with a matching wavefront count share one vmapped batch, and
    odd shapes fall back to the single-launch path. Groups are chunked at
    ``max_batch`` and drained deterministically in ticket order (each
    chunk executes in order of its earliest submission — never in dict or
    group-iteration order). All three paths are bit-exact per launch.

    Failure semantics are the legacy strict mode: if any launch fails
    (e.g. hits ``max_steps``), the whole flush raises a
    ``KernelLaunchError`` naming the poisoned launch's ticket and tag, and
    every launch is restored to the queue so the caller can ``discard``
    that ticket and retry the rest. ``Scheduler`` supersedes this with
    per-launch quarantine and incremental ``drain``.
    """

    def __init__(self, cfg: GGPUConfig, max_batch: int = 64):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self.executor = Executor(cfg)
        self._pending: List[Request] = []

    def __len__(self) -> int:
        return len(self._pending)

    def submit(self, prog: np.ndarray, mem0: np.ndarray, n_items: int,
               tag: str = "") -> int:
        """Queue a launch; returns its ticket (index into flush() order)."""
        self._pending.append(Request(prog, mem0, n_items, tag))
        return len(self._pending) - 1

    def discard(self, ticket: int) -> Request:
        """Remove and return a pending launch by its current ticket (the
        recovery path after a failed flush: drop the poisoned launch,
        flush the rest). Later tickets shift down by one."""
        return self._pending.pop(ticket)

    def _plan_chunks(self, pending: List[Request]
                     ) -> List[Tuple[str, List[int]]]:
        """Legacy-shaped view of the shared planner (kind, tickets)."""
        return [(c.kind, list(c.members))
                for c in plan_chunks(pending, self.cfg, self.max_batch)]

    def flush(self) -> List[Result]:
        """Run every queued launch; results come back in submission order
        with the queue's grouping recorded in ``info['batch_size']`` and
        the submission ``tag`` (if any) in ``info['tag']``."""
        pending, self._pending = self._pending, []
        try:
            return self._run_all(pending)
        except BaseException:
            self._pending = pending + self._pending
            raise

    def _run_all(self, pending: List[Request]) -> List[Result]:
        results: List[Optional[Result]] = [None] * len(pending)

        def blame(chunk, exc: KernelLaunchError):
            """Re-raise a chunk failure naming the submission ticket."""
            ticket = chunk[exc.index]
            tag = pending[ticket].tag
            raise KernelLaunchError(
                f"launch ticket {ticket}" + (f" (tag {tag!r})" if tag
                                             else "")
                + f" hit max_steps without halting; discard({ticket}) "
                f"and flush() again to retry the rest", ticket) from exc

        for kind, chunk in self._plan_chunks(pending):
            try:
                outs = self.executor.run(kind, [pending[i] for i in chunk])
            except KernelLaunchError as exc:
                blame(chunk, exc)
            for i, out in zip(chunk, outs):
                results[i] = out
        for i, req in enumerate(pending):
            results[i].info["ticket"] = i
            if req.tag:
                results[i].info["tag"] = req.tag
        return results  # type: ignore[return-value]
