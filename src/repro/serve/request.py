"""Request/Result model for the serving subsystem.

A ``Request`` is one queued G-GPU kernel launch plus its serving metadata:
the ``tag`` a caller uses to correlate results, a ``priority`` (higher
drains earlier), and an optional modeled-time ``deadline_us`` used as a
tie-breaker (earliest-deadline-first within a priority class). The
``ticket`` identifies the request within its scheduler and orders results.

``KernelLaunch`` is the pre-package name of this class and remains as an
alias for compatibility (``repro.serve.engine`` re-exports it); the extra
fields all default, so positional ``KernelLaunch(prog, mem0, n_items,
tag)`` construction is unchanged.

``Result`` is a (mem, info) named tuple — exactly the pair the engine's
``run_kernel`` returns, so code that unpacks ``mem, info = result`` keeps
working. The serving layer adds ``info["ticket"]``, ``info["batch_size"]``
(how many launches shared the dispatch) and ``info["tag"]`` (when set).

A request may declare an ``out_region=(lo, hi)``: the half-open slice of
the final memory image the caller actually wants back. The async launch
path then downloads only that slice (``Result.mem`` holds it), and
``(0, 0)`` means cycles-only — no memory transfer at all (how the DSE
evaluator collects). Without a region, ``Result.mem`` is the full image,
bit-exact with direct ``run_kernel``.

**Dependency edges.** ``deps`` declares that this request consumes the
output of earlier requests: each ``Dep(producer, dst, src)`` names a
producer *ticket*, the half-open region ``dst`` of *this* request's
memory image the producer's output lands in, and optionally the region
``src`` of the producer's final image to read (default: the producer's
declared ``out_region``). A dependency-aware scheduler dispatches the
consumer only once every producer has been dispatched, and patches the
producer's device-resident output directly into the consumer's staged
memory — the words at ``dst`` in ``mem0`` are placeholders (conventionally
zeros) that never travel through the host. Producers that exist only to
feed consumers declare ``out_region=(0, 0)`` so nothing is downloaded
anywhere along the chain. ``schedule`` labels the lowering schedule the
kernel was compiled with (``repro.compiler.Schedule.label()``); the fleet
keys its learned service-time model on (kernel, schedule), since tuned
and default lowerings of one kernel have different true cycle counts.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import zlib
from typing import NamedTuple, Optional, Tuple

import numpy as np


def result_checksum(mem) -> int:
    """CRC32 of a result's memory words — the optional output audit a
    ``Request`` may carry (``audit=``). A caller who knows the expected
    output (e.g. a replayed trace, or any idempotent kernel) stamps the
    fault-free checksum on the request; the scheduler then verifies every
    collected result and treats a mismatch as a *corrupted* launch
    (retried or quarantined, never silently returned). Cheap: one pass
    over the downloaded words that were coming back anyway."""
    return zlib.crc32(np.ascontiguousarray(
        np.asarray(mem, np.int32)).tobytes())


@functools.lru_cache(maxsize=4096)
def _static_ops_cached(prog_bytes: bytes, width: int) -> tuple:
    """Content-keyed twin of ``engine.stepper._static_ops``: serving
    traffic re-dispatches the same few programs forever, so the opcode
    set is computed once per program *content*, not once per chunk."""
    prog = np.frombuffer(prog_bytes, np.int32).reshape(-1, width)
    return tuple(sorted({int(o) for o in prog[:, 0]}))


@dataclasses.dataclass(frozen=True)
class Dep:
    """One dependency edge: this request's ``dst`` region is fed by
    ``producer``'s final-memory ``src`` region (``None``: the producer's
    declared ``out_region``, resolved at admission). Regions are
    half-open ``(lo, hi)`` word slices and must have equal width."""
    producer: int
    dst: Tuple[int, int]
    src: Optional[Tuple[int, int]] = None


@dataclasses.dataclass
class Request:
    """One queued G-GPU kernel launch with serving metadata."""
    prog: np.ndarray
    mem0: np.ndarray
    n_items: int
    tag: str = ""
    priority: int = 0            # higher drains earlier
    deadline_us: float = math.inf  # modeled-time deadline (EDF tie-break)
    ticket: int = -1             # assigned by the scheduler at submit
    out_region: Optional[Tuple[int, int]] = None  # download slice (lo, hi)
    deps: Tuple[Dep, ...] = ()   # producer edges (see module doc)
    schedule: str = ""           # lowering-schedule label ("" = unknown)
    audit: Optional[int] = None  # expected result_checksum(mem) (or None)
    attempts: int = 0            # completed re-dispatches (retry policy)
    arrival_s: Optional[float] = None  # wall clock at admission (stamped
    #                              by the scheduler; deadline-drop policies
    #                              measure the latency budget from here)

    def __post_init__(self):
        self.prog = np.asarray(self.prog, np.int32)
        self.mem0 = np.asarray(self.mem0, np.int32)
        self.n_items = int(self.n_items)
        if self.out_region is not None:
            # validate at admission: a malformed region must bounce the
            # submit (per-request, handleable), not poison every later
            # drain from inside the dispatch path
            lo, hi = self.out_region
            if not (0 <= lo <= hi <= self.mem0.shape[0]):
                raise ValueError(
                    f"out_region {self.out_region} outside memory image "
                    f"[0, {self.mem0.shape[0]})")
        self.deps = tuple(self.deps)
        for d in self.deps:
            if not isinstance(d, Dep):
                raise ValueError(f"deps must be Dep instances, got {d!r}")
            lo, hi = d.dst
            if not (0 <= lo <= hi <= self.mem0.shape[0]):
                raise ValueError(
                    f"dep dst {d.dst} outside memory image "
                    f"[0, {self.mem0.shape[0]})")
            if d.src is not None and d.src[1] - d.src[0] != hi - lo:
                raise ValueError(
                    f"dep src {d.src} and dst {d.dst} widths differ")

    def kernel_key(self) -> tuple:
        """Same-kernel identity: launches sharing this key fold into one
        cohort stepper call (program, item count, memory shape)."""
        return (self.prog.tobytes(), self.n_items, self.mem0.shape[0])

    def static_ops(self) -> tuple:
        """The program's opcode set (the decode-specialization jit static),
        via a process-wide content-keyed cache — repeat traffic never
        rescans its program."""
        return _static_ops_cached(self.prog.tobytes(), self.prog.shape[1])


# compatibility alias: the pre-package launch record
KernelLaunch = Request


class Result(NamedTuple):
    """One completed launch: final memory image + the engine info dict."""
    mem: np.ndarray
    info: dict

    @property
    def ticket(self) -> int:
        return self.info.get("ticket", -1)
