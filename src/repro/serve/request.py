"""Request/Result model for the serving subsystem.

A ``Request`` is one queued G-GPU kernel launch plus its serving metadata:
the ``tag`` a caller uses to correlate results, a ``priority`` (higher
drains earlier), and an optional modeled-time ``deadline_us`` used as a
tie-breaker (earliest-deadline-first within a priority class). The
``ticket`` identifies the request within its scheduler and orders results.

``KernelLaunch`` is the pre-package name of this class and remains as an
alias for compatibility (``repro.serve.engine`` re-exports it); the extra
fields all default, so positional ``KernelLaunch(prog, mem0, n_items,
tag)`` construction is unchanged.

``Result`` is a (mem, info) named tuple — exactly the pair the engine's
``run_kernel`` returns, so code that unpacks ``mem, info = result`` keeps
working. The serving layer adds ``info["ticket"]``, ``info["batch_size"]``
(how many launches shared the dispatch) and ``info["tag"]`` (when set).
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One queued G-GPU kernel launch with serving metadata."""
    prog: np.ndarray
    mem0: np.ndarray
    n_items: int
    tag: str = ""
    priority: int = 0            # higher drains earlier
    deadline_us: float = math.inf  # modeled-time deadline (EDF tie-break)
    ticket: int = -1             # assigned by the scheduler at submit

    def __post_init__(self):
        self.prog = np.asarray(self.prog, np.int32)
        self.mem0 = np.asarray(self.mem0, np.int32)
        self.n_items = int(self.n_items)

    def kernel_key(self) -> tuple:
        """Same-kernel identity: launches sharing this key fold into one
        cohort stepper call (program, item count, memory shape)."""
        return (self.prog.tobytes(), self.n_items, self.mem0.shape[0])


# compatibility alias: the pre-package launch record
KernelLaunch = Request


class Result(NamedTuple):
    """One completed launch: final memory image + the engine info dict."""
    mem: np.ndarray
    info: dict

    @property
    def ticket(self) -> int:
        return self.info.get("ticket", -1)
