"""Trace-driven open-loop load generator for the serving stack.

Closed-loop benchmarking (submit a burst, drain, repeat — what
``bench_throughput`` measures) can only see *capacity*; it never observes
queueing, because the client politely waits. Tail latency under real
traffic needs an **open-loop** driver: arrivals happen at predetermined
wall-clock times whether or not the server has kept up, so backlog and
the p99 it produces are properties of the *offered load*, exactly as in
production serving studies.

Two arrival processes, both deterministic under a fixed seed:

  * ``poisson_arrivals`` — memoryless inter-arrival gaps at ``rate_hz``,
    the standard open-loop model.
  * ``bursty_arrivals`` — bursts of simultaneous arrivals whose start
    times are themselves Poisson, the adversarial shape for a
    continuous-batching scheduler (all-at-once admission, then silence).

``replay`` drives any target with the ``submit_request``/``drain``
protocol (``Scheduler`` and ``Fleet`` both) from an arrival trace:
requests are admitted the moment their arrival time passes, the target
is drained opportunistically between arrivals, and each completion is
stamped with the wall clock. A request's **latency** is completion wall
time minus its *scheduled* arrival — admission or queueing delay counts
against the server, as it should in an open-loop harness. The returned
``LoadResult`` reports p50/p99/mean latency and the sustained service
rate; ``benchmarks/serve_bench.py`` records them in ``BENCH_serve.json``
(schema ggpu-serve/3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.registry import TRAFFIC
from repro.serve.request import Request

__all__ = ["poisson_arrivals", "bursty_arrivals", "replay", "LoadResult"]


def poisson_arrivals(rate_hz: float, n: int, seed: int = 0) -> np.ndarray:
    """``n`` arrival times (seconds from trace start) with exponential
    inter-arrival gaps at mean rate ``rate_hz``. Deterministic per seed."""
    if rate_hz <= 0:
        raise ValueError("rate_hz must be > 0")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_hz, int(n)))


def bursty_arrivals(n_bursts: int, burst: int, gap_s: float,
                    seed: int = 0) -> np.ndarray:
    """``n_bursts`` bursts of ``burst`` simultaneous arrivals; burst start
    times are Poisson with mean spacing ``gap_s``. Deterministic per
    seed."""
    if gap_s <= 0:
        raise ValueError("gap_s must be > 0")
    rng = np.random.default_rng(seed)
    starts = np.cumsum(rng.exponential(gap_s, int(n_bursts)))
    return np.repeat(starts, int(burst))


# -- registry plugins --------------------------------------------------------
# The TRAFFIC axis contract is the *normalized* generator signature
# ``(n, seed=0) -> arrivals`` so CI sweeps can drive any registered
# pattern interchangeably; the raw parameterized functions above stay the
# API for callers that tune rates/shapes themselves.

@TRAFFIC.register("poisson")
def poisson_traffic(n: int, seed: int = 0,
                    rate_hz: float = 200.0) -> np.ndarray:
    """Registry adapter: memoryless arrivals at a fixed default rate."""
    return poisson_arrivals(rate_hz, n, seed)


@TRAFFIC.register("bursty")
def bursty_traffic(n: int, seed: int = 0, burst: int = 8,
                   gap_s: float = 0.004) -> np.ndarray:
    """Registry adapter: all-at-once bursts, trimmed to exactly ``n``
    arrivals (the adversarial shape for continuous batching)."""
    n_bursts = -(-int(n) // burst)
    return bursty_arrivals(n_bursts, burst, gap_s, seed)[:int(n)]


@dataclasses.dataclass
class LoadResult:
    """Outcome of one open-loop replay (latencies in seconds, aligned
    with the arrival trace; ``nan`` marks a quarantined request)."""
    arrivals: np.ndarray
    latencies: np.ndarray
    duration_s: float
    served: int
    quarantined: int

    def _pct(self, q: float) -> float:
        lat = self.latencies[~np.isnan(self.latencies)]
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    @property
    def p50_ms(self) -> float:
        return self._pct(50) * 1e3

    @property
    def p99_ms(self) -> float:
        return self._pct(99) * 1e3

    @property
    def mean_ms(self) -> float:
        lat = self.latencies[~np.isnan(self.latencies)]
        return float(lat.mean()) * 1e3 if lat.size else float("nan")

    @property
    def rate_per_s(self) -> float:
        """Sustained service rate over the whole replay."""
        return self.served / self.duration_s if self.duration_s else 0.0

    def report(self) -> dict:
        return {
            "served": self.served,
            "quarantined": self.quarantined,
            "duration_s": round(self.duration_s, 6),
            "rate_per_s": round(self.rate_per_s, 3),
            "p50_ms": round(self.p50_ms, 4),
            "p99_ms": round(self.p99_ms, 4),
            "mean_ms": round(self.mean_ms, 4),
        }


def replay(target, arrivals: Sequence[float],
           make_request: Callable[[int], Request],
           drain_budget: Optional[int] = None) -> LoadResult:
    """Open-loop replay of an arrival trace against ``target`` (anything
    with ``submit_request(req) -> ticket``, ``drain(budget)``, and a
    ``quarantined`` dict — ``Scheduler`` or ``Fleet``).

    ``make_request(i)`` builds the request for arrival ``i`` (trace
    order). Arrivals are admitted as their times pass; between arrivals
    the target is drained with ``drain_budget`` launches per call
    (``None``: everything pending), which bounds how long a drain can
    hold off a due admission. Latency for arrival ``i`` is completion
    wall time minus ``arrivals[i]``."""
    arrivals = np.asarray(arrivals, dtype=float)
    n = arrivals.size
    order = np.argsort(arrivals, kind="stable")
    latencies = np.full(n, np.nan)
    ticket_of: Dict[int, int] = {}          # target ticket -> arrival index
    done = 0
    seen_quarantined: set = set()
    t0 = time.perf_counter()

    def settle(results: List) -> int:
        nonlocal done
        now = time.perf_counter() - t0
        mono = time.monotonic()
        for res in results:
            i = ticket_of[res.info["ticket"]]
            if not np.isnan(latencies[i]):
                continue  # already settled (a resilient target may hedge)
            # a resilient fleet stamps when the result actually settled
            # inside its drain; back the completion time up by that age
            # so a drain that kept polling (e.g. waiting out a straggler)
            # does not inflate everyone else's measured latency
            age = mono - res.info.get("settled_s", mono)
            latencies[i] = (now - age) - arrivals[i]
            done += 1
        for tk in target.quarantined:
            if tk in ticket_of and tk not in seen_quarantined:
                seen_quarantined.add(tk)
                done += 1
        return len(results)

    next_up = 0
    while done < n:
        now = time.perf_counter() - t0
        while next_up < n and arrivals[order[next_up]] <= now:
            i = int(order[next_up])
            ticket_of[target.submit_request(make_request(i))] = i
            next_up += 1
        if len(ticket_of) > done:
            settle(target.drain(drain_budget))
        elif next_up < n:
            # idle until the next arrival is due (capped so a coarse
            # sleep never delays admission noticeably)
            wait = arrivals[order[next_up]] - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(min(wait, 5e-4))
    duration = time.perf_counter() - t0
    return LoadResult(arrivals=arrivals, latencies=latencies,
                      duration_s=duration, served=done - len(seen_quarantined),
                      quarantined=len(seen_quarantined))
