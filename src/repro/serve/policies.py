"""Chunk-planning policies — the ``SCHEDULERS`` registry axis built-ins.

A policy is a callable ``(requests, cfg, max_batch) -> List[Chunk]``
(the contract of ``scheduler.plan_chunks``): it groups the ready set
into cohort/batch/single dispatches and fixes their execution order.
``Scheduler(policy="name")`` resolves through the registry, so a new
policy — preemptive, deadline-only, fairness-weighted — is one
registered callable (or one drop-in file under
``repro/registry/plugins/``) that every consumer can name.

Built-ins:

  * ``cohort`` — the default continuous-batching plan
    (``plan_chunks``): same-kernel cohort folding, wavefront-bucketed
    vmap batches, ordered by (priority desc, deadline asc, first
    ticket). This is the pre-registry behavior, bit- and order-exact.
  * ``fifo`` — strict submission order: only *adjacent* same-kernel
    runs fold into cohorts, nothing is reordered across submission
    ticks. The predictable-latency counterpoint: admission order is
    completion order, at the cost of cohort occupancy.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.ggpu.engine import GGPUConfig
from repro.registry import SCHEDULERS
from repro.serve.request import Request
from repro.serve.scheduler import Chunk, plan_chunks

SCHEDULERS.register("cohort", plan_chunks)


@SCHEDULERS.register("fifo")
def plan_fifo(requests: Sequence[Request], cfg: GGPUConfig,
              max_batch: int = 64) -> List[Chunk]:
    """Strict-FIFO plan: walk the submission order, folding only
    *consecutive* launches of the same kernel into cohorts (capped at
    ``max_batch``); everything else dispatches as singles, in order.
    Priorities and deadlines are ignored — the policy's contract is that
    completion order is admission order."""
    chunks: List[Chunk] = []
    run: List[int] = []

    def close_run():
        if not run:
            return
        kind = "cohort" if len(run) > 1 else "single"
        for lo in range(0, len(run), max_batch):
            part = run[lo:lo + max_batch]
            chunks.append(Chunk(kind if len(part) > 1 else "single",
                                tuple(part)))
        run.clear()

    prev_key = None
    for i, r in enumerate(requests):
        key = r.kernel_key()
        if key != prev_key:
            close_run()
            prev_key = key
        run.append(i)
    close_run()
    return chunks
