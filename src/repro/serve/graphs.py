"""Kernel-graph serving: submit a compiled ``Program`` as a dependency
DAG of requests with device-resident inter-stage chaining.

``submit_program`` turns each stage of a ``repro.compiler.Program`` into
one ``Request``: graph inputs are staged into the stage's memory image
host-side (they have to travel once), stage-fed arrays are left as zero
placeholders covered by ``Dep`` edges, intermediate stages declare the
empty ``out_region`` (their output is never downloaded anywhere — it
flows producer→consumer entirely on the device via the scheduler's patch
path), and only the final stage's declared output region reaches the
host. The per-stage lowering ``Schedule`` label rides along on
``Request.schedule`` so a fleet's learned service-time model keys tuned
and default lowerings separately.

Submitting N instances of the same program **stage-major** (all instances'
stage 0, then all stage 1, …) is the throughput idiom: each stage's
requests share a kernel key and fold into one cohort dispatch, and the
consumer chunk's patches collapse into a single fused ``BlockPatch`` read
of the producer chunk — one device op per edge per *chunk*, not per
request. ``submit_programs`` does exactly that.

``run_program`` is the one-shot convenience (submit, drain, return the
final stage's output). Two host-staged references bracket it:

  * ``run_chains_host_staged`` — the pre-graph DAG idiom and the bench's
    gated baseline: each instance's chain is executed stage-by-stage,
    downloading the full final image and host-re-staging it into the
    next stage's memory. Without dependency edges this is how a DAG ran:
    the per-chain barrier structure serializes every edge through the
    host *and* hides cross-chain same-kernel folding opportunities from
    the scheduler (stage 0 of chain 2 is only built after chain 1
    finished entirely).
  * ``run_programs_host_staged`` — the strongest manual workaround: the
    caller restructures the workload stage-major (all instances' stage
    k, one drain barrier, download, re-stage). This recovers cohort
    folding and is reported alongside for calibration; the remaining
    delta vs the pipelined path is the per-edge host round-trip and the
    lost cross-stage overlap, which shrink to parity on a single-core
    host where simulator compute dominates.
"""
from __future__ import annotations

import math
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.compiler import Program
from repro.serve.request import Dep, Request


class GraphTickets(NamedTuple):
    """Tickets of one submitted program instance, in stage order."""
    stages: List[int]

    @property
    def final(self) -> int:
        return self.stages[-1]


def _stage_requests(program: Program,
                    inputs: Dict[str, np.ndarray],
                    tag: str, priority: int,
                    deadline_us: float) -> List[Request]:
    """Build one program instance's per-stage requests. ``deps`` are
    expressed in *local stage indices*; ``submit_program`` rewrites them
    to real tickets as it submits."""
    inputs = {n: np.asarray(v, np.int32).reshape(-1)
              for n, v in dict(inputs).items()}
    missing = set(program.in_sizes) - set(inputs)
    if missing:
        raise ValueError(f"missing graph inputs: {sorted(missing)}")
    reqs: List[Request] = []
    for idx, ck in enumerate(program.stages):
        feed = {}
        deps: List[Dep] = []
        layout = ck.layout
        for arr, (kind, ref) in program.sources[idx].items():
            ln = ck.kernel.arrays[arr]
            if kind == "input":
                feed[arr] = inputs[ref]
            else:
                feed[arr] = np.zeros(ln, np.int32)   # placeholder words
                producer = program.stages[ref]
                deps.append(Dep(ref, (layout[arr], layout[arr] + ln),
                                (producer.out.start, producer.out.stop)))
        final = idx == len(program.stages) - 1
        reqs.append(Request(
            ck.prog, ck.build_mem(feed), ck.n_items,
            tag=f"{tag}:{ck.name}" if tag else "",
            priority=priority, deadline_us=deadline_us,
            out_region=((ck.out.start, ck.out.stop) if final else (0, 0)),
            deps=tuple(deps), schedule=ck.schedule.label()))
    return reqs


def submit_program(target, program: Program,
                   inputs: Dict[str, np.ndarray], *, tag: str = "",
                   priority: int = 0,
                   deadline_us: float = math.inf) -> GraphTickets:
    """Submit one program instance to ``target`` (a ``Scheduler`` or
    ``Fleet`` — anything with ``submit_request``) as a dependency DAG;
    returns the stage tickets. Only the final stage downloads anything;
    every inter-stage edge stays device-resident."""
    reqs = _stage_requests(program, inputs, tag, priority, deadline_us)
    tickets: List[int] = []
    for req in reqs:
        req.deps = tuple(Dep(tickets[d.producer], d.dst, d.src)
                         for d in req.deps)
        tickets.append(target.submit_request(req))
    return GraphTickets(tickets)


def submit_programs(target, program: Program,
                    instances: Sequence[Dict[str, np.ndarray]], *,
                    tag: str = "", priority: int = 0,
                    deadline_us: float = math.inf) -> List[GraphTickets]:
    """Submit N instances of ``program`` stage-major, so each stage's
    launches fold into cohort chunks and each producer→consumer edge is
    one fused device read per chunk (module doc)."""
    per_instance = [_stage_requests(program, ins, tag, priority,
                                    deadline_us)
                    for ins in instances]
    tickets: List[List[int]] = [[] for _ in per_instance]
    for stage in range(len(program.stages)):
        for inst, reqs in enumerate(per_instance):
            req = reqs[stage]
            req.deps = tuple(Dep(tickets[inst][d.producer], d.dst, d.src)
                             for d in req.deps)
            tickets[inst].append(target.submit_request(req))
    return [GraphTickets(t) for t in tickets]


def extract_outputs(results, handles: Sequence[GraphTickets]
                    ) -> List[Optional[np.ndarray]]:
    """Pick each instance's final-stage output out of a drain's results
    (``None`` where the final stage did not complete — e.g. a quarantined
    ancestor)."""
    by_ticket = {r.info["ticket"]: r.mem for r in results}
    return [by_ticket.get(h.final) for h in handles]


def run_program(target, program: Program,
                inputs: Dict[str, np.ndarray]) -> np.ndarray:
    """Submit one instance and drain: the device-resident one-shot. The
    returned array is bit-exact with ``program.reference(inputs)`` /
    ``run_host`` — the graph tests assert all three agree."""
    handle = submit_program(target, program, inputs)
    out = extract_outputs(target.drain(), [handle])[0]
    if out is None:
        raise RuntimeError(
            f"program {program.name!r}: final stage (ticket "
            f"{handle.final}) did not complete — check quarantined")
    return out


def run_chains_host_staged(target, program: Program,
                           instances: Sequence[Dict[str, np.ndarray]]
                           ) -> List[np.ndarray]:
    """The pre-graph DAG idiom (module doc): every instance's chain runs
    stage-by-stage through the host — submit one stage, drain, download
    the full final image, slice the output, re-stage it into the next
    stage's memory. The ``graph`` bench section gates the device-resident
    pipelined path against this."""
    out: List[np.ndarray] = []
    for ins in instances:
        ins = {n: np.asarray(v, np.int32).reshape(-1)
               for n, v in dict(ins).items()}
        prev: Dict[int, np.ndarray] = {}
        for idx, ck in enumerate(program.stages):
            feed = {}
            for arr, (kind, ref) in program.sources[idx].items():
                feed[arr] = ins[ref] if kind == "input" else prev[ref]
            ticket = target.submit_request(
                Request(ck.prog, ck.build_mem(feed), ck.n_items,
                        schedule=ck.schedule.label()))
            (res,) = [r for r in target.drain()
                      if r.info["ticket"] == ticket]
            prev[idx] = np.asarray(res.mem)[ck.out]
        out.append(prev[len(program.stages) - 1])
    return out


def run_programs_host_staged(target, program: Program,
                             instances: Sequence[Dict[str, np.ndarray]]
                             ) -> List[np.ndarray]:
    """The stage-major host-staged reference (module doc): execute N
    instances stage-by-stage with a drain barrier per stage, downloading
    every stage's declared output and re-staging it host-side into the
    next stage's memory image. Same cohort folding per stage as the
    device-resident path — the measured delta is purely the per-edge
    host round-trip plus the lost cross-stage pipelining."""
    instances = [{n: np.asarray(v, np.int32).reshape(-1)
                  for n, v in dict(ins).items()} for ins in instances]
    outs: List[Dict[int, np.ndarray]] = [{} for _ in instances]
    for idx, ck in enumerate(program.stages):
        tickets = []
        for inst, ins in enumerate(instances):
            feed = {}
            for arr, (kind, ref) in program.sources[idx].items():
                feed[arr] = ins[ref] if kind == "input" else outs[inst][ref]
            tickets.append(target.submit_request(Request(
                ck.prog, ck.build_mem(feed), ck.n_items,
                out_region=(ck.out.start, ck.out.stop),
                schedule=ck.schedule.label())))
        results = {r.info["ticket"]: r.mem for r in target.drain()}
        for inst, t in enumerate(tickets):
            outs[inst][idx] = results[t]
    last = len(program.stages) - 1
    return [o[last] for o in outs]


def run_program_host_staged(target, program: Program,
                            inputs: Dict[str, np.ndarray]) -> np.ndarray:
    return run_programs_host_staged(target, program, [inputs])[0]
