from repro.sharding.ctx import current_rules, set_rules, shard_hint  # noqa: F401
from repro.sharding.rules import ShardingRules, make_rules, param_shardings, input_shardings  # noqa: F401
