"""Ambient sharding context.

Model code calls ``shard_hint(x, kind)`` at layout-critical points; what that
means is decided by the active :class:`ShardingRules` (set by the launcher /
dry-run / MeshPlanner). With no rules set (unit tests, single device) hints
are no-ops, so model code never depends on a mesh being present.
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def set_rules(rules):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def shard_hint(x, kind: str):
    """Apply a with_sharding_constraint for activation ``kind`` if rules are
    active and the constraint divides evenly; otherwise identity."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.activation_spec(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))
