"""Logical-axis -> mesh-axis rule engine.

Every parameter carries logical axis names from the schema; every activation
hint (`shard_hint`) names a layout point. Rules resolve both to
PartitionSpecs with *divisibility checks*: a mapping that does not divide
evenly falls back down a candidate list (ending in replication), so every
arch lowers on every mesh — head counts of 40/20/15/10 on a 16-way axis
simply fall back rather than failing, which GSPMD would reject.

The MeshPlanner mutates a :class:`ShardingRules` (its DSE knobs) and
re-lowers; this module is deliberately data-driven for that reason.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

# candidate mesh axes per logical axis, in preference order. Each entry is a
# tuple of mesh-axis names to use jointly (e.g. FSDP over ("pod","data")).
DEFAULT_PARAM_RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "vocab":   (("model",),),
    "ffn":     (("model",),),
    "qkv":     (("model",),),
    "kv":      (("model",),),
    "experts": (("model",),),
    "embed":   (),                       # replicated unless fsdp=True
}
FSDP_EMBED = (("pod", "data"), ("data",))


@dataclass
class ShardingRules:
    mesh: jax.sharding.Mesh
    # mesh axis names present (subset of pod/data/model)
    dp_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    fsdp: bool = True                    # shard "embed" dims over dp axes
    seq_shard: bool = True               # sequence parallelism for activations
    seq_attn_min_s: int = 16384          # min S for seq-parallel attention
    param_rules: Dict[str, Tuple[Tuple[str, ...], ...]] = field(
        default_factory=lambda: dict(DEFAULT_PARAM_RULES))

    def __post_init__(self):
        names = self.mesh.axis_names
        self.dp_axes = tuple(a for a in self.dp_axes if a in names)
        self._sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    # -- helpers ------------------------------------------------------------
    def axes_size(self, axes: Sequence[str]) -> int:
        return int(np.prod([self._sizes[a] for a in axes])) if axes else 1

    def _fits(self, dim: int, axes: Sequence[str], used: set) -> bool:
        return (axes and not (set(axes) & used)
                and all(a in self._sizes for a in axes)
                and dim % self.axes_size(axes) == 0)

    # -- params -------------------------------------------------------------
    def param_spec(self, shape: Tuple[int, ...], logical: Tuple[object, ...]) -> P:
        used: set = set()
        out = []
        for dim, name in zip(shape, logical):
            cands: Tuple[Tuple[str, ...], ...] = ()
            if name is not None:
                cands = tuple(self.param_rules.get(name, ()))
                if name == "embed" and self.fsdp:
                    cands = cands + FSDP_EMBED
            chosen = None
            for axes in cands:
                if self._fits(dim, axes, used):
                    chosen = axes
                    break
            if chosen:
                used.update(chosen)
                out.append(chosen if len(chosen) > 1 else chosen[0])
            else:
                out.append(None)
        return P(*out)

    # -- activations ----------------------------------------------------------
    def activation_spec(self, kind: str, shape: Tuple[int, ...]) -> Optional[P]:
        """PartitionSpec for an activation hint, or None (no constraint)."""
        dp = tuple(a for a in self.dp_axes)
        dp_n = self.axes_size(dp)
        tp_n = self._sizes.get(self.tp_axis, 1)

        def dp_if(b):
            return (dp if len(dp) > 1 else dp[0]) if (dp and b % dp_n == 0 and b >= dp_n) else None

        if kind == "acts":               # (B, S, D)
            b, s, d = shape
            sp = self.tp_axis if (self.seq_shard and s % tp_n == 0 and s >= tp_n) else None
            return P(dp_if(b), sp, None)
        if kind == "acts_ffn":           # (B, S, Dff) - recurrent widths
            b, s, d = shape
            tp = self.tp_axis if d % tp_n == 0 else None
            return P(dp_if(b), None, tp)
        if kind == "logits":             # (B, S, V) or (B, V)
            v = shape[-1]
            tp = self.tp_axis if v % tp_n == 0 else None
            return P(dp_if(shape[0]), *([None] * (len(shape) - 2)), tp)
        if kind == "heads":              # (B, S, H, hd) pre-attention
            b, s, h, _ = shape
            if h % tp_n == 0 and h >= tp_n:
                return P(dp_if(b), None, self.tp_axis, None)
            if self.seq_shard and s % tp_n == 0 \
                    and s >= self.seq_attn_min_s:
                # head count below/indivisible by the axis (40, 15, 10):
                # sequence-parallel attention at long context only — it
                # divides peak memory ~tp_n x (llama4 prefill 18 -> 5.7
                # GiB) but adds bwd gathers that regress short-seq
                # training (smollm collective 1.7 -> 16.2 s; refuted
                # there, see EXPERIMENTS.md §Perf)
                return P(dp_if(b), self.tp_axis, None, None)
            return P(dp_if(b), None, None, None)
        if kind == "expert_buf":         # (E, C, D)
            e = shape[0]
            tp = self.tp_axis if e % tp_n == 0 else None
            return P(tp, None, None)
        if kind == "expert_buf4":        # (B, E, C, D) grouped dispatch
            b, e = shape[0], shape[1]
            tp = self.tp_axis if e % tp_n == 0 else None
            return P(dp_if(b), tp, None, None)
        if kind == "kv_cache":           # (B, S, Hkv, hd)
            b, s, h, _hd = shape
            if h % tp_n == 0:            # prefer head sharding (local attn math)
                return P(dp_if(b), None, self.tp_axis, None)
            if s % tp_n == 0 and s >= tp_n:
                # GQA head counts below the axis size: shard the sequence
                # dim (attention reduces over S; XLA inserts the partial-
                # softmax collectives). head_dim-sharding was tried and
                # REFUTED: 20x collective regression on granite decode
                # with no temp win on qwen1.5-4b (EXPERIMENTS.md §Perf).
                return P(dp_if(b), self.tp_axis, None, None)
            return P(dp_if(b), None, None, None)
        if kind == "tokens":             # (B, S)
            return P(dp_if(shape[0]), None)
        if kind == "launch":             # (N, ...) batched G-GPU launches
            # data-parallel fleet sharding: the G-GPU engine shards the
            # leading launch axis of a cohort/batch dispatch over the dp
            # axes (repro.ggpu.engine.stepper), falling back to
            # replication when N does not divide — entry points pad
            # first, so the fallback only fires for hand-built meshes
            return P(dp_if(shape[0]), *([None] * (len(shape) - 1)))
        return None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_rules(mesh, **kw) -> ShardingRules:
    return ShardingRules(mesh=mesh, **kw)


# ---------------------------------------------------------------------------
# trees of shardings for params / optimizer / inputs
# ---------------------------------------------------------------------------

def param_shardings(rules: ShardingRules, cfg: ModelConfig):
    """Tree of NamedShardings matching ``schema.abstract_params``."""
    from repro.models.schema import schema, tree_map_schema
    return tree_map_schema(
        lambda s: rules.named(rules.param_spec(s.shape, s.axes)), schema(cfg))


def opt_state_shardings(rules: ShardingRules, cfg: ModelConfig):
    from repro.optim.adamw import AdamWState
    ps = param_shardings(rules, cfg)
    scalar = rules.named(P())
    return AdamWState(m=ps, v=ps, step=scalar)


def input_shardings(rules: ShardingRules, batch_tree):
    """Shard batch inputs: leading dim over dp when divisible (tokens,
    embeds, labels); positions replicated."""
    def spec(path_leaf):
        arr = path_leaf
        b = arr.shape[0]
        dp = tuple(rules.dp_axes)
        dp_n = rules.axes_size(dp)
        lead = (dp if len(dp) > 1 else dp[0]) if (dp and b % dp_n == 0 and b >= dp_n) else None
        return rules.named(P(lead, *([None] * (arr.ndim - 1))))
    return jax.tree.map(spec, batch_tree)


def cache_shardings(rules: ShardingRules, cache_tree):
    """Shard decode caches: batch over dp, kv-heads over model if divisible."""
    def spec(arr):
        if arr.ndim >= 5:                # stacked KV: (reps, B, S, Hkv, hd)
            _, b, s, h, _ = arr.shape[:5]
            sp = rules.activation_spec("kv_cache", (b, s, h, arr.shape[4]))
            return rules.named(P(None, *sp))
        if arr.ndim >= 2:                # recurrent states: (reps, B, ...)
            b = arr.shape[1]
            dp = tuple(rules.dp_axes)
            dp_n = rules.axes_size(dp)
            lead = (dp if len(dp) > 1 else dp[0]) if (dp and b % dp_n == 0 and b >= dp_n) else None
            return rules.named(P(None, lead, *([None] * (arr.ndim - 2))))
        return rules.named(P(*([None] * arr.ndim)))
    return jax.tree.map(spec, cache_tree)
