"""Cycle-approximate SIMT simulator of the G-GPU, in JAX.

Compatibility facade: the monolithic simulator that used to live here has
been split into the composable execution-engine package
``repro.ggpu.engine`` (stage boundaries, cycle model, and the
``MemorySystem`` protocol are documented in DESIGN.md):

  * ``engine.frontend``  — fetch/decode with min-PC reconvergence
  * ``engine.alu``       — the PE datapath (shared with the Pallas twin in
    ``repro.kernels.pe_simd``)
  * ``engine.memsys``    — pluggable cache organizations (the paper's
    central shared cache, plus banked per-CU variants for DSE)
  * ``engine.scheduler`` — resident-wavefront selection + lockstep rounds
  * ``engine.stepper``   — the jitted ``while_loop`` machine, fused
    dispatch, and batched (vmapped) multi-kernel launches

Architecture model (FGPU per the paper):
  * a G-GPU has ``n_cus`` Compute Units; each CU is a SIMD machine of 8
    Processing Elements, so a 64-item wavefront issues over 64/8 = 8 cycles;
  * wavefronts are distributed round-robin over CUs; a CU round-robins issue
    among its resident wavefronts (which is what hides memory latency);
  * full thread divergence: every work-item has its own PC; each step a
    wavefront executes the instruction at the *minimum* active PC with the
    lane mask ``pc == pc_min`` — the standard SIMT serialization model;
  * the default memory system is one central, direct-mapped, write-back
    data cache shared by all CUs with ``ports`` data movers (the paper's
    multi-port cache), whose port contention is the reason the paper's
    8-CU xcorr/parallel_sel *lose* performance.

The functional state (registers, memory) is exact; cycles are approximate
per the cost model above. ``run_kernel`` keeps its original signature and
bit-exact results; ``run_kernel_batch`` is the multi-launch path. This
module is purely a re-export facade — stage internals (``exec_alu`` and
friends) live in ``repro.ggpu.engine`` and should be imported from there.
"""
from __future__ import annotations

from repro.ggpu.engine import (GGPUConfig, KernelLaunchError, MachineState,
                               ScalarConfig, run_kernel, run_kernel_batch,
                               run_kernel_cohort)

__all__ = [
    "GGPUConfig", "ScalarConfig", "MachineState", "KernelLaunchError",
    "run_kernel", "run_kernel_batch", "run_kernel_cohort",
]
