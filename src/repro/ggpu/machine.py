"""Cycle-approximate SIMT simulator of the G-GPU, in JAX.

Architecture model (FGPU per the paper):
  * a G-GPU has ``n_cus`` Compute Units; each CU is a SIMD machine of 8
    Processing Elements, so a 64-item wavefront issues over 64/8 = 8 cycles;
  * wavefronts are distributed round-robin over CUs; a CU round-robins issue
    among its resident wavefronts (which is what hides memory latency);
  * full thread divergence: every work-item has its own PC; each step a
    wavefront executes the instruction at the *minimum* active PC with the
    lane mask ``pc == pc_min`` (divergent paths serialize, reconvergence is
    automatic at the min-PC join) — the standard SIMT serialization model;
  * one central, direct-mapped, write-back data cache shared by all CUs
    with ``ports`` data movers (the paper's multi-port cache). Port
    contention — the reason the paper's 8-CU xcorr/parallel_sel *lose*
    performance — is modeled as a shared issue budget of cache lines per
    cycle.

The functional state (registers, memory) is exact; cycles are approximate
per the cost model above (documented in DESIGN.md). The whole stepper is a
``jax.lax.while_loop`` over vectorized (W, L) tensors, jitted once per
program shape; the PE execute stage has a Pallas TPU kernel twin
(``repro.kernels.pe_simd``) validated against ``exec_alu`` below.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ggpu import isa


@dataclass(frozen=True)
class GGPUConfig:
    n_cus: int = 1
    wavefront: int = 64
    pes_per_cu: int = 8
    cache_lines: int = 256   # 16 KiB data cache (FGPU default)
    line_words: int = 16
    miss_penalty: int = 24
    dram_line_cycles: int = 4
    max_wf_per_cu: int = 8
    ports: int = 4
    freq_mhz: float = 500.0
    max_steps: int = 2_000_000

    @property
    def issue_cycles(self) -> int:
        return max(1, self.wavefront // self.pes_per_cu)


@dataclass(frozen=True)
class ScalarConfig(GGPUConfig):
    """The RISC-V-class in-order scalar baseline: 1 lane, 1 PE, CPI~1,
    non-pipelined MUL/DIV (CV32E40P-style), single memory port."""
    n_cus: int = 1
    wavefront: int = 1
    pes_per_cu: int = 1
    ports: int = 1
    cache_lines: int = 256
    freq_mhz: float = 667.0


class MachineState(NamedTuple):
    pc: jax.Array          # (W, L) int32
    regs: jax.Array        # (W, L, 32) int32
    done: jax.Array        # (W, L) bool
    mem: jax.Array         # (M+1,) int32 (last slot = write sink)
    tags: jax.Array        # (cache_lines,) int32, -1 = invalid
    cycles: jax.Array      # () int32 (lockstep-round total)
    stats: jax.Array       # (4,) int64: instrs, mem_ops, hits, misses
    step: jax.Array        # () int32


def _mulh32(a, b):
    """Signed 32x32 -> high 32 bits with pure int32 ops (no int64 needed).
    Standard decomposition a = a_hi*2^16 + a_lo (a_lo unsigned); all
    partial products fit int32."""
    a_lo = a & 0xFFFF
    a_hi = a >> 16                      # arithmetic
    b_lo = b & 0xFFFF
    b_hi = b >> 16
    t1 = (a_lo * b_lo).astype(jnp.uint32) >> 16
    t2 = a_hi * b_lo + t1.astype(jnp.int32)
    t3 = a_lo * b_hi + (t2 & 0xFFFF)
    return a_hi * b_hi + (t2 >> 16) + (t3 >> 16)


def exec_alu(op, a, b, imm, pc_min):
    """Vectorized ALU for one instruction per wavefront.

    op: (W, 1) int32; a, b: (W, L) int32 source values; imm: (W, 1).
    Returns (result (W,L), pc_target (W,1), is_store_val).
    This is the PE datapath the Pallas kernel mirrors."""
    sh = jnp.clip(b, 0, 31)
    shi = jnp.clip(imm, 0, 31)
    au = a.astype(jnp.uint32)
    b_safe = jnp.where(b == 0, 1, b)
    cases = [
        (isa.ADD, a + b), (isa.SUB, a - b), (isa.MUL, a * b),
        (isa.MULH, _mulh32(a, b)),
        (isa.DIV, jnp.where(b == 0, 0, a // b_safe)),
        (isa.REM, jnp.where(b == 0, 0, a % b_safe)),
        (isa.AND, a & b), (isa.OR, a | b), (isa.XOR, a ^ b),
        (isa.SLL, a << sh), (isa.SRL, (au >> sh.astype(jnp.uint32))
                             .astype(jnp.int32)), (isa.SRA, a >> sh),
        (isa.SLT, (a < b).astype(jnp.int32)),
        (isa.ADDI, a + imm), (isa.ANDI, a & imm), (isa.ORI, a | imm),
        (isa.XORI, a ^ imm),
        (isa.SLLI, a << shi), (isa.SRLI, (au >> shi.astype(jnp.uint32))
                               .astype(jnp.int32)), (isa.SRAI, a >> shi),
        (isa.SLTI, (a < imm).astype(jnp.int32)),
        (isa.LUI, imm << 12),
    ]
    result = jnp.zeros_like(a)
    for code, val in cases:
        result = jnp.where(op == code, val, result)
    return result


def _branch_taken(op, a, b):
    taken = jnp.zeros_like(a, dtype=bool)
    taken = jnp.where(op == isa.BEQ, a == b, taken)
    taken = jnp.where(op == isa.BNE, a != b, taken)
    taken = jnp.where(op == isa.BLT, a < b, taken)
    taken = jnp.where(op == isa.BGE, a >= b, taken)
    return taken


@functools.partial(jax.jit, static_argnames=("cfg", "n_items", "prog_len"))
def _run(prog, mem0, cfg: GGPUConfig, n_items: int, prog_len: int):
    L = cfg.wavefront
    W = (n_items + L - 1) // L
    n_cus = cfg.n_cus
    cu_of_w = jnp.arange(W, dtype=jnp.int32) % n_cus
    gid = (jnp.arange(W)[:, None] * L + jnp.arange(L)[None, :]).astype(jnp.int32)
    lane_valid = gid < n_items

    line_shift = int(np.log2(cfg.line_words))
    is_branch = jnp.asarray(isa.IS_BRANCH)
    is_mem = jnp.asarray(isa.IS_MEM)
    gpu_extra = jnp.asarray(
        isa.SCALAR_EXTRA if cfg.pes_per_cu == 1 else isa.GPU_EXTRA)

    st = MachineState(
        pc=jnp.zeros((W, L), jnp.int32),
        regs=jnp.zeros((W, L, isa.N_REGS), jnp.int32),
        done=~lane_valid,
        mem=jnp.concatenate([mem0, jnp.zeros((1,), jnp.int32)]),
        tags=jnp.full((cfg.cache_lines,), -1, jnp.int32),
        cycles=jnp.zeros((), jnp.int32),
        stats=jnp.zeros((4,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )
    msize = mem0.shape[0]

    def cond(s: MachineState):
        return (~jnp.all(s.done)) & (s.step < cfg.max_steps)

    def body(s: MachineState):
        active = ~s.done                                     # (W, L)
        live = jnp.any(active, axis=1)                       # (W,)
        # FGPU holds at most `max_wf_per_cu` resident wavefronts per CU:
        # rank each live wavefront within its CU (w = i*n_cus + cu order)
        # and run only the first 8. This is why 8 CUs have an 8x larger
        # concurrent working set — and why the paper's xcorr THRASHES.
        live_mat = live.reshape(-1, n_cus)                   # (W/n_cus, n_cus)
        rank = jnp.cumsum(live_mat.astype(jnp.int32), axis=0) - 1
        resident_mat = live_mat & (rank < cfg.max_wf_per_cu)
        resident = resident_mat.reshape(-1)                  # (W,)
        active = active & resident[:, None]
        wf_live = resident
        pc_min = jnp.min(jnp.where(active, s.pc, prog_len), axis=1,
                         keepdims=True)                      # (W, 1)
        instr = prog[jnp.clip(pc_min[:, 0], 0, prog_len - 1)]  # (W, 5)
        op = instr[:, 0:1]
        rd, rs, rt = instr[:, 1], instr[:, 2], instr[:, 3]
        imm = instr[:, 4:5]
        exec_m = active & (s.pc == pc_min)                   # (W, L)

        a = jnp.take_along_axis(s.regs, rs[:, None, None], axis=2)[:, :, 0]
        b = jnp.take_along_axis(s.regs, rt[:, None, None], axis=2)[:, :, 0]

        res = exec_alu(op, a, b, imm, pc_min)
        res = jnp.where(op == isa.TID, gid, res)
        res = jnp.where(op == isa.NITEMS, n_items, res)
        res = jnp.where(op == isa.WGID, gid // L, res)

        # --- memory ---
        addr = jnp.clip(a + imm, 0, msize - 1)
        is_load = op == isa.LW
        is_store = op == isa.SW
        mem_mask = exec_m & (is_load | is_store)
        loaded = s.mem[jnp.where(mem_mask, addr, msize)]
        res = jnp.where(is_load, loaded, res)
        # masked store: inactive lanes write the sink slot (index msize)
        waddr = jnp.where(exec_m & is_store, addr, msize)
        mem = s.mem.at[waddr].set(b)

        # --- cache model (cycle accounting only) ---
        line = (addr >> line_shift) % cfg.cache_lines
        tag = addr >> line_shift
        line_m = jnp.where(mem_mask, line, 0)
        hit = (s.tags[line_m] == tag) & mem_mask
        miss = mem_mask & ~hit
        tags = s.tags.at[jnp.where(miss, line, cfg.cache_lines)].set(
            tag, mode="drop")
        # Port traffic: lanes of one wavefront coalesce into per-line
        # requests, but DISTINCT wavefronts issue distinct requests even for
        # the same line -> count per-wavefront unique hit lines. DRAM fills
        # coalesce globally (MSHR): count globally-unique missed lines.
        w_ix = jnp.broadcast_to(jnp.arange(W)[:, None], line.shape)
        t_hit = jnp.zeros((W, cfg.cache_lines + 1), jnp.int32).at[
            w_ix, jnp.where(hit, line, cfg.cache_lines)].max(1, mode="drop")
        hit_lines = jnp.sum(t_hit[:, :-1])
        t_miss = jnp.zeros((cfg.cache_lines + 1,), jnp.int32).at[
            jnp.where(miss, line, cfg.cache_lines)].max(1, mode="drop")
        miss_lines = jnp.sum(t_miss[:-1])

        # --- writeback ---
        do_wr = exec_m & (rd[:, None] != 0) & (~is_branch[op[:, 0]][:, None]) \
            & (~is_store)
        regs = jnp.where(
            do_wr[:, :, None] & (jnp.arange(isa.N_REGS) == rd[:, None, None]),
            res[:, :, None], s.regs)

        # --- control flow ---
        taken = _branch_taken(op, a, b) & exec_m
        pc_next = jnp.where(taken, imm, pc_min + 1)
        pc = jnp.where(exec_m, pc_next, s.pc)
        done = s.done | (exec_m & (op == isa.HALT))

        # --- cycles: lockstep-round model ---
        # One "round" = every live wavefront issues one instruction. Round
        # time = max(slowest CU's issue work, shared cache service time):
        # CU-side: issue cycles (+ non-pipelined op extras) summed over its
        #   resident wavefronts, plus any un-hidden dependent-miss latency
        #   (hidden when other wavefronts can issue — the SIMT trick);
        # memory-side: unique hit lines stream through `ports` movers,
        #   unique missed lines pay the DRAM fill bandwidth. This shared
        #   term is what saturates copy/vec_mul and degrades xcorr at 8 CUs.
        wf_exec = jnp.any(exec_m, axis=1)                    # (W,)
        base = (cfg.issue_cycles + gpu_extra[op[:, 0]]) \
            * wf_exec.astype(jnp.int32)
        cu_issue = jnp.zeros((n_cus,), jnp.int32).at[cu_of_w].add(base)
        wf_resident = jnp.zeros((n_cus,), jnp.int32).at[cu_of_w].add(
            wf_live.astype(jnp.int32))
        cu_time = cu_issue
        # hits stream through the multi-port cache concurrently with
        # issue; misses serialize on the single AXI/DRAM path and cannot
        # be hidden once every resident wavefront is stalled on them
        hit_service = (hit_lines + cfg.ports - 1) // cfg.ports
        round_t = (jnp.maximum(jnp.max(cu_time), hit_service)
                   + miss_lines * cfg.dram_line_cycles)
        cycles = s.cycles + round_t.astype(jnp.int32)

        stats = s.stats + jnp.array([
            jnp.sum(wf_exec), jnp.sum(mem_mask), jnp.sum(hit), jnp.sum(miss),
        ], jnp.int32)
        return MachineState(pc, regs, done, mem, tags, cycles, stats,
                            s.step + 1)

    final = jax.lax.while_loop(cond, body, st)
    return final


def run_kernel(prog: np.ndarray, mem0: np.ndarray, n_items: int,
               cfg: GGPUConfig):
    """Execute a kernel. Returns (mem_final, info dict)."""
    final = _run(jnp.asarray(prog), jnp.asarray(mem0, jnp.int32), cfg,
                 int(n_items), int(prog.shape[0]))
    cycles = int(np.asarray(final.cycles))
    stats = np.asarray(final.stats)
    if not bool(np.asarray(final.done).all()):
        raise RuntimeError("kernel hit max_steps without halting")
    return np.asarray(final.mem)[:-1], {
        "cycles": cycles,
        "instrs": int(stats[0]),
        "mem_ops": int(stats[1]),
        "hits": int(stats[2]),
        "misses": int(stats[3]),
        "steps": int(np.asarray(final.step)),
        "time_us": float(cycles / cfg.freq_mhz),
    }
