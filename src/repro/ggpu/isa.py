"""FGPU-like ISA for the G-GPU SIMT machine.

A compact MIPS-flavoured RISC ISA, matching FGPU's shape: 32 registers per
work-item, global-memory loads/stores through the central data cache, and
SIMT intrinsics (thread id / item count) in place of FGPU's OpenCL runtime
registers. Instructions are stored unpacked as an int32 ``(P, 5)`` matrix
``[op, rd, rs, rt, imm]`` — simulator-friendly; bit-packing is a hardware
concern the cycle model does not need.

Branch targets are absolute instruction indices (resolved by the assembler
from labels).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

# --- opcodes ---------------------------------------------------------------
HALT = 0
ADD, SUB, MUL, MULH, DIV, REM = 1, 2, 3, 4, 5, 6
AND, OR, XOR, SLL, SRL, SRA, SLT = 7, 8, 9, 10, 11, 12, 13
ADDI, ANDI, ORI, XORI, SLLI, SRLI, SRAI, SLTI, LUI = 14, 15, 16, 17, 18, 19, 20, 21, 22
LW, SW = 23, 24
BEQ, BNE, BLT, BGE = 25, 26, 27, 28
TID, NITEMS, WGID = 29, 30, 31

N_OPS = 32
N_REGS = 32

OP_NAMES = {
    v: k for k, v in dict(
        HALT=HALT, ADD=ADD, SUB=SUB, MUL=MUL, MULH=MULH, DIV=DIV, REM=REM,
        AND=AND, OR=OR, XOR=XOR, SLL=SLL, SRL=SRL, SRA=SRA, SLT=SLT,
        ADDI=ADDI, ANDI=ANDI, ORI=ORI, XORI=XORI, SLLI=SLLI, SRLI=SRLI,
        SRAI=SRAI, SLTI=SLTI, LUI=LUI, LW=LW, SW=SW, BEQ=BEQ, BNE=BNE,
        BLT=BLT, BGE=BGE, TID=TID, NITEMS=NITEMS, WGID=WGID).items()
}

IS_BRANCH = np.zeros(N_OPS, bool)
IS_BRANCH[[BEQ, BNE, BLT, BGE]] = True
IS_MEM = np.zeros(N_OPS, bool)
IS_MEM[[LW, SW]] = True
# extra (non-pipelined) cycles per op on the scalar baseline
SCALAR_EXTRA = np.zeros(N_OPS, np.int32)
SCALAR_EXTRA[[MUL, MULH]] = 3
SCALAR_EXTRA[[DIV, REM]] = 8       # CV32E40P-class hardware divider
# extra PE cycles on the G-GPU: deep pipeline hides MUL; FGPU has no native
# divider (soft-divide microkernel, ~50 cycles/item -> 8 lanes x 50 per
# 8-item issue group = 400 per wavefront instruction)
GPU_EXTRA = np.zeros(N_OPS, np.int32)
GPU_EXTRA[[DIV, REM]] = 400


@dataclass
class Assembler:
    """Tiny builder-style assembler with labels.

    >>> a = Assembler()
    >>> a.tid(1); a.lw(2, 1, base); a.addi(2, 2, 5); a.sw(2, 1, base); a.halt()
    """
    instrs: List[Tuple[int, int, int, int, int]] = field(default_factory=list)
    labels: Dict[str, int] = field(default_factory=dict)
    fixups: List[Tuple[int, str]] = field(default_factory=list)

    def _emit(self, op, rd=0, rs=0, rt=0, imm=0):
        self.instrs.append([op, rd, rs, rt, imm])
        return self

    def label(self, name: str):
        self.labels[name] = len(self.instrs)
        return self

    def _branch(self, op, rs, rt, target: str):
        self.fixups.append((len(self.instrs), target))
        return self._emit(op, 0, rs, rt, 0)

    # --- mnemonics ---
    def halt(self): return self._emit(HALT)
    def add(self, rd, rs, rt): return self._emit(ADD, rd, rs, rt)
    def sub(self, rd, rs, rt): return self._emit(SUB, rd, rs, rt)
    def mul(self, rd, rs, rt): return self._emit(MUL, rd, rs, rt)
    def mulh(self, rd, rs, rt): return self._emit(MULH, rd, rs, rt)
    def div(self, rd, rs, rt): return self._emit(DIV, rd, rs, rt)
    def rem(self, rd, rs, rt): return self._emit(REM, rd, rs, rt)
    def and_(self, rd, rs, rt): return self._emit(AND, rd, rs, rt)
    def or_(self, rd, rs, rt): return self._emit(OR, rd, rs, rt)
    def xor(self, rd, rs, rt): return self._emit(XOR, rd, rs, rt)
    def sll(self, rd, rs, rt): return self._emit(SLL, rd, rs, rt)
    def srl(self, rd, rs, rt): return self._emit(SRL, rd, rs, rt)
    def sra(self, rd, rs, rt): return self._emit(SRA, rd, rs, rt)
    def slt(self, rd, rs, rt): return self._emit(SLT, rd, rs, rt)
    def addi(self, rd, rs, imm): return self._emit(ADDI, rd, rs, 0, imm)
    def andi(self, rd, rs, imm): return self._emit(ANDI, rd, rs, 0, imm)
    def ori(self, rd, rs, imm): return self._emit(ORI, rd, rs, 0, imm)
    def xori(self, rd, rs, imm): return self._emit(XORI, rd, rs, 0, imm)
    def slli(self, rd, rs, imm): return self._emit(SLLI, rd, rs, 0, imm)
    def srli(self, rd, rs, imm): return self._emit(SRLI, rd, rs, 0, imm)
    def srai(self, rd, rs, imm): return self._emit(SRAI, rd, rs, 0, imm)
    def slti(self, rd, rs, imm): return self._emit(SLTI, rd, rs, 0, imm)
    def lui(self, rd, imm): return self._emit(LUI, rd, 0, 0, imm)
    def li(self, rd, imm):
        """Load (possibly large) immediate."""
        if -2048 <= imm < 2048:
            return self.addi(rd, 0, imm)
        self.lui(rd, imm >> 12)
        return self.ori(rd, rd, imm & 0xFFF)
    def mv(self, rd, rs): return self.addi(rd, rs, 0)
    def lw(self, rd, rs, imm=0): return self._emit(LW, rd, rs, 0, imm)
    def sw(self, rt, rs, imm=0): return self._emit(SW, 0, rs, rt, imm)
    def beq(self, rs, rt, tgt): return self._branch(BEQ, rs, rt, tgt)
    def bne(self, rs, rt, tgt): return self._branch(BNE, rs, rt, tgt)
    def blt(self, rs, rt, tgt): return self._branch(BLT, rs, rt, tgt)
    def bge(self, rs, rt, tgt): return self._branch(BGE, rs, rt, tgt)
    def tid(self, rd): return self._emit(TID, rd)
    def nitems(self, rd): return self._emit(NITEMS, rd)
    def wgid(self, rd): return self._emit(WGID, rd)

    def assemble(self) -> np.ndarray:
        prog = np.array(self.instrs, np.int32).reshape(-1, 5)
        for idx, name in self.fixups:
            if name not in self.labels:
                raise KeyError(f"undefined label {name!r}")
            prog[idx, 4] = self.labels[name]
        return prog
