"""Machine configuration for the G-GPU execution engine.

``GGPUConfig`` is a frozen dataclass so it can serve as a static ``jax.jit``
argument: every distinct configuration compiles its own stepper. New in the
engine package (vs the original monolithic ``machine.py``):

  * ``memsys``   — selects the cache organization by registry name
                   (``"shared"`` | ``"banked"`` | ``"banked-iso"``, see
                   ``repro.ggpu.engine.memsys``). This is the knob GPUPlanner's
                   DSE sweeps in addition to memory divisions and pipelines.
  * ``fuse``     — fused-dispatch width: how many lockstep rounds the stepper
                   retires per ``while_loop`` iteration. ``fuse=1`` is the
                   legacy one-instruction-per-iteration dispatch (the memory
                   pipeline is engaged every round); ``fuse>1`` cuts the trip
                   count and lets straight-line (no load/store) rounds retire
                   through a cheap path that skips the memory system entirely.

Both knobs are cycle- and result-neutral for ``memsys="shared"``: they change
how fast the simulator runs, never what it computes (DESIGN.md §Invariants).

  * ``pipeline_depth`` — the number of pipeline stages GPUPlanner inserted
    into the logic path to close timing (``GGPUVersion.pipelines``). Unlike
    ``memsys``/``fuse`` this knob IS architectural: the analytic map assumes
    pipelining is free, but each inserted stage adds one un-bypassed cycle
    between a wavefront's back-to-back instructions and deepens the branch
    shadow, so depth ``d`` costs ``d`` extra issue cycles per executing
    wavefront per round plus ``d`` refill cycles when a wavefront takes a
    branch (DESIGN.md §Pipeline-latency feedback). ``pipeline_depth=0`` is
    bit-exact with the pre-knob engine; the DSE subsystem (``repro.dse``)
    sets it from the planner's version so wall-clock = cycles(d) / fmax(d)
    reflects the real fmax-vs-CPI trade-off.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class GGPUConfig:
    n_cus: int = 1
    wavefront: int = 64
    pes_per_cu: int = 8
    cache_lines: int = 256   # 16 KiB data cache (FGPU default)
    line_words: int = 16
    miss_penalty: int = 24
    dram_line_cycles: int = 4
    max_wf_per_cu: int = 8
    ports: int = 4
    freq_mhz: float = 500.0
    max_steps: int = 2_000_000
    memsys: str = "shared"   # cache organization (engine.memsys registry)
    fuse: int = 4            # rounds retired per while_loop iteration
    pipeline_depth: int = 0  # planner-inserted stages: extra issue/branch CPI

    @property
    def issue_cycles(self) -> int:
        return max(1, self.wavefront // self.pes_per_cu)


@dataclass(frozen=True)
class ScalarConfig(GGPUConfig):
    """The RISC-V-class in-order scalar baseline: 1 lane, 1 PE, CPI~1,
    non-pipelined MUL/DIV (CV32E40P-style), single memory port."""
    n_cus: int = 1
    wavefront: int = 1
    pes_per_cu: int = 1
    ports: int = 1
    cache_lines: int = 256
    freq_mhz: float = 667.0
