"""Frontend stage: fetch, decode, operand read, and retire.

The SIMT front end of each lockstep round:

  * **fetch** — every wavefront executes the instruction at the *minimum*
    active PC with the lane mask ``pc == pc_min`` (divergent paths
    serialize; reconvergence is automatic at the min-PC join);
  * **decode/operand read** — per-wavefront register-file gather of the two
    source operands;
  * **retire** — masked register writeback and PC advance (branch targets
    are absolute instruction indices).

All helpers are pure (W, L)-tensor functions so the stepper can compose
them inside ``lax.while_loop`` and ``jax.vmap``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.ggpu import isa


class Fetched(NamedTuple):
    """One decoded instruction per wavefront plus its execution mask."""
    op: jax.Array      # (W, 1) int32 opcode
    rd: jax.Array      # (W,)  destination register
    rs: jax.Array      # (W,)  source register 1
    rt: jax.Array      # (W,)  source register 2
    imm: jax.Array     # (W, 1) immediate / branch target
    pc_min: jax.Array  # (W, 1) the fetched PC
    exec_m: jax.Array  # (W, L) bool: lanes executing this round
    a: jax.Array       # (W, L) rs operand values
    b: jax.Array       # (W, L) rt operand values


def fetch_decode(prog, prog_len: int, pc, active, regs) -> Fetched:
    """Min-PC fetch + operand gather for every wavefront.

    ``regs`` is laid out (W, N_REGS, L) — register-major — so that the
    per-wavefront operand reads and the writeback are contiguous
    L-length row windows (one gather/scatter window per wavefront rather
    than W*L scalars)."""
    pc_min = jnp.min(jnp.where(active, pc, prog_len), axis=1, keepdims=True)
    instr = prog[jnp.clip(pc_min[:, 0], 0, prog_len - 1)]       # (W, 5)
    op = instr[:, 0:1]
    rd, rs, rt = instr[:, 1], instr[:, 2], instr[:, 3]
    imm = instr[:, 4:5]
    exec_m = active & (pc == pc_min)
    a = jnp.take_along_axis(regs, rs[:, None, None], axis=1)[:, 0]
    b = jnp.take_along_axis(regs, rt[:, None, None], axis=1)[:, 0]
    return Fetched(op, rd, rs, rt, imm, pc_min, exec_m, a, b)


def apply_intrinsics(res, op, gid, n_items, wavefront: int,
                     ops_present=None):
    """SIMT intrinsic results (thread id / item count / workgroup id),
    overriding the ALU result where the opcode matches."""
    if ops_present is None or isa.TID in ops_present:
        res = jnp.where(op == isa.TID, gid, res)
    if ops_present is None or isa.NITEMS in ops_present:
        res = jnp.where(op == isa.NITEMS, n_items, res)
    if ops_present is None or isa.WGID in ops_present:
        res = jnp.where(op == isa.WGID, gid // wavefront, res)
    return res


def writeback(regs, f: Fetched, res, is_branch, dense: bool = False):
    """Masked register-file writeback (r0 is hardwired zero; branches and
    stores write nothing). One contiguous (L,) row-window scatter per
    wavefront into ``regs`` (W, N_REGS, L) — masked lanes rewrite their
    previous value — rather than a dense full-register-file select, so a
    round only touches one register row per wavefront. ``dense=True``
    keeps the original full select (the legacy reference stepper); both
    produce identical register files."""
    do_wr = f.exec_m & (f.rd[:, None] != 0) \
        & (~is_branch[f.op[:, 0]][:, None]) & (~(f.op == isa.SW))
    if dense:
        return jnp.where(
            do_wr[:, None, :]
            & (jnp.arange(isa.N_REGS)[None, :, None]
               == f.rd[:, None, None]),
            res[:, None, :], regs)
    prev = jnp.take_along_axis(regs, f.rd[:, None, None], axis=1)[:, 0]
    return regs.at[jnp.arange(regs.shape[0]), f.rd].set(
        jnp.where(do_wr, res, prev))


def advance(pc, done, f: Fetched, taken):
    """PC update (fallthrough or absolute branch target) and HALT retire."""
    pc_next = jnp.where(taken, f.imm, f.pc_min + 1)
    pc = jnp.where(f.exec_m, pc_next, pc)
    done = done | (f.exec_m & (f.op == isa.HALT))
    return pc, done
