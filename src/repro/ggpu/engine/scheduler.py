"""Scheduler stage: resident-wavefront selection and the lockstep-round
cycle model.

One "round" = every resident wavefront issues one instruction. The round
time is max(slowest CU's issue work, memory hit service) plus the DRAM fill
term — the model under which FGPU's round-robin issue hides memory latency
until every resident wavefront is stalled (DESIGN.md §Cycle model).

Both helpers operate on a cohort of ``n_elems`` independent machines folded
into the wavefront axis (element e owns wavefronts [e*W, (e+1)*W)); cycle
accounting is per element. Single launches are ``n_elems == 1``.
"""
from __future__ import annotations

import jax.numpy as jnp


def select_resident(done, *, n_cus: int, max_wf_per_cu: int,
                    n_elems: int = 1, force_rank: bool = False):
    """FGPU holds at most ``max_wf_per_cu`` resident wavefronts per CU:
    rank each live wavefront within its element's CU (w = i*n_cus + cu
    order) and run only the first ``max_wf_per_cu``. This is why 8 CUs
    have an 8x larger concurrent working set — and why the paper's xcorr
    THRASHES.

    When every machine can hold all of its wavefronts at once the ranking
    is statically a no-op and is skipped (``force_rank`` disables the
    shortcut for the legacy reference stepper) — also lifting the old
    requirement that W divide evenly into CU columns.

    Returns (active (W, L) lane mask, resident (W,) wavefront mask)."""
    W = done.shape[0] // n_elems
    active = ~done                                       # (n_elems*W, L)
    live = jnp.any(active, axis=1)                       # (n_elems*W,)
    if W <= n_cus * max_wf_per_cu and not force_rank:
        resident = live
    else:
        live_mat = live.reshape(n_elems, -1, n_cus)
        rank = jnp.cumsum(live_mat.astype(jnp.int32), axis=1) - 1
        resident = (live_mat & (rank < max_wf_per_cu)).reshape(-1)
    return active & resident[:, None], resident


def round_cost(op_col, exec_m, *, extra, issue_cycles: int, cu_of_w,
               n_cus: int, n_elems: int, hit_service, fill_cycles,
               use_scatter: bool = False, pipe_stall=None):
    """Per-element cycle cost of one lockstep round.

    CU-side: issue cycles (+ non-pipelined op extras) summed over each CU's
    issuing wavefronts; memory-side: hit traffic streams through the data
    movers concurrently with issue, while DRAM fills serialize on the
    AXI/DRAM path and cannot be hidden once every resident wavefront is
    stalled on them. Returns (round_cycles (n_elems,), wf_exec (W,)).

    ``pipe_stall`` (optional, (n_elems*W,) int32) is the pipeline-latency
    feedback term: per-wavefront extra cycles this round from
    planner-inserted pipeline stages (dependency bubbles + branch refill,
    see ``stepper``). ``None`` (depth 0) keeps the exact pre-knob cost
    expression — bit-exactness at depth 0 is by construction."""
    wf_exec = jnp.any(exec_m, axis=1)                    # (n_elems*W,)
    base = (issue_cycles + extra[op_col]) * wf_exec.astype(jnp.int32)
    if pipe_stall is not None:
        base = base + pipe_stall
    W = base.shape[0] // n_elems
    if W % n_cus == 0 and not use_scatter:
        # within an element, cu_of_w = w % n_cus: reshape-sum == scatter-add
        cu_issue = jnp.sum(base.reshape(n_elems, -1, n_cus), axis=1)
    else:
        elem_of_w = jnp.repeat(jnp.arange(n_elems, dtype=jnp.int32), W)
        cu_issue = jnp.zeros((n_elems * n_cus,), jnp.int32).at[
            elem_of_w * n_cus + cu_of_w].add(base).reshape(n_elems, n_cus)
    round_t = jnp.maximum(jnp.max(cu_issue, axis=1), hit_service) \
        + fill_cycles
    return round_t, wf_exec
