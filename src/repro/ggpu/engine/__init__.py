"""G-GPU execution engine: composable pipeline stages for the SIMT
cycle-approximate simulator.

Stage modules (boundaries documented in DESIGN.md):

  * ``config``    — ``GGPUConfig`` / ``ScalarConfig`` (jit-static knobs,
    including the ``memsys`` organization and ``fuse`` dispatch width)
  * ``frontend``  — fetch/decode (min-PC reconvergence), operand read,
    retire (writeback + PC advance)
  * ``alu``       — the PE integer datapath, shared with the Pallas twin in
    ``repro.kernels.pe_simd``
  * ``memsys``    — the ``MemorySystem`` protocol and cache organizations
    (``SharedCache``, ``BankedPerCUCache``)
  * ``scheduler`` — resident-wavefront selection and the lockstep-round
    cycle model
  * ``stepper``   — composition root: the jitted ``while_loop`` machine,
    fused dispatch, and the single/batched launch entry points

``repro.ggpu.machine`` remains as a thin compatibility facade over this
package.
"""
from repro.ggpu.engine.alu import branch_taken, exec_alu, select_alu
from repro.ggpu.engine.config import GGPUConfig, ScalarConfig
from repro.ggpu.engine.memsys import (MEMSYS_REGISTRY, BankedPerCUCache,
                                      CacheResult, MemorySystem, SharedCache,
                                      get_memsys)
from repro.ggpu.engine.stepper import (BlockPatch, KernelLaunchError,
                                       LaunchHandle,
                                       MachineState, XorBlockPatch,
                                       cohort_rows,
                                       launch_shards,
                                       run_kernel, run_kernel_async,
                                       run_kernel_batch,
                                       run_kernel_batch_async,
                                       run_kernel_cohort,
                                       run_kernel_cohort_async)

__all__ = [
    "GGPUConfig", "ScalarConfig", "MachineState", "KernelLaunchError",
    "LaunchHandle", "BlockPatch", "XorBlockPatch", "cohort_rows",
    "launch_shards",
    "run_kernel", "run_kernel_batch", "run_kernel_cohort",
    "run_kernel_async", "run_kernel_batch_async", "run_kernel_cohort_async",
    "exec_alu", "select_alu", "branch_taken",
    "MemorySystem", "SharedCache", "BankedPerCUCache", "CacheResult",
    "MEMSYS_REGISTRY", "get_memsys",
]
