"""ALU stage: the PE integer datapath, shared by the simulator and the
Pallas TPU twin.

``select_alu`` is the single source of truth for the select-tree datapath:
every lane computes the candidate results and the per-wavefront opcode
selects one. It is written in plain ``jnp`` so the same function body traces
both inside the ``lax.while_loop`` stepper (``engine.stepper``) and inside
the Pallas kernel (``repro.kernels.pe_simd``).

``ops_present`` enables decode specialization: ``run_kernel`` passes the
static set of opcodes that actually appear in the program, and the select
tree is pruned to just those cases at trace time — the simulator analogue of
the paper's "pipelining logic on demand" (hardware is only instantiated for
what the kernel uses). Pruning is result-neutral: a case that is never
selected contributes nothing.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.ggpu import isa


def _mulh32(a, b):
    """Signed 32x32 -> high 32 bits with pure int32 ops (no int64 needed).
    Standard decomposition a = a_hi*2^16 + a_lo (a_lo unsigned); all
    partial products fit int32."""
    a_lo = a & 0xFFFF
    a_hi = a >> 16                      # arithmetic
    b_lo = b & 0xFFFF
    b_hi = b >> 16
    t1 = (a_lo * b_lo).astype(jnp.uint32) >> 16
    t2 = a_hi * b_lo + t1.astype(jnp.int32)
    t3 = a_lo * b_hi + (t2 & 0xFFFF)
    return a_hi * b_hi + (t2 >> 16) + (t3 >> 16)


def alu_cases(a, b, imm):
    """The (opcode -> thunk) case table. Thunks defer the arithmetic so a
    pruned select tree never materializes the unused candidates."""
    sh = jnp.clip(b, 0, 31)
    shi = jnp.clip(imm, 0, 31)
    au = a.astype(jnp.uint32)
    b_safe = jnp.where(b == 0, 1, b)
    return [
        (isa.ADD, lambda: a + b), (isa.SUB, lambda: a - b),
        (isa.MUL, lambda: a * b), (isa.MULH, lambda: _mulh32(a, b)),
        (isa.DIV, lambda: jnp.where(b == 0, 0, a // b_safe)),
        (isa.REM, lambda: jnp.where(b == 0, 0, a % b_safe)),
        (isa.AND, lambda: a & b), (isa.OR, lambda: a | b),
        (isa.XOR, lambda: a ^ b),
        (isa.SLL, lambda: a << sh),
        (isa.SRL, lambda: (au >> sh.astype(jnp.uint32)).astype(jnp.int32)),
        (isa.SRA, lambda: a >> sh),
        (isa.SLT, lambda: (a < b).astype(jnp.int32)),
        (isa.ADDI, lambda: a + imm), (isa.ANDI, lambda: a & imm),
        (isa.ORI, lambda: a | imm), (isa.XORI, lambda: a ^ imm),
        (isa.SLLI, lambda: a << shi),
        (isa.SRLI, lambda: (au >> shi.astype(jnp.uint32)).astype(jnp.int32)),
        (isa.SRAI, lambda: a >> shi),
        (isa.SLTI, lambda: (a < imm).astype(jnp.int32)),
        (isa.LUI, lambda: jnp.broadcast_to(imm << 12, a.shape)),
    ]


def select_alu(op, a, b, imm, ops_present=None):
    """Vectorized ALU for one instruction per wavefront.

    op, imm: (W, 1) int32; a, b: (W, L) int32 source values. Returns the
    (W, L) result. ``ops_present`` (a static container of opcodes, or None
    for all) prunes the select tree."""
    result = jnp.zeros_like(a)
    for code, thunk in alu_cases(a, b, imm):
        if ops_present is None or code in ops_present:
            result = jnp.where(op == code, thunk(), result)
    return result


def exec_alu(op, a, b, imm, pc_min=None):
    """Back-compat entry point (full, unpruned datapath). ``pc_min`` is
    accepted and ignored, matching the original ``machine.exec_alu``."""
    return select_alu(op, a, b, imm)


def branch_taken(op, a, b, ops_present=None):
    """Branch resolution for the four conditional branches."""
    taken = jnp.zeros_like(a, dtype=bool)
    for code, cmp in ((isa.BEQ, lambda: a == b), (isa.BNE, lambda: a != b),
                      (isa.BLT, lambda: a < b), (isa.BGE, lambda: a >= b)):
        if ops_present is None or code in ops_present:
            taken = jnp.where(op == code, cmp(), taken)
    return taken
