"""Stepper: composes the engine stages into the jitted SIMT machine.

The whole machine is one ``jax.lax.while_loop`` over vectorized (W, L)
tensors, jitted once per (program shape, config, opcode set). Each loop
iteration retires up to ``cfg.fuse`` lockstep rounds (**fused dispatch**);
within a fused iteration, a round whose in-flight instructions are all
straight-line (no load/store) takes a fast path that skips the memory
system entirely. Both are wall-clock optimizations only: results, cycles,
and stats are bit-identical to one-round-per-iteration dispatch
(DESIGN.md §Invariants).

The core simulates a **cohort** of ``B`` independent machines by folding
the batch into the wavefront axis (element e owns wavefronts
[e*W, (e+1)*W) and the memory words [e*M, (e+1)*M)); cycles/stats/steps
are tracked per element. ``B == 1`` is the single-launch case.

Entry points:

  * ``run_kernel``        — single launch; exact signature and bit-exact
    results of the original monolithic ``machine.run_kernel``.
    ``legacy=True`` selects the seed-faithful reference stepper
    (one round per iteration, one-hot scatter cache accounting, dense
    writeback, unpruned datapath) for differential testing/benchmarks.
  * ``run_kernel_cohort`` — N launches of the *same kernel* (program,
    n_items, memory shape) over different memory images, folded into one
    stepper call: per-round fixed costs are amortized across the cohort
    and the straight-line fast path stays a real branch. This is the fast
    multi-launch path ``serve.engine.LaunchQueue`` uses.
  * ``run_kernel_batch``  — N heterogeneous launches, padded to a common
    (program, mem) envelope and ``jax.vmap``-ed over the stepper. Fully
    general (different programs), but vmap turns the fast-path branch into
    a select, so prefer cohorts where shapes allow.

Per-launch cycles/stats are exact in all three: padding a program with
HALT words and a memory image with zeros is state-invisible to the
machine, and cohort elements are fully isolated.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ggpu import isa
from repro.ggpu.engine import alu, frontend, scheduler
from repro.ggpu.engine.config import GGPUConfig
from repro.ggpu.engine.memsys import SharedCache, get_memsys, load_store


class MachineState(NamedTuple):
    pc: jax.Array          # (B*W, L) int32
    regs: jax.Array        # (B*W, 32, L) int32 (register-major: row reads)
    done: jax.Array        # (B*W, L) bool
    mem: jax.Array         # (B*M+1,) int32 (last slot = write sink)
    tags: jax.Array        # memsys tag state (shape per organization)
    cycles: jax.Array      # (B,) int32 (lockstep-round total per element)
    stats: jax.Array       # (B, 4) int32: instrs, mem_ops, hits, misses
    step: jax.Array        # (B,) int32


def _n_wavefronts(n_items: int, cfg: GGPUConfig) -> int:
    L = cfg.wavefront
    W = (n_items + L - 1) // L
    # the per-CU residency ranking reshapes (W,) -> (W/n_cus, n_cus); round
    # W up with always-done wavefronts when it would be ragged (state of an
    # invalid wavefront never changes, so this is result/cycle-neutral)
    if W > cfg.n_cus * cfg.max_wf_per_cu and W % cfg.n_cus:
        W += cfg.n_cus - W % cfg.n_cus
    return W


def _build_core(cfg: GGPUConfig, B: int, W: int, prog_len: int, msize: int,
                ops, legacy: bool = False):
    """Returns ``core(prog, mem_flat, n_items) -> MachineState`` for one
    static machine shape: ``B`` cohort elements of ``W`` wavefronts each,
    ``mem_flat`` the concatenated (B*msize,) memory images. ``ops`` is the
    static opcode set for decode specialization (None = unpruned);
    ``legacy`` selects the seed-faithful reference round."""
    L = cfg.wavefront
    n_cus = cfg.n_cus
    memsys = get_memsys(cfg.memsys)
    if legacy and not isinstance(memsys, SharedCache):
        raise ValueError("legacy reference stepper only models 'shared'")
    if legacy and cfg.pipeline_depth:
        raise ValueError("legacy reference stepper predates the "
                         "pipeline_depth knob (seed model: depth 0 only)")
    fuse = 1 if legacy else max(1, cfg.fuse)
    ops_present = None if ops is None else frozenset(ops)
    has_mem = ops_present is None or bool({isa.LW, isa.SW} & ops_present)

    elem_of_w = jnp.repeat(jnp.arange(B, dtype=jnp.int32), W)   # (B*W,)
    cu_of_w = jnp.tile(jnp.arange(W, dtype=jnp.int32) % n_cus, B)
    gid = jnp.tile(
        (jnp.arange(W)[:, None] * L + jnp.arange(L)[None, :])
        .astype(jnp.int32), (B, 1))                             # elem-local
    mem_off = (elem_of_w * msize)[:, None]                      # (B*W, 1)
    sink = B * msize
    is_branch = jnp.asarray(isa.IS_BRANCH)
    extra = jnp.asarray(
        isa.SCALAR_EXTRA if cfg.pes_per_cu == 1 else isa.GPU_EXTRA)
    zeros_e = jnp.zeros((B,), jnp.int32)

    def per_elem_sum(x):
        return jnp.sum(x.reshape(B, -1), axis=1).astype(jnp.int32)

    def core(prog, mem_flat, n_items, msize_clip):
        """``msize_clip`` is the launch's own memory size (traced): the
        address clip must bind at each launch's boundary, not the padded
        batch envelope, or an out-of-range access would read the padding
        instead of the launch's last word as a single run does."""
        n_items = n_items.astype(jnp.int32)
        msize_clip = msize_clip.astype(jnp.int32)
        lane_valid = gid < n_items
        st = MachineState(
            pc=jnp.zeros((B * W, L), jnp.int32),
            regs=jnp.zeros((B * W, isa.N_REGS, L), jnp.int32),
            done=~lane_valid,
            mem=jnp.concatenate([mem_flat, jnp.zeros((1,), jnp.int32)]),
            tags=memsys.init_tags(cfg, B),
            cycles=jnp.zeros((B,), jnp.int32),
            stats=jnp.zeros((B, 4), jnp.int32),
            step=jnp.zeros((B,), jnp.int32),
        )

        def round_step(s: MachineState) -> MachineState:
            # masking `active` by each element's running predicate makes a
            # post-halt (or past-max_steps) round an exact no-op for that
            # element — no per-round control flow needed, which keeps fused
            # sub-rounds branch-free while step/cycle accounting stays
            # identical to one-round-per-iteration dispatch
            runvec = (~jnp.all(s.done.reshape(B, -1), axis=1)) \
                & (s.step < cfg.max_steps)                      # (B,)
            active, _ = scheduler.select_resident(
                s.done, n_cus=n_cus, max_wf_per_cu=cfg.max_wf_per_cu,
                n_elems=B, force_rank=legacy)
            active = active & jnp.repeat(runvec, W)[:, None]
            f = frontend.fetch_decode(prog, prog_len, s.pc, active, s.regs)
            res = alu.select_alu(f.op, f.a, f.b, f.imm, ops_present)
            res = frontend.apply_intrinsics(res, f.op, gid, n_items, L,
                                            ops_present)

            def mem_round(res):
                addr_local = jnp.clip(f.a + f.imm, 0, msize_clip - 1)
                is_load = f.op == isa.LW
                is_store = f.op == isa.SW
                mem, loaded, mem_mask = load_store(
                    s.mem, addr_local + mem_off, f.b, f.exec_m, is_load,
                    is_store, sink, always_scatter=legacy)
                res = jnp.where(is_load, loaded, res)
                if legacy:
                    cr = memsys.access(s.tags, addr_local, mem_mask,
                                       cu_of_w=cu_of_w, elem_of_w=elem_of_w,
                                       n_elems=B, cfg=cfg, one_hot=True)
                else:
                    cr = memsys.access(s.tags, addr_local, mem_mask,
                                       cu_of_w=cu_of_w, elem_of_w=elem_of_w,
                                       n_elems=B, cfg=cfg)
                return (res, mem, cr.tags, cr.hit_service, cr.fill_cycles,
                        per_elem_sum(mem_mask), per_elem_sum(cr.hit),
                        per_elem_sum(cr.miss))

            def alu_round(res):
                return (res, s.mem, s.tags, zeros_e, zeros_e, zeros_e,
                        zeros_e, zeros_e)

            if not has_mem:
                out = alu_round(res)
            elif fuse > 1:
                # fused-dispatch fast path: straight-line rounds (no lane
                # touching memory) skip the cache model and the mem scatter
                any_mem = jnp.any((f.op == isa.LW) | (f.op == isa.SW))
                out = jax.lax.cond(any_mem, mem_round, alu_round, res)
            else:                      # legacy dispatch: memsys every round
                out = mem_round(res)
            res, mem, tags, hit_service, fill, n_mem, n_hit, n_miss = out

            regs = frontend.writeback(s.regs, f, res, is_branch,
                                      dense=legacy)
            taken = alu.branch_taken(f.op, f.a, f.b, ops_present) & f.exec_m
            pc, done = frontend.advance(s.pc, s.done, f, taken)
            if cfg.pipeline_depth > 0:
                # pipeline-latency feedback: each planner-inserted stage adds
                # one un-bypassed dependency bubble per issuing wavefront and
                # one refill cycle when the wavefront takes a branch
                pipe_stall = cfg.pipeline_depth * (
                    jnp.any(f.exec_m, axis=1).astype(jnp.int32)
                    + jnp.any(taken, axis=1).astype(jnp.int32))
            else:
                pipe_stall = None
            round_t, wf_exec = scheduler.round_cost(
                f.op[:, 0], f.exec_m, extra=extra,
                issue_cycles=cfg.issue_cycles, cu_of_w=cu_of_w,
                n_cus=n_cus, n_elems=B, hit_service=hit_service,
                fill_cycles=fill, use_scatter=legacy,
                pipe_stall=pipe_stall)
            cycles = s.cycles + round_t.astype(jnp.int32)
            stats = s.stats + jnp.stack(
                [per_elem_sum(wf_exec), n_mem, n_hit, n_miss], axis=1)
            return MachineState(pc, regs, done, mem, tags, cycles, stats,
                                s.step + runvec.astype(jnp.int32))

        def still_running(s: MachineState):
            return jnp.any((~jnp.all(s.done.reshape(B, -1), axis=1))
                           & (s.step < cfg.max_steps))

        if fuse == 1:
            body = round_step
        else:
            # fused dispatch: retire up to `fuse` rounds per while_loop
            # iteration (fori_loop keeps the compiled body single-copy)
            def body(s: MachineState) -> MachineState:
                return jax.lax.fori_loop(
                    0, fuse, lambda _, x: round_step(x), s)

        return jax.lax.while_loop(still_running, body, st)

    return core


@functools.partial(jax.jit,
                   static_argnames=("cfg", "W", "prog_len", "ops", "legacy"))
def _run_single(prog, mem0, n_items, cfg, W, prog_len, ops, legacy=False):
    msize = mem0.shape[0]
    return _build_core(cfg, 1, W, prog_len, msize, ops, legacy)(
        prog, mem0, n_items, jnp.asarray(msize, jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "B", "W", "prog_len", "ops"))
def _run_cohort(prog, mems_flat, n_items, cfg, B, W, prog_len, ops):
    msize = mems_flat.shape[0] // B
    return _build_core(cfg, B, W, prog_len, msize, ops)(
        prog, mems_flat, n_items, jnp.asarray(msize, jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg", "W", "prog_len", "ops"))
def _run_batch(progs, mems, n_items, msizes, cfg, W, prog_len, ops):
    core = _build_core(cfg, 1, W, prog_len, mems.shape[1], ops)
    return jax.vmap(core)(progs, mems, n_items, msizes)


class KernelLaunchError(RuntimeError):
    """A launch did not halt within ``cfg.max_steps``. ``index`` is the
    position of the failing launch within the call's own argument list."""

    def __init__(self, message: str, index: int = 0):
        super().__init__(message)
        self.index = index


def _static_ops(prog: np.ndarray):
    return tuple(sorted({int(o) for o in prog[:, 0]}))


def _info(cycles: int, stats, steps: int, cfg: GGPUConfig) -> dict:
    return {
        "cycles": cycles,
        "instrs": int(stats[0]),
        "mem_ops": int(stats[1]),
        "hits": int(stats[2]),
        "misses": int(stats[3]),
        "steps": steps,
        "time_us": float(cycles / cfg.freq_mhz),
        "memsys": cfg.memsys,
    }


def run_kernel(prog: np.ndarray, mem0: np.ndarray, n_items: int,
               cfg: GGPUConfig, *, legacy: bool = False):
    """Execute a kernel. Returns (mem_final, info dict).

    ``legacy=True`` runs the seed-faithful reference stepper (identical
    results and cycles, pre-refactor wall-clock) for differential testing
    and as the baseline of ``benchmarks.engine_bench``."""
    prog = np.asarray(prog, np.int32)
    final = _run_single(
        jnp.asarray(prog), jnp.asarray(mem0, jnp.int32),
        jnp.asarray(int(n_items), jnp.int32), cfg,
        _n_wavefronts(int(n_items), cfg), int(prog.shape[0]),
        None if legacy else _static_ops(prog), legacy)
    if not bool(np.asarray(final.done).all()):
        raise KernelLaunchError("kernel hit max_steps without halting")
    cycles = int(np.asarray(final.cycles)[0])
    return np.asarray(final.mem)[:-1], _info(
        cycles, np.asarray(final.stats)[0], int(np.asarray(final.step)[0]),
        cfg)


def run_kernel_cohort(prog: np.ndarray, mems: Sequence[np.ndarray],
                      n_items: int, cfg: GGPUConfig
                      ) -> List[Tuple[np.ndarray, dict]]:
    """Execute the same kernel over B memory images as one folded stepper
    call (B*W wavefronts, per-element accounting). Bit-exact per launch."""
    prog = np.asarray(prog, np.int32)
    mems = [np.asarray(m, np.int32) for m in mems]
    if not mems:
        return []
    msize = mems[0].shape[0]
    if any(m.shape[0] != msize for m in mems):
        raise ValueError("cohort memory images must share one shape")
    B = len(mems)
    final = _run_cohort(
        jnp.asarray(prog), jnp.asarray(np.concatenate(mems)),
        jnp.asarray(int(n_items), jnp.int32), cfg, B,
        _n_wavefronts(int(n_items), cfg), int(prog.shape[0]),
        _static_ops(prog))
    done = np.asarray(final.done).reshape(B, -1)
    mem_f = np.asarray(final.mem)[:-1].reshape(B, msize)
    cycles = np.asarray(final.cycles)
    stats = np.asarray(final.stats)
    steps = np.asarray(final.step)
    out = []
    for i in range(B):
        if not done[i].all():
            raise KernelLaunchError(
                f"cohort kernel {i} hit max_steps without halting", i)
        info = _info(int(cycles[i]), stats[i], int(steps[i]), cfg)
        info["batch_size"] = B
        out.append((mem_f[i], info))
    return out


def run_kernel_batch(progs: Sequence[np.ndarray],
                     mems: Sequence[np.ndarray],
                     n_items: Sequence[int],
                     cfg: GGPUConfig) -> List[Tuple[np.ndarray, dict]]:
    """Execute N heterogeneous kernel launches as one vmapped stepper call.

    Programs are padded to a common length with HALT words and memory
    images zero-padded to a common size; per-launch results and cycle
    counts are exact (the padding is invisible to the machine — each
    launch's address clip still binds at its own memory size). Returns a
    list of (mem_final, info) in submission order."""
    if not (len(progs) == len(mems) == len(n_items)):
        raise ValueError("progs, mems, n_items must have equal length")
    if not progs:
        return []
    progs = [np.asarray(p, np.int32) for p in progs]
    mems = [np.asarray(m, np.int32) for m in mems]
    P = max(p.shape[0] for p in progs)
    M = max(m.shape[0] for m in mems)
    prog_b = np.stack([np.pad(p, ((0, P - p.shape[0]), (0, 0)))
                       for p in progs])                  # HALT == all-zeros
    mem_b = np.stack([np.pad(m, (0, M - m.shape[0])) for m in mems])
    W = max(_n_wavefronts(int(n), cfg) for n in n_items)
    ops = tuple(sorted(set().union(*(_static_ops(p) for p in progs))))
    final = _run_batch(
        jnp.asarray(prog_b), jnp.asarray(mem_b),
        jnp.asarray(np.asarray(n_items, np.int32)),
        jnp.asarray(np.array([m.shape[0] for m in mems], np.int32)),
        cfg, W, P, ops)
    done = np.asarray(final.done)
    mem_f = np.asarray(final.mem)[:, :-1]
    cycles = np.asarray(final.cycles)[:, 0]
    stats = np.asarray(final.stats)[:, 0]
    steps = np.asarray(final.step)[:, 0]
    out = []
    for i, m in enumerate(mems):
        if not done[i].all():
            raise KernelLaunchError(
                f"batched kernel {i} hit max_steps without halting", i)
        info = _info(int(cycles[i]), stats[i], int(steps[i]), cfg)
        info["batch_size"] = len(progs)
        out.append((mem_f[i, :m.shape[0]], info))
    return out
