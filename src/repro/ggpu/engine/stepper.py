"""Stepper: composes the engine stages into the jitted SIMT machine.

The whole machine is one ``jax.lax.while_loop`` over vectorized (W, L)
tensors, jitted once per (program shape, config, opcode set). Each loop
iteration retires up to ``cfg.fuse`` lockstep rounds (**fused dispatch**);
within a fused iteration, a round whose in-flight instructions are all
straight-line (no load/store) takes a fast path that skips the memory
system entirely. Both are wall-clock optimizations only: results, cycles,
and stats are bit-identical to one-round-per-iteration dispatch
(DESIGN.md §Invariants).

The core simulates a **cohort** of ``B`` independent machines by folding
the batch into the wavefront axis (element e owns wavefronts
[e*W, (e+1)*W) and the memory words [e*M, (e+1)*M)); cycles/stats/steps
are tracked per element. ``B == 1`` is the single-launch case.

Entry points:

  * ``run_kernel``        — single launch; exact signature and bit-exact
    results of the original monolithic ``machine.run_kernel``.
    ``legacy=True`` selects the seed-faithful reference stepper
    (one round per iteration, one-hot scatter cache accounting, dense
    writeback, unpruned datapath) for differential testing/benchmarks.
  * ``run_kernel_cohort`` — N launches of the *same kernel* (program,
    n_items, memory shape) over different memory images, folded into one
    stepper call: per-round fixed costs are amortized across the cohort
    and the straight-line fast path stays a real branch. This is the fast
    multi-launch path ``serve.engine.LaunchQueue`` uses.
  * ``run_kernel_batch``  — N heterogeneous launches, padded to a common
    (program, mem) envelope and ``jax.vmap``-ed over the stepper. Fully
    general (different programs), but vmap turns the fast-path branch into
    a select, so prefer cohorts where shapes allow.

Per-launch cycles/stats are exact in all three: padding a program with
HALT words and a memory image with zeros is state-invisible to the
machine, and cohort elements are fully isolated.

**Sharded execution.** The cohort and batch async entry points accept a
``mesh=`` (a ``jax.sharding.Mesh``, e.g. ``repro.launch.mesh.
make_launch_mesh()``): the leading launch axis is then sharded across
the mesh's data-parallel axes with ``shard_map``, so a fleet of N
simulated G-GPU instances maps onto M physical devices. Each device
runs its *own* ``while_loop`` over its slice of the launches — there is
no cross-device collective anywhere in the machine, so a device retires
its shard as soon as its own launches halt. Launch counts that do not
divide the shard count are padded (cohorts with a copy of the first
image, batches with a 1-item HALT filler); padding is sliced away at
resolution and never observable. Cohort sizes are additionally bucketed
to powers of two per shard (``cohort_rows``, sharded or not), so
open-loop serving traffic with arbitrary pending counts compiles
O(log B) steppers rather than one per distinct cohort size — the
compiled-envelope discipline that keeps tail latency flat under Poisson
arrivals. Per-launch results, cycles, and stats
are bit-exact vs the single-device path by construction: cohort
elements are fully isolated, so a B-element cohort split into M local
(B/M)-element cohorts computes identical bits. A mesh whose
data-parallel extent is 1 (or ``mesh=None``) falls back to the
single-device path. Partition specs come from the
``repro.sharding.rules`` rule engine (the ``"launch"`` activation
kind). CPU CI simulates 8 devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

**Async launch pipeline.** Every entry point has an ``_async`` twin
(``run_kernel_async`` / ``run_kernel_cohort_async`` /
``run_kernel_batch_async``) that returns a ``LaunchHandle`` future
immediately after dispatch instead of blocking on the device. The sync
entry points are thin blocking wrappers over the same jitted callables
(``handle.results()`` right after dispatch), so both paths share one
compile cache and are bit-exact by construction. Three properties make
the async path cheap (DESIGN.md §Async launch pipeline):

  * **donation** — the staged memory image (host copy + appended write
    sink) is donated to XLA (``donate_argnums``), so the final memory
    aliases the input buffer instead of allocating a second envelope.
    Caller arrays are never donated: staging always copies host-side.
  * **lazy, sliced download** — resolving a handle fetches only the tiny
    ``done/cycles/stats/step`` arrays; memory is pulled on first access,
    and a declared ``out_region=(lo, hi)`` downloads just that slice of
    each launch's image (``(0, 0)``: cycles-only, no transfer at all).
  * **async dispatch** — the handle returns while the device still runs,
    so the caller can plan, stage, and dispatch the next launch during
    the current one's compute (the serving scheduler's pipelined drain).
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ggpu import isa
from repro.ggpu.engine import alu, frontend, scheduler
from repro.ggpu.engine.config import GGPUConfig
from repro.ggpu.engine.memsys import SharedCache, get_memsys, load_store


class MachineState(NamedTuple):
    pc: jax.Array          # (B*W, L) int32
    regs: jax.Array        # (B*W, 32, L) int32 (register-major: row reads)
    done: jax.Array        # (B*W, L) bool
    mem: jax.Array         # (B*M+1,) int32 (last slot = write sink)
    tags: jax.Array        # memsys tag state (shape per organization)
    cycles: jax.Array      # (B,) int32 (lockstep-round total per element)
    stats: jax.Array       # (B, 4) int32: instrs, mem_ops, hits, misses
    step: jax.Array        # (B,) int32


def _n_wavefronts(n_items: int, cfg: GGPUConfig) -> int:
    L = cfg.wavefront
    W = (n_items + L - 1) // L
    # the per-CU residency ranking reshapes (W,) -> (W/n_cus, n_cus); round
    # W up with always-done wavefronts when it would be ragged (state of an
    # invalid wavefront never changes, so this is result/cycle-neutral)
    if W > cfg.n_cus * cfg.max_wf_per_cu and W % cfg.n_cus:
        W += cfg.n_cus - W % cfg.n_cus
    return W


def _build_core(cfg: GGPUConfig, B: int, W: int, prog_len: int, msize: int,
                ops, legacy: bool = False):
    """Returns ``core(prog, mem_sink, n_items) -> MachineState`` for one
    static machine shape: ``B`` cohort elements of ``W`` wavefronts each,
    ``mem_sink`` the concatenated (B*msize + 1,) memory images with the
    write sink already appended (callers stage it host-side so the jitted
    wrappers can donate the buffer — the final memory aliases it). ``ops``
    is the static opcode set for decode specialization (None = unpruned);
    ``legacy`` selects the seed-faithful reference round."""
    L = cfg.wavefront
    n_cus = cfg.n_cus
    memsys = get_memsys(cfg.memsys)
    if legacy and not isinstance(memsys, SharedCache):
        raise ValueError("legacy reference stepper only models 'shared'")
    if legacy and cfg.pipeline_depth:
        raise ValueError("legacy reference stepper predates the "
                         "pipeline_depth knob (seed model: depth 0 only)")
    fuse = 1 if legacy else max(1, cfg.fuse)
    ops_present = None if ops is None else frozenset(ops)
    has_mem = ops_present is None or bool({isa.LW, isa.SW} & ops_present)

    elem_of_w = jnp.repeat(jnp.arange(B, dtype=jnp.int32), W)   # (B*W,)
    cu_of_w = jnp.tile(jnp.arange(W, dtype=jnp.int32) % n_cus, B)
    gid = jnp.tile(
        (jnp.arange(W)[:, None] * L + jnp.arange(L)[None, :])
        .astype(jnp.int32), (B, 1))                             # elem-local
    mem_off = (elem_of_w * msize)[:, None]                      # (B*W, 1)
    sink = B * msize
    is_branch = jnp.asarray(isa.IS_BRANCH)
    extra = jnp.asarray(
        isa.SCALAR_EXTRA if cfg.pes_per_cu == 1 else isa.GPU_EXTRA)
    zeros_e = jnp.zeros((B,), jnp.int32)

    def per_elem_sum(x):
        return jnp.sum(x.reshape(B, -1), axis=1).astype(jnp.int32)

    def core(prog, mem_sink, n_items, msize_clip):
        """``msize_clip`` is the launch's own memory size (traced): the
        address clip must bind at each launch's boundary, not the padded
        batch envelope, or an out-of-range access would read the padding
        instead of the launch's last word as a single run does."""
        n_items = n_items.astype(jnp.int32)
        msize_clip = msize_clip.astype(jnp.int32)
        lane_valid = gid < n_items
        st = MachineState(
            pc=jnp.zeros((B * W, L), jnp.int32),
            regs=jnp.zeros((B * W, isa.N_REGS, L), jnp.int32),
            done=~lane_valid,
            mem=mem_sink,
            tags=memsys.init_tags(cfg, B),
            cycles=jnp.zeros((B,), jnp.int32),
            stats=jnp.zeros((B, 4), jnp.int32),
            step=jnp.zeros((B,), jnp.int32),
        )

        def round_step(s: MachineState) -> MachineState:
            # masking `active` by each element's running predicate makes a
            # post-halt (or past-max_steps) round an exact no-op for that
            # element — no per-round control flow needed, which keeps fused
            # sub-rounds branch-free while step/cycle accounting stays
            # identical to one-round-per-iteration dispatch
            runvec = (~jnp.all(s.done.reshape(B, -1), axis=1)) \
                & (s.step < cfg.max_steps)                      # (B,)
            active, _ = scheduler.select_resident(
                s.done, n_cus=n_cus, max_wf_per_cu=cfg.max_wf_per_cu,
                n_elems=B, force_rank=legacy)
            active = active & jnp.repeat(runvec, W)[:, None]
            f = frontend.fetch_decode(prog, prog_len, s.pc, active, s.regs)
            res = alu.select_alu(f.op, f.a, f.b, f.imm, ops_present)
            res = frontend.apply_intrinsics(res, f.op, gid, n_items, L,
                                            ops_present)

            def mem_round(res):
                addr_local = jnp.clip(f.a + f.imm, 0, msize_clip - 1)
                is_load = f.op == isa.LW
                is_store = f.op == isa.SW
                mem, loaded, mem_mask = load_store(
                    s.mem, addr_local + mem_off, f.b, f.exec_m, is_load,
                    is_store, sink, always_scatter=legacy)
                res = jnp.where(is_load, loaded, res)
                if legacy:
                    cr = memsys.access(s.tags, addr_local, mem_mask,
                                       cu_of_w=cu_of_w, elem_of_w=elem_of_w,
                                       n_elems=B, cfg=cfg, one_hot=True)
                else:
                    cr = memsys.access(s.tags, addr_local, mem_mask,
                                       cu_of_w=cu_of_w, elem_of_w=elem_of_w,
                                       n_elems=B, cfg=cfg)
                return (res, mem, cr.tags, cr.hit_service, cr.fill_cycles,
                        per_elem_sum(mem_mask), per_elem_sum(cr.hit),
                        per_elem_sum(cr.miss))

            def alu_round(res):
                return (res, s.mem, s.tags, zeros_e, zeros_e, zeros_e,
                        zeros_e, zeros_e)

            if not has_mem:
                out = alu_round(res)
            elif fuse > 1:
                # fused-dispatch fast path: straight-line rounds (no lane
                # touching memory) skip the cache model and the mem scatter
                any_mem = jnp.any((f.op == isa.LW) | (f.op == isa.SW))
                out = jax.lax.cond(any_mem, mem_round, alu_round, res)
            else:                      # legacy dispatch: memsys every round
                out = mem_round(res)
            res, mem, tags, hit_service, fill, n_mem, n_hit, n_miss = out

            regs = frontend.writeback(s.regs, f, res, is_branch,
                                      dense=legacy)
            taken = alu.branch_taken(f.op, f.a, f.b, ops_present) & f.exec_m
            pc, done = frontend.advance(s.pc, s.done, f, taken)
            if cfg.pipeline_depth > 0:
                # pipeline-latency feedback: each planner-inserted stage adds
                # one un-bypassed dependency bubble per issuing wavefront and
                # one refill cycle when the wavefront takes a branch
                pipe_stall = cfg.pipeline_depth * (
                    jnp.any(f.exec_m, axis=1).astype(jnp.int32)
                    + jnp.any(taken, axis=1).astype(jnp.int32))
            else:
                pipe_stall = None
            round_t, wf_exec = scheduler.round_cost(
                f.op[:, 0], f.exec_m, extra=extra,
                issue_cycles=cfg.issue_cycles, cu_of_w=cu_of_w,
                n_cus=n_cus, n_elems=B, hit_service=hit_service,
                fill_cycles=fill, use_scatter=legacy,
                pipe_stall=pipe_stall)
            cycles = s.cycles + round_t.astype(jnp.int32)
            stats = s.stats + jnp.stack(
                [per_elem_sum(wf_exec), n_mem, n_hit, n_miss], axis=1)
            return MachineState(pc, regs, done, mem, tags, cycles, stats,
                                s.step + runvec.astype(jnp.int32))

        def still_running(s: MachineState):
            return jnp.any((~jnp.all(s.done.reshape(B, -1), axis=1))
                           & (s.step < cfg.max_steps))

        if fuse == 1:
            body = round_step
        else:
            # fused dispatch: retire up to `fuse` rounds per while_loop
            # iteration (fori_loop keeps the compiled body single-copy)
            def body(s: MachineState) -> MachineState:
                return jax.lax.fori_loop(
                    0, fuse, lambda _, x: round_step(x), s)

        return jax.lax.while_loop(still_running, body, st)

    return core


# The memory argument of each jitted wrapper arrives with the write sink
# already appended and is DONATED: the machine's final memory aliases the
# staged input buffer (same shape/dtype), so a launch allocates one memory
# envelope, not two. Staging (in the *_async entry points) always copies
# host-side, so a caller's array is never invalidated.

@functools.partial(jax.jit,
                   static_argnames=("cfg", "W", "prog_len", "ops", "legacy"),
                   donate_argnums=(1,))
def _run_single(prog, mem_sink, n_items, cfg, W, prog_len, ops,
                legacy=False):
    msize = mem_sink.shape[0] - 1
    return _build_core(cfg, 1, W, prog_len, msize, ops, legacy)(
        prog, mem_sink, n_items, jnp.asarray(msize, jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "B", "W", "prog_len", "ops"),
                   donate_argnums=(1,))
def _run_cohort(prog, mems_sink, n_items, cfg, B, W, prog_len, ops):
    msize = (mems_sink.shape[0] - 1) // B
    return _build_core(cfg, B, W, prog_len, msize, ops)(
        prog, mems_sink, n_items, jnp.asarray(msize, jnp.int32))


@functools.partial(jax.jit, static_argnames=("cfg", "W", "prog_len", "ops"),
                   donate_argnums=(1,))
def _run_batch(progs, mems_sink, n_items, msizes, cfg, W, prog_len, ops):
    core = _build_core(cfg, 1, W, prog_len, mems_sink.shape[1] - 1, ops)
    return jax.vmap(core)(progs, mems_sink, n_items, msizes)


# -- sharded execution over a device mesh -----------------------------------

def launch_shards(mesh) -> int:
    """How many ways the launch axis splits over ``mesh``: the product of
    its data-parallel axis sizes (``None``: 1 — no sharding)."""
    if mesh is None:
        return 1
    rules = _launch_rules(mesh)
    return rules.axes_size(rules.dp_axes)


def cohort_rows(B: int, shards: int = 1) -> int:
    """Padded cohort size for a ``B``-launch cohort over ``shards``
    devices: the per-shard slice is rounded up to a power of two, so the
    staged rows are ``shards * 2^ceil(log2(ceil(B/shards)))``. The bucket
    (not ``B``) is what the compiled stepper is traced for — open-loop
    traffic with arbitrary pending counts compiles O(log B) steppers
    instead of one per distinct cohort size, which is what keeps p99
    launch latency flat under Poisson arrivals. Padding elements are
    copies of the cohort's first image; every resolution path slices them
    away before they can be observed."""
    b_local = -(-B // shards)
    return shards * (1 << max(0, b_local - 1).bit_length())


@functools.lru_cache(maxsize=None)
def _launch_rules(mesh):
    """The sharding rule engine bound to ``mesh`` for launch placement
    (no model axes in play: FSDP/sequence sharding off)."""
    from repro.sharding.rules import make_rules
    return make_rules(mesh, fsdp=False, seq_shard=False)


def _launch_spec(mesh, ndim: int):
    """PartitionSpec sharding a leading launch axis of an ``ndim``-array
    over ``mesh``'s data-parallel axes (via the rule engine's ``launch``
    activation kind — the shard count always divides here because entry
    points pad first)."""
    rules = _launch_rules(mesh)
    shards = rules.axes_size(rules.dp_axes)
    spec = rules.activation_spec("launch", (shards,) + (1,) * (ndim - 1))
    assert spec is not None and spec[0] is not None
    return spec


def _launch_sharding(mesh, ndim: int):
    return jax.sharding.NamedSharding(mesh, _launch_spec(mesh, ndim))


@functools.lru_cache(maxsize=None)
def _sharded_cohort_fn(cfg, B_local, W, prog_len, msize, ops, mesh):
    """Jitted sharded cohort stepper: every mesh shard runs an isolated
    ``B_local``-element cohort over its row of the staged memory
    (``(shards, B_local*msize + 1)`` — one write sink per shard). Each
    shard's ``while_loop`` converges on its own launches only; there are
    no collectives. The memory rows keep their leading device axis
    (out-spec sharded), every other state leaf concatenates per-element
    along axis 0 — exactly the unsharded cohort layout for ``shards *
    B_local`` elements."""
    from jax.experimental.shard_map import shard_map
    core = _build_core(cfg, B_local, W, prog_len, msize, ops)
    spec = _launch_spec(mesh, 1)
    row_spec = _launch_spec(mesh, 2)

    def local(prog, mem_rows, n_items):
        st = core(prog, mem_rows[0], n_items, jnp.asarray(msize, jnp.int32))
        return st._replace(mem=st.mem[None])

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(), row_spec,
                  jax.sharding.PartitionSpec()),
        out_specs=MachineState(pc=spec, regs=spec, done=spec, mem=row_spec,
                               tags=spec, cycles=spec, stats=spec,
                               step=spec),
        check_rep=False)              # while_loop has no replication rule
    return jax.jit(fn, donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _sharded_batch_fn(cfg, W, prog_len, msize, ops, mesh):
    """Jitted sharded heterogeneous-batch stepper: the vmapped launch
    axis is split across the mesh's data-parallel axes; each shard vmaps
    the single-launch core over its local launches and loops until only
    *they* halt."""
    from jax.experimental.shard_map import shard_map
    core = _build_core(cfg, 1, W, prog_len, msize, ops)
    spec = _launch_spec(mesh, 1)
    fn = shard_map(jax.vmap(core), mesh=mesh,
                   in_specs=(spec, spec, spec, spec), out_specs=spec,
                   check_rep=False)
    return jax.jit(fn, donate_argnums=(1,))


class KernelLaunchError(RuntimeError):
    """A launch did not halt within ``cfg.max_steps``. ``index`` is the
    position of the failing launch within the call's own argument list."""

    def __init__(self, message: str, index: int = 0):
        super().__init__(message)
        self.index = index


def _static_ops(prog: np.ndarray):
    return tuple(sorted({int(o) for o in prog[:, 0]}))


def _info(cycles: int, stats, steps: int, cfg: GGPUConfig) -> dict:
    return {
        "cycles": cycles,
        "instrs": int(stats[0]),
        "mem_ops": int(stats[1]),
        "hits": int(stats[2]),
        "misses": int(stats[3]),
        "steps": steps,
        "time_us": float(cycles / cfg.freq_mhz),
        "memsys": cfg.memsys,
    }


Region = Optional[Tuple[int, int]]


# -- device-resident chaining (patches) --------------------------------------
#
# A *patch* overwrites a region of a launch's staged memory with a device
# array — typically another launch's ``device_mem``/``device_mem_block``
# output — so a consumer kernel reads its producer's result without any
# host transfer. Patches are applied to the freshly staged buffer BEFORE
# the jitted stepper consumes (and donates) it, so they change neither the
# compiled envelope nor the donation discipline. Two forms:
#
#   * per-launch: a sequence with one entry per launch, each ``None`` or a
#     list of ``(dst_lo, dst_hi, src_array)`` tuples (an optional fourth
#     element ``"xor"`` flips bits instead of overwriting — the SEU
#     injection form, see ``repro.faults``);
#   * ``BlockPatch(lo, hi, block)``: one uniform region for every real
#     launch of the chunk, ``block`` row ``j`` feeding launch ``j`` — a
#     single fused device op, the chunk-to-chunk fast path;
#   * ``XorBlockPatch(lo, hi, block)``: same shape contract, but XORed
#     into the staged words rather than overwriting them. A zero row is a
#     no-op, so a chunk-wide SEU plan stays one fused dispatch even when
#     only a few launches are hit (bit-exact off-by-default: injection
#     disabled means the patch is simply absent, not an identity op).


class BlockPatch(NamedTuple):
    """One uniform staged-memory patch across all ``B`` real launches of a
    chunk: ``block`` is ``(B, hi - lo)``; row ``j`` overwrites launch
    ``j``'s words ``[lo, hi)``."""
    lo: int
    hi: int
    block: jax.Array


class XorBlockPatch(NamedTuple):
    """Like :class:`BlockPatch` but ``block`` row ``j`` is XORed into
    launch ``j``'s words ``[lo, hi)`` — the fused single-event-upset
    (bit-flip) injection primitive. Rows of zeros leave their launch
    untouched."""
    lo: int
    hi: int
    block: jax.Array


def _check_patches(patches, B: int, sizes: Sequence[int]):
    """Validate patch bounds against each launch's own memory size."""
    if isinstance(patches, (BlockPatch, XorBlockPatch)):
        lo, hi, block = patches
        if not all(0 <= lo <= hi <= s for s in sizes[:B]):
            raise ValueError(f"block patch [{lo}, {hi}) outside a launch's "
                             f"memory image (sizes {list(sizes[:B])})")
        if tuple(block.shape) != (B, hi - lo):
            raise ValueError(f"block patch expects shape {(B, hi - lo)}, "
                             f"got {tuple(block.shape)}")
        return
    patches = list(patches)
    if len(patches) != B:
        raise ValueError(f"patches has {len(patches)} entries for "
                         f"{B} launches")
    for plist, size in zip(patches, sizes):
        for entry in (plist or ()):
            lo, hi, src = entry[0], entry[1], entry[2]
            if len(entry) > 3 and entry[3] not in ("set", "xor"):
                raise ValueError(f"patch op must be 'set' or 'xor', "
                                 f"got {entry[3]!r}")
            if not (0 <= lo <= hi <= size):
                raise ValueError(f"patch [{lo}, {hi}) outside memory "
                                 f"image [0, {size})")
            if np.shape(src) != (hi - lo,):
                raise ValueError(f"patch [{lo}, {hi}) expects "
                                 f"{hi - lo} words, got {np.shape(src)}")


@functools.partial(jax.jit, static_argnames=("lo", "hi"),
                   donate_argnums=(0,))
def _patch_rows_block(body, block, lo, hi):
    """Jitted ``BlockPatch`` application to a row-per-launch staging
    buffer: one compiled dispatch (donating the staging buffer) instead
    of a handful of eager ops — the patch cost is fixed per chunk, so it
    must not scale the pipelined path's dispatch overhead."""
    return body.at[:block.shape[0], lo:hi].set(block)


@functools.partial(jax.jit, static_argnames=("msize", "lo", "hi"),
                   donate_argnums=(0,))
def _patch_flat_block(staged, block, msize, lo, hi):
    """Jitted ``BlockPatch`` application to a flat cohort/single staging
    buffer (reshape + patch + reflatten fused into one dispatch)."""
    rows = (staged.shape[0] - 1) // msize
    body = staged[:rows * msize].reshape(rows, msize)
    body = body.at[:block.shape[0], lo:hi].set(block)
    return jnp.concatenate([body.reshape(-1), staged[rows * msize:]])


@functools.partial(jax.jit, static_argnames=("lo", "hi"),
                   donate_argnums=(0,))
def _xor_rows_block(body, block, lo, hi):
    """Jitted ``XorBlockPatch`` application to a row-per-launch staging
    buffer: bit-flips land as one compiled dispatch, same cost profile as
    the dependency-feed ``BlockPatch`` fast path."""
    region = body[:block.shape[0], lo:hi]
    return body.at[:block.shape[0], lo:hi].set(region ^ block)


@functools.partial(jax.jit, static_argnames=("msize", "lo", "hi"),
                   donate_argnums=(0,))
def _xor_flat_block(staged, block, msize, lo, hi):
    """Jitted ``XorBlockPatch`` application to a flat cohort/single
    staging buffer."""
    rows = (staged.shape[0] - 1) // msize
    body = staged[:rows * msize].reshape(rows, msize)
    region = body[:block.shape[0], lo:hi]
    body = body.at[:block.shape[0], lo:hi].set(region ^ block)
    return jnp.concatenate([body.reshape(-1), staged[rows * msize:]])


def _patch_rows(body: jax.Array, patches) -> jax.Array:
    """Apply patches to a row-per-launch view of the staged memory."""
    if isinstance(patches, XorBlockPatch):
        lo, hi, block = patches
        return _xor_rows_block(body, block, lo=lo, hi=hi)
    if isinstance(patches, BlockPatch):
        lo, hi, block = patches
        return _patch_rows_block(body, block, lo=lo, hi=hi)
    for i, plist in enumerate(patches):
        for entry in (plist or ()):
            lo, hi, src = entry[0], entry[1], entry[2]
            if len(entry) > 3 and entry[3] == "xor":
                body = body.at[i, lo:hi].set(body[i, lo:hi] ^ src)
            else:
                body = body.at[i, lo:hi].set(src)
    return body


def _patch_flat(staged: jax.Array, msize: int, patches) -> jax.Array:
    """Patch a flat ``(rows*msize + 1,)`` cohort/single staging buffer.
    Padding rows (copies of the first image) stay unpatched — they are
    sliced away at resolution and each launch is isolated, so they are
    never observable."""
    if isinstance(patches, XorBlockPatch):
        lo, hi, block = patches
        return _xor_flat_block(staged, block, msize=msize, lo=lo, hi=hi)
    if isinstance(patches, BlockPatch):
        lo, hi, block = patches
        return _patch_flat_block(staged, block, msize=msize, lo=lo, hi=hi)
    rows = (staged.shape[0] - 1) // msize
    body = staged[:rows * msize].reshape(rows, msize)
    body = _patch_rows(body, patches)
    return jnp.concatenate([body.reshape(-1), staged[rows * msize:]])


@functools.partial(jax.jit, static_argnames=("B", "msize", "lo", "hi"))
def _slice_block(mem, B, msize, lo, hi):
    """All launches' [lo, hi) regions of a flat cohort/single memory as one
    fused (B, hi-lo) device computation — one dispatch per chunk."""
    return mem[:B * msize].reshape(B, msize)[:, lo:hi]


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def _slice_batch(mem, lo, hi):
    """All launches' [lo, hi) regions of a batched (N, M+1) memory."""
    return mem[:, lo:hi]


@functools.partial(jax.jit, static_argnames=("B", "msize", "lo", "hi"))
def _slice_rows(mem_rows, B, msize, lo, hi):
    """All launches' [lo, hi) regions of a sharded-cohort memory
    (``(shards, B_local*msize + 1)`` device rows) as one fused device
    computation — padding rows beyond ``B`` are dropped."""
    shards = mem_rows.shape[0]
    b_local = (mem_rows.shape[1] - 1) // msize
    flat = mem_rows[:, :b_local * msize].reshape(shards * b_local, msize)
    return flat[:B, lo:hi]


def _check_regions(regions: Optional[Sequence[Region]], B: int,
                   sizes: Sequence[int]) -> Optional[List[Region]]:
    """Validate per-launch output regions against each launch's own memory
    size. ``None`` (no slicing) stays ``None`` so the full-image download
    path is taken."""
    if regions is None:
        return None
    regions = list(regions)
    if len(regions) != B:
        raise ValueError(f"out_regions has {len(regions)} entries for "
                         f"{B} launches")
    for r, size in zip(regions, sizes):
        if r is None:
            continue
        lo, hi = r
        if not (0 <= lo <= hi <= size):
            raise ValueError(f"out_region {r} outside memory image "
                             f"[0, {size})")
    if all(r is None for r in regions):
        return None
    return regions


class LaunchHandle:
    """Future for one in-flight (possibly folded) kernel launch.

    ``wait()`` blocks until the device retires the launch, fetching only
    the tiny ``done/cycles/stats/step`` arrays, and raises
    ``KernelLaunchError`` (with the failing position in ``index``) when a
    launch hit ``max_steps``. The final memory stays device-resident until
    asked for: ``mem(i)`` downloads launch ``i``'s image — the declared
    ``out_region`` slice when one was given (``(0, 0)``: no transfer at
    all), the full image otherwise. ``results()`` returns the same
    ``(mem, info)`` pairs as the sync entry point, bit-exact.

    ``donated`` is the staged device buffer the dispatch consumed; XLA
    invalidates it at dispatch (the final memory aliases it), and the
    handle never reads it — tests assert ``donated.is_deleted()``.

    Sharded dispatches (``mesh=``) pad the launch axis up to the shard
    count: ``rows`` is the padded element count, ``B`` stays the real
    one, and every resolution path slices the padding away before it can
    be observed. Kind ``"shard-cohort"`` additionally remembers that the
    final memory is laid out as per-shard rows rather than one flat
    image.
    """

    def __init__(self, final: MachineState, cfg: GGPUConfig, kind: str,
                 B: int, msize: int, n_keep: Optional[Sequence[int]],
                 regions: Optional[Sequence[Region]], batch_size:
                 Optional[int], donated, rows: Optional[int] = None):
        self._final = final
        self._cfg = cfg
        self._kind = kind
        self._B = B
        self._rows = B if rows is None else rows
        self._msize = msize
        self._n_keep = list(n_keep) if n_keep is not None else None
        self._regions = _check_regions(
            regions, B, self._n_keep if self._n_keep is not None
            else [msize] * B)
        self._batch_size = batch_size
        self.donated = donated
        self._small = None                     # (cycles, stats, steps)
        self._mem_full = None
        self._mems: dict = {}

    def __len__(self) -> int:
        return self._B

    def ready(self) -> bool:
        """Non-blocking: has the device finished this dispatch?"""
        try:
            return bool(self._final.done.is_ready())
        except AttributeError:                 # non-jax array (never async)
            return True

    def wait(self) -> "LaunchHandle":
        """Block until retired; fetch only the small per-launch arrays.
        Raises ``KernelLaunchError`` naming the first failing launch."""
        if self._small is not None:
            return self
        f = self._final
        # padding elements (rows > B) are sliced away before inspection:
        # a sharded dispatch's fillers are never observable, including in
        # the failure path
        done = np.asarray(f.done).reshape(self._rows, -1)[:self._B]
        if self._kind == "batch":
            cycles = np.asarray(f.cycles)[:self._B, 0]
            stats = np.asarray(f.stats)[:self._B, 0]
            steps = np.asarray(f.step)[:self._B, 0]
        else:
            cycles = np.asarray(f.cycles)[:self._B]
            stats = np.asarray(f.stats)[:self._B]
            steps = np.asarray(f.step)[:self._B]
        for i in range(self._B):
            if not done[i].all():
                what = {"single": "kernel", "cohort": f"cohort kernel {i}",
                        "shard-cohort": f"cohort kernel {i}",
                        "batch": f"batched kernel {i}"}[self._kind]
                raise KernelLaunchError(
                    f"{what} hit max_steps without halting", i)
        self._small = (cycles, stats, steps)
        return self

    # -- resolution ----------------------------------------------------------

    def info(self, i: int = 0) -> dict:
        cycles, stats, steps = self.wait()._small
        info = _info(int(cycles[i]), stats[i], int(steps[i]), self._cfg)
        if self._batch_size is not None:
            info["batch_size"] = self._batch_size
        return info

    def infos(self) -> List[dict]:
        return [self.info(i) for i in range(self._B)]

    def mem(self, i: int = 0) -> np.ndarray:
        """Launch ``i``'s final memory: the declared region slice when one
        was given, the full image otherwise (downloaded once, cached).

        Same-kernel chunks declare the same region for every launch, so
        the uniform case collapses all downloads into **one** fused device
        slice per chunk (``_slice_block``) instead of one dispatch per
        launch."""
        region = self._regions[i] if self._regions is not None else None
        if region is None:
            return self._full_mem(i)
        if i not in self._mems:
            lo, hi = region
            if hi <= lo:
                self._mems[i] = np.zeros(0, np.int32)
            elif all(r == region for r in self._regions):
                if self._kind == "batch":
                    block = np.asarray(_slice_batch(self._final.mem, lo, hi))
                elif self._kind == "shard-cohort":
                    block = np.asarray(_slice_rows(
                        self._final.mem, self._B, self._msize, lo, hi))
                else:
                    block = np.asarray(_slice_block(
                        self._final.mem, self._B, self._msize, lo, hi))
                for j in range(self._B):
                    self._mems[j] = block[j]
            elif self._kind == "batch":
                self._mems[i] = np.asarray(self._final.mem[i, lo:hi])
            elif self._kind == "shard-cohort":
                b_local = (self._final.mem.shape[1] - 1) // self._msize
                shard, slot = divmod(i, b_local)
                base = slot * self._msize
                self._mems[i] = np.asarray(
                    self._final.mem[shard, base + lo:base + hi])
            else:
                base = i * self._msize
                self._mems[i] = np.asarray(
                    self._final.mem[base + lo:base + hi])
        return self._mems[i]

    def _full_mem(self, i: int) -> np.ndarray:
        if self._mem_full is None:
            m = np.asarray(self._final.mem)
            if self._kind == "batch":
                self._mem_full = m[:, :-1]
            elif self._kind == "shard-cohort":
                # per-shard rows: drop each row's write sink, flatten the
                # shard axis back into one element-major image stack
                self._mem_full = m[:, :-1].reshape(-1, self._msize)
            else:
                self._mem_full = m[:-1].reshape(self._rows, self._msize)
        row = self._mem_full[i]
        return row[:self._n_keep[i]] if self._n_keep is not None else row

    # -- device-resident access (no host transfer) ---------------------------

    def device_mem(self, i: int = 0,
                   region: Optional[Tuple[int, int]] = None) -> jax.Array:
        """Launch ``i``'s final-memory ``[lo, hi)`` slice as a
        device-resident array (default: the full image). Never blocks and
        never touches the host — this is the producer side of the
        device-resident chaining protocol: feed the result straight into a
        consumer launch's ``patches``. The returned array only *reads*
        the final memory (donation is unaffected), and XLA sequences it
        after the producing dispatch, so no explicit wait is needed."""
        if region is None:
            size = (self._n_keep[i] if self._n_keep is not None
                    else self._msize)
            region = (0, size)
        lo, hi = region
        if self._kind == "batch":
            return self._final.mem[i, lo:hi]
        if self._kind == "shard-cohort":
            b_local = (self._final.mem.shape[1] - 1) // self._msize
            shard, slot = divmod(i, b_local)
            base = slot * self._msize
            return self._final.mem[shard, base + lo:base + hi]
        base = i * self._msize
        return self._final.mem[base + lo:base + hi]

    def device_mem_block(self, lo: int, hi: int) -> jax.Array:
        """All ``B`` launches' ``[lo, hi)`` slices as one device-resident
        ``(B, hi - lo)`` array — one fused device op per chunk, the fast
        path for feeding a whole producer chunk into a consumer chunk's
        ``BlockPatch``. Never blocks, never touches the host."""
        if self._kind == "batch":
            return _slice_batch(self._final.mem, lo, hi)[:self._B]
        if self._kind == "shard-cohort":
            return _slice_rows(self._final.mem, self._B, self._msize,
                               lo, hi)
        return _slice_block(self._final.mem, self._B, self._msize, lo, hi)

    def results(self) -> List[Tuple[np.ndarray, dict]]:
        """All launches as (mem, info) pairs — exactly what the sync entry
        point returns."""
        return [(self.mem(i), self.info(i)) for i in range(self._B)]

    def result(self) -> Tuple[np.ndarray, dict]:
        """Single-launch convenience: the (mem, info) pair."""
        if self._B != 1:
            raise ValueError(f"handle holds {self._B} launches; "
                             "use results()")
        return self.mem(0), self.info(0)


def _stage(mems: Sequence[np.ndarray]) -> jax.Array:
    """Host-copy the image(s) plus the write-sink slot into one fresh
    device buffer — the buffer the jitted wrapper donates."""
    return jnp.asarray(np.concatenate(list(mems)
                                      + [np.zeros(1, np.int32)]))


def run_kernel_async(prog: np.ndarray, mem0: np.ndarray, n_items: int,
                     cfg: GGPUConfig, *, out_region: Region = None,
                     patches=None, legacy: bool = False) -> LaunchHandle:
    """Dispatch a single launch asynchronously; returns a ``LaunchHandle``
    while the device still runs. ``out_region=(lo, hi)`` limits the
    eventual memory download to that slice of the final image. ``patches``
    optionally overwrites regions of the staged memory with device arrays
    before dispatch (a flat list of ``(lo, hi, src)`` — the single-launch
    form of the chunk-level patch protocol above)."""
    prog = np.asarray(prog, np.int32)
    mem0 = np.asarray(mem0, np.int32)
    staged = _stage([mem0])
    if patches is not None:
        msize = mem0.shape[0]
        per_launch = (patches
                      if isinstance(patches, (BlockPatch, XorBlockPatch))
                      else [list(patches)])
        _check_patches(per_launch, 1, [msize])
        staged = _patch_flat(staged, msize, per_launch)
    final = _run_single(
        jnp.asarray(prog), staged,
        jnp.asarray(int(n_items), jnp.int32), cfg,
        _n_wavefronts(int(n_items), cfg), int(prog.shape[0]),
        None if legacy else _static_ops(prog), legacy)
    return LaunchHandle(final, cfg, "single", 1, mem0.shape[0], None,
                        [out_region] if out_region is not None else None,
                        None, staged)


def run_kernel(prog: np.ndarray, mem0: np.ndarray, n_items: int,
               cfg: GGPUConfig, *, legacy: bool = False):
    """Execute a kernel. Returns (mem_final, info dict).

    ``legacy=True`` runs the seed-faithful reference stepper (identical
    results and cycles, pre-refactor wall-clock) for differential testing
    and as the baseline of ``benchmarks.engine_bench``."""
    return run_kernel_async(prog, mem0, n_items, cfg,
                            legacy=legacy).result()


def run_kernel_cohort_async(prog: np.ndarray, mems: Sequence[np.ndarray],
                            n_items: int, cfg: GGPUConfig, *,
                            out_regions: Optional[Sequence[Region]] = None,
                            patches=None, mesh=None) -> LaunchHandle:
    """Dispatch B same-kernel launches as one folded stepper call,
    asynchronously. ``out_regions`` optionally declares one download slice
    per launch (``None`` entries download that launch's full image).
    ``patches`` optionally overwrites regions of the staged memory with
    device arrays before dispatch — a ``BlockPatch`` or one
    ``[(lo, hi, src), ...]`` list per launch (see the patch protocol
    above). ``mesh`` shards the launch axis across the mesh's
    data-parallel devices (see module doc); a 1-extent mesh falls back to
    the single-device path."""
    prog = np.asarray(prog, np.int32)
    mems = [np.asarray(m, np.int32) for m in mems]
    if not mems:
        raise ValueError("empty cohort")
    msize = mems[0].shape[0]
    if any(m.shape[0] != msize for m in mems):
        raise ValueError("cohort memory images must share one shape")
    B = len(mems)
    if patches is not None:
        _check_patches(patches, B, [msize] * B)
    shards = launch_shards(mesh)
    if shards > 1 and B > 1:
        return _dispatch_cohort_sharded(prog, mems, n_items, cfg, mesh,
                                        shards, out_regions, patches)
    rows = cohort_rows(B)
    staged = _stage(mems + [mems[0]] * (rows - B))
    if patches is not None:
        staged = _patch_flat(staged, msize, patches)
    final = _run_cohort(
        jnp.asarray(prog), staged,
        jnp.asarray(int(n_items), jnp.int32), cfg, rows,
        _n_wavefronts(int(n_items), cfg), int(prog.shape[0]),
        _static_ops(prog))
    return LaunchHandle(final, cfg, "cohort", B, msize, None, out_regions,
                        B, staged, rows=rows)


def _dispatch_cohort_sharded(prog, mems, n_items, cfg, mesh, shards,
                             out_regions, patches=None) -> LaunchHandle:
    """Shard a cohort's launch axis over ``mesh``: pad B up to the
    ``cohort_rows`` bucket with copies of the first image (same kernel,
    same halt behavior — sliced away at resolution), stage one memory row
    per shard (its slice of the images plus a private write sink), and
    dispatch the shard_map'd stepper once."""
    B, msize = len(mems), mems[0].shape[0]
    n_rows = cohort_rows(B, shards)
    padded = mems + [mems[0]] * (n_rows - B)
    b_local = n_rows // shards
    rows = np.stack([
        np.concatenate(padded[s * b_local:(s + 1) * b_local]
                       + [np.zeros(1, np.int32)])
        for s in range(shards)])
    staged = jax.device_put(rows, _launch_sharding(mesh, 2))
    if patches is not None:
        # patch the element-major row view, then restore the per-shard
        # row layout + sharding (the resulting reshard is what moves a
        # producer's output to its consumer's shard — still no host hop)
        body = staged[:, :b_local * msize].reshape(n_rows, msize)
        body = _patch_rows(body, patches)
        staged = jax.device_put(
            jnp.concatenate([body.reshape(shards, b_local * msize),
                             staged[:, b_local * msize:]], axis=1),
            _launch_sharding(mesh, 2))
    final = _sharded_cohort_fn(
        cfg, b_local, _n_wavefronts(int(n_items), cfg),
        int(prog.shape[0]), msize, _static_ops(prog), mesh)(
        jnp.asarray(prog), staged, jnp.asarray(int(n_items), jnp.int32))
    return LaunchHandle(final, cfg, "shard-cohort", B, msize, None,
                        out_regions, B, staged, rows=n_rows)


def run_kernel_cohort(prog: np.ndarray, mems: Sequence[np.ndarray],
                      n_items: int, cfg: GGPUConfig
                      ) -> List[Tuple[np.ndarray, dict]]:
    """Execute the same kernel over B memory images as one folded stepper
    call (B*W wavefronts, per-element accounting). Bit-exact per launch."""
    mems = list(mems)                # materialize once: iterators welcome
    if not mems:
        return []
    return run_kernel_cohort_async(prog, mems, n_items, cfg).results()


def run_kernel_batch_async(progs: Sequence[np.ndarray],
                           mems: Sequence[np.ndarray],
                           n_items: Sequence[int], cfg: GGPUConfig, *,
                           out_regions: Optional[Sequence[Region]] = None,
                           patches=None, mesh=None) -> LaunchHandle:
    """Dispatch N heterogeneous launches as one vmapped stepper call,
    asynchronously (padding exactly as ``run_kernel_batch``). ``patches``
    optionally overwrites regions of the staged memory with device arrays
    before dispatch (see the patch protocol above; bounds check against
    each launch's own memory size, not the padded envelope). ``mesh``
    shards the vmapped launch axis across the mesh's data-parallel
    devices, padding N up to the shard count with trivial 1-item HALT
    fillers (invisible at resolution); a 1-extent mesh falls back to the
    single-device path."""
    if not (len(progs) == len(mems) == len(n_items)):
        raise ValueError("progs, mems, n_items must have equal length")
    if not progs:
        raise ValueError("empty batch")
    progs = [np.asarray(p, np.int32) for p in progs]
    mems = [np.asarray(m, np.int32) for m in mems]
    n_items = [int(n) for n in n_items]
    B = len(progs)
    if patches is not None:
        _check_patches(patches, B, [m.shape[0] for m in mems])
    shards = launch_shards(mesh)
    pad = -B % shards if shards > 1 and B > 1 else 0
    if pad:
        width = progs[0].shape[1]
        progs = progs + [np.zeros((1, width), np.int32)] * pad  # HALT
        mems = mems + [np.zeros(1, np.int32)] * pad
        n_items = n_items + [1] * pad
    P = max(p.shape[0] for p in progs)
    M = max(m.shape[0] for m in mems)
    prog_b = np.stack([np.pad(p, ((0, P - p.shape[0]), (0, 0)))
                       for p in progs])                  # HALT == all-zeros
    # each row zero-padded to the envelope plus its own write-sink slot
    mem_b = np.stack([np.pad(m, (0, M + 1 - m.shape[0])) for m in mems])
    W = max(_n_wavefronts(int(n), cfg) for n in n_items)
    ops = tuple(sorted(set().union(*(_static_ops(p) for p in progs))))
    n_arr = jnp.asarray(np.asarray(n_items, np.int32))
    msz_arr = jnp.asarray(np.array([m.shape[0] for m in mems], np.int32))
    if shards > 1 and B > 1:
        sharding = _launch_sharding(mesh, 2)
        staged = jax.device_put(mem_b, sharding)
        if patches is not None:
            # batch rows are already row-per-launch; patch then reshard
            staged = jax.device_put(_patch_rows(staged, patches), sharding)
        final = _sharded_batch_fn(cfg, W, P, M, ops, mesh)(
            jnp.asarray(prog_b), staged, n_arr, msz_arr)
    else:
        staged = jnp.asarray(mem_b)
        if patches is not None:
            staged = _patch_rows(staged, patches)
        final = _run_batch(jnp.asarray(prog_b), staged, n_arr, msz_arr,
                           cfg, W, P, ops)
    return LaunchHandle(final, cfg, "batch", B, M,
                        [m.shape[0] for m in mems[:B]], out_regions,
                        B, staged, rows=B + pad)


def run_kernel_batch(progs: Sequence[np.ndarray],
                     mems: Sequence[np.ndarray],
                     n_items: Sequence[int],
                     cfg: GGPUConfig) -> List[Tuple[np.ndarray, dict]]:
    """Execute N heterogeneous kernel launches as one vmapped stepper call.

    Programs are padded to a common length with HALT words and memory
    images zero-padded to a common size; per-launch results and cycle
    counts are exact (the padding is invisible to the machine — each
    launch's address clip still binds at its own memory size). Returns a
    list of (mem_final, info) in submission order."""
    progs = list(progs)              # materialize once: iterators welcome
    if not progs:
        return []
    return run_kernel_batch_async(progs, list(mems), list(n_items),
                                  cfg).results()
