"""Memory-system stage: pluggable cache organizations behind one protocol.

The functional memory (the flat word array, masked loads/stores through the
write sink) is organization-independent and lives in ``load_store``. What a
``MemorySystem`` models is the *cycle cost* of a round's coalesced memory
traffic — the component the paper identifies as the make-or-break of a
G-GPU version ("breaking the memory hierarchy in a smart fashion").

The stepper simulates ``n_elems`` independent machines at once (cohort
batching: a kernel launch batch folded into the wavefront axis), so every
organization keeps per-element tag state and returns per-element cycle
terms. Single launches are simply ``n_elems == 1``.

Protocol (structural; implementations are frozen dataclasses so a config
naming them stays hashable/jit-static):

    init_tags(cfg, n_elems)  -> tag-state array (threaded through the loop)
    access(tags, addr, mem_mask, *, cu_of_w, elem_of_w, n_elems, cfg)
        -> CacheResult

``addr`` is element-local. ``CacheResult`` carries the updated tag state,
per-lane hit/miss masks (for stats), and two (n_elems,) cycle terms the
scheduler folds into each element's round time:

    hit_service  — cycles for hit traffic to stream through the data movers
    fill_cycles  — cycles of DRAM fill bandwidth for missed lines

Implementations:

  * ``SharedCache``     — the FGPU model: one central direct-mapped
    write-back cache with ``cfg.ports`` movers shared by all CUs. Port
    contention on this shared structure is why the paper's 8-CU
    xcorr/parallel_sel *lose* performance. Cycle-identical to the original
    ``machine.py`` cost model (``one_hot=True`` reproduces its exact
    scatter-based op sequence for the legacy reference stepper).
  * ``BankedPerCUCache`` — one private direct-mapped bank per CU, each with
    its own ``cfg.ports`` movers (aggregate hit bandwidth scales with CU
    count; banks fill independently from the shared DRAM path, no cross-CU
    MSHR coalescing). ``iso_capacity=True`` splits ``cfg.cache_lines``
    across the banks (area-neutral sweep point); ``False`` gives every bank
    the full ``cfg.cache_lines`` (the throw-area-at-it sweep point the
    8-CU xcorr thrashing motivates).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


def unique_count(vals, valid, sentinel, axis=-1):
    """Number of distinct ``vals`` among ``valid`` entries, per row.

    Sort-based (invalid entries map to ``sentinel``, which must exceed any
    valid value): a sorted run's first element marks each distinct value.
    Replaces one-hot scatter-max counting — same counts, but sorts
    vectorize on CPU where scatters serialize."""
    v = jnp.sort(jnp.where(valid, vals, sentinel), axis=axis)
    first = jnp.concatenate(
        [jnp.ones_like(jnp.take(v, jnp.array([0]), axis=axis), bool),
         jnp.take(v, jnp.arange(1, v.shape[axis]), axis=axis)
         != jnp.take(v, jnp.arange(0, v.shape[axis] - 1), axis=axis)],
        axis=axis)
    return jnp.sum(first & (v != sentinel), axis=axis)


class CacheResult(NamedTuple):
    tags: jax.Array          # updated tag state
    hit: jax.Array           # (W, L) bool — lanes that hit
    miss: jax.Array          # (W, L) bool — lanes that missed
    hit_service: jax.Array   # (n_elems,) int32 — mover cycles, hit traffic
    fill_cycles: jax.Array   # (n_elems,) int32 — DRAM fill cycles


@runtime_checkable
class MemorySystem(Protocol):
    name: str

    def init_tags(self, cfg, n_elems: int) -> jax.Array: ...

    def access(self, tags, addr, mem_mask, *, cu_of_w, elem_of_w,
               n_elems: int, cfg) -> CacheResult: ...


def load_store(mem, addr, store_val, exec_m, is_load, is_store, sink: int,
               always_scatter: bool = False):
    """Functional memory access, identical for every organization.

    Masked store: inactive lanes write the sink slot (index ``sink``, the
    last word); masked load: inactive lanes read the sink (never written
    back). The store scatter only runs in rounds where some wavefront
    actually stores — a load-only round would scatter nothing but sink
    writes, and the sink is architecturally invisible
    (``always_scatter=True`` keeps the unconditional scatter of the legacy
    reference stepper). Returns (new_mem, loaded, mem_mask)."""
    mem_mask = exec_m & (is_load | is_store)
    loaded = mem[jnp.where(mem_mask, addr, sink)]

    def do_store(m):
        waddr = jnp.where(exec_m & is_store, addr, sink)
        return m.at[waddr].set(store_val)

    if always_scatter:
        mem = do_store(mem)
    else:
        mem = jax.lax.cond(jnp.any(is_store), do_store, lambda m: m, mem)
    return mem, loaded, mem_mask


def _per_elem_sum(x, n_elems: int):
    return jnp.sum(x.reshape(n_elems, -1), axis=1)


@dataclass(frozen=True)
class SharedCache:
    """One central multi-port cache shared by all CUs (the paper's model);
    one such cache per simulated element."""
    name: str = "shared"

    def init_tags(self, cfg, n_elems: int) -> jax.Array:
        return jnp.full((n_elems, cfg.cache_lines), -1, jnp.int32)

    def access(self, tags, addr, mem_mask, *, cu_of_w, elem_of_w,
               n_elems: int, cfg, one_hot: bool = False) -> CacheResult:
        line_shift = int(np.log2(cfg.line_words))
        line = (addr >> line_shift) % cfg.cache_lines
        tag = addr >> line_shift
        elem_b = jnp.broadcast_to(elem_of_w[:, None], addr.shape)
        line_m = jnp.where(mem_mask, line, 0)
        hit = (tags[jnp.where(mem_mask, elem_b, 0), line_m] == tag) & mem_mask
        miss = mem_mask & ~hit
        new_tags = tags.at[jnp.where(miss, elem_b, n_elems),
                           jnp.where(miss, line, 0)].set(tag, mode="drop")
        # Port traffic: lanes of one wavefront coalesce into per-line
        # requests, but DISTINCT wavefronts issue distinct requests even for
        # the same line -> count per-wavefront unique hit lines. DRAM fills
        # coalesce globally (MSHR): count per-element-unique missed lines.
        if one_hot:
            # the original machine.py op sequence (scatter-max one-hot),
            # kept as the seed-faithful reference; single element only
            assert n_elems == 1
            W = addr.shape[0]
            w_ix = jnp.broadcast_to(jnp.arange(W)[:, None], line.shape)
            t_hit = jnp.zeros((W, cfg.cache_lines + 1), jnp.int32).at[
                w_ix, jnp.where(hit, line, cfg.cache_lines)].max(
                    1, mode="drop")
            hit_lines = jnp.sum(t_hit[:, :-1])[None]
            t_miss = jnp.zeros((cfg.cache_lines + 1,), jnp.int32).at[
                jnp.where(miss, line, cfg.cache_lines)].max(1, mode="drop")
            miss_lines = jnp.sum(t_miss[:-1])[None]
        else:
            hit_lines = _per_elem_sum(unique_count(line, hit,
                                                   cfg.cache_lines), n_elems)
            miss_lines = unique_count(line.reshape(n_elems, -1),
                                      miss.reshape(n_elems, -1),
                                      cfg.cache_lines)
        hit_service = (hit_lines + cfg.ports - 1) // cfg.ports
        fill_cycles = miss_lines * cfg.dram_line_cycles
        return CacheResult(new_tags, hit, miss, hit_service, fill_cycles)


@dataclass(frozen=True)
class BankedPerCUCache:
    """Per-CU private banks; each bank has its own movers and fills its own
    missed lines — the DSE counterpoint to the shared organization."""
    iso_capacity: bool = False

    @property
    def name(self) -> str:
        return "banked-iso" if self.iso_capacity else "banked"

    def lines(self, cfg) -> int:
        if self.iso_capacity:
            return max(1, cfg.cache_lines // cfg.n_cus)
        return cfg.cache_lines

    def init_tags(self, cfg, n_elems: int) -> jax.Array:
        return jnp.full((n_elems * cfg.n_cus, self.lines(cfg)), -1,
                        jnp.int32)

    def access(self, tags, addr, mem_mask, *, cu_of_w, elem_of_w,
               n_elems: int, cfg) -> CacheResult:
        n_cus = cfg.n_cus
        lines = self.lines(cfg)
        n_banks = n_elems * n_cus
        line_shift = int(np.log2(cfg.line_words))
        line = (addr >> line_shift) % lines
        tag = addr >> line_shift
        bank_of_w = elem_of_w * n_cus + cu_of_w                 # (W,)
        bank_b = jnp.broadcast_to(bank_of_w[:, None], addr.shape)
        line_m = jnp.where(mem_mask, line, 0)
        hit = (tags[jnp.where(mem_mask, bank_b, 0), line_m] == tag) \
            & mem_mask
        miss = mem_mask & ~hit
        new_tags = tags.at[jnp.where(miss, bank_b, n_banks),
                           jnp.where(miss, line, 0)].set(tag, mode="drop")
        # Per-wavefront unique hit lines (lane coalescing), summed per
        # bank; each bank streams its own traffic through `ports` movers,
        # banks run concurrently -> an element's slowest bank sets its
        # service time.
        per_wf = unique_count(line, hit, lines)                 # (W,)
        per_bank = jnp.zeros((n_banks,), jnp.int32).at[bank_of_w].add(per_wf)
        hit_service = jnp.max(
            (per_bank.reshape(n_elems, n_cus) + cfg.ports - 1) // cfg.ports,
            axis=1)
        # Per-bank unique missed slots each pay a DRAM fill on the shared
        # AXI path (no cross-CU coalescing: distinct banks fill separately).
        slot = bank_b * lines + line
        fill_cycles = unique_count(slot.reshape(n_elems, -1),
                                   miss.reshape(n_elems, -1),
                                   n_banks * lines) * cfg.dram_line_cycles
        return CacheResult(new_tags, hit, miss, hit_service, fill_cycles)


from repro.registry import MEMSYS  # noqa: E402  (axis import after models)

MEMSYS.register("shared", SharedCache())
MEMSYS.register("banked", BankedPerCUCache(iso_capacity=False))
MEMSYS.register("banked-iso", BankedPerCUCache(iso_capacity=True))


class _MemsysMapping(Mapping):
    """Read-only mapping view of the ``MEMSYS`` registry axis — the
    compatibility shape of the pre-registry ``MEMSYS_REGISTRY`` dict.
    Iteration/membership reflect every registered organization,
    including drop-in plugins (``repro/registry/plugins/``)."""

    def __getitem__(self, name: str) -> MemorySystem:
        return MEMSYS.get(name)

    def __iter__(self):
        return iter(MEMSYS.names())

    def __len__(self) -> int:
        return len(MEMSYS)


MEMSYS_REGISTRY: Mapping = _MemsysMapping()


def get_memsys(name: str) -> MemorySystem:
    """Resolve a memory-system name through the registry (the axis's
    ``UnknownPluginError`` is a ``KeyError``, preserving the original
    contract and message shape)."""
    return MEMSYS.get(name)
