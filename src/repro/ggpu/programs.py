"""The paper's seven AMD OpenCL SDK micro-benchmarks, as ISA programs.

Each benchmark provides the SIMT (G-GPU) kernel — one work-item per output
element — and the sequential scalar (RISC-V baseline) program, plus a numpy
reference for correctness. Input sizes follow Table III: the scalar core
gets the small size, the G-GPU the large one (sized to saturate 8 CUs), and
Fig-5 speed-ups scale the scalar cycle count by the input-size ratio exactly
as the paper does (a pessimistic-for-G-GPU convention).

mat_mul sizes are element counts of the output matrix (16x16 scalar,
64x64 G-GPU — the paper's 128 -> 2048 element ratio of 16 is preserved).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

import numpy as np

from repro.ggpu.isa import Assembler


@dataclass
class Bench:
    name: str
    gpu_prog: np.ndarray
    gpu_mem: np.ndarray
    gpu_items: int
    gpu_out: slice
    scalar_prog: np.ndarray
    scalar_mem: np.ndarray
    scalar_out: slice
    ref: Callable[[np.ndarray, int], np.ndarray]   # (mem0, n) -> expected out
    gpu_n: int
    scalar_n: int


def _rand(n, lo=-100, hi=100, seed=0):
    return np.random.default_rng(seed).integers(lo, hi, n).astype(np.int32)


# ---------------------------------------------------------------------------
# copy
# ---------------------------------------------------------------------------

def _copy(n_scalar=512, n_gpu=32768):
    def mem(n):
        return np.concatenate([_rand(n, seed=1), np.zeros(n, np.int32)])

    g = Assembler()
    g.tid(1).lw(2, 1, 0).sw(2, 1, n_gpu).halt()

    s = Assembler()
    s.li(1, 0).li(2, n_scalar)
    s.label("loop").bge(1, 2, "end")
    s.lw(3, 1, 0).sw(3, 1, n_scalar).addi(1, 1, 1).beq(0, 0, "loop")
    s.label("end").halt()

    ref = lambda m, n: m[:n]
    return Bench("copy", g.assemble(), mem(n_gpu), n_gpu,
                 slice(n_gpu, 2 * n_gpu), s.assemble(), mem(n_scalar),
                 slice(n_scalar, 2 * n_scalar), ref, n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# vec_mul
# ---------------------------------------------------------------------------

def _vec_mul(n_scalar=1024, n_gpu=65536):
    def mem(n):
        return np.concatenate([_rand(n, seed=2), _rand(n, seed=3),
                               np.zeros(n, np.int32)])

    g = Assembler()
    g.tid(1).lw(2, 1, 0).lw(3, 1, n_gpu).mul(2, 2, 3).sw(2, 1, 2 * n_gpu).halt()

    s = Assembler()
    s.li(1, 0).li(2, n_scalar)
    s.label("loop").bge(1, 2, "end")
    s.lw(3, 1, 0).lw(4, 1, n_scalar).mul(3, 3, 4).sw(3, 1, 2 * n_scalar)
    s.addi(1, 1, 1).beq(0, 0, "loop")
    s.label("end").halt()

    ref = lambda m, n: (m[:n].astype(np.int64) * m[n:2 * n]).astype(np.int32)
    return Bench("vec_mul", g.assemble(), mem(n_gpu), n_gpu,
                 slice(2 * n_gpu, 3 * n_gpu), s.assemble(), mem(n_scalar),
                 slice(2 * n_scalar, 3 * n_scalar), ref, n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# mat_mul (dim x dim, one item per output element)
# ---------------------------------------------------------------------------

def _mat_mul(dim_scalar=16, dim_gpu=64):
    def mem(d):
        return np.concatenate([_rand(d * d, -10, 10, seed=4),
                               _rand(d * d, -10, 10, seed=5),
                               np.zeros(d * d, np.int32)])

    def gpu(d):
        lg = int(np.log2(d))
        n2 = d * d
        g = Assembler()
        g.tid(1)
        g.srli(2, 1, lg)          # row
        g.andi(3, 1, d - 1)       # col
        g.slli(4, 2, lg)          # row*d
        g.li(5, 0).li(6, 0).li(7, d)
        g.label("loop").bge(6, 7, "done")
        g.add(8, 4, 6).lw(8, 8, 0)              # A[row*d + k]
        g.slli(9, 6, lg).add(9, 9, 3).lw(9, 9, n2)  # B[k*d + col]
        g.mul(8, 8, 9).add(5, 5, 8)
        g.addi(6, 6, 1).beq(0, 0, "loop")
        g.label("done").sw(5, 1, 2 * n2).halt()
        return g

    def scalar(d):
        lg = int(np.log2(d))
        n2 = d * d
        s = Assembler()
        s.li(1, 0).li(7, d)                      # r1 = row
        s.label("rloop").bge(1, 7, "end")
        s.li(2, 0)                               # r2 = col
        s.label("cloop").bge(2, 7, "rnext")
        s.slli(4, 1, lg)                         # row*d
        s.li(5, 0).li(6, 0)
        s.label("kloop").bge(6, 7, "kdone")
        s.add(8, 4, 6).lw(8, 8, 0)
        s.slli(9, 6, lg).add(9, 9, 2).lw(9, 9, n2)
        s.mul(8, 8, 9).add(5, 5, 8)
        s.addi(6, 6, 1).beq(0, 0, "kloop")
        s.label("kdone").add(10, 4, 2).sw(5, 10, 2 * n2)
        s.addi(2, 2, 1).beq(0, 0, "cloop")
        s.label("rnext").addi(1, 1, 1).beq(0, 0, "rloop")
        s.label("end").halt()
        return s

    def ref(m, n2):
        d = int(np.sqrt(n2))
        a = m[:n2].reshape(d, d).astype(np.int64)
        b = m[n2:2 * n2].reshape(d, d).astype(np.int64)
        return (a @ b).astype(np.int32).reshape(-1)

    dg, ds = dim_gpu, dim_scalar
    return Bench("mat_mul", gpu(dg).assemble(), mem(dg), dg * dg,
                 slice(2 * dg * dg, 3 * dg * dg), scalar(ds).assemble(),
                 mem(ds), slice(2 * ds * ds, 3 * ds * ds), ref,
                 dg * dg, ds * ds)


# ---------------------------------------------------------------------------
# fir (16 taps; first items diverge on the boundary)
# ---------------------------------------------------------------------------

def _fir(n_scalar=128, n_gpu=4096, taps=16):
    def mem(n):
        return np.concatenate([_rand(n, seed=6), _rand(taps, -8, 8, seed=7),
                               np.zeros(n, np.int32)])

    def build(n, outer: bool):
        a = Assembler()
        if outer:
            a.li(11, 0).li(12, n)
            a.label("outer").bge(11, 12, "end")
            i_reg = 11
        else:
            a.tid(1)
            i_reg = 1
        a.li(5, 0).li(6, 0).li(7, taps)
        a.label("loop").bge(6, 7, "done")
        a.sub(8, i_reg, 6)
        a.blt(8, 0, "skip")
        a.lw(9, 8, 0).lw(10, 6, n).mul(9, 9, 10).add(5, 5, 9)
        a.label("skip").addi(6, 6, 1).beq(0, 0, "loop")
        a.label("done").sw(5, i_reg, n + taps)
        if outer:
            a.addi(11, 11, 1).beq(0, 0, "outer")
            a.label("end").halt()
        else:
            a.halt()
        return a

    def ref(m, n):
        x = m[:n].astype(np.int64)
        h = m[n:n + taps].astype(np.int64)
        out = np.zeros(n, np.int64)
        for t in range(taps):
            out[t:] += h[t] * x[:n - t]
        return out.astype(np.int32)

    return Bench("fir", build(n_gpu, False).assemble(), mem(n_gpu), n_gpu,
                 slice(n_gpu + taps, 2 * n_gpu + taps),
                 build(n_scalar, True).assemble(), mem(n_scalar),
                 slice(n_scalar + taps, 2 * n_scalar + taps), ref,
                 n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# div_int (integer division: the G-GPU's weak spot, per the paper)
# ---------------------------------------------------------------------------

def _div_int(n_scalar=512, n_gpu=4096):
    def mem(n):
        a = _rand(n, -1000, 1000, seed=8)
        b = _rand(n, 1, 50, seed=9)
        return np.concatenate([a, b, np.zeros(n, np.int32)])

    g = Assembler()
    g.tid(1).lw(2, 1, 0).lw(3, 1, n_gpu).div(2, 2, 3).sw(2, 1, 2 * n_gpu).halt()

    s = Assembler()
    s.li(1, 0).li(2, n_scalar)
    s.label("loop").bge(1, 2, "end")
    s.lw(3, 1, 0).lw(4, 1, n_scalar).div(3, 3, 4).sw(3, 1, 2 * n_scalar)
    s.addi(1, 1, 1).beq(0, 0, "loop")
    s.label("end").halt()

    def ref(m, n):
        a, b = m[:n].astype(np.int64), m[n:2 * n].astype(np.int64)
        return (a // b).astype(np.int32)   # python floor-div matches DIV

    return Bench("div_int", g.assemble(), mem(n_gpu), n_gpu,
                 slice(2 * n_gpu, 3 * n_gpu), s.assemble(), mem(n_scalar),
                 slice(2 * n_scalar, 3 * n_scalar), ref, n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# xcorr (circular cross-correlation, O(n^2), cache-pressure heavy)
# ---------------------------------------------------------------------------

def _xcorr(n_scalar=256, n_gpu=4096):
    def mem(n):
        return np.concatenate([_rand(n, -20, 20, seed=10),
                               _rand(n, -20, 20, seed=11),
                               np.zeros(n, np.int32)])

    def build(n, outer: bool):
        a = Assembler()
        if outer:
            a.li(11, 0).li(12, n)
            a.label("outer").bge(11, 12, "end")
            lag = 11
        else:
            a.tid(1)
            lag = 1
        a.li(5, 0).li(6, 0).li(7, n)
        a.label("loop").bge(6, 7, "done")
        a.lw(8, 6, 0)                       # a[i]
        a.add(9, 6, lag)
        a.blt(9, 7, "nowrap")
        a.sub(9, 9, 7)
        a.label("nowrap").lw(2, 9, n)       # b[(i+lag) mod n]
        a.mul(8, 8, 2).add(5, 5, 8)
        a.addi(6, 6, 1).beq(0, 0, "loop")
        a.label("done").sw(5, lag, 2 * n)
        if outer:
            a.addi(11, 11, 1).beq(0, 0, "outer")
            a.label("end").halt()
        else:
            a.halt()
        return a

    def ref(m, n):
        a = m[:n].astype(np.int64)
        b = m[n:2 * n].astype(np.int64)
        return np.array([(a * np.roll(b, -lag)).sum() for lag in range(n)],
                        np.int64).astype(np.int32)

    return Bench("xcorr", build(n_gpu, False).assemble(), mem(n_gpu), n_gpu,
                 slice(2 * n_gpu, 3 * n_gpu), build(n_scalar, True).assemble(),
                 mem(n_scalar), slice(2 * n_scalar, 3 * n_scalar), ref,
                 n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# parallel_sel (rank sort: branch-divergent compares)
# ---------------------------------------------------------------------------

def _parallel_sel(n_scalar=128, n_gpu=2048):
    def mem(n):
        return np.concatenate([_rand(n, -500, 500, seed=12),
                               np.zeros(n, np.int32)])

    def build(n, outer: bool):
        a = Assembler()
        if outer:
            a.li(11, 0).li(12, n)
            a.label("outer").bge(11, 12, "end")
            i_reg = 11
        else:
            a.tid(1)
            i_reg = 1
        a.lw(2, i_reg, 0)                    # v = a[i]
        a.li(5, 0).li(6, 0).li(7, n)
        a.label("loop").bge(6, 7, "done")
        a.lw(8, 6, 0)
        a.blt(8, 2, "inc")
        a.bne(8, 2, "next")
        a.bge(6, i_reg, "next")
        a.label("inc").addi(5, 5, 1)
        a.label("next").addi(6, 6, 1).beq(0, 0, "loop")
        a.label("done").sw(2, 5, n)          # out[rank] = v
        if outer:
            a.addi(11, 11, 1).beq(0, 0, "outer")
            a.label("end").halt()
        else:
            a.halt()
        return a

    def ref(m, n):
        return np.sort(m[:n], kind="stable").astype(np.int32)

    return Bench("parallel_sel", build(n_gpu, False).assemble(), mem(n_gpu),
                 n_gpu, slice(n_gpu, 2 * n_gpu),
                 build(n_scalar, True).assemble(), mem(n_scalar),
                 slice(n_scalar, 2 * n_scalar), ref, n_gpu, n_scalar)


# ---------------------------------------------------------------------------
# reduction (segmented parallel dot/sum: few outputs over many inputs —
# beyond the paper's seven; the shape none of them cover)
# ---------------------------------------------------------------------------

REDUCTION_SEG = 64          # inputs folded per work-item (power of two)


def _reduction(n_scalar=1024, n_gpu=32768, seg=REDUCTION_SEG):
    """Parallel reduction: work-item ``i`` computes the dot product of the
    ``seg``-long segments ``a[i*seg:(i+1)*seg] . b[...]`` and stores the
    partial at ``out[i]`` — the first (parallel) phase of a tree dot/sum.
    Unlike the paper's seven benches, the item count is ``n/seg``, so the
    launch is load-heavy per item with few wavefronts — the shape a fleet
    router places on a small high-clock device while wide launches go to a
    many-CU one."""
    if n_scalar % seg or n_gpu % seg:
        raise ValueError(f"reduction sizes must be multiples of seg={seg}")
    lg = int(np.log2(seg))
    if 1 << lg != seg:
        raise ValueError("seg must be a power of two")

    def mem(n):
        return np.concatenate([_rand(n, -100, 100, seed=13),
                               _rand(n, -100, 100, seed=14),
                               np.zeros(n // seg, np.int32)])

    def build(n, outer: bool):
        k = n // seg
        a = Assembler()
        if outer:
            a.li(11, 0).li(12, k)
            a.label("outer").bge(11, 12, "end")
            i_reg = 11
        else:
            a.tid(1)
            i_reg = 1
        a.slli(2, i_reg, lg)                 # base = i * seg
        a.li(3, 0).li(4, 0).li(5, seg)
        a.label("loop").bge(4, 5, "done")
        a.add(6, 2, 4)
        a.lw(7, 6, 0).lw(8, 6, n).mul(7, 7, 8).add(3, 3, 7)
        a.addi(4, 4, 1).beq(0, 0, "loop")
        a.label("done").sw(3, i_reg, 2 * n)
        if outer:
            a.addi(11, 11, 1).beq(0, 0, "outer")
            a.label("end").halt()
        else:
            a.halt()
        return a

    def ref(m, n):
        a = m[:n].astype(np.int64).reshape(-1, seg)
        b = m[n:2 * n].astype(np.int64).reshape(-1, seg)
        return (a * b).sum(axis=1).astype(np.int32)

    return Bench("reduction", build(n_gpu, False).assemble(), mem(n_gpu),
                 n_gpu // seg, slice(2 * n_gpu, 2 * n_gpu + n_gpu // seg),
                 build(n_scalar, True).assemble(), mem(n_scalar),
                 slice(2 * n_scalar, 2 * n_scalar + n_scalar // seg), ref,
                 n_gpu, n_scalar)


def all_benches() -> Dict[str, Bench]:
    """Every bench registered on the ``BENCHES`` axis, built at default
    (Table III) sizes: the paper's seven plus the ``reduction``
    extension in their legacy table order, then any drop-in plugin
    benches (``repro/registry/plugins/``). The paper tables only report
    the seven in ``PAPER_CYCLES``."""
    from repro.registry import BENCHES
    from repro.registry.benches import ordered_names
    return {n: BENCHES.get(n).build() for n in ordered_names()}


# paper values for comparison (Table III, k-cycles)
PAPER_CYCLES = {
    "mat_mul": dict(riscv=202, cu1=48, cu2=28, cu4=18, cu8=14),
    "copy": dict(riscv=71, cu1=73, cu2=36, cu4=24, cu8=22),
    "vec_mul": dict(riscv=78, cu1=100, cu2=49, cu4=31, cu8=26),
    "fir": dict(riscv=542, cu1=694, cu2=358, cu4=185, cu8=169),
    "div_int": dict(riscv=32, cu1=209, cu2=105, cu4=57, cu8=62),
    "xcorr": dict(riscv=542, cu1=5343, cu2=2802, cu4=1467, cu8=2079),
    "parallel_sel": dict(riscv=765, cu1=5979, cu2=3157, cu4=1656, cu8=1660),
}
PAPER_INPUT = {
    "mat_mul": (128, 2048), "copy": (512, 32768), "vec_mul": (1024, 65536),
    "fir": (128, 4096), "div_int": (512, 4096), "xcorr": (256, 4096),
    "parallel_sel": (128, 2048),
}
