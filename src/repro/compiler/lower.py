"""Lowering: expression graph -> G-GPU ISA programs.

The codegen walks the (CSE'd, folded) expression DAG and emits through
``repro.ggpu.isa.Assembler``, producing *two* programs per kernel from the
same IR:

  * the **SIMT** program — one work item per output element; the engine
    tiles items over CUs/wavefronts exactly as for the hand-written
    benches (an optional ``coarsen`` factor folds several outputs into
    one item, trading wavefront count for per-item work — the workload
    side of the tiling knob);
  * the **sequential scalar** program — the same per-item body wrapped in
    an outer loop over items, the RISC-V-baseline shape of Table III.

Codegen strategy (deliberately close to the hand-written idiom, so simple
kernels compile to the *same instruction sequences* and therefore the same
cycle counts):

  * **register allocation** — lowest-free-register, scope-based: each
    ``Reduce``/``Guard`` body is a scope whose registers free at scope
    exit; a value is freed eagerly when its last structural use is read
    in the scope that allocated it. Shared (CSE) nodes stay resident
    until their owner scope closes. R0 is the hardwired zero; constants
    fold into immediates wherever an I-form exists.
  * **loop-invariant hoisting** — compound subexpressions of a reduction
    body that do not read the loop counter are materialized once before
    the loop (sound because inputs are read-only — see ``ir`` module
    doc). The loop bound is a cached ``Const`` node, so in-body uses of
    the same constant (e.g. a circular wrap limit) hit its register.
  * **guarded terms** — ``Reduce(.., Guard(c, e))`` emits the FGPU
    boundary idiom: branch-if-false over the term and its accumulate.
    ``x - Guard(c, y)`` (and +/or/xor) emits a conditional-update peephole
    (branch over a single in-place op), matching the hand-written
    circular-wrap sequence.

Address expressions peel their constant tail into the load/store
immediate field, so ``a[i]`` is one ``LW`` with the array base in ``imm``.

Every choice above is a **schedule knob** (``Schedule``): the default
schedule reproduces the hand-written idiom exactly (and therefore the
golden cycle counts), while the autotuner (``repro.compiler.autotune``)
sweeps the alternatives — output coarsening, hoisting off, the branch-free
select lowering of guards, address-peeling off — and keeps whichever
lowering is fastest in true cycles on the target design point.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.compiler import opt
from repro.compiler.ir import (Bin, CompileError, Const, Expr, Guard, Item,
                               Kernel, Load, LoopVar, Reduce, children,
                               eval_expr, w32)
from repro.ggpu.isa import Assembler

#: Bin op -> (register mnemonic, immediate mnemonic or None)
_MNEMONICS = {
    "add": ("add", "addi"), "sub": ("sub", None), "mul": ("mul", None),
    "div": ("div", None), "rem": ("rem", None),
    "and": ("and_", "andi"), "or": ("or_", "ori"), "xor": ("xor", "xori"),
    "shl": ("sll", "slli"), "srl": ("srl", "srli"), "sra": ("sra", "srai"),
    "slt": ("slt", "slti"),
}
#: branch emitted when the condition is FALSE (skip the guarded body)
_INV_BRANCH = {"lt": "bge", "ge": "blt", "eq": "bne", "ne": "beq"}
#: ops whose identity element is 0 (conditional-update peephole)
_COND_UPDATE_OPS = ("add", "sub", "or", "xor")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point in the lowering-choice space the autotuner searches.

    ``coarsen`` tiles that many consecutive output elements onto one work
    item (must divide the kernel's output length). ``hoist`` enables
    loop-invariant hoisting. ``branchy`` selects the Guard lowering:
    ``True`` emits the hand-written branch idioms (branch-over-term,
    conditional update), ``False`` rewrites every ``Guard(c, e)`` into the
    branch-free ``cond_val(c) * e`` select before codegen — more ALU work,
    no divergence. ``peel`` enables peeling constant address tails into
    the LW/SW immediate field; off, addresses materialize through the
    register file (the register-pressure end of that trade-off).

    ``Schedule()`` is the default lowering — bit- and cycle-identical to
    the pre-schedule compiler on every kernel.
    """
    coarsen: int = 1
    hoist: bool = True
    branchy: bool = True
    peel: bool = True

    def __post_init__(self):
        if self.coarsen < 1:
            raise CompileError(f"coarsen={self.coarsen} must be >= 1")

    def label(self) -> str:
        """Compact stable label, e.g. ``c2+nohoist+select``; ``c1`` is
        the default schedule."""
        parts = [f"c{self.coarsen}"]
        if not self.hoist:
            parts.append("nohoist")
        if not self.branchy:
            parts.append("select")
        if not self.peel:
            parts.append("nopeel")
        return "+".join(parts)

    def sort_key(self) -> tuple:
        """Deterministic tie-break order: the default schedule first,
        then least-surprising (closest to default) lowerings."""
        return (self.coarsen != 1, self.coarsen, not self.branchy,
                not self.hoist, not self.peel)


DEFAULT_SCHEDULE = Schedule()


class _Codegen:
    """One emission pass over a kernel body (SIMT or scalar variant)."""

    def __init__(self, asm: Assembler, roots: Sequence[Expr],
                 layout: Dict[str, int], item_reg: int,
                 schedule: Schedule = DEFAULT_SCHEDULE):
        self.asm = asm
        self.layout = layout
        self.schedule = schedule
        self.uses = opt.use_counts(roots)
        self.free = sorted(set(range(2, 32)) - {item_reg})
        self.cache: Dict[Expr, int] = {Item(): item_reg}
        self.owner: Dict[Expr, int] = {Item(): 0}
        self.scopes: List[List[Expr]] = [[Item()]]
        self._labels = itertools.count()
        self._vars_memo: Dict[Expr, frozenset] = {}

    # -- registers ----------------------------------------------------------

    def _alloc(self, node: Optional[Expr]) -> int:
        if not self.free:
            raise CompileError(
                "out of registers: expression too wide for the 32-entry "
                "register file — split the kernel or reduce sharing")
        reg = self.free.pop(0)
        if node is not None:
            self.cache[node] = reg
            self.owner[node] = len(self.scopes) - 1
            self.scopes[-1].append(node)
        return reg

    def _free_reg(self, reg: int):
        if reg != 0:
            self.free.append(reg)
            self.free.sort()

    def release(self, e: Expr):
        """Account one read of ``e``; frees its register on the last read
        if the current scope owns it (otherwise the owner scope exit
        does)."""
        if e not in self.cache:
            return                       # r0 constant / peeled node
        self.uses[e] = self.uses.get(e, 1) - 1
        if self.uses[e] <= 0 and self.owner[e] == len(self.scopes) - 1:
            self._evict(e)

    def _evict(self, e: Expr):
        reg = self.cache.pop(e)
        self.scopes[self.owner.pop(e)].remove(e)
        self._free_reg(reg)

    def _open_scope(self):
        self.scopes.append([])

    def _close_scope(self):
        for e in self.scopes.pop():
            self._free_reg(self.cache.pop(e))
            self.owner.pop(e)

    def _label(self) -> str:
        return f"L{next(self._labels)}"

    # -- emission -----------------------------------------------------------

    def emit(self, e: Expr) -> int:
        if e in self.cache:
            return self.cache[e]
        if isinstance(e, Const):
            if e.v == 0:
                return 0
            reg = self._alloc(e)
            self.asm.li(reg, e.v)
            return reg
        if isinstance(e, LoopVar):
            raise CompileError("loop variable escaped its Reduce")
        if isinstance(e, Bin):
            return self._emit_bin(e)
        if isinstance(e, Load):
            base, imm, node = self._emit_addr(e.idx)
            off = self.layout[e.array]
            rd = self._reuse_or_alloc(e, node, base)
            self.asm.lw(rd, base, off + imm)
            return rd
        if isinstance(e, Guard):
            return self._emit_guard(e)
        if isinstance(e, Reduce):
            return self._emit_reduce(e)
        raise CompileError(f"cannot lower {type(e).__name__}")

    def _reuse_or_alloc(self, e: Expr, operand: Optional[Expr],
                        operand_reg: int) -> int:
        """Destination register: reuse ``operand``'s register in place when
        this read retires it (dataflow-safe — operands are read before
        writeback), else allocate."""
        if operand is not None and operand in self.cache \
                and self.cache[operand] == operand_reg:
            self.release(operand)
            if operand not in self.cache:        # retired: mutate in place
                self.cache[e] = operand_reg
                self.owner[e] = len(self.scopes) - 1
                self.scopes[-1].append(e)
                # reclaim it from the free list — it is live again
                self.free.remove(operand_reg)
                return operand_reg
            return self._alloc(e)
        if operand is not None:
            self.release(operand)
        return self._alloc(e)

    def _emit_bin(self, e: Bin) -> int:
        reg_mn, imm_mn = _MNEMONICS[e.op]
        # conditional-update peephole: x OP Guard(c, y) with identity 0
        if isinstance(e.b, Guard) and e.op in _COND_UPDATE_OPS:
            return self._emit_cond_update(e)
        b_const = isinstance(e.b, Const)
        if b_const and e.op == "sub" and -2048 <= -e.b.v < 2048:
            ra = self.emit(e.a)
            rd = self._reuse_or_alloc(e, e.a, ra)
            self.asm.addi(rd, ra, -e.b.v)
            return rd
        if b_const and imm_mn is not None and -2048 <= e.b.v < 2048:
            ra = self.emit(e.a)
            rd = self._reuse_or_alloc(e, e.a, ra)
            getattr(self.asm, imm_mn)(rd, ra, e.b.v)
            return rd
        ra = self.emit(e.a)
        rb = self.emit(e.b)
        self.release(e.b)
        rd = self._reuse_or_alloc(e, e.a, ra)
        getattr(self.asm, reg_mn)(rd, ra, rb)
        return rd

    def _transfer(self, old: Expr, new: Expr) -> int:
        """Rebind ``old``'s live register to ``new`` (in-place mutation)."""
        reg = self.cache.pop(old)
        self.scopes[self.owner.pop(old)].remove(old)
        self.uses[old] = 0
        self.cache[new] = reg
        self.owner[new] = len(self.scopes) - 1
        self.scopes[-1].append(new)
        return reg

    def _emit_cond_update(self, e: Bin) -> int:
        """``x OP Guard(c, y)``: branch over a single in-place update when
        the guard is false (the hand-written circular-wrap idiom). The
        update mutates x's register when this op and the condition are its
        last reads; otherwise x is copied first."""
        g: Guard = e.b
        ra = self.emit(e.a)
        rca, rcb = self.emit(g.cond.a), self.emit(g.cond.b)
        pending = 1 + (g.cond.a == e.a) + (g.cond.b == e.a)
        in_place = (e.a in self.cache
                    and self.uses.get(e.a, 0) <= pending
                    and self.owner.get(e.a) == len(self.scopes) - 1)
        if in_place:
            rd = ra
        else:
            rd = self._alloc(e)
            self.asm.mv(rd, ra)
        skip = self._label()
        getattr(self.asm, _INV_BRANCH[g.cond.op])(rca, rcb, skip)
        self.release(g.cond.a)
        self.release(g.cond.b)
        if in_place:
            self._transfer(e.a, e)
        else:
            self.release(e.a)
        self._open_scope()
        ry = self.emit(g.body)
        self.release(g.body)
        getattr(self.asm, _MNEMONICS[e.op][0])(rd, rd, ry)
        self._close_scope()
        self.asm.label(skip)
        return rd

    def _emit_guard(self, e: Guard) -> int:
        rd = self._alloc(e)
        self.asm.li(rd, 0)
        rca, rcb = self.emit(e.cond.a), self.emit(e.cond.b)
        skip = self._label()
        getattr(self.asm, _INV_BRANCH[e.cond.op])(rca, rcb, skip)
        self.release(e.cond.a)
        self.release(e.cond.b)
        self._open_scope()
        rb = self.emit(e.body)
        self.release(e.body)
        self.asm.mv(rd, rb)
        self._close_scope()
        self.asm.label(skip)
        return rd

    def _vars_of(self, e: Expr) -> frozenset:
        """The free index variables (``Item`` / unbound ``LoopVar``) an
        expression reads; a ``Reduce`` binds its own counter."""
        if e in self._vars_memo:
            return self._vars_memo[e]
        if isinstance(e, (Item, LoopVar)):
            out = frozenset({e})
        else:
            out = frozenset().union(
                *(self._vars_of(c) for c in children(e))) \
                if children(e) else frozenset()
            if isinstance(e, Reduce):
                out -= {e.var}
        self._vars_memo[e] = out
        return out

    def _hoist(self, e: Expr, newvar: Expr):
        """Materialize compound subexpressions of a loop body that do not
        read the loop counter before the loop opens. A node is hoistable
        when it avoids ``newvar`` and every other variable it reads is
        already live (an enclosing loop's counter or the item index).
        Disabled schedules recompute invariants inside the loop instead
        (fewer registers live across the loop)."""
        if not self.schedule.hoist:
            return
        if isinstance(e, (Const, Item, LoopVar)):
            return
        vs = self._vars_of(e)
        if newvar not in vs and all(v in self.cache for v in vs):
            if e not in self.cache:
                self.emit(e)
            return
        for c in children(e):
            self._hoist(c, newvar)

    def _emit_reduce(self, e: Reduce) -> int:
        acc = self._alloc(e)
        self.asm.li(acc, 0)
        var_reg = self._alloc(e.var)
        self.asm.li(var_reg, 0)
        rlim = self.emit(Const(e.count))
        self._hoist(e.body, e.var)
        top, done = self._label(), self._label()
        self.asm.label(top)
        self.asm.bge(var_reg, rlim, done)
        self._open_scope()
        body = e.body
        if isinstance(body, Guard):
            # FGPU boundary idiom: skip the term AND its accumulate
            rca, rcb = self.emit(body.cond.a), self.emit(body.cond.b)
            skip = self._label()
            getattr(self.asm, _INV_BRANCH[body.cond.op])(rca, rcb, skip)
            self.release(body.cond.a)
            self.release(body.cond.b)
            rb = self.emit(body.body)
            self.release(body.body)
            self.asm.add(acc, acc, rb)
            self._close_scope()
            self.asm.label(skip)
        else:
            rb = self.emit(body)
            self.release(body)
            self.asm.add(acc, acc, rb)
            self._close_scope()
        self.asm.addi(var_reg, var_reg, 1)
        self.asm.beq(0, 0, top)
        self.asm.label(done)
        # retire the loop counter; the bound Const stays cached (shared)
        if e.var in self.cache:
            self._evict(e.var)
        return acc

    def _emit_addr(self, e: Expr) -> Tuple[int, int, Optional[Expr]]:
        """(base register, immediate, node to release) for an address
        expression, peeling the constant tail into the immediate."""
        if e in self.cache:
            return self.cache[e], 0, e
        if not self.schedule.peel:
            # schedule knob: address constants materialize through the
            # register file (folded by the ADDI immediate forms instead)
            return self.emit(e), 0, e
        imm = 0
        peeled = False
        while isinstance(e, Bin) and e.op == "add" \
                and isinstance(e.b, Const) and e not in self.cache:
            imm += e.b.v
            e = e.a
            peeled = True
        if isinstance(e, Const):
            return 0, imm + e.v, None
        # a peeled base's reads are accounted to the skipped +const
        # wrappers, not the base itself — never release it here (it frees
        # at scope exit), or shared bases would retire early
        return self.emit(e), imm, (None if peeled else e)

    def store(self, addr: Expr, value: Expr, out_off: int):
        rv = self.emit(value)
        base, imm, node = self._emit_addr(opt.add(addr, Const(out_off)))
        self.asm.sw(rv, base, imm)
        self.release(value)
        if node is not None:
            self.release(node)


# ---------------------------------------------------------------------------
# program builders
# ---------------------------------------------------------------------------

def _scheduled_stores(kernel: Kernel,
                      schedule: Schedule) -> List[Tuple[Expr, Expr]]:
    """The store list the codegen lowers: the kernel's own under the
    branchy (default) schedule, the branch-free select rewrite otherwise.
    The kernel's IR — and therefore the oracle — is never mutated."""
    if schedule.branchy:
        return kernel.stores
    memo: Dict[Expr, Expr] = {}
    return [(opt.to_select(a, memo), opt.to_select(v, memo))
            for a, v in kernel.stores]


def build_simt(kernel: Kernel,
               schedule: Schedule = DEFAULT_SCHEDULE) -> np.ndarray:
    """The G-GPU program: TID -> item, body, stores, HALT."""
    asm = Assembler()
    layout = kernel.layout()
    stores = _scheduled_stores(kernel, schedule)
    roots = [r for a, v in stores
             for r in (v, opt.add(a, Const(layout["__out__"])))]
    asm.tid(1)
    gen = _Codegen(asm, roots, layout, item_reg=1, schedule=schedule)
    for addr, value in stores:
        gen.store(addr, value, layout["__out__"])
    asm.halt()
    return asm.assemble()


def build_scalar(kernel: Kernel,
                 schedule: Schedule = DEFAULT_SCHEDULE) -> np.ndarray:
    """The sequential baseline: the same body in an outer item loop."""
    asm = Assembler()
    layout = kernel.layout()
    stores = _scheduled_stores(kernel, schedule)
    roots = [r for a, v in stores
             for r in (v, opt.add(a, Const(layout["__out__"])))]
    asm.li(1, 0)
    gen = _Codegen(asm, roots, layout, item_reg=1, schedule=schedule)
    rlim = gen._alloc(None)
    asm.li(rlim, kernel.n_items)
    # hoist item-invariant work out of the outer loop
    for root in roots:
        gen._hoist(root, Item())
    top, end = gen._label(), gen._label()
    asm.label(top)
    asm.bge(1, rlim, end)
    gen._open_scope()
    for addr, value in stores:
        gen.store(addr, value, layout["__out__"])
    gen._close_scope()
    asm.addi(1, 1, 1)
    asm.beq(0, 0, top)
    asm.label(end)
    asm.halt()
    return asm.assemble()


# ---------------------------------------------------------------------------
# compiled kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompiledKernel:
    """A lowered kernel: both program variants, the memory layout, and the
    NumPy oracle for differential verification."""
    name: str
    kernel: Kernel
    prog: np.ndarray                 # SIMT program (one item per output)
    scalar_prog: np.ndarray          # sequential outer-loop program
    n_items: int
    schedule: Schedule = DEFAULT_SCHEDULE

    @property
    def layout(self) -> Dict[str, int]:
        return self.kernel.layout()

    @property
    def out(self) -> slice:
        off = self.layout["__out__"]
        return slice(off, off + self.kernel.out_len)

    @property
    def mem_size(self) -> int:
        return self.kernel.mem_size

    # -- memory images ------------------------------------------------------

    def _inputs_dict(self, inputs) -> Dict[str, np.ndarray]:
        names = list(self.kernel.arrays)
        if isinstance(inputs, dict):
            missing = set(names) - set(inputs)
            if missing:
                raise CompileError(f"missing inputs: {sorted(missing)}")
            d = {n: np.asarray(inputs[n], np.int32).reshape(-1)
                 for n in names}
        else:
            if len(inputs) != len(names):
                raise CompileError(
                    f"expected {len(names)} inputs, got {len(inputs)}")
            d = {n: np.asarray(x, np.int32).reshape(-1)
                 for n, x in zip(names, inputs)}
        for n, ln in self.kernel.arrays.items():
            if d[n].shape[0] != ln:
                raise CompileError(
                    f"input {n!r}: expected {ln} words, got {d[n].shape[0]}")
        return d

    def build_mem(self, inputs) -> np.ndarray:
        d = self._inputs_dict(inputs)
        return np.concatenate(
            [d[n] for n in self.kernel.arrays]
            + [np.zeros(self.kernel.out_len, np.int32)])

    def extract_inputs(self, mem: np.ndarray) -> Dict[str, np.ndarray]:
        layout = self.layout
        return {n: np.asarray(mem[layout[n]:layout[n] + ln], np.int32)
                for n, ln in self.kernel.arrays.items()}

    # -- the oracle ---------------------------------------------------------

    def reference(self, inputs) -> np.ndarray:
        """Expected output computed by the NumPy oracle (engine ALU
        semantics)."""
        d = self._inputs_dict(inputs)
        arrays = {n: np.asarray(v, np.int64) for n, v in d.items()}
        item = np.arange(self.n_items, dtype=np.int64)
        out = np.zeros(self.kernel.out_len, np.int64)
        addrs, vals = [], []
        for addr, value in self.kernel.stores:
            addrs.append(eval_expr(addr, item, arrays, {}))
            vals.append(eval_expr(value, item, arrays, {}))
        # collisions are checked across ALL stores of all items: lanes
        # have no inter-item store order, so an address written by two
        # different items races. The same item writing an address twice
        # (coarsened store pairs) is deterministic — program order — on
        # both the engine and this oracle, and is allowed.
        A = np.stack(addrs)                       # (n_stores, n_items)
        owner = np.broadcast_to(item, A.shape)
        pairs = np.unique(np.stack([A.ravel(), owner.ravel()], axis=1),
                          axis=0)
        if len(np.unique(pairs[:, 0])) != len(pairs):
            raise CompileError(
                f"kernel {self.name!r}: store addresses collide across "
                "work items (lanes have no inter-item store order)")
        for a, v in zip(addrs, vals):
            out[a] = w32(v)
        return out.astype(np.int32)

    # -- execution ----------------------------------------------------------

    def run(self, inputs, cfg, *, scalar: bool = False):
        """Execute on the engine; returns (out_array, info)."""
        from repro.ggpu.engine import run_kernel
        mem0 = self.build_mem(inputs)
        prog = self.scalar_prog if scalar else self.prog
        n = 1 if scalar else self.n_items
        mem, info = run_kernel(prog, mem0, n, cfg)
        return np.asarray(mem)[self.out], info

    def verify(self, inputs, cfg, *, scalar: bool = False) -> dict:
        """Differential check: engine output must be bit-exact vs the
        NumPy oracle. Returns the engine info dict."""
        got, info = self.run(inputs, cfg, scalar=scalar)
        np.testing.assert_array_equal(got, self.reference(inputs))
        return info

    def random_inputs(self, lo: int = -100, hi: int = 100,
                      seed: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        return {n: rng.integers(lo, hi, ln).astype(np.int32)
                for n, ln in self.kernel.arrays.items()}

    # -- interop ------------------------------------------------------------

    def as_bench(self, inputs=None, seed: int = 0):
        """A ``repro.ggpu.programs.Bench``-compatible record, so compiled
        kernels drop into ``dse.Evaluator`` (via ``workloads=``),
        ``serve``, and the bench tables."""
        from repro.ggpu.programs import Bench
        if inputs is None:
            inputs = self.random_inputs(seed=seed)
        mem0 = self.build_mem(inputs)

        def ref(m, _n, _self=self):
            return _self.reference(_self.extract_inputs(m))

        return Bench(self.name, self.prog, mem0, self.n_items, self.out,
                     self.scalar_prog, mem0.copy(), self.out, ref,
                     self.n_items, self.n_items)


def lower_kernel(kernel: Kernel,
                 schedule: Schedule = DEFAULT_SCHEDULE) -> CompiledKernel:
    return CompiledKernel(kernel.name, kernel, build_simt(kernel, schedule),
                          build_scalar(kernel, schedule), kernel.n_items,
                          schedule)
