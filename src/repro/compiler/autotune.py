"""Autotuning compiler back-end: schedule search over lowering choices.

The compiler's lowering knobs — ``coarsen`` tiling, loop-invariant
hoisting, the branchy ``Guard`` idiom vs branch-free select, and const
address peeling — are a per-kernel *schedule* (``lower.Schedule``). This
module searches a declared ``ScheduleSpace`` exhaustively: every
candidate is re-traced and lowered through the parameterized hooks
(``compile_kernel(..., schedule=...)``), **verified bit-exact against
the default kernel's IR oracle**, and costed in true cycles through
``dse.Evaluator``'s workload path.

Costing is content-addressed three ways, which is what makes sweeps and
re-runs near-free:

  * the candidate *program bytes* are a pure function of (IR, schedule),
    so the executor-level memo key ``(name, items, sha1(prog),
    sha1(mem))`` **is** an (IR, schedule, input) key;
  * the engine *configuration* enters through the shared per-config
    executor registry (``serve.executors.get_executor``), with
    ``freq_mhz`` normalized out (``sim_key``);
  * all cache-missing candidates of one ``autotune`` call are costed in
    a **single pipelined Scheduler drain** (``Evaluator.simulate``), so
    a whole schedule space costs one or two batched dispatches.

Two surfaces:

  * ``autotune(fn, shapes, cfg)`` — best ``CompiledKernel`` for one
    kernel on one engine config, plus a per-candidate report. The
    default schedule is always in the candidate set, so the tuned
    kernel is *never worse than the default by construction*; the
    choice is deterministic (min over ``(cycles, prog_len,
    schedule.sort_key())``).
  * ``codesign(defs, ...)`` — the co-design loop: one ``dse.search``
    per candidate schedule over a shared workload suite, ranked jointly
    by ``dse.joint_frontier`` so the Pareto frontier is over
    ``(DesignPoint, Schedule)`` pairs — a schedule that makes a small
    design fast enough evicts a bigger design from the frontier.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.compiler.frontend import compile_kernel
from repro.compiler.ir import CompileError
from repro.compiler.lower import DEFAULT_SCHEDULE, CompiledKernel, Schedule


# ---------------------------------------------------------------------------
# the schedule space
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScheduleSpace:
    """The declared per-kernel search space: the cross product of the
    lowering knobs, filtered to schedules valid for the kernel at hand
    (``coarsen`` must divide the output length)."""
    coarsen: Tuple[int, ...] = (1, 2, 4)
    hoist: Tuple[bool, ...] = (True, False)
    branchy: Tuple[bool, ...] = (True, False)
    peel: Tuple[bool, ...] = (True, False)

    def candidates(self, out_len: int) -> List[Schedule]:
        """Valid schedules for a kernel with ``out_len`` outputs, in
        deterministic (default-first) order. The default schedule is
        always included so a tuned kernel can never lose to it."""
        seen = {DEFAULT_SCHEDULE}
        for c in self.coarsen:
            if c < 1 or out_len % c:
                continue
            for h in self.hoist:
                for b in self.branchy:
                    for p in self.peel:
                        seen.add(Schedule(coarsen=c, hoist=h,
                                          branchy=b, peel=p))
        return sorted(seen, key=Schedule.sort_key)

    def size(self) -> int:
        return (len(set(self.coarsen)) * len(set(self.hoist))
                * len(set(self.branchy)) * len(set(self.peel)))


#: full space swept by the nightly compiler job
DEFAULT_SPACE = ScheduleSpace()

#: trimmed space for the PR-blocking smoke path: the coarsening axis plus
#: the branch idiom (the two knobs that move cycles the most), hoist/peel
#: pinned to their defaults
SMOKE_SPACE = ScheduleSpace(coarsen=(1, 2), hoist=(True,),
                            branchy=(True, False), peel=(True,))


# ---------------------------------------------------------------------------
# single-kernel autotuning
# ---------------------------------------------------------------------------

@dataclass
class CandidateReport:
    """One lowered candidate: its schedule, cost, and verification."""
    schedule: Schedule
    cycles: int
    time_us: float
    prog_len: int                # SIMT program length (static code size)
    verified: bool               # bit-exact vs the default kernel's oracle
    best: bool = False

    def report(self) -> dict:
        return {
            "schedule": self.schedule.label(),
            "cycles": int(self.cycles),
            "time_us": round(self.time_us, 3),
            "prog_len": int(self.prog_len),
            "verified": bool(self.verified),
            "best": bool(self.best),
        }


@dataclass
class AutotuneResult:
    """Outcome of one schedule search: the chosen kernel + the sweep."""
    name: str
    best: CompiledKernel
    candidates: List[CandidateReport]
    default_cycles: int
    best_cycles: int
    cache_hits: int = 0
    objective: object = field(repr=False, default=None)

    @property
    def best_schedule(self) -> Schedule:
        return self.best.schedule

    @property
    def speedup(self) -> float:
        """Default-lowering cycles over tuned cycles (>= 1.0 always:
        the default schedule is in the candidate set)."""
        return self.default_cycles / max(self.best_cycles, 1)

    def report(self) -> dict:
        return {
            "name": self.name,
            "best_schedule": self.best_schedule.label(),
            "default_cycles": int(self.default_cycles),
            "tuned_cycles": int(self.best_cycles),
            "tuned_vs_default": round(self.best_cycles
                                      / max(self.default_cycles, 1), 4),
            "n_candidates": len(self.candidates),
            "candidates": [c.report() for c in self.candidates],
        }


def _oracle_bench(k: CompiledKernel, mem0: np.ndarray,
                  expect: np.ndarray, label: str):
    """A ``programs.Bench`` record for one candidate whose reference is
    the *default* kernel's oracle output — so ``Evaluator(check=True)``
    enforces candidate-vs-original-IR bit-exactness while costing."""
    from repro.ggpu.programs import Bench

    def ref(_mem, _n, _expect=expect):
        return _expect

    return Bench(label, k.prog, mem0, k.n_items, k.out,
                 k.scalar_prog, mem0.copy(), k.out, ref,
                 k.n_items, k.n_items)


def autotune(fn: Callable, shapes, cfg, *,
             space: ScheduleSpace = DEFAULT_SPACE,
             name: Optional[str] = None,
             inputs=None, seed: int = 0) -> AutotuneResult:
    """Search ``space`` for the fastest schedule of ``fn`` on engine
    config ``cfg``.

    ``fn``/``shapes`` as in ``compile_kernel``; every candidate is traced
    fresh (coarsening changes the kernel IR), lowered, run through one
    batched ``dse.Evaluator`` drain with ``check=True`` against the
    default kernel's oracle, and ranked by true cycles. Deterministic:
    same (fn, shapes, space, cfg) -> same chosen schedule."""
    from repro.dse.evaluate import Evaluator

    base = compile_kernel(fn, shapes, name=name)
    name = base.name
    if inputs is None:
        inputs = base.random_inputs(seed=seed)
    mem0 = base.build_mem(inputs)
    expect = base.reference(inputs)

    kernels: Dict[str, CompiledKernel] = {}
    workloads: Dict[str, object] = {}
    for sched in space.candidates(base.kernel.out_len):
        k = base if sched == DEFAULT_SCHEDULE \
            else compile_kernel(fn, shapes, name=name, schedule=sched)
        label = f"{name}@{sched.label()}"
        kernels[label] = k
        workloads[label] = _oracle_bench(k, mem0, expect, label)

    ev = Evaluator(benches=(), workloads=workloads, check=True)
    before = ev.cache_size()
    ev.simulate(cfg)                 # one drain for every cache miss
    rows: List[CandidateReport] = []
    for label, k in kernels.items():
        info, _ = ev.cycles(cfg, label)
        rows.append(CandidateReport(
            schedule=k.schedule, cycles=int(info["cycles"]),
            time_us=info["cycles"] / cfg.freq_mhz,
            prog_len=int(k.prog.shape[0]), verified=True))

    best_row = min(rows, key=lambda r: (r.cycles, r.prog_len,
                                        r.schedule.sort_key()))
    best_row.best = True
    default_cycles = next(r.cycles for r in rows
                          if r.schedule == DEFAULT_SCHEDULE)
    best = kernels[f"{name}@{best_row.schedule.label()}"]
    return AutotuneResult(
        name=name, best=best, candidates=rows,
        default_cycles=default_cycles, best_cycles=best_row.cycles,
        cache_hits=before)


def autotune_suite(names: Sequence[str], cfg, *,
                   sizes: Optional[Dict[str, Tuple[int, ...]]] = None,
                   space: ScheduleSpace = DEFAULT_SPACE,
                   seed: int = 0) -> Dict[str, AutotuneResult]:
    """Autotune suite benches by name (sizes as in ``suite.
    hand_benches``), against the hand-written benches' memory images."""
    from repro.compiler.suite import def_args, hand_benches, kernel_def
    out: Dict[str, AutotuneResult] = {}
    hands = hand_benches(sizes)
    for n in names:
        fn, shapes = kernel_def(n, *def_args(n, hands[n]))
        out[n] = autotune(fn, shapes, cfg, space=space, name=n, seed=seed)
    return out


# ---------------------------------------------------------------------------
# co-design: (DesignPoint, Schedule) pairs on one frontier
# ---------------------------------------------------------------------------

@dataclass
class CodesignResult:
    """Per-schedule DSE results plus the joint co-designed frontier."""
    results: Dict[str, "object"]          # schedule label -> SearchResult
    joint: "object"                       # dse.JointResult

    @property
    def frontier(self):
        return self.joint.frontier

    def report(self) -> List[dict]:
        return self.joint.report()


def codesign(defs: Dict[str, Tuple[Callable, Dict[str, object]]],
             specs=None, *,
             space: ScheduleSpace = DEFAULT_SPACE,
             objective=None, seed: int = 0,
             **grid_kw) -> CodesignResult:
    """Close the HW/SW loop: rank ``(DesignPoint, Schedule)`` pairs.

    ``defs`` maps workload names to ``(fn, shapes)`` definitions (e.g.
    from ``suite.kernel_def``). For every schedule valid across **all**
    workloads, the suite is recompiled, verified against the default
    oracle, and swept through ``dse.search`` (specs or ``grid_kw`` as in
    ``enumerate_specs``); the per-schedule results are then ranked as
    one population by ``dse.joint_frontier``, so the returned frontier
    is over co-designed configurations."""
    from repro.dse.evaluate import Evaluator
    from repro.dse.search import cycle_objective, joint_frontier, search

    if objective is None:
        objective = cycle_objective
    if not defs:
        raise CompileError("codesign needs at least one workload")

    bases = {n: compile_kernel(fn, shapes, name=n)
             for n, (fn, shapes) in defs.items()}
    joint_len = math.gcd(*[b.kernel.out_len for b in bases.values()])
    prepared = {}
    for n, b in bases.items():
        inputs = b.random_inputs(seed=seed)
        prepared[n] = (b.build_mem(inputs), b.reference(inputs))

    results = {}
    for sched in space.candidates(joint_len):
        workloads = {}
        for n, (fn, shapes) in defs.items():
            k = bases[n] if sched == DEFAULT_SCHEDULE \
                else compile_kernel(fn, shapes, name=n, schedule=sched)
            mem0, expect = prepared[n]
            workloads[n] = _oracle_bench(k, mem0, expect, n)
        ev = Evaluator(benches=(), workloads=workloads, check=True)
        results[sched.label()] = search(specs, evaluator=ev,
                                        objective=objective, **grid_kw)
    return CodesignResult(results=results,
                          joint=joint_frontier(results, objective))
