"""Expression IR for the kernel compiler: nodes + the NumPy reference.

The compiler's internal representation is a *per-work-item scalar
expression graph*: every kernel output element is one expression over the
work-item index (``Item``), integer constants, loads from named input
arrays, reduction loops, and guarded (conditional) terms. The tensor-level
frontend (``repro.compiler.frontend``) never materializes intermediate
arrays — elementwise chains compose into one expression per output element
(fusion by construction), and ``repro.compiler.lower`` turns the graph
into a G-GPU ISA program.

Nodes are frozen dataclasses, so structurally identical subtrees compare
and hash equal — common-subexpression elimination is a cache keyed on the
node itself (``opt.use_counts`` / the codegen cache in ``lower``).

``eval_expr`` is the differential-testing oracle: a vectorized NumPy
evaluator with exactly the engine ALU's semantics (int32 wraparound,
floor division with div-by-zero -> 0, shift amounts clipped to [0, 31]).
Every compiled kernel is verified against it (``CompiledKernel.verify``).

Aliasing contract: input arrays are read-only and the output region is
write-only and disjoint from the inputs, so loop-invariant loads may be
hoisted and work items never observe each other's stores.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np


class CompileError(Exception):
    """A DSL expression the compiler cannot lower (shape mismatch, out of
    registers, unsupported construct)."""


# ---------------------------------------------------------------------------
# nodes
# ---------------------------------------------------------------------------

class Expr:
    """Base class for scalar expression nodes (int32-valued)."""
    __slots__ = ()


@dataclass(frozen=True)
class Item(Expr):
    """The global work-item index (TID on the SIMT build; the outer loop
    counter on the sequential scalar build)."""


@dataclass(frozen=True)
class Const(Expr):
    v: int


@dataclass(frozen=True)
class LoopVar(Expr):
    """A reduction loop counter, bound by the enclosing ``Reduce``."""
    uid: int


@dataclass(frozen=True)
class Bin(Expr):
    """Binary ALU op. ``op`` is one of ``BIN_OPS`` (engine ALU names)."""
    op: str
    a: Expr
    b: Expr


@dataclass(frozen=True)
class Load(Expr):
    """``mem[base(array) + idx]`` — the array base offset is resolved by
    the memory layout at lowering time."""
    array: str
    idx: Expr


@dataclass(frozen=True)
class Cond:
    """A branch condition (not first-class — only ``Guard`` consumes it).
    ``op`` in {'lt', 'ge', 'eq', 'ne'}, matching the four ISA branches."""
    op: str
    a: Expr
    b: Expr


@dataclass(frozen=True)
class Guard(Expr):
    """``body if cond else 0`` — compiled as a forward branch (the FGPU
    idiom for boundary conditions), evaluated as a masked select."""
    cond: Cond
    body: Expr


@dataclass(frozen=True)
class Reduce(Expr):
    """``sum(body for var in range(count))`` with int32 wraparound."""
    var: LoopVar
    count: int
    body: Expr


#: ops with a direct ALU opcode; 'slt' is the value-producing compare
BIN_OPS = ("add", "sub", "mul", "div", "rem", "and", "or", "xor",
           "shl", "srl", "sra", "slt")

_loopvar_ids = itertools.count()


def fresh_loopvar() -> LoopVar:
    return LoopVar(next(_loopvar_ids))


def children(e: Expr) -> Tuple[Expr, ...]:
    """The sub-expressions the codegen reads when materializing ``e`` (a
    ``Reduce``'s bound var is not a child — it is defined, not read)."""
    if isinstance(e, Bin):
        return (e.a, e.b)
    if isinstance(e, Load):
        return (e.idx,)
    if isinstance(e, Guard):
        return (e.cond.a, e.cond.b, e.body)
    if isinstance(e, Reduce):
        return (e.body,)
    return ()


# ---------------------------------------------------------------------------
# the NumPy oracle (engine ALU semantics, vectorized over work items)
# ---------------------------------------------------------------------------

_I32 = 1 << 32


def w32(x: np.ndarray) -> np.ndarray:
    """Wrap an int64 value vector to int32 two's-complement range."""
    return ((np.asarray(x, np.int64) + (1 << 31)) % _I32) - (1 << 31)


def wrap32(v: int) -> int:
    """Wrap a Python int to int32 — every ``Const`` must hold an already-
    wrapped value, or folding/strength-reduction would see a number the
    engine's register file cannot (e.g. ``1 << 31`` materializes as
    ``-2**31`` through LUI/ORI)."""
    return int(((int(v) + (1 << 31)) % _I32) - (1 << 31))


def _shift_amount(b):
    return np.clip(b, 0, 31)


def _eval_bin(op: str, a, b):
    if op == "add":
        return w32(a + b)
    if op == "sub":
        return w32(a - b)
    if op == "mul":
        return w32(a * b)
    if op == "div":
        # engine: floor division, div-by-zero -> 0
        safe = np.where(b == 0, 1, b)
        return w32(np.where(b == 0, 0, np.floor_divide(a, safe)))
    if op == "rem":
        safe = np.where(b == 0, 1, b)
        return w32(np.where(b == 0, 0, np.remainder(a, safe)))
    if op == "and":
        return w32(a & b)
    if op == "or":
        return w32(a | b)
    if op == "xor":
        return w32(a ^ b)
    if op == "shl":
        return w32(a << _shift_amount(b))
    if op == "srl":
        return w32((a & 0xFFFFFFFF) >> _shift_amount(b))
    if op == "sra":
        return w32(a >> _shift_amount(b))
    if op == "slt":
        return (np.asarray(a) < b).astype(np.int64)
    raise CompileError(f"unknown binary op {op!r}")


def _eval_cond(c: Cond, item, arrays, loops):
    a = eval_expr(c.a, item, arrays, loops)
    b = eval_expr(c.b, item, arrays, loops)
    if c.op == "lt":
        return a < b
    if c.op == "ge":
        return a >= b
    if c.op == "eq":
        return a == b
    if c.op == "ne":
        return a != b
    raise CompileError(f"unknown condition {c.op!r}")


def eval_expr(e: Expr, item: np.ndarray, arrays: Dict[str, np.ndarray],
              loops: Dict[LoopVar, int]) -> np.ndarray:
    """Evaluate ``e`` for a vector of work-item indices.

    ``item`` is the int64 vector of item indices; ``arrays`` maps input
    names to int64 value vectors (int32-wrapped); ``loops`` binds
    enclosing reduction counters. Out-of-range load indices are clipped to
    the array (a guarded load's discarded lane mirrors the engine's
    address clip)."""
    if isinstance(e, Item):
        return item
    if isinstance(e, Const):
        return np.full_like(item, np.int64(e.v))
    if isinstance(e, LoopVar):
        if e not in loops:
            raise CompileError("loop variable used outside its Reduce")
        return np.full_like(item, np.int64(loops[e]))
    if isinstance(e, Bin):
        return _eval_bin(e.op, eval_expr(e.a, item, arrays, loops),
                         eval_expr(e.b, item, arrays, loops))
    if isinstance(e, Load):
        arr = arrays[e.array]
        idx = eval_expr(e.idx, item, arrays, loops)
        return arr[np.clip(idx, 0, len(arr) - 1)]
    if isinstance(e, Guard):
        mask = _eval_cond(e.cond, item, arrays, loops)
        body = eval_expr(e.body, item, arrays, loops)
        return np.where(mask, body, np.int64(0))
    if isinstance(e, Reduce):
        acc = np.zeros_like(item)
        loops = dict(loops)
        for k in range(e.count):
            loops[e.var] = k
            acc = w32(acc + eval_expr(e.body, item, arrays, loops))
        return acc
    raise CompileError(f"cannot evaluate {type(e).__name__}")


# ---------------------------------------------------------------------------
# kernel container
# ---------------------------------------------------------------------------

@dataclass
class Kernel:
    """A lowered-ready kernel: named input arrays (in memory-layout
    order), the output length, and per-item stores.

    ``stores`` addresses are relative to the output base; every work item
    must write a distinct address (the engine gives no intra-round store
    ordering between lanes)."""
    name: str
    arrays: "Dict[str, int]"                    # name -> length, in order
    out_len: int
    n_items: int
    stores: "List[Tuple[Expr, Expr]]"           # (addr, value) per item

    def layout(self) -> Dict[str, int]:
        """name -> base word offset; inputs first, then the output."""
        off, out = {}, 0
        for name, ln in self.arrays.items():
            off[name] = out
            out += ln
        off["__out__"] = out
        return off

    @property
    def mem_size(self) -> int:
        return sum(self.arrays.values()) + self.out_len
