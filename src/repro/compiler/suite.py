"""DSL re-implementations of the eight hand-written benches.

Each of ``repro.ggpu.programs``' kernels re-derives from a one-line
tensor-DSL definition. The compiled kernels share the hand-written memory
layout (inputs in argument order, then the output region), so a compiled
program runs against the *same* memory image as its hand-written twin and
must produce bit-exact results — ``tests/test_compiler.py`` proves this
and pins golden cycle counts.

Compiled-vs-hand cycle parity (measured, see the golden test):

  * ``copy``, ``vec_mul``, ``div_int``, ``mat_mul``, ``fir``,
    ``reduction``, ``xcorr`` compile to the same instruction sequences as
    the hand-written programs (same per-round ops, same addresses) and
    are cycle-identical;
  * ``parallel_sel`` compiles to a *branch-free* arithmetic rank body
    instead of the hand-written divergent compare chain — more
    instructions per iteration but no wavefront divergence; its cycles
    are pinned as goldens and compared to the hand-written count in the
    test (documented-different, bit-exact results).

``dsl_benches`` returns ``programs.Bench`` records whose programs are the
compiled ones (memory images, references, and slices reused from the
hand-written builders), ready for ``dse.Evaluator(workloads=...)`` and
the serving stack.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.compiler.frontend import compile_kernel, dsl
from repro.compiler.ir import CompileError
from repro.compiler.lower import CompiledKernel, Schedule
from repro.ggpu import programs

KernelDef = Tuple[Callable, Dict[str, object]]


def d_copy(n: int) -> KernelDef:
    return (lambda a: a), dict(a=n)


def d_vec_mul(n: int) -> KernelDef:
    return (lambda a, b: a * b), dict(a=n, b=n)


def d_mat_mul(d: int) -> KernelDef:
    return (lambda a, b: a @ b), dict(a=(d, d), b=(d, d))


def d_fir(n: int, taps: int = 16) -> KernelDef:
    return (lambda x, h: dsl.fir(x, h)), dict(x=n, h=taps)


def d_div_int(n: int) -> KernelDef:
    return (lambda a, b: a // b), dict(a=n, b=n)


def d_xcorr(n: int) -> KernelDef:
    return (lambda a, b: dsl.xcorr(a, b)), dict(a=n, b=n)


def d_parallel_sel(n: int) -> KernelDef:
    return (lambda a: dsl.rank_sort(a)), dict(a=n)


def d_reduction(n: int, seg: int = programs.REDUCTION_SEG) -> KernelDef:
    return (lambda a, b: (a * b).seg_sum(seg)), dict(a=n, b=n)


#: bench name -> (fn, shapes) definition builder, taking the same size
#: arguments as the ``k_<name>`` kernel builders below. The autotuner
#: re-traces these under candidate schedules (`repro.compiler.autotune`).
_DEFS: Dict[str, Callable[..., KernelDef]] = {
    "copy": d_copy,
    "vec_mul": d_vec_mul,
    "mat_mul": d_mat_mul,
    "fir": d_fir,
    "div_int": d_div_int,
    "xcorr": d_xcorr,
    "parallel_sel": d_parallel_sel,
    "reduction": d_reduction,
}


def kernel_def(name: str, *args) -> KernelDef:
    """The traceable ``(fn, shapes)`` definition of a suite bench — the
    re-compilable form a schedule search needs. Resolved through the
    ``BENCHES`` registry axis, so a drop-in plugin bench that registers
    a ``kernel_def`` autotunes exactly like a built-in."""
    from repro.registry import BENCHES
    spec = BENCHES.get(name)
    if spec.kernel_def is None:
        raise KeyError(f"bench {name!r} registers no tensor-DSL "
                       "kernel_def (ISA-only bench)")
    fn, shapes = spec.kernel_def(*args)
    return fn, shapes


def _build(name: str, *args,
           schedule: Optional[Schedule] = None) -> CompiledKernel:
    fn, shapes = kernel_def(name, *args)
    return compile_kernel(fn, shapes, name=name, schedule=schedule)


def k_copy(n: int, **kw) -> CompiledKernel:
    return _build("copy", n, **kw)


def k_vec_mul(n: int, **kw) -> CompiledKernel:
    return _build("vec_mul", n, **kw)


def k_mat_mul(d: int, **kw) -> CompiledKernel:
    return _build("mat_mul", d, **kw)


def k_fir(n: int, taps: int = 16, **kw) -> CompiledKernel:
    return _build("fir", n, taps, **kw)


def k_div_int(n: int, **kw) -> CompiledKernel:
    return _build("div_int", n, **kw)


def k_xcorr(n: int, **kw) -> CompiledKernel:
    return _build("xcorr", n, **kw)


def k_parallel_sel(n: int, **kw) -> CompiledKernel:
    return _build("parallel_sel", n, **kw)


def k_reduction(n: int, seg: int = programs.REDUCTION_SEG,
                **kw) -> CompiledKernel:
    return _build("reduction", n, seg, **kw)


#: bench name -> (gpu-size kernel builder, scalar-size kernel builder)
#: taking the same size arguments as the ``programs._<name>`` builders
_BUILDERS = {
    "copy": k_copy,
    "vec_mul": k_vec_mul,
    "mat_mul": k_mat_mul,
    "fir": k_fir,
    "div_int": k_div_int,
    "xcorr": k_xcorr,
    "parallel_sel": k_parallel_sel,
    "reduction": k_reduction,
}


def suite_names() -> list:
    """The compile-suite membership: every registered bench with a
    tensor-DSL ``kernel_def``, in legacy table order (plugin benches
    join the suite — and its gated parity artifacts — by registering a
    def; ISA-only benches stay engine workloads outside the suite)."""
    from repro.registry import BENCHES
    from repro.registry.benches import ordered_names
    return [n for n in ordered_names()
            if BENCHES.get(n).kernel_def is not None]


def hand_benches(sizes: Optional[Dict[str, Tuple[int, ...]]] = None
                 ) -> Dict[str, "programs.Bench"]:
    """The hand-written benches at the given sizes (one build per name —
    shared by every suite entry point so nothing constructs them twice).
    ``sizes`` maps a name to the ``programs._<name>`` builder's size
    arguments (scalar, gpu[, extra]); defaults are Table III."""
    from repro.registry import BENCHES
    sizes = dict(sizes or {})
    out = {}
    for name in suite_names():
        build = BENCHES.get(name).build
        sz = sizes.get(name)
        out[name] = build(*sz) if sz is not None else build()
    return out


def def_args(name: str, b: "programs.Bench",
             scalar: bool = False) -> Tuple[int, ...]:
    """The ``kernel_def``/``k_<name>`` size arguments matching a built
    hand bench (gpu-size by default, scalar-size with ``scalar=True``)."""
    n = b.scalar_n if scalar else b.gpu_n
    if name == "mat_mul":
        return (int(np.sqrt(n)),)
    if name == "fir":
        return (n, 16)
    if name == "reduction":
        return (n, b.gpu_n // b.gpu_items)
    return (n,)


def compile_pair(name: str, b: "programs.Bench"
                 ) -> Tuple[CompiledKernel, CompiledKernel]:
    """(gpu-size, scalar-size) compiled kernels matching a hand bench."""
    return (_build(name, *def_args(name, b)),
            _build(name, *def_args(name, b, scalar=True)))


def dsl_kernels(sizes: Optional[Dict[str, Tuple[int, ...]]] = None
                ) -> Dict[str, Tuple[CompiledKernel, CompiledKernel]]:
    """Compile all eight benches; returns name -> (gpu-size kernel,
    scalar-size kernel). ``sizes`` as in ``hand_benches``."""
    return {name: compile_pair(name, b)
            for name, b in hand_benches(sizes).items()}


def dsl_benches(sizes: Optional[Dict[str, Tuple[int, ...]]] = None,
                prefix: str = "dsl_",
                hands: Optional[Dict[str, "programs.Bench"]] = None
                ) -> Dict[str, "programs.Bench"]:
    """``programs.Bench`` records with compiled programs in place of the
    hand-written ones. The memory images, output slices, item counts, and
    NumPy references are the hand-written builders' own — the compiled
    layout is verified to coincide. Pass ``hands`` (from
    ``hand_benches``) to reuse already-built benches."""
    out = {}
    for name, b in (hands or hand_benches(sizes)).items():
        kg, ks = compile_pair(name, b)
        if kg.mem_size != b.gpu_mem.shape[0] or kg.n_items != b.gpu_items \
                or kg.out != b.gpu_out:
            raise CompileError(
                f"compiled {name} layout diverges from the hand-written "
                f"bench: mem {kg.mem_size} vs {b.gpu_mem.shape[0]}, "
                f"items {kg.n_items} vs {b.gpu_items}, "
                f"out {kg.out} vs {b.gpu_out}")
        out[prefix + name] = dataclasses.replace(
            b, name=prefix + name, gpu_prog=kg.prog,
            scalar_prog=ks.scalar_prog)
    return out
