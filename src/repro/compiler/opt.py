"""Optimizer: folding smart constructors, strength reduction, CSE/graph
analyses, and the fusion story.

Expressions are built through the smart constructors here, which fold at
construction time:

  * **constant folding** — ``Bin`` of two ``Const``s evaluates through the
    NumPy oracle (so folding is bit-faithful to the engine ALU, including
    wraparound and div-by-zero);
  * **algebraic identities** — ``x+0``, ``x*1``, ``x*0``, ``x<<0``,
    ``x//1``, ``x%1``, and constant canonicalization to the right operand
    of commutative ops (which also flattens ``(x+c1)+c2`` so address
    offsets land in load/store immediates);
  * **strength reduction** — multiply / floor-divide / floor-mod by a
    power-of-two constant become shift / arithmetic-shift / mask. These
    are exact for *all* int32 values (floor semantics match arithmetic
    shift and two's-complement masking), so no sign analysis is needed.

**CSE** falls out of the frozen-dataclass IR: structurally identical
subtrees are equal and hash equal, so ``use_counts`` + the codegen cache
in ``lower`` materialize each distinct subexpression once (e.g. the
``a[i-t]`` index shared by a FIR guard and its load).

**Fusion** happens a level up, by construction: the frontend composes
per-element callables, so an elementwise chain compiles to one load per
input, a straight ALU run, and one store — exactly the straight-line
rounds the engine's fused dispatch (``GGPUConfig.fuse``) retires through
its memory-system-skipping fast path (DESIGN.md §Compiler).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, Iterable, Set

import numpy as np

from repro.compiler.ir import (Bin, CompileError, Cond, Const, Expr, Guard,
                               Reduce, _eval_bin, children, wrap32)


def _as_expr(x) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, (int, np.integer)):
        return Const(wrap32(int(x)))
    raise CompileError(f"expected int or Expr, got {type(x).__name__}")


def _fold(op: str, a: int, b: int) -> Expr:
    return Const(int(_eval_bin(op, np.int64(a), np.int64(b))))


def _log2(v: int):
    if v > 0 and (v & (v - 1)) == 0:
        return v.bit_length() - 1
    return None


def binop(op: str, a, b) -> Expr:
    """Folding constructor for every ALU binary op."""
    a, b = _as_expr(a), _as_expr(b)
    if isinstance(a, Const) and isinstance(b, Const):
        return _fold(op, a.v, b.v)
    # canonicalize constants to the rhs of commutative ops
    if op in ("add", "mul", "and", "or", "xor") and isinstance(a, Const):
        a, b = b, a
    if isinstance(b, Const):
        v = b.v
        if op in ("add", "sub") and v == 0:
            return a
        if op == "mul":
            if v == 0:
                return Const(0)
            if v == 1:
                return a
            k = _log2(v)
            if k is not None:
                return binop("shl", a, Const(k))
        if op == "div":
            if v == 1:
                return a
            k = _log2(v)
            if k is not None:       # floor div == arithmetic shift (all i32)
                return binop("sra", a, Const(k))
        if op == "rem":
            if v == 1:
                return Const(0)
            k = _log2(v)
            if k is not None:       # floor mod == two's-complement mask
                return binop("and", a, Const(v - 1))
        if op in ("shl", "srl", "sra") and v == 0:
            return a
        if op in ("or", "xor") and v == 0:
            return a
        if op == "and" and v == 0:
            return Const(0)
    # (x + c1) + c2 -> x + (c1+c2): keeps address offsets in immediates
    if op in ("add", "sub") and isinstance(b, Const) \
            and isinstance(a, Bin) and a.op == "add" \
            and isinstance(a.b, Const):
        delta = a.b.v + (b.v if op == "add" else -b.v)
        return binop("add", a.a, Const(wrap32(delta)))
    if op not in ("add", "sub", "mul", "div", "rem", "and", "or", "xor",
                  "shl", "srl", "sra", "slt"):
        raise CompileError(f"unknown binary op {op!r}")
    return Bin(op, a, b)


def add(a, b) -> Expr:
    return binop("add", a, b)


def sub(a, b) -> Expr:
    return binop("sub", a, b)


def mul(a, b) -> Expr:
    return binop("mul", a, b)


def div(a, b) -> Expr:
    return binop("div", a, b)


def rem(a, b) -> Expr:
    return binop("rem", a, b)


def lt_val(a, b) -> Expr:
    """0/1 value of ``a < b`` (signed) — the SLT datapath."""
    return binop("slt", a, b)


def ne_val(a, b) -> Expr:
    """0/1 value of ``a != b`` built from XOR + two sign compares."""
    x = binop("xor", a, b)
    return binop("or", lt_val(Const(0), x), lt_val(x, Const(0)))


def eq_val(a, b) -> Expr:
    return binop("xor", ne_val(a, b), Const(1))


def cond(op: str, a, b) -> Cond:
    a, b = _as_expr(a), _as_expr(b)
    if op in ("gt", "le"):          # normalize to the four ISA branches
        op = {"gt": "lt", "le": "ge"}[op]
        a, b = b, a
    if op not in ("lt", "ge", "eq", "ne"):
        raise CompileError(f"unknown condition {op!r}")
    return Cond(op, a, b)


def guard(c: Cond, body) -> Expr:
    body = _as_expr(body)
    if isinstance(c.a, Const) and isinstance(c.b, Const):
        a, b = c.a.v, c.b.v
        taken = {"lt": a < b, "ge": a >= b,
                 "eq": a == b, "ne": a != b}[c.op]
        return body if taken else Const(0)
    if isinstance(body, Const) and body.v == 0:
        return Const(0)
    return Guard(c, body)


def cond_val(c: Cond) -> Expr:
    """The 0/1 *value* of a condition, on the ALU datapath instead of the
    branch unit — the building block of branch-free (select) lowering."""
    if c.op == "lt":
        return lt_val(c.a, c.b)
    if c.op == "ge":
        return binop("xor", lt_val(c.a, c.b), Const(1))
    if c.op == "eq":
        return eq_val(c.a, c.b)
    if c.op == "ne":
        return ne_val(c.a, c.b)
    raise CompileError(f"unknown condition {c.op!r}")


def to_select(e: Expr, memo: Dict[Expr, Expr] = None) -> Expr:
    """Rewrite every ``Guard`` in ``e`` into the branch-free select form
    ``cond_val(c) * body``.  Bit-exact: the oracle evaluates ``Guard`` as a
    masked select whose body always runs (``ir.eval_expr``), and the engine
    ALU has no traps, so multiplying by the 0/1 condition value is the same
    function.  Shared subtrees stay shared through ``memo`` (CSE-preserving),
    and callers may pass one memo across several roots."""
    if memo is None:
        memo = {}
    if e in memo:
        return memo[e]
    if isinstance(e, Guard):
        c = cond(e.cond.op, to_select(e.cond.a, memo), to_select(e.cond.b, memo))
        out = mul(cond_val(c), to_select(e.body, memo))
    elif isinstance(e, Bin):
        out = binop(e.op, to_select(e.a, memo), to_select(e.b, memo))
    elif isinstance(e, Reduce):
        from repro.compiler.ir import Reduce as _R
        body = to_select(e.body, memo)
        out = e if body is e.body else _R(e.var, e.count, body)
    else:
        from repro.compiler.ir import Load
        if isinstance(e, Load):
            idx = to_select(e.idx, memo)
            out = e if idx is e.idx else Load(e.array, idx)
        else:
            out = e                 # Item / Const / LoopVar: leaves
    memo[e] = out
    return out


def reduce_sum(count: int, body_fn) -> Expr:
    """``sum(body_fn(k) for k in range(count))`` as a ``Reduce`` node;
    ``body_fn`` receives the bound ``LoopVar``."""
    from repro.compiler.ir import fresh_loopvar
    if count < 1:
        return Const(0)
    var = fresh_loopvar()
    body = _as_expr(body_fn(var))
    if isinstance(body, Const):     # loop-invariant body folds entirely
        return _fold("mul", body.v, count)
    return Reduce(var, count, body)


# ---------------------------------------------------------------------------
# graph analyses (consumed by the codegen)
# ---------------------------------------------------------------------------

def use_counts(roots: Iterable[Expr]) -> Dict[Expr, int]:
    """Number of *materialization-time reads* of every distinct node in the
    DAG: a shared (structurally equal) subtree is counted once per parent
    reference but its children only once — mirroring the codegen, which
    computes each distinct node into one register and serves later
    references from the cache."""
    counts: Dict[Expr, int] = {}

    def walk(e: Expr):
        counts[e] = counts.get(e, 0) + 1
        if counts[e] > 1:
            return
        for c in children(e):
            walk(c)

    for r in roots:
        walk(r)
    return counts


def contains_vars(e: Expr, vars_: FrozenSet[Expr],
                  memo: Dict[Expr, bool] = None) -> bool:
    """Whether ``e`` reads any of ``vars_`` (``Item`` / ``LoopVar`` nodes)
    — the loop-variance test behind invariant hoisting."""
    if memo is None:
        memo = {}
    if e in memo:
        return memo[e]
    if e in vars_:
        memo[e] = True
        return True
    out = any(contains_vars(c, vars_, memo) for c in children(e))
    memo[e] = out
    return out


def collect_ops(roots: Iterable[Expr]) -> Set[str]:
    """All distinct ``Bin`` op names in the DAG (for tests/diagnostics)."""
    seen: Set[Expr] = set()
    ops: Set[str] = set()

    def walk(e: Expr):
        if e in seen:
            return
        seen.add(e)
        if isinstance(e, Bin):
            ops.add(e.op)
        for c in children(e):
            walk(c)

    for r in roots:
        walk(r)
    return ops
