"""Tensor-expression frontend: ``compile_kernel`` and the ``dsl`` helpers.

A traced, NumPy-flavoured API over the compiler stack::

    from repro.compiler import compile_kernel

    k = compile_kernel(lambda a, b: (a * b).seg_sum(64),
                       dict(a=32768, b=32768))
    out, info = k.run(k.random_inputs(), GGPUConfig(n_cus=4))

The callable is traced once with symbolic ``Tensor`` placeholders (one per
parameter, shapes from the ``shapes`` mapping). A ``Tensor`` is *lazy*: it
carries a shape and a per-element expression builder, so elementwise
chains fuse by construction — no intermediate arrays exist to store
(``repro.compiler.opt`` module doc). The traced result lowers to both
G-GPU program variants via ``repro.compiler.lower``.

Operators: ``+ - * // % & | ^ << >>`` (int32, engine ALU semantics),
``@`` (2-D matmul), ``Tensor.sum() / .seg_sum(seg)``, and the ``dsl``
namespace: ``dot``, ``fir`` (boundary-guarded convolution), ``xcorr``
(circular cross-correlation), ``stencil`` (constant-weight neighborhood
sum), ``rank_sort`` (scatter by rank — a computed store address), and
``wrap`` (circular index arithmetic).

``coarsen=C`` tiles C consecutive output elements onto one work item
(fewer wavefronts, more per-item work) — the workload half of the
CU/wavefront tiling the engine applies to ``n_items``.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.compiler import opt
from repro.compiler.ir import (CompileError, Const, Expr, Item, Kernel,
                               Load, children)
from repro.compiler.ir import wrap32 as ir_wrap32
from repro.compiler.lower import (DEFAULT_SCHEDULE, CompiledKernel, Schedule,
                                  lower_kernel)

Shape = Tuple[int, ...]


def _norm_shape(s) -> Shape:
    if isinstance(s, (int, np.integer)):
        return (int(s),)
    s = tuple(int(x) for x in s)
    if not s or any(x < 1 for x in s) or len(s) > 2:
        raise CompileError(f"unsupported shape {s}: need 1-D or 2-D, "
                           "positive dims")
    return s


def _size(s: Shape) -> int:
    n = 1
    for x in s:
        n *= x
    return n


class Tensor:
    """A lazy int32 tensor: shape + per-element expression builder (row-
    major linear index -> value expression)."""

    def __init__(self, shape: Shape, elem: Callable[[Expr], Expr]):
        self.shape = _norm_shape(shape)
        self.elem = elem

    @property
    def size(self) -> int:
        return _size(self.shape)

    # -- elementwise --------------------------------------------------------

    def _binary(self, other, op: str, rev: bool = False) -> "Tensor":
        if isinstance(other, (int, np.integer)):
            v = ir_wrap32(int(other))
            other = Tensor(self.shape, lambda i, _v=v: Const(_v))
        if not isinstance(other, Tensor):
            return NotImplemented
        if other.shape != self.shape:
            raise CompileError(f"shape mismatch: {self.shape} vs "
                               f"{other.shape} for {op!r}")
        a, b = (other, self) if rev else (self, other)
        return Tensor(self.shape,
                      lambda i: opt.binop(op, a.elem(i), b.elem(i)))

    def __add__(self, o):
        return self._binary(o, "add")

    def __radd__(self, o):
        return self._binary(o, "add", rev=True)

    def __sub__(self, o):
        return self._binary(o, "sub")

    def __rsub__(self, o):
        return self._binary(o, "sub", rev=True)

    def __mul__(self, o):
        return self._binary(o, "mul")

    def __rmul__(self, o):
        return self._binary(o, "mul", rev=True)

    def __floordiv__(self, o):
        return self._binary(o, "div")

    def __rfloordiv__(self, o):
        return self._binary(o, "div", rev=True)

    def __mod__(self, o):
        return self._binary(o, "rem")

    def __rmod__(self, o):
        return self._binary(o, "rem", rev=True)

    def __and__(self, o):
        return self._binary(o, "and")

    def __rand__(self, o):
        return self._binary(o, "and", rev=True)

    def __or__(self, o):
        return self._binary(o, "or")

    def __ror__(self, o):
        return self._binary(o, "or", rev=True)

    def __xor__(self, o):
        return self._binary(o, "xor")

    def __rxor__(self, o):
        return self._binary(o, "xor", rev=True)

    def __lshift__(self, o):
        return self._binary(o, "shl")

    def __rlshift__(self, o):
        return self._binary(o, "shl", rev=True)

    def __rshift__(self, o):
        return self._binary(o, "sra")

    def __rrshift__(self, o):
        return self._binary(o, "sra", rev=True)

    def __lt__(self, o):
        return self._binary(o, "slt")

    def __gt__(self, o):
        return self._binary(o, "slt", rev=True)

    def __neg__(self):
        return Tensor(self.shape,
                      lambda i: opt.sub(Const(0), self.elem(i)))

    # -- reductions ---------------------------------------------------------

    def seg_sum(self, seg: int) -> "Tensor":
        """Segmented sum: output ``i`` is the int32 sum of the ``seg``-long
        input segment ``[i*seg, (i+1)*seg)``."""
        n = self.size
        if seg < 1 or n % seg:
            raise CompileError(
                f"seg_sum: segment {seg} must divide the size {n}")
        return Tensor((n // seg,), lambda i: opt.reduce_sum(
            seg, lambda k: self.elem(opt.add(opt.mul(i, seg), k))))

    def sum(self) -> "Tensor":
        """Full reduction to one element."""
        return self.seg_sum(self.size)

    # -- matmul -------------------------------------------------------------

    def __matmul__(self, other: "Tensor") -> "Tensor":
        if not isinstance(other, Tensor):
            return NotImplemented
        if len(self.shape) != 2 or len(other.shape) != 2 \
                or self.shape[1] != other.shape[0]:
            raise CompileError(f"matmul shapes {self.shape} @ "
                               f"{other.shape} do not agree")
        m, kk = self.shape
        _, n = other.shape

        def elem(i: Expr) -> Expr:
            row = opt.div(i, n)
            col = opt.rem(i, n)
            return opt.reduce_sum(kk, lambda t: opt.mul(
                self.elem(opt.add(opt.mul(row, kk), t)),
                other.elem(opt.add(opt.mul(t, n), col))))

        return Tensor((m, n), elem)


class ScatterTensor:
    """A kernel result whose store *address* is computed per item (e.g.
    rank sort). ``addr``/``val`` map the item index expression to the
    output address (relative to the output base) and stored value."""

    def __init__(self, out_len: int, addr: Callable[[Expr], Expr],
                 val: Callable[[Expr], Expr]):
        self.out_len = out_len
        self.addr = addr
        self.val = val


# ---------------------------------------------------------------------------
# dsl namespace
# ---------------------------------------------------------------------------

class dsl:
    """Structured operators beyond the elementwise/NumPy surface."""

    @staticmethod
    def dot(a: Tensor, b: Tensor) -> Tensor:
        return (a * b).sum()

    @staticmethod
    def wrap(idx: Expr, n: int) -> Expr:
        """Circular index: ``idx - n if idx >= n else idx`` (for
        ``idx < 2n``) — compiles to the conditional-subtract idiom."""
        return opt.sub(idx, opt.guard(opt.cond("ge", idx, Const(n)),
                                      Const(n)))

    @staticmethod
    def fir(x: Tensor, h: Tensor) -> Tensor:
        """Boundary-guarded FIR filter: ``out[i] = sum_t h[t]*x[i-t]``
        for ``i - t >= 0``."""
        taps = h.size

        def elem(i: Expr) -> Expr:
            def term(t):
                j = opt.sub(i, t)
                return opt.guard(
                    opt.cond("ge", j, Const(0)),
                    opt.mul(x.elem(j), h.elem(t)))
            return opt.reduce_sum(taps, term)

        return Tensor(x.shape, elem)

    @staticmethod
    def xcorr(a: Tensor, b: Tensor) -> Tensor:
        """Circular cross-correlation:
        ``out[lag] = sum_i a[i]*b[(i+lag) mod n]``."""
        n = a.size
        if b.size != n:
            raise CompileError("xcorr operands must share a size")

        def elem(lag: Expr) -> Expr:
            return opt.reduce_sum(n, lambda i: opt.mul(
                a.elem(i), b.elem(dsl.wrap(opt.add(i, lag), n))))

        return Tensor(a.shape, elem)

    @staticmethod
    def stencil(x: Tensor, weights: Sequence[int],
                offsets: Sequence[int]) -> Tensor:
        """Constant-weight neighborhood sum with zero boundary:
        ``out[i] = sum_k w[k] * x[i + off[k]]`` for in-range indices."""
        if len(weights) != len(offsets):
            raise CompileError("stencil needs one weight per offset")
        n = x.size

        def elem(i: Expr) -> Expr:
            acc: Expr = Const(0)
            for w, off in zip(weights, offsets):
                if w == 0:
                    continue
                j = opt.add(i, Const(ir_wrap32(int(off))))
                term = opt.mul(x.elem(j), Const(ir_wrap32(int(w))))
                if off < 0:
                    term = opt.guard(opt.cond("ge", j, Const(0)), term)
                elif off > 0:
                    term = opt.guard(opt.cond("lt", j, Const(n)), term)
                acc = opt.add(acc, term)
            return acc

        return Tensor(x.shape, elem)

    @staticmethod
    def rank_sort(a: Tensor) -> ScatterTensor:
        """Stable rank sort (the paper's ``parallel_sel``): item ``i``
        stores ``a[i]`` at its rank — ``#{j : a[j] < a[i]}`` plus the tie
        count ``#{j < i : a[j] == a[i]}``. Branch-free arithmetic body
        (no wavefront divergence), scatter store."""
        n = a.size

        def addr(i: Expr) -> Expr:
            v = a.elem(i)

            def term(j):
                aj = a.elem(j)
                below = opt.lt_val(aj, v)
                # eq from the compares already in flight (CSE shares
                # ``below``): eq = !(aj<v | v<aj)
                eq = opt.binop(
                    "xor",
                    opt.binop("or", below, opt.lt_val(v, aj)), Const(1))
                return opt.add(below,
                               opt.binop("and", eq, opt.lt_val(j, i)))

            return opt.reduce_sum(n, term)

        return ScatterTensor(n, addr, lambda i: a.elem(i))


# ---------------------------------------------------------------------------
# compile_kernel
# ---------------------------------------------------------------------------

def compile_kernel(fn: Callable, shapes: Union[Dict[str, object],
                                               Sequence[object]],
                   name: Optional[str] = None,
                   coarsen: int = 1,
                   schedule: Optional[Schedule] = None) -> CompiledKernel:
    """Trace ``fn`` over symbolic tensors and lower to G-GPU programs.

    ``shapes`` maps the callable's parameter names to int / (rows, cols)
    shapes (a sequence is matched positionally). ``coarsen`` folds that
    many consecutive output elements into each work item.

    ``schedule`` selects the full lowering schedule (coarsening plus the
    hoist / branchy / peel codegen knobs — see ``repro.compiler.lower.
    Schedule`` and the autotuner in ``repro.compiler.autotune``). When
    given, its ``coarsen`` field is authoritative and the legacy
    ``coarsen`` argument must agree or stay at its default."""
    if schedule is None:
        schedule = Schedule(coarsen=coarsen)
    elif coarsen != 1 and coarsen != schedule.coarsen:
        raise CompileError(
            f"coarsen={coarsen} conflicts with schedule {schedule.label()}")
    coarsen = schedule.coarsen
    params = list(inspect.signature(fn).parameters)
    if isinstance(shapes, dict):
        missing = [p for p in params if p not in shapes]
        if missing:
            raise CompileError(f"no shape given for parameters {missing}")
        shape_list = [shapes[p] for p in params]
    else:
        if len(shapes) != len(params):
            raise CompileError(f"{len(params)} parameters but "
                               f"{len(shapes)} shapes")
        shape_list = list(shapes)

    arrays: Dict[str, int] = {}
    placeholders: List[Tensor] = []
    for p, s in zip(params, shape_list):
        shape = _norm_shape(s)
        arrays[p] = _size(shape)
        placeholders.append(
            Tensor(shape, lambda i, _p=p: Load(_p, i)))

    out = fn(*placeholders)
    if isinstance(out, Tensor):
        out = ScatterTensor(out.size, lambda i: i, out.elem)
    if not isinstance(out, ScatterTensor):
        raise CompileError(
            f"kernel must return a Tensor or ScatterTensor, got "
            f"{type(out).__name__}")

    if coarsen < 1 or out.out_len % coarsen:
        raise CompileError(
            f"coarsen={coarsen} must divide the output length "
            f"{out.out_len}")
    stores = []
    item = Item()
    for t in range(coarsen):
        idx = opt.add(opt.mul(item, coarsen), t)
        stores.append((out.addr(idx), out.val(idx)))

    kernel = Kernel(
        name=name or getattr(fn, "__name__", "kernel").replace(
            "<lambda>", "kernel"),
        arrays=arrays, out_len=out.out_len,
        n_items=out.out_len // coarsen, stores=stores)
    return lower_kernel(kernel, schedule)


# ---------------------------------------------------------------------------
# compile_graph: split one traced expression into a multi-kernel Program
# ---------------------------------------------------------------------------

class _GraphBuilder:
    """Trace-time stage accumulator for ``compile_graph``: each
    materialization appends one stage (a tensor whose elements land in a
    named virtual buffer earlier stages and graph inputs feed)."""

    def __init__(self):
        # (buffer name, the tensor/scatter whose elements fill it)
        self.stages: List[Tuple[str, object]] = []

    @staticmethod
    def buffer_name(idx: int) -> str:
        # the leading dot keeps generated names out of the identifier
        # space, so they can never collide with a graph parameter
        return f".s{idx}"

    def materialize(self, t: "GraphTensor") -> "GraphTensor":
        """Cut here: record ``t`` as a stage and return the tensor that
        reads the stage's output buffer."""
        if t.buffer is not None:
            return t
        buf = self.buffer_name(len(self.stages))
        self.stages.append((buf, t))
        return GraphTensor(t.shape, lambda i, _b=buf: Load(_b, i),
                           self, buffer=buf)


class GraphTensor(Tensor):
    """A ``Tensor`` that records *stage cuts* while tracing a graph:
    a reduction (``seg_sum``/``sum``/``@``) materializes its fused
    elementwise operands as map stages, and any further use of a reduced
    expression materializes the reduction itself — so one traced
    expression splits into a pipeline of individually-lowerable kernels
    at exactly the reduction boundaries. ``buffer`` names the virtual
    array this tensor *is* (a graph input or a stage output); ``None``
    means a fused, not-yet-materialized expression. Plain ``Tensor``
    operands (e.g. from ``dsl`` helpers) fuse into the consuming stage
    without extra cuts."""

    def __init__(self, shape: Shape, elem: Callable[[Expr], Expr],
                 builder: _GraphBuilder, has_reduce: bool = False,
                 buffer: Optional[str] = None):
        super().__init__(shape, elem)
        self.builder = builder
        self.has_reduce = has_reduce
        self.buffer = buffer

    def _lift(self, other):
        if isinstance(other, (int, np.integer)):
            v = ir_wrap32(int(other))
            return GraphTensor(self.shape, lambda i, _v=v: Const(_v),
                               self.builder)
        if isinstance(other, GraphTensor) and other.has_reduce:
            return self.builder.materialize(other)
        return other

    def _binary(self, other, op: str, rev: bool = False):
        me = (self.builder.materialize(self) if self.has_reduce else self)
        other = me._lift(other)
        if not isinstance(other, Tensor):
            return NotImplemented
        if other.shape != me.shape:
            raise CompileError(f"shape mismatch: {me.shape} vs "
                               f"{other.shape} for {op!r}")
        a, b = (other, me) if rev else (me, other)
        return GraphTensor(me.shape,
                           lambda i: opt.binop(op, a.elem(i), b.elem(i)),
                           self.builder)

    def __neg__(self):
        me = (self.builder.materialize(self) if self.has_reduce else self)
        return GraphTensor(me.shape,
                           lambda i: opt.sub(Const(0), me.elem(i)),
                           self.builder)

    def seg_sum(self, seg: int) -> "GraphTensor":
        n = self.size
        if seg < 1 or n % seg:
            raise CompileError(
                f"seg_sum: segment {seg} must divide the size {n}")
        src = self if self.buffer is not None \
            else self.builder.materialize(self)
        return GraphTensor((n // seg,), lambda i: opt.reduce_sum(
            seg, lambda k: src.elem(opt.add(opt.mul(i, seg), k))),
            self.builder, has_reduce=True)

    def __matmul__(self, other):
        if not isinstance(other, Tensor):
            return NotImplemented
        if len(self.shape) != 2 or len(other.shape) != 2 \
                or self.shape[1] != other.shape[0]:
            raise CompileError(f"matmul shapes {self.shape} @ "
                               f"{other.shape} do not agree")
        a = self if self.buffer is not None \
            else self.builder.materialize(self)
        b = other
        if isinstance(b, GraphTensor) and b.buffer is None:
            b = self.builder.materialize(b)
        m, kk = a.shape
        _, n = b.shape

        def elem(i: Expr) -> Expr:
            row = opt.div(i, n)
            col = opt.rem(i, n)
            return opt.reduce_sum(kk, lambda t: opt.mul(
                a.elem(opt.add(opt.mul(row, kk), t)),
                b.elem(opt.add(opt.mul(t, n), col))))

        return GraphTensor((m, n), elem, self.builder, has_reduce=True)


def _load_names(stores) -> set:
    """All array names a stage's store expressions read."""
    seen: set = set()
    names: set = set()
    work = [e for pair in stores for e in pair]
    while work:
        e = work.pop()
        if id(e) in seen:
            continue
        seen.add(id(e))
        if isinstance(e, Load):
            names.add(e.array)
        work.extend(children(e))
    return names


def _stage_schedule(schedules, idx: int) -> Schedule:
    if schedules is None:
        return DEFAULT_SCHEDULE
    if isinstance(schedules, dict):
        s = schedules.get(idx)
    else:
        s = schedules[idx] if idx < len(schedules) else None
    return s if s is not None else DEFAULT_SCHEDULE


@dataclasses.dataclass
class Program:
    """A compiled multi-kernel graph: ``stages`` in topological order and
    the wiring of each stage's input arrays to graph inputs or earlier
    stages' outputs (``sources[idx][array] = ("input", name) |
    ("stage", j)``). Stage ``idx`` writes the virtual buffer ``.s{idx}``;
    the last stage's output is the graph's."""
    name: str
    stages: List[CompiledKernel]
    sources: List[Dict[str, Tuple[str, object]]]
    in_sizes: Dict[str, int]

    @property
    def out_len(self) -> int:
        return self.stages[-1].kernel.out_len

    def _stage_inputs(self, idx: int, inputs: Dict[str, np.ndarray],
                      outs: Dict[int, np.ndarray]) -> Dict[str, np.ndarray]:
        return {arr: (inputs[ref] if kind == "input" else outs[ref])
                for arr, (kind, ref) in self.sources[idx].items()}

    def reference(self, inputs) -> np.ndarray:
        """The graph's expected output: each stage's NumPy oracle chained
        through the stage wiring — the bit-exactness target for every
        execution strategy (host-staged or device-resident)."""
        inputs = {n: np.asarray(v, np.int32).reshape(-1)
                  for n, v in dict(inputs).items()}
        missing = set(self.in_sizes) - set(inputs)
        if missing:
            raise CompileError(f"missing inputs: {sorted(missing)}")
        outs: Dict[int, np.ndarray] = {}
        val = None
        for idx, ck in enumerate(self.stages):
            val = np.asarray(
                ck.reference(self._stage_inputs(idx, inputs, outs)),
                np.int32)
            outs[idx] = val
        return val

    def run_host(self, inputs, cfg) -> np.ndarray:
        """Execute stage-by-stage on the engine with host-staged chaining
        (download each stage's full output, re-stage it into the next
        stage's memory image) — the independently-run-stages baseline the
        device-resident serving path must match bit-exactly."""
        inputs = {n: np.asarray(v, np.int32).reshape(-1)
                  for n, v in dict(inputs).items()}
        outs: Dict[int, np.ndarray] = {}
        val = None
        for idx, ck in enumerate(self.stages):
            val, _ = ck.run(self._stage_inputs(idx, inputs, outs), cfg)
            outs[idx] = val
        return val

    def random_inputs(self, lo: int = -100, hi: int = 100,
                      seed: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(seed)
        return {n: rng.integers(lo, hi, ln).astype(np.int32)
                for n, ln in self.in_sizes.items()}


def compile_graph(fn: Callable, shapes: Union[Dict[str, object],
                                              Sequence[object]],
                  name: Optional[str] = None,
                  schedules: Union[Dict[int, Schedule],
                                   Sequence[Optional[Schedule]],
                                   None] = None) -> Program:
    """Trace ``fn`` and split it at reduction boundaries into a
    multi-kernel ``Program`` graph.

    Where ``compile_kernel`` fuses everything into one kernel,
    ``compile_graph`` cuts the traced expression wherever a reduction
    consumes a fused elementwise chain (the chain becomes a *map* stage)
    and wherever a reduced expression is consumed further (the reduction
    becomes its own stage) — e.g. ``(a * b).seg_sum(64) * k`` compiles to
    a map → reduce → scale pipeline of three kernels. Each stage is an
    ordinary ``CompiledKernel``, individually autotunable: ``schedules``
    maps stage index → ``Schedule`` (dict or sequence; missing entries
    lower with the default schedule). An expression with no reduction
    compiles to a single-stage program identical to ``compile_kernel``.
    The serving layer executes programs with device-resident inter-stage
    chaining (``repro.serve.graphs.submit_program``)."""
    params = list(inspect.signature(fn).parameters)
    if isinstance(shapes, dict):
        missing = [p for p in params if p not in shapes]
        if missing:
            raise CompileError(f"no shape given for parameters {missing}")
        shape_list = [shapes[p] for p in params]
    else:
        if len(shapes) != len(params):
            raise CompileError(f"{len(params)} parameters but "
                               f"{len(shapes)} shapes")
        shape_list = list(shapes)

    builder = _GraphBuilder()
    sizes: Dict[str, int] = {}
    placeholders: List[GraphTensor] = []
    for p, s in zip(params, shape_list):
        shape = _norm_shape(s)
        sizes[p] = _size(shape)
        placeholders.append(
            GraphTensor(shape, lambda i, _p=p: Load(_p, i), builder,
                        buffer=p))

    out = fn(*placeholders)
    gname = name or getattr(fn, "__name__", "graph").replace(
        "<lambda>", "graph")
    if isinstance(out, ScatterTensor):
        builder.stages.append(
            (builder.buffer_name(len(builder.stages)), out))
    elif isinstance(out, Tensor):
        if not (isinstance(out, GraphTensor) and builder.stages
                and out.buffer == builder.stages[-1][0]):
            # the result is not already the last stage's buffer:
            # materialize it as the final stage (covers fused
            # expressions, identity of an input, and plain Tensors
            # produced by dsl helpers)
            builder.stages.append(
                (builder.buffer_name(len(builder.stages)), out))
    else:
        raise CompileError(
            f"graph must return a Tensor or ScatterTensor, got "
            f"{type(out).__name__}")

    stage_sizes: Dict[str, int] = {}
    stages: List[CompiledKernel] = []
    sources: List[Dict[str, Tuple[str, object]]] = []
    for idx, (buf, t) in enumerate(builder.stages):
        sched = _stage_schedule(schedules, idx)
        coarsen = sched.coarsen
        if isinstance(t, ScatterTensor):
            out_len, addr, val = t.out_len, t.addr, t.val
        else:
            out_len, addr, val = t.size, (lambda i: i), t.elem
        if coarsen < 1 or out_len % coarsen:
            raise CompileError(
                f"stage {idx}: coarsen={coarsen} must divide the stage "
                f"output length {out_len}")
        stores = []
        item = Item()
        for c in range(coarsen):
            ie = opt.add(opt.mul(item, coarsen), c)
            stores.append((addr(ie), val(ie)))
        reads = _load_names(stores)
        arrays: Dict[str, int] = {}
        srcs: Dict[str, Tuple[str, object]] = {}
        for p in params:                       # inputs in signature order
            if p in reads:
                arrays[p] = sizes[p]
                srcs[p] = ("input", p)
        for j in range(idx):                   # then stage feeds by index
            bn = builder.stages[j][0]
            if bn in reads:
                arrays[bn] = stage_sizes[bn]
                srcs[bn] = ("stage", j)
        unknown = reads - set(arrays)
        if unknown:
            raise CompileError(
                f"stage {idx} reads unknown arrays {sorted(unknown)}")
        kernel = Kernel(name=f"{gname}_s{idx}", arrays=arrays,
                        out_len=out_len, n_items=out_len // coarsen,
                        stores=stores)
        stages.append(lower_kernel(kernel, sched))
        sources.append(srcs)
        stage_sizes[buf] = out_len
    if isinstance(schedules, dict):
        bad = [k for k in schedules if not 0 <= k < len(stages)]
        if bad:
            raise CompileError(f"schedules for nonexistent stages {bad} "
                               f"(program has {len(stages)})")
    return Program(gname, stages, sources, sizes)
