"""Kernel compiler front-end: tensor-expression DSL -> G-GPU programs.

The workload-side generator that pairs with the hardware-side GPUPlanner
(the paper's "fully-automated" loop closed on both ends): a small traced
tensor DSL (``frontend``) over a per-item scalar expression IR (``ir``),
folded/strength-reduced/CSE'd (``opt``) and lowered to both the SIMT and
sequential-scalar ISA programs (``lower``) under a parameterized
``Schedule`` (coarsening, hoisting, branch idiom, const peeling). Every
compiled kernel is differentially verifiable against a NumPy oracle with
exact engine ALU semantics, ``suite`` re-derives all eight hand-written
benches from one-line DSL definitions, and ``autotune`` searches the
schedule space per kernel — or jointly with the hardware design space
(``codesign``) — costed in true cycles through ``dse.Evaluator``
(DESIGN.md §Compiler, §Autotuner).
"""
from repro.compiler.autotune import (DEFAULT_SPACE, SMOKE_SPACE,
                                     AutotuneResult, CodesignResult,
                                     ScheduleSpace, autotune,
                                     autotune_suite, codesign)
from repro.compiler.frontend import (GraphTensor, Program, ScatterTensor,
                                     Tensor, compile_graph, compile_kernel,
                                     dsl)
from repro.compiler.ir import CompileError
from repro.compiler.lower import DEFAULT_SCHEDULE, CompiledKernel, Schedule
from repro.compiler.suite import (compile_pair, def_args, dsl_benches,
                                  dsl_kernels, hand_benches, kernel_def)

__all__ = [
    "compile_kernel", "compile_graph", "Program", "GraphTensor",
    "dsl", "Tensor", "ScatterTensor",
    "CompiledKernel", "CompileError", "dsl_benches", "dsl_kernels",
    "hand_benches", "compile_pair", "kernel_def", "def_args",
    "Schedule", "DEFAULT_SCHEDULE", "ScheduleSpace", "DEFAULT_SPACE",
    "SMOKE_SPACE", "autotune", "autotune_suite", "AutotuneResult",
    "codesign", "CodesignResult",
]
