"""Kernel compiler front-end: tensor-expression DSL -> G-GPU programs.

The workload-side generator that pairs with the hardware-side GPUPlanner
(the paper's "fully-automated" loop closed on both ends): a small traced
tensor DSL (``frontend``) over a per-item scalar expression IR (``ir``),
folded/strength-reduced/CSE'd (``opt``) and lowered to both the SIMT and
sequential-scalar ISA programs (``lower``). Every compiled kernel is
differentially verifiable against a NumPy oracle with exact engine ALU
semantics, and ``suite`` re-derives all eight hand-written benches from
one-line DSL definitions so ``dse.search``, ``serve.Fleet``, and the
benchmarks can sweep generated workloads instead of a fixed list
(DESIGN.md §Compiler).
"""
from repro.compiler.frontend import (ScatterTensor, Tensor, compile_kernel,
                                     dsl)
from repro.compiler.ir import CompileError
from repro.compiler.lower import CompiledKernel
from repro.compiler.suite import (compile_pair, dsl_benches, dsl_kernels,
                                  hand_benches)

__all__ = [
    "compile_kernel", "dsl", "Tensor", "ScatterTensor",
    "CompiledKernel", "CompileError", "dsl_benches", "dsl_kernels",
    "hand_benches", "compile_pair",
]
