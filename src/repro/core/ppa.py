"""G-GPU PPA estimator: memory inventory + logic model -> Table I.

The baseline inventory mirrors FGPU's memory map (register files, CV
scratchpads, instruction memory and wavefront state per CU; the central
multi-port data cache, tag store, RTM and data-mover FIFOs in the memory
controller; AXI/control buffers at top). Counts are chosen to reproduce the
paper's #Memory column (42 blocks per CU + 9 fixed at the 500 MHz baseline).

Logic (FF/comb) counts and areas are linear-in-CU fits to Table I — the
paper itself reports area "grows linearly with the number of CUs".
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.sram import Macro, divided_path_delay

# ---------------------------------------------------------------------------
# baseline inventory (per the FGPU architecture; counts match Table I's 51
# blocks at 1 CU: 42 per-CU + 9 fixed)
# ---------------------------------------------------------------------------

def baseline_inventory() -> List[Macro]:
    return [
        # --- per CU (42 blocks) ---
        Macro("rf_bank", 4096, 32, count=2, zone="cu"),         # register file
        Macro("cv_scratch", 2048, 32, count=8, zone="cu"),      # CV scratchpads
        Macro("instr_mem", 4096, 32, count=2, zone="cu"),
        Macro("wf_state", 512, 64, count=8, zone="cu"),         # scheduler state
        Macro("lsu_fifo", 256, 64, count=8, zone="cu"),         # LSU queues
        # --- memory controller (fixed, 6 blocks) ---
        Macro("dcache_data", 2048, 64, count=2, zone="ctrl", per_cu=False),
        Macro("dcache_tag", 1024, 24, count=2, zone="ctrl", per_cu=False),
        Macro("rtm", 1024, 32, count=2, zone="ctrl", per_cu=False),
        # --- top (3 blocks) ---
        Macro("axi_buf", 512, 64, count=3, zone="top", per_cu=False),
    ]


# --- logic model: linear fits to Table I -----------------------------------
FF_PER_CU, FF_FIXED = 104_617, 15_161          # 119778 @1CU, 852094-ish @8
COMB_PER_CU, COMB_FIXED = 83_776, 44_050
LOGIC_AREA_PER_CU_MM2, LOGIC_AREA_FIXED_MM2 = 1.23, 0.28
LOGIC_LEAK_PER_CU_MW, LOGIC_LEAK_FIXED_MW = 0.05, 0.05
LOGIC_DYN_W_PER_CU_GHZ = 3.25                  # dynamic logic power / CU / GHz
LOGIC_DYN_W_FIXED_GHZ = 0.70

# logic critical path (pipelineable); the paper pipelines "on demand"
LOGIC_PATH_NS = 1.82
PIPELINE_GAIN = 0.82          # one stage removes ~18% of the path
PIPELINE_FF_COST = 260        # registers per inserted stage
# top-level interconnect (CU <-> memory controller). NOT pipelineable (the
# paper tried and failed — Section IV); QUADRATIC in CU count: the span of
# the floorplan grows ~linearly with CUs and unbuffered RC wire delay grows
# with length^2 (this reproduces the paper's 8CU@667 -> 600 MHz derate
# while 4CU@667 still closes).
IC_BASE_NS = 1.43
IC_QUAD_NS = 0.0048


@dataclass
class GGPUVersion:
    n_cus: int
    freq_mhz: float
    inventory: List[Macro]
    pipelines: int = 0

    # --- timing ---
    def mem_path_ns(self) -> float:
        return max(divided_path_delay(m) for m in self.inventory)

    def critical_memory(self) -> Macro:
        return max(self.inventory, key=divided_path_delay)

    def logic_path_ns(self) -> float:
        return LOGIC_PATH_NS * (PIPELINE_GAIN ** self.pipelines)

    def interconnect_ns(self) -> float:
        return IC_BASE_NS + IC_QUAD_NS * (self.n_cus - 1) ** 2

    def paths(self) -> Dict[str, float]:
        return {"memory": self.mem_path_ns(), "logic": self.logic_path_ns(),
                "interconnect": self.interconnect_ns()}

    def fmax_mhz(self) -> float:
        return 1000.0 / max(self.paths().values())

    def layout_fmax_mhz(self) -> float:
        """Post-layout fmax: same model (interconnect already included);
        kept separate for reporting symmetry with the paper's flow."""
        return self.fmax_mhz()

    # --- area / power / counts ---
    def _n_inst(self, m: Macro) -> int:
        return m.count * (self.n_cus if m.per_cu else 1)

    def n_memories(self) -> int:
        return sum(self._n_inst(m) for m in self.inventory)

    def memory_area_mm2(self) -> float:
        return sum(m.area_mm2() * (self.n_cus if m.per_cu else 1)
                   for m in self.inventory)

    def logic_area_mm2(self) -> float:
        return (LOGIC_AREA_FIXED_MM2 + LOGIC_AREA_PER_CU_MM2 * self.n_cus
                + self.pipelines * PIPELINE_FF_COST * 4e-6)

    def total_area_mm2(self) -> float:
        return self.memory_area_mm2() + self.logic_area_mm2()

    def n_ff(self) -> int:
        return int(FF_FIXED + FF_PER_CU * self.n_cus
                   + self.pipelines * PIPELINE_FF_COST)

    def n_comb(self) -> int:
        extra_mux = sum(m.divided * self._n_inst(m) for m in self.inventory)
        return int(COMB_FIXED + COMB_PER_CU * self.n_cus + 64 * extra_mux)

    def leakage_mw(self) -> float:
        mem = sum(m.leakage_mw() * (self.n_cus if m.per_cu else 1)
                  for m in self.inventory)
        return mem + LOGIC_LEAK_FIXED_MW + LOGIC_LEAK_PER_CU_MW * self.n_cus

    def dynamic_w(self) -> float:
        ghz = self.freq_mhz / 1000.0
        mem = sum(m.dynamic_mw(self.freq_mhz) * (self.n_cus if m.per_cu else 1)
                  for m in self.inventory) / 1000.0
        logic = (LOGIC_DYN_W_FIXED_GHZ + LOGIC_DYN_W_PER_CU_GHZ * self.n_cus) * ghz
        return mem + logic

    def total_w(self) -> float:
        return self.leakage_mw() / 1000.0 + self.dynamic_w()

    def report(self) -> Dict:
        return {
            "n_cus": self.n_cus, "freq_mhz": self.freq_mhz,
            "total_area_mm2": round(self.total_area_mm2(), 2),
            "memory_area_mm2": round(self.memory_area_mm2(), 2),
            "n_ff": self.n_ff(), "n_comb": self.n_comb(),
            "n_memory": self.n_memories(),
            "leakage_mw": round(self.leakage_mw(), 2),
            "dynamic_w": round(self.dynamic_w(), 2),
            "total_w": round(self.total_w(), 2),
            "fmax_mhz": round(self.fmax_mhz(), 1),
            "pipelines": self.pipelines,
        }


# Table I, for calibration-error reporting in the benchmarks
PAPER_TABLE1 = {
    (1, 500): dict(area=4.19, mem_area=2.68, ff=119778, comb=127826, mem=51,
                   leak=4.62, dyn=1.97, total=2.055),
    (2, 500): dict(area=7.45, mem_area=4.64, ff=229171, comb=214243, mem=93,
                   leak=8.54, dyn=3.63, total=3.77),
    (4, 500): dict(area=13.84, mem_area=8.56, ff=437318, comb=387246, mem=177,
                   leak=16.07, dyn=6.88, total=7.14),
    (8, 500): dict(area=26.51, mem_area=16.39, ff=852094, comb=714256, mem=345,
                   leak=30.79, dyn=13.33, total=13.86),
    (1, 590): dict(area=4.66, mem_area=3.15, ff=120035, comb=128894, mem=68,
                   leak=4.73, dyn=2.57, total=2.66),
    (2, 590): dict(area=8.16, mem_area=5.34, ff=229172, comb=221946, mem=120,
                   leak=8.73, dyn=4.63, total=4.81),
    (4, 590): dict(area=15.03, mem_area=9.72, ff=436807, comb=397995, mem=224,
                   leak=16.41, dyn=8.70, total=9.02),
    (8, 590): dict(area=28.65, mem_area=18.49, ff=850559, comb=737232, mem=432,
                   leak=31.25, dyn=16.81, total=17.40),
    (1, 667): dict(area=4.77, mem_area=3.26, ff=120035, comb=130802, mem=71,
                   leak=4.65, dyn=2.62, total=2.72),
    (2, 667): dict(area=8.27, mem_area=5.45, ff=229172, comb=222028, mem=123,
                   leak=8.72, dyn=4.69, total=4.87),
    (4, 667): dict(area=15.15, mem_area=9.83, ff=436807, comb=398124, mem=227,
                   leak=16.43, dyn=8.75, total=9.07),
    (8, 667): dict(area=28.69, mem_area=18.60, ff=848511, comb=730506, mem=435,
                   leak=30.21, dyn=19.10, total=19.76),
}
# paper: the 8CU@667 layout only closes at 600 MHz (interconnect wires)
PAPER_LAYOUT_DERATE = {(8, 667): 600.0}
