"""MeshPlanner: GPUPlanner's DSE loop, retargeted at TPU-pod sharding.

The structural mapping (DESIGN.md §2):

  GPUPlanner (65nm ASIC)              MeshPlanner (TPU v5e pod)
  ----------------------------------  ---------------------------------------
  spec: #CUs + frequency target       spec: arch x input shape x mesh + HBM
  first-order PPA map (spreadsheet)   first-order roofline/memory estimator
  critical path in a memory macro     per-device HBM over budget
    -> divide the macro                 -> divide the tensor: remat policy up,
                                          sequence-shard activations, FSDP the
                                          master weights, split microbatches
  critical path in logic              step time bound by a roofline term
    -> insert pipeline stage            -> microbatch pipelining (overlap
                                          reduce-scatter with compute)
  critical path in interconnect       collective term dominates
    -> STOP (wires don't pipeline)      -> re-shard (head vs seq), or accept:
                                          ICI-bound is the pod-level analogue
  logic/physical synthesis            jit lower + compile
  PPA-vs-spec check                   memory_analysis / roofline-vs-target

Like the paper's map, iterations run on the cheap analytic estimator; the
expensive "synthesis" (XLA compile) validates the final candidate.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.models.config import ModelConfig, ShapeSpec, SHAPES, cell_supported
from repro.roofline.analysis import HBM_PER_CHIP, HBM_BW, ICI_BW, PEAK_FLOPS


@dataclass
class Knobs:
    """The DSE action space (all appliable to dryrun/train launches)."""
    remat: str = "dots"              # none | dots | full
    fsdp: bool = True
    seq_shard: bool = True
    microbatches: int = 1
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    use_flash_kernel: bool = False   # Pallas flash attention (TPU target)

    def apply(self, cfg: ModelConfig) -> ModelConfig:
        return cfg.replace(remat=self.remat, attn_q_chunk=self.attn_q_chunk,
                           attn_kv_chunk=self.attn_kv_chunk,
                           use_pallas=self.use_flash_kernel)


@dataclass
class Estimate:
    """First-order per-device model — the 'dynamic spreadsheet'."""
    params_bytes: float
    opt_bytes: float
    act_bytes: float
    total_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float

    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


@dataclass
class MapEntry:
    iteration: int
    estimate: Estimate
    bottleneck: str
    action: str


@dataclass
class MeshPlan:
    arch: str
    shape: str
    knobs: Knobs
    estimate: Estimate
    map_log: List[MapEntry] = field(default_factory=list)
    fits: bool = True
    reason: str = ""


def estimate(cfg: ModelConfig, shape: ShapeSpec, knobs: Knobs,
             n_devices: int = 256, tp: int = 16) -> Estimate:
    """Analytic per-device memory + roofline terms (documented first-order
    model; the compile-backed analyzer is ground truth)."""
    dp = n_devices // tp
    n = cfg.n_params()
    n_act = cfg.n_active_params()
    d = cfg.d_model
    train = shape.kind == "train"

    # --- parameter + optimizer bytes (f32 master; FSDP shards over dp
    # for training AND serving — the sharding rules 2-D shard weights) ---
    shard = n_devices if knobs.fsdp else tp
    params_bytes = 4.0 * n / shard
    opt_bytes = (8.0 * n / shard) if train else 0.0

    # --- activation bytes ---
    tokens_loc = shape.global_batch * shape.seq_len / dp
    if train:
        sp = tp if knobs.seq_shard else 1
        per_layer = tokens_loc / sp * d * 2.0          # bf16 residual
        remat_k = {"none": 8.0, "dots": 3.0, "full": 1.0}[knobs.remat]
        act = per_layer * cfg.n_layers * remat_k / knobs.microbatches
        # transient attention scores (flash kernel keeps them in VMEM)
        if not knobs.use_flash_kernel:
            bh = shape.global_batch / dp * max(cfg.n_heads // tp, 1)
            act += bh * knobs.attn_q_chunk * min(
                knobs.attn_kv_chunk, shape.seq_len) * 4.0
    elif shape.kind == "prefill":
        act = tokens_loc * d * 2.0 * 4
    else:
        kvb = (shape.global_batch * shape.seq_len * cfg.n_kv_heads
               * cfg.hd * 2 * 2.0)
        n_attn = sum(1 for k in cfg.pattern() if k in ("attn", "swa", "local"))
        if cfg.window:
            kvb = kvb * min(1.0, cfg.window / shape.seq_len)
        act = kvb * n_attn / n_devices
    total = params_bytes + opt_bytes + act

    # --- roofline terms (model flops; HLO waste shows up in validation) ---
    if train:
        flops_dev = 6.0 * n_act * shape.global_batch * shape.seq_len / n_devices
        remat_f = 8.0 / 6.0 if knobs.remat != "none" else 1.0
        flops_dev *= remat_f
        # non-flash blocked attention computes masked pairs too (2x causal)
        attn_flops = (12.0 * shape.seq_len * cfg.n_heads * cfg.hd
                      * cfg.n_layers * shape.global_batch * shape.seq_len
                      / n_devices)
        if cfg.window:
            attn_flops *= min(1.0, 2.0 * cfg.window / shape.seq_len)
        if not knobs.use_flash_kernel:
            attn_flops *= 2.0
        flops_dev += attn_flops
        bytes_dev = (params_bytes + opt_bytes) * 3 + act * 6
        coll = (2.0 * n / tp * 2.0                      # TP all-reduces (bf16)
                + (2.0 * n / shard) * 2.0 * knobs.microbatches  # FSDP gathers
                + 4.0 * n / shard)                      # grad reduce-scatter
    else:
        toks = 1 if shape.kind == "decode" else shape.seq_len
        flops_dev = 2.0 * n_act * shape.global_batch * toks / n_devices
        bytes_dev = params_bytes / 2 + act * (2 if shape.kind == "decode" else 4)
        coll = 2.0 * n / tp * (0.25 if shape.kind == "decode" else 2.0)
    return Estimate(
        params_bytes=params_bytes, opt_bytes=opt_bytes, act_bytes=act,
        total_bytes=total,
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        collective_s=coll / n_devices / ICI_BW * 16,
    )


# ---------------------------------------------------------------------------
# the planning loop (mirrors core.planner.plan)
# ---------------------------------------------------------------------------

_MEM_ACTIONS = ("remat_dots", "remat_full", "seq_shard", "fsdp",
                "microbatch_2", "microbatch_4", "microbatch_8",
                "attn_chunk_down")


def plan(cfg: ModelConfig, shape: ShapeSpec, *, n_devices: int = 256,
         tp: int = 16, hbm_budget: float = HBM_PER_CHIP,
         step_target_s: Optional[float] = None) -> MeshPlan:
    ok, reason = cell_supported(cfg, shape)
    knobs = Knobs(remat="none" if shape.kind != "train" else "dots")
    if not ok:
        return MeshPlan(cfg.name, shape.name, knobs,
                        estimate(cfg, shape, knobs, n_devices, tp),
                        fits=False, reason=reason)
    log: List[MapEntry] = []
    it = 0
    actions = list(_MEM_ACTIONS)
    while True:
        it += 1
        est = estimate(cfg, shape, knobs, n_devices, tp)
        if est.total_bytes <= hbm_budget:
            break
        # memory over budget -> "divide the memory" (paper's move)
        applied = None
        while actions:
            a = actions.pop(0)
            if a == "remat_dots" and knobs.remat == "none":
                knobs.remat = "dots"; applied = a; break
            if a == "remat_full" and knobs.remat != "full" \
                    and shape.kind == "train":
                knobs.remat = "full"; applied = a; break
            if a == "seq_shard" and not knobs.seq_shard:
                knobs.seq_shard = True; applied = a; break
            if a == "fsdp" and not knobs.fsdp:
                knobs.fsdp = True; applied = a; break
            if a.startswith("microbatch_") and shape.kind == "train":
                m = int(a.split("_")[1])
                if m > knobs.microbatches and shape.global_batch % m == 0:
                    knobs.microbatches = m; applied = a; break
            if a == "attn_chunk_down" and knobs.attn_q_chunk > 128:
                knobs.attn_q_chunk = 128; knobs.attn_kv_chunk = 512
                applied = a; break
        if applied is None:
            log.append(MapEntry(it, est, "memory",
                                "STOP: no memory-division action left"))
            return MeshPlan(cfg.name, shape.name, knobs, est, log,
                            fits=False,
                            reason=f"{est.total_bytes/2**30:.1f} GiB > budget")
        log.append(MapEntry(it, est, "memory",
                            f"divide: {applied} "
                            f"({est.total_bytes/2**30:.1f} GiB over budget)"))
        if it > 16:
            return MeshPlan(cfg.name, shape.name, knobs, est, log, False,
                            "did not converge")

    # optional step-time loop: attack the dominant roofline term
    if step_target_s is not None:
        for _ in range(4):
            est = estimate(cfg, shape, knobs, n_devices, tp)
            step = max(est.compute_s, est.memory_s, est.collective_s)
            if step <= step_target_s:
                break
            b = est.bound()
            if b == "memory" and not knobs.use_flash_kernel:
                knobs.use_flash_kernel = True
                log.append(MapEntry(it, est, b,
                                    "enable Pallas flash attention "
                                    "(scores stay in VMEM)"))
            elif b == "collective" and knobs.microbatches < 8 \
                    and shape.kind == "train" \
                    and shape.global_batch % (knobs.microbatches * 2) == 0:
                knobs.microbatches *= 2
                log.append(MapEntry(it, est, b,
                                    "insert pipeline: more microbatches to "
                                    "overlap reduce-scatter with compute"))
            else:
                log.append(MapEntry(it, est, b,
                                    "STOP: term is interconnect-bound "
                                    "(pod-level wires) — accept"))
                break
            it += 1

    est = estimate(cfg, shape, knobs, n_devices, tp)
    log.append(MapEntry(it + 1, est, "-", "plan accepted"))
    return MeshPlan(cfg.name, shape.name, knobs, est, log,
                    fits=est.total_bytes <= hbm_budget)


def validate(plan_: MeshPlan, *, multi_pod: bool = False, out_dir=None):
    """'Synthesis': lower + compile the planned cell and return the
    compile-backed roofline record (dryrun.run_cell with the plan's knobs).
    Requires the 512-device env (see launch.dryrun)."""
    from repro.launch.dryrun import run_cell
    k = plan_.knobs
    return run_cell(plan_.arch, plan_.shape, multi_pod=multi_pod,
                    remat=k.remat, microbatches=k.microbatches,
                    fsdp=k.fsdp, seq_shard=k.seq_shard, out_dir=out_dir)


def plan_all(archs, shapes=None, **kw) -> Dict[str, MeshPlan]:
    from repro.configs import get_config
    out = {}
    for a in archs:
        for s in (shapes or SHAPES):
            out[f"{a}/{s}"] = plan(get_config(a), SHAPES[s], **kw)
    return out
