"""SRAM macro timing/area/power model (65nm commercial node, calibrated).

The paper's memory compiler offers single/dual-port low-power SRAM with
16-65536 words x 2-144 bits. We model a macro's access delay as
``t0 + ta*log2(words) + tb*log2(bits)`` (wordline/bitline RC growth), its
area as ``a0 + ka*words*bits`` (a fixed per-block periphery overhead plus
linear bit-cell area — the overhead is exactly why two MxN blocks cost more
than one 2MxN block, the paper's central area trade-off), and leakage
proportional to bits with a per-block adder.

Constants are calibrated so the baseline G-GPU inventory reproduces the
paper's anchor points: 2.0 ns worst memory path (500 MHz), and the Table I
memory-area column (see ``repro.core.ppa``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace

# --- calibrated constants (65nm LP) ----------------------------------------
# delay = T0 + TA*sqrt(words) + TB*log2(bits): bitline RC grows with the
# word count (sqrt via hierarchical bitlines), wordline with width.
T0_NS = 0.70          # sense-amp + periphery
TA_NS = 0.0185        # bitline term per sqrt(word)
TB_NS = 0.02          # per doubling of bits
A0_MM2 = 0.0115       # per-block periphery overhead (superlinearity source)
KA_MM2_PER_BIT = 1.1375e-6
LEAK_MW_BLOCK = 0.012
LEAK_MW_PER_KBIT = 0.0024
DYN_MW_PER_GHZ_KBIT_PORT = 0.95   # activity-scaled

MIN_WORDS, MAX_WORDS = 16, 65536
MIN_BITS, MAX_BITS = 2, 144


@dataclass(frozen=True)
class Macro:
    """One SRAM block instance group.

    ``count`` physical blocks of ``words x bits`` (count > 1 after
    divisions); ``zone`` places it in the floorplan partition
    (cu | ctrl | top); ``per_cu`` scales the instance count with n_cus."""
    name: str
    words: int
    bits: int
    count: int = 1
    ports: int = 2                   # the G-GPU needs dual-port (paper)
    zone: str = "cu"
    per_cu: bool = True
    divided: int = 0                 # number of word-divisions applied

    def delay_ns(self) -> float:
        return (T0_NS + TA_NS * math.sqrt(self.words)
                + TB_NS * math.log2(self.bits))

    def area_mm2(self) -> float:
        return self.count * (A0_MM2 + KA_MM2_PER_BIT * self.words * self.bits)

    def leakage_mw(self) -> float:
        kbit = self.words * self.bits / 1024.0
        return self.count * (LEAK_MW_BLOCK + LEAK_MW_PER_KBIT * kbit)

    def dynamic_mw(self, freq_mhz: float, activity: float = 0.25) -> float:
        kbit = self.words * self.bits / 1024.0
        return (self.count * DYN_MW_PER_GHZ_KBIT_PORT * (freq_mhz / 1000.0)
                * math.sqrt(kbit) * self.ports * activity)

    def divide_words(self) -> "Macro":
        """The paper's memory-division step: split #words in two. Block
        count doubles; a MUX on the address MSB joins them (logic cost
        accounted by the planner)."""
        if self.words // 2 < MIN_WORDS:
            raise ValueError(f"{self.name}: cannot divide below {MIN_WORDS} words")
        return replace(self, words=self.words // 2, count=self.count * 2,
                       divided=self.divided + 1)

    def divide_bits(self) -> "Macro":
        """Alternative split on word size (data concat, no address MUX)."""
        if self.bits // 2 < MIN_BITS:
            raise ValueError(f"{self.name}: cannot divide below {MIN_BITS} bits")
        return replace(self, bits=self.bits // 2, count=self.count * 2,
                       divided=self.divided + 1)


# MUX levels added in front of a divided memory add logic delay; each
# division level costs one 2:1 mux stage on the read path.
MUX_DELAY_NS = 0.02


def divided_path_delay(m: Macro) -> float:
    """Access delay of a (possibly divided) macro including its MUX tree."""
    return m.delay_ns() + MUX_DELAY_NS * m.divided
