"""GPUPlanner: the paper's automated spec -> versions flow (Fig. 2).

``plan(n_cus, freq_target)`` runs the iterative *map*: estimate the three
candidate critical paths (memory macro / logic / top-level interconnect),
then

  * critical path in a memory block  -> divide it (words first, word-size
    when the word count bottoms out) — the paper's memory-division strategy;
  * critical path in logic           -> insert a pipeline stage on demand;
  * critical path in the interconnect -> STOP: not fixable by division or
    pipelining (the paper's own 8CU@667 -> 600 MHz finding); report the
    best achievable frequency instead.

Each iteration is logged — the log *is* the paper's "dynamic spreadsheet"
map that tells a designer which memory to divide next before paying for
synthesis. ``enumerate_versions`` reproduces the 12-version Table I sweep.

This module is the *analytic* half of the DSE stack. The joint search —
composing these versions with the cycle-accurate engine (cache
organization, pipeline-latency feedback, Pareto ranking) — lives in
``repro.dse``; ``sweep_memsys`` here is a thin deprecation shim over
``repro.dse.sweep_memsys``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ppa import GGPUVersion, baseline_inventory
from repro.core.sram import MIN_WORDS, Macro, divided_path_delay

MAX_PIPELINES = 4
MAX_DIVISIONS_PER_MACRO = 6


@dataclass
class MapEntry:
    iteration: int
    fmax_mhz: float
    bottleneck: str            # memory:<name> | logic | interconnect
    action: str
    paths: Dict[str, float]


@dataclass
class Plan:
    version: GGPUVersion
    achieved: bool
    map_log: List[MapEntry] = field(default_factory=list)
    reason: str = ""


def _divide_macro(m: Macro) -> Optional[Macro]:
    if m.divided >= MAX_DIVISIONS_PER_MACRO:
        return None
    if m.words // 2 >= MIN_WORDS:
        return m.divide_words()
    if m.bits > 2:
        return m.divide_bits()
    return None


def plan(n_cus: int, freq_target_mhz: float,
         inventory: Optional[List[Macro]] = None) -> Plan:
    """Iterate the map until the target closes or the bottleneck is
    un-fixable. Deterministic and cheap — this is the 'first-order PPA
    estimation' stage of the paper's flow; synthesis (for us: the cycle
    simulator + benchmarks) validates the result."""
    v = GGPUVersion(n_cus, freq_target_mhz,
                    list(inventory or baseline_inventory()))
    target_ns = 1000.0 / freq_target_mhz
    log: List[MapEntry] = []
    it = 0
    while max(v.paths().values()) > target_ns:
        it += 1
        paths = v.paths()
        worst = max(paths, key=paths.get)
        if worst == "memory":
            mi = max(range(len(v.inventory)),
                     key=lambda i: divided_path_delay(v.inventory[i]))
            m = v.inventory[mi]
            m2 = _divide_macro(m)
            if m2 is None:
                log.append(MapEntry(it, v.fmax_mhz(), f"memory:{m.name}",
                                    "STOP: macro cannot divide further", paths))
                return Plan(v, False, log,
                            f"memory {m.name} at division limit")
            v.inventory[mi] = m2
            act = (f"divide {m.name}: {m.words}x{m.bits} -> "
                   f"2x {m2.words}x{m2.bits} (blocks {m.count}->{m2.count})")
            log.append(MapEntry(it, v.fmax_mhz(), f"memory:{m.name}", act,
                                paths))
        elif worst == "logic":
            if v.pipelines >= MAX_PIPELINES:
                log.append(MapEntry(it, v.fmax_mhz(), "logic",
                                    "STOP: pipeline limit", paths))
                return Plan(v, False, log, "logic pipeline limit reached")
            v.pipelines += 1
            log.append(MapEntry(it, v.fmax_mhz(), "logic",
                                f"insert pipeline stage #{v.pipelines}", paths))
        else:  # interconnect
            log.append(MapEntry(
                it, v.fmax_mhz(), "interconnect",
                "STOP: top-level wires dominate; pipelining ineffective "
                "(paper Sec. IV) — reduce CUs or accept lower frequency",
                paths))
            return Plan(v, False, log,
                        f"interconnect-bound at {v.fmax_mhz():.0f} MHz "
                        f"with {n_cus} CUs")
        if it > 64:
            return Plan(v, False, log, "did not converge")
    log.append(MapEntry(it + 1, v.fmax_mhz(), "-", "target met", v.paths()))
    return Plan(v, True, log)


def enumerate_versions(cus=(1, 2, 4, 8), freqs=(500.0, 590.0, 667.0)
                       ) -> List[Plan]:
    """The paper's 12-version sweep (Table I). Versions that miss their
    target report the best achievable frequency (8CU@667 -> ~600 MHz)."""
    out = []
    for f in freqs:
        for c in cus:
            p = plan(c, f)
            if not p.achieved:
                # the paper keeps the layout at its achievable frequency
                p.version.freq_mhz = round(p.version.fmax_mhz(), 0)
            out.append(p)
    return out


def sweep_memsys(bench: str = "xcorr",
                 n_cus: Sequence[int] = (1, 8),
                 memsys: Optional[Sequence[str]] = None,
                 sizes: Optional[Tuple[int, int]] = (64, 1024),
                 **cfg_kw) -> Dict[Tuple[int, str], dict]:
    """Deprecated shim: the cache-organization sweep moved into the unified
    DSE subsystem. Import ``sweep_memsys`` from ``repro.dse`` instead
    (same signature and return shape)."""
    import warnings

    from repro.dse.search import sweep_memsys as _sweep
    warnings.warn(
        "repro.core.planner.sweep_memsys is deprecated; use "
        "repro.dse.sweep_memsys (the unified DSE subsystem)",
        DeprecationWarning, stacklevel=2)
    return _sweep(bench=bench, n_cus=n_cus, memsys=memsys, sizes=sizes,
                  **cfg_kw)


def speedup_table(ggpu_cycles: Dict[str, Dict[int, int]],
                  scalar_cycles: Dict[str, int],
                  input_ratio: Dict[str, float],
                  ggpu_freq_mhz: float = 667.0,
                  scalar_freq_mhz: float = 667.0):
    """Fig. 5's metric: speedup = scalar_cycles * input_ratio / ggpu_cycles
    (the paper's pessimistic-for-G-GPU linear input scaling), in cycles —
    and wall-clock speedup when frequencies differ."""
    rows = {}
    for k, per_cu in ggpu_cycles.items():
        rows[k] = {
            ncu: scalar_cycles[k] * input_ratio[k] / cyc
            for ncu, cyc in per_cu.items()
        }
    return rows
