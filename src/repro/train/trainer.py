"""Fault-tolerant training loop.

Responsibilities:
  * auto-resume from the newest complete checkpoint (params, optimizer,
    step) — kill the process at any point and re-run the same command;
  * periodic atomic checkpoints (``save_every``);
  * deterministic data: batch = f(seed, step), so an interrupted-and-
    resumed run is bit-identical to an uninterrupted one (tested);
  * straggler/failure hooks: per-step wall-time watchdog that logs
    outliers, and an injectable failure for tests (``fail_at_step``).

Distribution comes from the sharding rules: pass ``rules`` to shard
params/opt/batches on the active mesh (single-host CPU smoke runs pass
None and everything stays local).
"""
from __future__ import annotations

import dataclasses
import math
import time
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, device_put_batch, make_source
from repro.models.config import ModelConfig
from repro.models.schema import abstract_params, init_params
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.sharding import set_rules
from repro.train import checkpoint


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    save_every: int = 50
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1
    ckpt_dir: str = "checkpoints"
    straggler_factor: float = 3.0     # log steps slower than 3x median
    fail_at_step: int = -1            # test hook: raise at this step


class Trainer:
    def __init__(self, cfg: ModelConfig, hp: adamw.AdamWConfig,
                 tc: TrainConfig, data_cfg: DataConfig, rules=None):
        self.cfg, self.hp, self.tc, self.rules = cfg, hp, tc, rules
        self.data = make_source(data_cfg)
        self.step_fn = jax.jit(
            make_train_step(cfg, hp, microbatches=tc.microbatches),
            donate_argnums=(0, 1))
        self.metrics_log = []

    # -- state ---------------------------------------------------------------
    def init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        opt = adamw.init(params)
        if self.rules is not None:
            from repro.sharding.rules import (opt_state_shardings,
                                              param_shardings)
            ps = param_shardings(self.rules, self.cfg)
            params = jax.device_put(params, ps)
            opt = jax.device_put(opt, opt_state_shardings(self.rules, self.cfg))
        return params, opt, 0

    def resume_or_init(self):
        last = checkpoint.latest_step(self.tc.ckpt_dir)
        if last is None:
            return self.init_state()
        params_like = abstract_params(self.cfg)
        opt_like = adamw.AdamWState(
            m=abstract_params(self.cfg), v=abstract_params(self.cfg),
            step=jax.ShapeDtypeStruct((), jnp.int32))
        shard = opt_shard = None
        if self.rules is not None:
            from repro.sharding.rules import (opt_state_shardings,
                                              param_shardings)
            shard = param_shardings(self.rules, self.cfg)
            opt_shard = opt_state_shardings(self.rules, self.cfg)
        params, opt, man = checkpoint.restore(
            self.tc.ckpt_dir, last, params_like, opt_like, shard, opt_shard)
        print(f"[trainer] resumed from step {last}")
        return params, opt, last

    # -- loop ----------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        params, opt, start = self.resume_or_init()
        durations = []
        ctx = set_rules(self.rules) if self.rules is not None else _null()
        with ctx:
            for step in range(start, self.tc.steps):
                if step == self.tc.fail_at_step:
                    raise RuntimeError(f"injected failure at step {step}")
                t0 = time.time()
                batch = device_put_batch(self.data.batch_at(step))
                params, opt, metrics = self.step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                durations.append(dt)
                med = float(np.median(durations[-50:]))
                if dt > self.tc.straggler_factor * med and len(durations) > 5:
                    print(f"[trainer] straggler: step {step} took {dt:.2f}s "
                          f"(median {med:.2f}s)")
                if (step + 1) % self.tc.log_every == 0 or step == start:
                    print(f"[trainer] step {step + 1}: loss={loss:.4f} "
                          f"lr={float(metrics['lr']):.2e} "
                          f"gnorm={float(metrics['grad_norm']):.2f} "
                          f"({dt:.2f}s)")
                self.metrics_log.append({"step": step + 1, "loss": loss})
                if (step + 1) % self.tc.save_every == 0 \
                        or step + 1 == self.tc.steps:
                    checkpoint.save(self.tc.ckpt_dir, step + 1, params, opt,
                                    {"arch": self.cfg.name})
        final_loss = self.metrics_log[-1]["loss"] if self.metrics_log else math.nan
        return {"final_loss": final_loss, "steps": self.tc.steps,
                "params": params}


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
