"""Sharded checkpointing with elastic (mesh-independent) restore.

Layout: ``<dir>/step_<N>/{manifest.json, arrays.npz}``. Arrays are saved
fully-replicated-equivalent (gathered) with flattened pytree paths as npz
keys; restore re-shards onto WHATEVER mesh/rules the new job runs — a
checkpoint written on a 16x16 pod restores onto 2x16x16 or a single CPU
host unchanged. That mesh independence is the elastic-restart mechanism:
lose a pod, re-plan with MeshPlanner, restore, continue.

Atomicity: writes go to ``step_<N>.tmp`` then ``os.replace`` — a job killed
mid-save never corrupts the latest checkpoint (restore picks the newest
complete manifest).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_key_str(k) for k in path)
        flat[key] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def save(ckpt_dir, step: int, params, opt_state=None, extra: dict = None):
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    final = ckpt_dir / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "time": time.time(), **(extra or {})}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") \
                and not d.name.endswith(".tmp") \
                and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, params_like, opt_like=None,
            shardings=None, opt_shardings=None) -> Tuple[Any, Any, dict]:
    """Restore onto the CURRENT mesh: ``shardings`` trees (matching
    params_like / opt_like structure) re-shard each array via device_put.
    ``*_like`` provide structure only (ShapeDtypeStructs are fine)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    def rebuild(like, prefix, shard_tree):
        flat_like = _flatten({prefix: like})
        shard_flat = _flatten({prefix: shard_tree}) if shard_tree is not None \
            else {k: None for k in flat_like}
        out_flat = {}
        for key, leaf in flat_like.items():
            arr = jnp.asarray(data[key])
            sh = shard_flat.get(key)
            out_flat[key] = jax.device_put(arr, sh) if sh is not None else arr
        # unflatten by path
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        paths = [k for k, _ in
                 sorted(_flatten({prefix: like}).items())]
        # order: match tree_flatten order via path-flatten order
        flat_pairs = jax.tree_util.tree_flatten_with_path({prefix: like})[0]
        ordered = ["/".join(_key_str(p) for p in path)
                   for path, _ in flat_pairs]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like),
            [out_flat[k] for k in ordered])

    params = rebuild(params_like, "params", shardings)
    opt = rebuild(opt_like, "opt", opt_shardings) if opt_like is not None \
        else None
    return params, opt, manifest
