"""Compiler front-end: IR/optimizer units, the 8-bench DSL suite (bit-
exact vs hand-written, golden cycles), fusion's engine-visible effect,
and the acceptance path (user segmented reduction -> engine -> dse.search
-> serve.Fleet).

Golden cycle counts are pinned at the reduced bench sizes below on a
2-CU shared-memsys machine; seven of the eight compiled benches are
*cycle-identical* with the hand-written programs (same instruction
sequences), ``parallel_sel`` intentionally compiles to a branch-free
arithmetic rank body (documented in ``repro.compiler.suite``).
"""
import os

import numpy as np
import pytest

from repro.compiler import (CompileError, CompiledKernel, compile_kernel,
                            dsl, dsl_benches)
from repro.compiler import ir, opt
from repro.compiler.suite import dsl_kernels
from repro.ggpu import isa, programs
from repro.ggpu.engine import GGPUConfig, ScalarConfig, run_kernel

FAST = os.environ.get("GGPU_FAST_TESTS", "0") not in ("", "0")

#: reduced (scalar, gpu[, seg]) sizes keeping the suite interactive
SIZES = {
    "copy": (64, 512), "vec_mul": (64, 512), "div_int": (64, 512),
    "reduction": (64, 512, 8), "fir": (64, 512), "mat_mul": (8, 16),
    "xcorr": (32, 128), "parallel_sel": (32, 128),
}
#: pinned compiled-program cycles at SIZES on GGPUConfig(n_cus=2); the
#: paired value is the hand-written program's count (equal everywhere but
#: parallel_sel — branch-free body, more instrs, no divergence)
GOLDEN_CYCLES = {
    "copy": (384, 384),
    "vec_mul": (576, 576),
    "div_int": (2176, 2176),
    "reduction": (848, 848),
    "fir": (5092, 5092),
    "mat_mul": (2912, 2912),
    "xcorr": (10384, 10384),
    "parallel_sel": (12416, 7840),
}
CYCLE_IDENTICAL = sorted(n for n, (d, h) in GOLDEN_CYCLES.items() if d == h)

CFG2 = GGPUConfig(n_cus=2)


@pytest.fixture(scope="module")
def suite():
    return dsl_benches(SIZES)


@pytest.fixture(scope="module")
def hand():
    return {n: getattr(programs, f"_{n}")(*sz) for n, sz in SIZES.items()}


# ---------------------------------------------------------------------------
# IR / optimizer units
# ---------------------------------------------------------------------------

def test_constant_folding_matches_engine_alu():
    assert opt.binop("add", ir.Const(2 ** 31 - 1), ir.Const(1)) \
        == ir.Const(-2 ** 31)                      # int32 wraparound
    assert opt.binop("div", ir.Const(-7), ir.Const(2)) == ir.Const(-4)
    assert opt.binop("div", ir.Const(5), ir.Const(0)) == ir.Const(0)
    assert opt.binop("rem", ir.Const(-5), ir.Const(3)) == ir.Const(1)
    assert opt.binop("mul", ir.Const(1 << 20), ir.Const(1 << 20)) \
        == ir.Const(0)


def test_algebraic_identities():
    x = ir.Item()
    assert opt.add(x, 0) is x
    assert opt.mul(x, 1) is x
    assert opt.mul(x, 0) == ir.Const(0)
    assert opt.div(x, 1) is x
    assert opt.rem(x, 1) == ir.Const(0)
    # constant canonicalization flattens chained address offsets
    e = opt.add(opt.add(x, 5), 7)
    assert e == ir.Bin("add", x, ir.Const(12))


def test_strength_reduction():
    x = ir.Item()
    assert opt.mul(x, 8) == ir.Bin("shl", x, ir.Const(3))
    assert opt.div(x, 8) == ir.Bin("sra", x, ir.Const(3))
    assert opt.rem(x, 8) == ir.Bin("and", x, ir.Const(7))
    # floor semantics: sra/mask are exact for negatives too
    assert int(ir._eval_bin("sra", np.int64(-5), np.int64(1))) == -5 // 2
    assert int(ir._eval_bin("and", np.int64(-5), np.int64(1))) == -5 % 2


def test_cse_by_structural_equality():
    x = ir.Item()
    a = opt.mul(opt.add(x, 3), opt.add(x, 3))
    counts = opt.use_counts([a])
    assert counts[ir.Bin("add", x, ir.Const(3))] == 2
    assert counts[x] == 1                     # children counted once


def test_shape_and_input_errors():
    with pytest.raises(CompileError):
        compile_kernel(lambda a, b: a + b, dict(a=8, b=16))
    with pytest.raises(CompileError):
        compile_kernel(lambda a: a.seg_sum(3), dict(a=8))
    with pytest.raises(CompileError):
        compile_kernel(lambda a: a, dict(b=8))
    with pytest.raises(CompileError):
        compile_kernel(lambda a: a, dict(a=8), coarsen=3)
    k = compile_kernel(lambda a: a, dict(a=8))
    with pytest.raises(CompileError):
        k.build_mem({"a": np.zeros(9, np.int32)})


def test_out_of_registers_is_reported():
    def deep(a):
        # 40 distinct shared terms, each used again later: all stay live
        # across the first sum — more than the register file holds
        terms = [a + (i + 1) for i in range(40)]
        s1, s2 = terms[0], terms[0]
        for t in terms[1:]:
            s1 = s1 + t
        for t in terms[1:]:
            s2 = s2 ^ t
        return s1 + s2
    with pytest.raises(CompileError, match="register"):
        compile_kernel(deep, dict(a=8))


# ---------------------------------------------------------------------------
# fusion: elementwise chains are one load per input + one store
# ---------------------------------------------------------------------------

def test_fusion_minimizes_memory_traffic():
    k = compile_kernel(lambda a, b, c: (a * b + c) ^ (a >> 2),
                       dict(a=64, b=64, c=64), name="chain")
    ops = list(k.prog[:, 0])
    assert ops.count(isa.LW) == 3         # a (shared via CSE), b, c
    assert ops.count(isa.SW) == 1         # no intermediate arrays
    ins = k.random_inputs(seed=1)
    info = k.verify(ins, CFG2)
    # engine-visible: exactly 4 memory ops per item, everything else
    # retires through straight-line (fast-path-eligible) rounds
    assert info["mem_ops"] == 4 * 64


def test_mul_pow2_emits_shift_not_mul():
    k = compile_kernel(lambda a: a * 8, dict(a=64))
    ops = set(k.prog[:, 0])
    assert isa.SLLI in ops and isa.MUL not in ops


# ---------------------------------------------------------------------------
# the 8-bench DSL suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SIZES))
def test_suite_bit_exact_and_golden_cycles(name, suite, hand):
    b = hand[name]
    d = suite[f"dsl_{name}"]
    mem, info = run_kernel(d.gpu_prog, d.gpu_mem, d.gpu_items, CFG2)
    np.testing.assert_array_equal(mem[d.gpu_out],
                                  b.ref(b.gpu_mem, b.gpu_n))
    want_dsl, _want_hand = GOLDEN_CYCLES[name]
    assert info["cycles"] == want_dsl, \
        f"{name}: compiled cycles {info['cycles']} != golden {want_dsl}"


@pytest.mark.parametrize("name", CYCLE_IDENTICAL if not FAST
                         else CYCLE_IDENTICAL[:3])
def test_suite_cycle_identical_with_hand_written(name, suite, hand):
    """Seven benches compile to the hand-written instruction sequences —
    identical cycles, stats, and memory behavior."""
    b = hand[name]
    d = suite[f"dsl_{name}"]
    _, ih = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG2)
    _, idd = run_kernel(d.gpu_prog, d.gpu_mem, d.gpu_items, CFG2)
    for k in ("cycles", "instrs", "mem_ops", "hits", "misses", "steps"):
        assert idd[k] == ih[k], f"{name}.{k}"


@pytest.mark.parametrize("name", ["copy", "fir", "mat_mul"]
                         if FAST else sorted(SIZES))
def test_suite_scalar_programs_bit_exact(name, suite, hand):
    b = hand[name]
    d = suite[f"dsl_{name}"]
    mem, _ = run_kernel(d.scalar_prog, d.scalar_mem, 1, ScalarConfig())
    np.testing.assert_array_equal(mem[d.scalar_out],
                                  b.ref(b.scalar_mem, b.scalar_n))


def test_suite_layout_guard():
    """A compiled kernel whose layout diverged from the hand-written twin
    must be rejected, not silently mis-mapped."""
    ks = dsl_kernels({"copy": (64, 512)})
    kg, _ = ks["copy"]
    assert kg.mem_size == 1024 and kg.out == slice(512, 1024)


# ---------------------------------------------------------------------------
# acceptance: user-written segmented reduction through the whole stack
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def user_kernel() -> CompiledKernel:
    return compile_kernel(lambda a, b: ((a - b) * a).seg_sum(32),
                          dict(a=512, b=512), name="user_segred")


def test_user_segred_bit_exact_on_all_machines(user_kernel):
    ins = user_kernel.random_inputs(seed=3)
    for cus in (1, 2, 4):           # the acceptance matrix, never trimmed
        user_kernel.verify(ins, GGPUConfig(n_cus=cus))
    user_kernel.verify(ins, ScalarConfig(), scalar=True)


def test_user_segred_in_dse_search(user_kernel):
    from repro import dse
    wl = {"user_segred": user_kernel.as_bench(seed=7)}
    ev = dse.Evaluator(benches=(), workloads=wl, check=True)
    res = dse.search(specs=dse.enumerate_specs(cus=(1, 2),
                                               freq_targets=(667.0,)),
                     evaluator=ev)
    assert res.frontier, "compiled workload produced no frontier"
    rows = res.report()
    assert all("time_us" in r for r in rows) and len(rows) == 2
    for p in res.points:
        assert "user_segred" in p.per_bench
        assert p.per_bench["user_segred"].cycles > 0


def test_user_segred_routable_by_fleet(user_kernel):
    from repro.serve import Fleet
    fleet = Fleet([("small", GGPUConfig(n_cus=1)),
                   ("wide", GGPUConfig(n_cus=4))])
    ins = user_kernel.random_inputs(seed=9)
    mem0 = user_kernel.build_mem(ins)
    want = user_kernel.reference(ins)
    tickets = [fleet.submit(user_kernel.prog, mem0, user_kernel.n_items,
                            tag="user_segred") for _ in range(3)]
    results = fleet.drain()
    assert [r.info["ticket"] for r in results] == tickets
    for r in results:
        np.testing.assert_array_equal(r.mem[user_kernel.out], want)
        assert r.info["device"] in ("small", "wide")
    assert not fleet.quarantined


# ---------------------------------------------------------------------------
# tiling / structured ops
# ---------------------------------------------------------------------------

def test_coarsen_folds_outputs_per_item():
    n = 256
    k1 = compile_kernel(lambda a, b: a * b, dict(a=n, b=n))
    k4 = compile_kernel(lambda a, b: a * b, dict(a=n, b=n), coarsen=4)
    assert k4.n_items == n // 4 and k1.n_items == n
    ins = k1.random_inputs(seed=5)
    out1, _ = k1.run(ins, CFG2)
    out4, _ = k4.run(ins, CFG2)
    np.testing.assert_array_equal(out1, out4)


def test_stencil_boundaries():
    k = compile_kernel(lambda x: dsl.stencil(x, [1, -2, 1], [-1, 0, 1]),
                       dict(x=128), name="laplace")
    ins = k.random_inputs(seed=6)
    k.verify(ins, CFG2)
    x = ins["x"].astype(np.int64)
    want = np.zeros(128, np.int64)
    want[1:] += x[:-1]
    want -= 2 * x
    want[:-1] += x[1:]
    np.testing.assert_array_equal(k.reference(ins), ir.w32(want))


def test_rank_sort_is_stable_on_ties():
    k = compile_kernel(lambda a: dsl.rank_sort(a), dict(a=16))
    ins = {"a": np.array([3, 1, 3, 1] * 4, np.int32)}
    np.testing.assert_array_equal(
        k.reference(ins), np.sort(ins["a"], kind="stable"))


def test_scatter_collision_detected():
    from repro.compiler import ScatterTensor
    k = compile_kernel(
        lambda a: ScatterTensor(4, lambda i: ir.Const(0),
                                lambda i: a.elem(i)), dict(a=4))
    with pytest.raises(CompileError, match="collide"):
        k.reference({"a": np.arange(4, dtype=np.int32)})


def test_scatter_cross_item_collision_detected_under_coarsen():
    """Two *different* items hitting one address race even when each
    store pair is collision-free on its own."""
    from repro.compiler import ScatterTensor
    k = compile_kernel(
        lambda a: ScatterTensor(4, lambda i: opt.div(i, 3),
                                lambda i: a.elem(i)),
        dict(a=4), coarsen=2)       # addrs per item: {0: (0,0), 1: (0,1)}
    with pytest.raises(CompileError, match="collide"):
        k.reference({"a": np.arange(4, dtype=np.int32)})


def test_scatter_intra_item_overwrite_is_deterministic():
    """One item writing an address twice follows program order on both
    the engine and the oracle — allowed, and bit-exact."""
    from repro.compiler import ScatterTensor
    k = compile_kernel(
        lambda a: ScatterTensor(
            4, lambda i: opt.mul(opt.div(i, 2), 2),
            lambda i: a.elem(i)),
        dict(a=4), coarsen=2)       # item0 -> addr 0 twice, item1 -> 2
    ins = {"a": np.array([5, 6, 7, 8], np.int32)}
    ref = k.reference(ins)
    np.testing.assert_array_equal(ref, [6, 0, 8, 0])
    k.verify(ins, GGPUConfig(n_cus=1))


def test_out_of_range_constants_wrap_to_int32():
    """Python ints beyond int32 wrap at construction, so folding,
    strength reduction, and codegen all see the value the engine's
    register file holds (1<<31 materializes as -2**31; 1<<32 wraps to a
    zero divisor -> div-by-zero -> 0)."""
    assert opt._as_expr(1 << 31) == ir.Const(-2 ** 31)
    k = compile_kernel(lambda a: (a < (1 << 31)) + a // (1 << 32)
                       + (a * (1 << 32)), dict(a=16), name="wrap")
    ins = {"a": np.array([-5, -1, 0, 1, 7, 2 ** 31 - 1, -2 ** 31, 12]
                         * 2, np.int32)}
    np.testing.assert_array_equal(
        k.reference(ins), np.zeros(16, np.int32))   # slt vs INT32_MIN…
    k.verify(ins, GGPUConfig(n_cus=1))


def test_reflected_operators_bit_exact():
    """int-on-the-left forms of every documented operator."""
    k = compile_kernel(
        lambda a: (7 - a) + (1 | a) + (6 & a) + (5 ^ a)
        + (1 << a) + (-64 >> a) + (100 // a) + (100 % a) + (3 * a),
        dict(a=32), name="reflected")
    k.verify(k.random_inputs(lo=-8, hi=8, seed=2), CFG2)
