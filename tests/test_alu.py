"""exec_alu edge cases against a numpy int64-then-wrap-to-int32 reference:
MULH/DIV/REM with negative (and INT_MIN) operands, shift-amount clipping,
and LUI. Parametrized — no hypothesis needed."""
import numpy as np
import pytest

from repro.ggpu import isa
from repro.ggpu.engine.alu import exec_alu, select_alu

INT_MIN = -2**31
SIGNED_PAIRS = [
    (INT_MIN, -1), (INT_MIN, 1), (INT_MIN, INT_MIN),
    (-7, 3), (7, -3), (-7, -3), (7, 3),
    (5, 0), (-5, 0), (0, -3), (2**31 - 1, 2**31 - 1), (-1, -1),
]
SHIFT_PAIRS = [(1, 0), (1, 5), (1, 31), (1, 32), (-8, 40), (INT_MIN, 100),
               (123, -1), (-1, 31)]


def _run(opcode, pairs, imm=0):
    a = np.array([p[0] for p in pairs], np.int32)[None, :]
    b = np.array([p[1] for p in pairs], np.int32)[None, :]
    op = np.full((1, 1), opcode, np.int32)
    immv = np.full((1, 1), imm, np.int32)
    return np.asarray(exec_alu(op, a, b, immv))[0]


def _i64(pairs):
    return (np.array([p[0] for p in pairs], np.int64),
            np.array([p[1] for p in pairs], np.int64))


def _wrap32(x64):
    return x64.astype(np.uint64).astype(np.uint32).astype(np.int32)


def test_mulh_signed():
    """MULH = high 32 bits of the exact signed 64-bit product."""
    a, b = _i64(SIGNED_PAIRS)
    np.testing.assert_array_equal(_run(isa.MULH, SIGNED_PAIRS),
                                  ((a * b) >> 32).astype(np.int32))


def test_div_floor_semantics():
    """DIV is floor division (jnp/python semantics, not C truncation);
    divide-by-zero yields 0; INT_MIN/-1 wraps to INT_MIN."""
    a, b = _i64(SIGNED_PAIRS)
    ref = _wrap32(np.where(b == 0, 0,
                           np.floor_divide(a, np.where(b == 0, 1, b))))
    np.testing.assert_array_equal(_run(isa.DIV, SIGNED_PAIRS), ref)


def test_rem_sign_follows_divisor():
    """REM pairs with floor DIV: result sign follows the divisor
    (python % semantics); x rem 0 = 0."""
    a, b = _i64(SIGNED_PAIRS)
    ref = _wrap32(np.where(b == 0, 0, np.mod(a, np.where(b == 0, 1, b))))
    np.testing.assert_array_equal(_run(isa.REM, SIGNED_PAIRS), ref)
    # invariant: a == DIV*b + REM wherever b != 0 (mod 2^32)
    q = _run(isa.DIV, SIGNED_PAIRS).astype(np.int64)
    r = _run(isa.REM, SIGNED_PAIRS).astype(np.int64)
    nz = b != 0
    np.testing.assert_array_equal(_wrap32((q * b + r))[nz],
                                  _wrap32(a)[nz])


@pytest.mark.parametrize("opcode", [isa.SLL, isa.SRL, isa.SRA])
def test_shift_amount_clipping(opcode):
    """Shift amounts clip to [0, 31]: negative -> 0, >=32 -> 31."""
    a, b = _i64(SHIFT_PAIRS)
    sh = np.clip(b, 0, 31)
    if opcode == isa.SLL:
        ref = _wrap32(a << sh)
    elif opcode == isa.SRA:
        ref = (a.astype(np.int32) >> sh.astype(np.int32)).astype(np.int32)
    else:                                   # SRL: logical on uint32
        ref = (a.astype(np.int64).astype(np.uint64).astype(np.uint32)
               >> sh.astype(np.uint32)).astype(np.int32)
    np.testing.assert_array_equal(_run(opcode, SHIFT_PAIRS), ref)


@pytest.mark.parametrize("opcode,iop", [(isa.SLL, isa.SLLI),
                                        (isa.SRL, isa.SRLI),
                                        (isa.SRA, isa.SRAI)])
@pytest.mark.parametrize("amount", [0, 7, 31, 32, 63, -2])
def test_immediate_shifts_match_register_shifts(opcode, iop, amount):
    vals = [(v, amount) for v, _ in SHIFT_PAIRS]
    np.testing.assert_array_equal(_run(iop, vals, imm=amount),
                                  _run(opcode, vals))


@pytest.mark.parametrize("imm", [0, 1, -1, 2047, -2048, 0x7FFFF, -0x80000])
def test_lui(imm):
    pairs = [(0, 0), (99, -7)]              # operands must be ignored
    ref = _wrap32(np.full(len(pairs), np.int64(imm) << 12))
    np.testing.assert_array_equal(_run(isa.LUI, pairs, imm=imm), ref)


def test_pruned_select_tree_matches_full():
    """Decode specialization: pruning the select tree to the present ops
    is result-neutral."""
    a = np.array([p[0] for p in SIGNED_PAIRS], np.int32)[None, :]
    b = np.array([p[1] for p in SIGNED_PAIRS], np.int32)[None, :]
    op = np.full((1, 1), isa.MULH, np.int32)
    imm = np.zeros((1, 1), np.int32)
    full = select_alu(op, a, b, imm, None)
    pruned = select_alu(op, a, b, imm, frozenset({isa.MULH}))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(pruned))
