"""Unified DSE subsystem: pipeline-latency feedback bit-exactness,
DesignPoint/inventory composition, Pareto-dominance properties, the joint
analytic+cycle-accurate search, and the BENCH_dse.json artifact schema.

The depth-0 bit-exactness matrix runs all seven paper benches; under
``GGPU_FAST_TESTS=1`` the machine axis is trimmed (the knob is gated on a
static config field, so one machine proves the graph is unchanged —
the full matrix is the paper-faithful check for the default tier-1 run)."""
import functools
import json
import os

import numpy as np
import pytest

from repro import dse
from repro.dse import (DesignSpec, Evaluator, design_point, dominates,
                       memsys_inventory, pareto_frontier)
from repro.ggpu import programs
from repro.ggpu.engine import GGPUConfig, ScalarConfig, run_kernel

FAST = os.environ.get("GGPU_FAST_TESTS", "0") not in ("", "0")

# 512-item GPU sizes: W=8 wavefronts, divisible by every CU count (the
# legacy reference stepper predates ragged-W rounding and needs W % n_cus
# == 0); mat_mul dim 32 -> 1024 items, W=16
BENCH_BUILDERS = {
    "copy": lambda: programs._copy(32, 512),
    "vec_mul": lambda: programs._vec_mul(32, 512),
    "mat_mul": lambda: programs._mat_mul(4, 32),
    "fir": lambda: programs._fir(32, 512),
    "div_int": lambda: programs._div_int(16, 512),
    "xcorr": lambda: programs._xcorr(16, 512),
    "parallel_sel": lambda: programs._parallel_sel(32, 512),
    # the PR-3 extension bench (seg=8 keeps gpu_items=512 -> W=8)
    "reduction": lambda: programs._reduction(64, 4096, seg=8),
}
MACHINES = ["scalar", 2] if FAST else ["scalar", 1, 2, 4, 8]


@functools.lru_cache(maxsize=None)
def _bench(name):
    return BENCH_BUILDERS[name]()


# ---------------------------------------------------------------------------
# pipeline-depth knob: bit-exact at depth 0, architectural above it
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("machine", MACHINES)
@pytest.mark.parametrize("name", sorted(BENCH_BUILDERS))
def test_depth0_bit_exact_vs_legacy(name, machine):
    """pipeline_depth=0 (the default) must be bit-exact with the seed
    engine — results, cycles, stats, steps — on every bench and machine.
    The legacy reference stepper IS the pre-knob engine."""
    b = _bench(name)
    if machine == "scalar":
        cfg = ScalarConfig()
        args = (b.scalar_prog, b.scalar_mem, 1)
    else:
        cfg = GGPUConfig(n_cus=machine)
        args = (b.gpu_prog, b.gpu_mem, b.gpu_items)
    assert cfg.pipeline_depth == 0
    mem_n, i_n = run_kernel(*args, cfg)
    mem_l, i_l = run_kernel(*args, cfg, legacy=True)
    np.testing.assert_array_equal(mem_n, mem_l)
    for k in ("cycles", "instrs", "mem_ops", "hits", "misses", "steps"):
        assert i_n[k] == i_l[k], k


def test_depth_increases_cpi_not_results():
    """Deeper pipelines cost cycles (dependency bubbles + branch refill)
    but never change functional results — the fmax-vs-CPI trade-off the
    analytic map cannot see."""
    b = _bench("xcorr")
    cycles = {}
    for d in (0, 1, 2):
        mem, info = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                               GGPUConfig(n_cus=2, pipeline_depth=d))
        np.testing.assert_array_equal(mem[b.gpu_out],
                                      b.ref(b.gpu_mem, b.gpu_n))
        cycles[d] = info["cycles"]
    assert cycles[0] < cycles[1] < cycles[2]


def test_depth_batching_invariants():
    """Cohort/batch launches charge the pipeline feedback identically to a
    single launch."""
    from repro.ggpu.engine import run_kernel_batch, run_kernel_cohort
    b = _bench("xcorr")
    cfg = GGPUConfig(n_cus=2, pipeline_depth=2)
    mem_s, i_s = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg)
    (mem_c, i_c), = run_kernel_cohort(b.gpu_prog, [b.gpu_mem],
                                      b.gpu_items, cfg)
    (mem_b, i_b), = run_kernel_batch([b.gpu_prog], [b.gpu_mem],
                                     [b.gpu_items], cfg)
    np.testing.assert_array_equal(mem_s, mem_c)
    np.testing.assert_array_equal(mem_s, mem_b)
    assert i_s["cycles"] == i_c["cycles"] == i_b["cycles"]


def test_legacy_rejects_pipeline_depth():
    b = _bench("copy")
    with pytest.raises(ValueError):
        run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                   GGPUConfig(pipeline_depth=1), legacy=True)


# ---------------------------------------------------------------------------
# DesignPoint composition
# ---------------------------------------------------------------------------

def test_design_point_closes_the_loop():
    """The engine config inherits the map's inserted pipeline stages and
    the achieved (possibly derated) frequency."""
    p = design_point(DesignSpec(n_cus=1, freq_target_mhz=667.0))
    assert p.plan.achieved
    assert p.config.pipeline_depth == p.version.pipelines > 0
    assert p.freq_mhz == 667.0
    # the paper's failure case derates to the interconnect-bound fmax
    p8 = design_point(DesignSpec(n_cus=8, freq_target_mhz=667.0))
    assert not p8.plan.achieved
    assert 580 <= p8.freq_mhz <= 620
    assert p8.config.freq_mhz == p8.version.freq_mhz


def test_design_point_depth_override():
    p = design_point(DesignSpec(n_cus=1, freq_target_mhz=667.0,
                                pipeline_depth=0))
    assert p.config.pipeline_depth == 0
    assert p.version.pipelines > 0          # the map still inserted stages


def test_memsys_inventory_area_coupling():
    """The analytic map prices the cache organization: full-size per-CU
    banks cost area, capacity-split banks stay near the shared point."""
    from repro.core.ppa import GGPUVersion
    areas = {}
    for ms in ("shared", "banked", "banked-iso"):
        v = GGPUVersion(8, 500.0, memsys_inventory(ms, 8))
        areas[ms] = v.total_area_mm2()
    assert areas["banked"] > areas["shared"]
    assert areas["shared"] < areas["banked-iso"] < areas["banked"]
    with pytest.raises(KeyError):
        memsys_inventory("l3-victim", 8)


# ---------------------------------------------------------------------------
# Pareto dominance
# ---------------------------------------------------------------------------

def test_dominates_properties():
    assert dominates((1, 1), (2, 1))
    assert dominates((1, 1), (2, 2))
    assert not dominates((1, 1), (1, 1))          # irreflexive on ties
    assert not dominates((1, 2), (2, 1))          # incomparable
    assert not dominates((2, 1), (1, 2))
    with pytest.raises(ValueError):
        dominates((1,), (1, 2))


def test_pareto_frontier_basic():
    pts = [(1, 5), (2, 2), (5, 1), (3, 3), (2, 2)]
    front = pareto_frontier(pts, key=lambda p: p)
    # (3,3) dominated by (2,2); equal points are both kept, order stable
    assert front == [(1, 5), (2, 2), (5, 1), (2, 2)]


def test_pareto_frontier_single_and_empty():
    assert pareto_frontier([], key=lambda p: p) == []
    assert pareto_frontier([(4, 2)], key=lambda p: p) == [(4, 2)]


# ---------------------------------------------------------------------------
# the joint search (the PR's acceptance shape: >= 24 points, non-empty
# frontier, analytic-only picks excluded by the cycle model)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _search_result():
    specs = dse.enumerate_specs(cus=(1, 2, 4, 8),
                                freq_targets=(500.0, 667.0, 750.0),
                                memsys=("shared", "banked"))
    assert len(specs) >= 24
    ev = Evaluator(benches=("xcorr",), sizes={"xcorr": (16, 128)})
    return dse.search(specs=specs, evaluator=ev), ev


def test_search_frontier_excludes_analytic_pick():
    res, _ = _search_result()
    assert len(res.points) >= 24
    assert res.frontier                               # non-empty Pareto set
    assert res.excluded_analytic, \
        "the cycle model must reject some free-pipelining analytic pick"
    front_ids = {id(p) for p in res.frontier}
    for p in res.excluded_analytic:
        assert id(p) not in front_ids
        # excluded points really are dominated under cycle-accurate metrics
        assert any(dominates((q.time_us, q.area_mm2),
                             (p.time_us, p.area_mm2)) for q in res.points)
        # ...and they are the deep-pipeline high-frequency-target designs
        assert p.point.config.pipeline_depth > 0


def test_search_points_are_consistent():
    res, _ = _search_result()
    for p in res.points:
        assert p.time_us >= p.analytic_time_us > 0    # depth never helps CPI
        assert p.area_mm2 > 0 and p.power_w > 0
        assert p.energy_uj == pytest.approx(p.power_w * p.time_us)
        for m in p.per_bench.values():
            assert m.cycles >= m.analytic_cycles > 0


def test_evaluator_caches_configs():
    """Re-evaluating the same sweep must not simulate anything new, and
    config-sharing points (same depth from different freq targets) share
    cache entries (memoized on the shared serve executors)."""
    res, ev = _search_result()
    n_cached = ev.cache_size()
    ev.evaluate([p.point for p in res.points])
    assert ev.cache_size() == n_cached
    assert n_cached < 2 * len(res.points)     # folding actually happened


def test_evaluator_shares_executor_cycle_cache():
    """Two evaluators with identical bench content share the memo on the
    process-wide executor: the second evaluation dispatches nothing."""
    from repro.serve.executors import get_executor
    cfg = GGPUConfig(n_cus=2)
    ev1 = Evaluator(benches=("copy",), sizes={"copy": (16, 128)})
    info1, _ = ev1.cycles(cfg, "copy")
    dispatches = get_executor(cfg).stats.dispatches
    ev2 = Evaluator(benches=("copy",), sizes={"copy": (16, 128)})
    info2, _ = ev2.cycles(cfg, "copy")
    assert get_executor(cfg).stats.dispatches == dispatches
    assert info2["cycles"] == info1["cycles"]


def test_evaluator_check_reverifies_despite_shared_memo():
    """check=True must actually verify results even when an unchecked
    evaluator already memoized the bench on the shared executor (it
    re-simulates once, then trusts its own verification)."""
    from repro.serve.executors import get_executor
    cfg = GGPUConfig(n_cus=2)
    ev1 = Evaluator(benches=("vec_mul",), sizes={"vec_mul": (16, 128)})
    ev1.cycles(cfg, "vec_mul")
    d0 = get_executor(cfg).stats.dispatches
    ev2 = Evaluator(benches=("vec_mul",), sizes={"vec_mul": (16, 128)},
                    check=True)
    ev2.cycles(cfg, "vec_mul")
    assert get_executor(cfg).stats.dispatches == d0 + 1   # re-simulated
    ev2.cycles(cfg, "vec_mul")                            # now verified
    assert get_executor(cfg).stats.dispatches == d0 + 1


def test_artifact_schema(tmp_path):
    res, _ = _search_result()
    ref = min(res.frontier, key=lambda p: p.time_us)
    path = dse.write_artifact(tmp_path / "BENCH_dse.json", ref, res)
    art = json.loads(path.read_text())
    assert art["schema"] == "ggpu-dse/1"
    assert art["reference"] == ref.label()
    for bench, row in art["benches"].items():
        for key in ("cycles", "sim_wall_s", "fmax_mhz", "area_mm2",
                    "perf_per_area", "time_us"):
            assert key in row, (bench, key)
    assert set(art["frontier"]) == {p.label() for p in res.frontier}
    assert art["excluded_analytic"] == [p.label()
                                        for p in res.excluded_analytic]
    assert len(art["points"]) == len(res.points)
    on_front = [r["label"] for r in art["points"] if r["on_frontier"]]
    assert set(on_front) == set(art["frontier"])


def test_sweep_memsys_moved_and_shimmed():
    """The unified subsystem owns the sweep; the old planner entry point
    still works but warns."""
    sweep = dse.sweep_memsys(bench="xcorr", n_cus=(1,), sizes=(16, 128))
    assert {(1, ms) for ms in ("shared", "banked", "banked-iso")} == \
        set(sweep)
    from repro.core.planner import sweep_memsys as old_sweep
    with pytest.warns(DeprecationWarning):
        legacy = old_sweep(bench="xcorr", n_cus=(1,), sizes=(16, 128))
    assert {k: v["cycles"] for k, v in legacy.items()} == \
        {k: v["cycles"] for k, v in sweep.items()}
