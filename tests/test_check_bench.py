"""Perf-regression gate (``benchmarks/check_bench.py``): the committed
baselines must pass against themselves, and injected regressions — a
cycle drift, a broken routing invariant, a throughput collapse — must
fail the gate (this is the CI demonstration the dse-/serve-smoke jobs
rely on)."""
import copy
import json
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))

from benchmarks.check_bench import check_artifacts, main  # noqa: E402

BASELINES = ROOT / "benchmarks" / "baselines"


@pytest.fixture(scope="module")
def dse_base():
    return json.loads((BASELINES / "BENCH_dse.json").read_text())


@pytest.fixture(scope="module")
def serve_base():
    return json.loads((BASELINES / "BENCH_serve.json").read_text())


@pytest.fixture(scope="module")
def compiler_base():
    return json.loads((BASELINES / "BENCH_compiler.json").read_text())


@pytest.fixture(scope="module")
def resilience_base():
    return json.loads((BASELINES / "BENCH_resilience.json").read_text())


def test_baselines_pass_against_themselves(dse_base, serve_base,
                                           compiler_base, resilience_base):
    assert check_artifacts(copy.deepcopy(dse_base), dse_base) == []
    assert check_artifacts(copy.deepcopy(serve_base), serve_base) == []
    assert check_artifacts(copy.deepcopy(compiler_base),
                           compiler_base) == []
    assert check_artifacts(copy.deepcopy(resilience_base),
                           resilience_base) == []


def test_injected_cycle_regression_fails(dse_base):
    fresh = copy.deepcopy(dse_base)
    bench = next(iter(fresh["benches"]))
    fresh["benches"][bench]["cycles"] += 1
    violations = check_artifacts(fresh, dse_base)
    assert any("cycles" in v for v in violations), violations


def test_cycles_are_exact_not_banded(dse_base):
    """Even a 0.1% cycle drift fails — cycles carry no tolerance band."""
    fresh = copy.deepcopy(dse_base)
    for row in fresh["benches"].values():
        row["cycles"] = int(row["cycles"] * 1.001) + 1
    assert check_artifacts(fresh, dse_base)


def test_modeled_time_band(dse_base):
    fresh = copy.deepcopy(dse_base)
    for row in fresh["benches"].values():
        row["time_us"] *= 1.1                  # within ±25%
    assert check_artifacts(fresh, dse_base) == []
    for row in fresh["benches"].values():
        row["time_us"] *= 1.5                  # now far outside
    violations = check_artifacts(fresh, dse_base)
    assert any("time_us" in v for v in violations), violations


def test_frontier_membership_is_exact(dse_base):
    fresh = copy.deepcopy(dse_base)
    fresh["frontier"] = fresh["frontier"][:-1]
    violations = check_artifacts(fresh, dse_base)
    assert any("frontier" in v for v in violations), violations


def test_serve_routing_invariant(serve_base):
    fresh = copy.deepcopy(serve_base)
    fresh["fleet"]["beats_both_pins"] = False
    violations = check_artifacts(fresh, serve_base)
    assert any("beats_both_pins" in v for v in violations), violations


def test_serve_cache_and_occupancy_exact(serve_base):
    fresh = copy.deepcopy(serve_base)
    fresh["cache_hit_rate"] = 0.0
    violations = check_artifacts(fresh, serve_base)
    assert any("cache_hit_rate" in v for v in violations), violations
    fresh = copy.deepcopy(serve_base)
    fresh["batch_occupancy"] = 1.0
    assert any("batch_occupancy" in v
               for v in check_artifacts(fresh, serve_base))


def test_serve_async_speedup_gate(serve_base):
    """A pipelined drain that stops beating the sync serial drain by
    ASYNC_MIN_SPEEDUP fails the gate — on single-device runs. Multi-
    device runs partition XLA's host thread pool (which perturbs exactly
    the overlap this gate measures) and are gated on the sharded speedup
    instead."""
    from benchmarks.serve_bench import ASYNC_MIN_SPEEDUP
    fresh = copy.deepcopy(serve_base)
    fresh["async_speedup"] = ASYNC_MIN_SPEEDUP - 0.1
    fresh["n_devices"] = 1
    fresh["sharded"]["bit_exact"] = True     # isolate the async gate
    violations = check_artifacts(fresh, serve_base)
    assert any("async_speedup" in v for v in violations), violations
    fresh["n_devices"] = 8
    violations = check_artifacts(fresh, serve_base)
    assert not any("async_speedup" in v for v in violations), violations


def test_serve_sharded_gates(serve_base):
    """The sharded scheduler must stay bit-exact everywhere, and at >= 8
    simulated devices must clear SHARDED_MIN_SPEEDUP over the
    single-device async scheduler; the committed baseline (produced at 8
    devices) itself clears the gate."""
    from benchmarks.serve_bench import (SHARDED_MIN_DEVICES,
                                        SHARDED_MIN_SPEEDUP)
    assert serve_base["n_devices"] >= SHARDED_MIN_DEVICES
    assert serve_base["sharded"]["speedup"] >= SHARDED_MIN_SPEEDUP
    assert serve_base["sharded"]["bit_exact"] is True
    fresh = copy.deepcopy(serve_base)
    fresh["sharded"]["bit_exact"] = False
    violations = check_artifacts(fresh, serve_base)
    assert any("bit_exact" in v for v in violations), violations
    fresh = copy.deepcopy(serve_base)
    fresh["sharded"]["speedup"] = SHARDED_MIN_SPEEDUP - 0.2
    violations = check_artifacts(fresh, serve_base)
    assert any("sharded.speedup" in v for v in violations), violations
    # a single-device run legitimately sees no sharded speedup
    fresh["n_devices"] = 1
    violations = check_artifacts(fresh, serve_base)
    assert not any("sharded.speedup" in v for v in violations), violations


def test_serve_latency_gates(serve_base):
    """Open-loop latency: dropped requests and malformed percentiles are
    absolute failures; p50/p99 drift beyond the host band fails too."""
    fresh = copy.deepcopy(serve_base)
    fresh["latency"]["served"] = fresh["latency"]["n"] - 1
    violations = check_artifacts(fresh, serve_base)
    assert any("latency" in v and "served" in v for v in violations)
    fresh = copy.deepcopy(serve_base)
    fresh["latency"]["p99_ms"] = serve_base["latency"]["p99_ms"] * 10
    violations = check_artifacts(fresh, serve_base)
    assert any("latency.p99_ms" in v for v in violations), violations
    fresh["latency"]["p99_ms"] = serve_base["latency"]["p99_ms"] * 2
    violations = check_artifacts(fresh, serve_base)
    assert not any("latency.p99_ms" in v for v in violations), violations


def test_serve_host_throughput_band(serve_base):
    fresh = copy.deepcopy(serve_base)
    fresh["launches_per_sec"] = serve_base["launches_per_sec"] / 2
    assert check_artifacts(fresh, serve_base) == []     # within x4 band
    fresh["launches_per_sec"] = serve_base["launches_per_sec"] / 10
    violations = check_artifacts(fresh, serve_base)
    assert any("launches_per_sec" in v for v in violations), violations
    # tightened band (pinned runners): half throughput now fails
    fresh["launches_per_sec"] = serve_base["launches_per_sec"] / 2
    assert check_artifacts(fresh, serve_base, host_tol=0.25)


def test_serve_graph_gates(serve_base):
    """The kernel-graph section: the committed baseline clears the
    structural GRAPH_MIN_SPEEDUP gate (device-count independent — no
    n_devices exemption like the async gate), and injected regressions
    in speedup, bit-exactness, or cohort folding all fail."""
    from benchmarks.serve_bench import GRAPH_MIN_SPEEDUP
    g = serve_base["graph"]
    assert g["speedup"] >= GRAPH_MIN_SPEEDUP
    assert g["bit_exact"] is True
    assert 0 < g["pipelined"]["dispatches"] <= len(g["stages"])
    fresh = copy.deepcopy(serve_base)
    fresh["graph"]["speedup"] = GRAPH_MIN_SPEEDUP - 0.1
    fresh["n_devices"] = 1                       # gate binds regardless
    violations = check_artifacts(fresh, serve_base)
    assert any("graph.speedup" in v for v in violations), violations
    fresh = copy.deepcopy(serve_base)
    fresh["graph"]["bit_exact"] = False
    violations = check_artifacts(fresh, serve_base)
    assert any("graph.bit_exact" in v for v in violations), violations
    fresh = copy.deepcopy(serve_base)
    fresh["graph"]["pipelined"]["dispatches"] = \
        serve_base["graph"]["instances"] * len(g["stages"])
    violations = check_artifacts(fresh, serve_base)
    assert any("dispatches" in v for v in violations), violations


def test_serve_graph_partial_artifact(serve_base):
    """A ``sections: ["graph"]`` artifact (benchmarks.run --graph) is
    gated on its graph section only — the missing throughput/fleet/
    latency sections must NOT produce violations — both via the marker
    and via the explicit ``--section graph`` restriction."""
    from benchmarks.serve_bench import GRAPH_MIN_SPEEDUP
    partial = {"schema": serve_base["schema"], "sections": ["graph"],
               "n_devices": 1,
               "graph_speedup": serve_base["graph_speedup"],
               "graph": copy.deepcopy(serve_base["graph"])}
    assert check_artifacts(copy.deepcopy(partial), serve_base) == []
    assert check_artifacts(copy.deepcopy(partial), serve_base,
                           section="graph") == []
    bad = copy.deepcopy(partial)
    bad["graph"]["speedup"] = GRAPH_MIN_SPEEDUP - 0.1
    violations = check_artifacts(bad, serve_base, section="graph")
    assert any("graph.speedup" in v for v in violations), violations
    assert check_artifacts(copy.deepcopy(partial), serve_base,
                           section="mystery")


def test_section_flag_cli(tmp_path, serve_base):
    """``check_bench ... --section graph`` is what the graph-smoke job
    runs: a partial artifact passes, an injected regression exits 1."""
    from benchmarks.serve_bench import GRAPH_MIN_SPEEDUP
    baseline = str(BASELINES / "BENCH_serve.json")
    partial = {"schema": serve_base["schema"], "sections": ["graph"],
               "n_devices": 1,
               "graph_speedup": serve_base["graph_speedup"],
               "graph": copy.deepcopy(serve_base["graph"])}
    good = tmp_path / "graph.json"
    good.write_text(json.dumps(partial))
    assert main([str(good), baseline, "--section", "graph"]) == 0
    partial["graph"]["speedup"] = GRAPH_MIN_SPEEDUP - 0.1
    bad = tmp_path / "graph_bad.json"
    bad.write_text(json.dumps(partial))
    assert main([str(bad), baseline, "--section", "graph"]) == 1


def test_serve_async_gate_is_report_only_below_two_cpus(serve_base):
    """On a 1-CPU host there is no second core for the pipelined drain
    to overlap onto: the speedup is recorded in the artifact but the
    async gate must not bind (``host_cpus`` marks the run)."""
    from benchmarks.serve_bench import ASYNC_MIN_SPEEDUP
    fresh = copy.deepcopy(serve_base)
    fresh["async_speedup"] = ASYNC_MIN_SPEEDUP - 0.1
    fresh["n_devices"] = 1
    fresh["sharded"]["bit_exact"] = True
    fresh["host_cpus"] = 1
    violations = check_artifacts(fresh, serve_base)
    assert not any("async_speedup" in v for v in violations), violations
    # with >= 2 host CPUs (and on legacy artifacts with no marker,
    # which default to gated) the same speedup fails
    fresh["host_cpus"] = 2
    assert any("async_speedup" in v
               for v in check_artifacts(fresh, serve_base))
    del fresh["host_cpus"]
    assert any("async_speedup" in v
               for v in check_artifacts(fresh, serve_base))


def test_resilience_baseline_invariants_hold(resilience_base):
    """The committed chaos baseline satisfies the absolute resilience
    invariants: served-correctly floor, zero silent corruption, bounded
    goodput degradation, eviction fired, hedging beats no-hedging."""
    from benchmarks.resilience_bench import (MIN_GOODPUT_RATIO,
                                             MIN_SERVED_CORRECT,
                                             invariant_problems)
    assert invariant_problems(resilience_base) == []
    assert resilience_base["served_correct_fraction"] >= MIN_SERVED_CORRECT
    assert resilience_base["silently_corrupted"] == 0
    assert resilience_base["goodput_ratio"] >= MIN_GOODPUT_RATIO
    assert resilience_base["device_loss"]["evicted"] is True
    assert resilience_base["device_loss"]["lost"] == 0
    assert resilience_base["straggler"]["hedged"]["p99_ms"] \
        < resilience_base["straggler"]["unhedged"]["p99_ms"]
    assert resilience_base["straggler"]["hedges_fired"] > 0
    assert resilience_base["hedge_p99_speedup"] > 1.0


def test_resilience_silent_corruption_fails(resilience_base):
    """One silently-served corrupted result fails the gate absolutely —
    the zero-corruption invariant plus the exact count comparison."""
    fresh = copy.deepcopy(resilience_base)
    fresh["seu"]["silently_corrupted"] = 1
    violations = check_artifacts(fresh, resilience_base)
    assert any("silently_corrupted" in v for v in violations), violations


def test_resilience_counts_are_exact_not_banded(resilience_base):
    """Fault decisions are pure hashes of (seed, kind, ticket, attempt),
    so the injection/served counts are deterministic at the committed
    seed — a drift of 1 fails."""
    fresh = copy.deepcopy(resilience_base)
    fresh["seu"]["injections"] += 1
    assert any("seu.injections" in v
               for v in check_artifacts(fresh, resilience_base))
    fresh = copy.deepcopy(resilience_base)
    fresh["device_loss"]["device_state"] = {"dev0": "active",
                                            "dev1": "active"}
    violations = check_artifacts(fresh, resilience_base)
    assert any("device_state" in v for v in violations), violations


def test_resilience_hedge_and_eviction_gates(resilience_base):
    fresh = copy.deepcopy(resilience_base)
    fresh["straggler"]["hedged"]["p99_ms"] = \
        fresh["straggler"]["unhedged"]["p99_ms"] + 1
    violations = check_artifacts(fresh, resilience_base)
    assert any("hedg" in v for v in violations), violations
    fresh = copy.deepcopy(resilience_base)
    fresh["device_loss"]["evicted"] = False
    violations = check_artifacts(fresh, resilience_base)
    assert any("evicted" in v for v in violations), violations
    fresh = copy.deepcopy(resilience_base)
    fresh["device_loss"]["lost"] = 2
    violations = check_artifacts(fresh, resilience_base)
    assert any("lost" in v for v in violations), violations


def test_resilience_goodput_band_and_floor(resilience_base):
    """goodput_ratio is wall-clock-derived: it gets the host ratio band,
    but collapsing below the absolute floor fails regardless."""
    from benchmarks.resilience_bench import MIN_GOODPUT_RATIO
    fresh = copy.deepcopy(resilience_base)
    floor = MIN_GOODPUT_RATIO + 0.01
    if resilience_base["seu"]["goodput_ratio"] > floor:
        fresh["seu"]["goodput_ratio"] = floor        # within band + floor
        fresh["goodput_ratio"] = floor
        assert not any("goodput" in v
                       for v in check_artifacts(fresh, resilience_base))
    fresh["seu"]["goodput_ratio"] = MIN_GOODPUT_RATIO / 2
    fresh["goodput_ratio"] = MIN_GOODPUT_RATIO / 2
    violations = check_artifacts(fresh, resilience_base)
    assert any("goodput_ratio" in v for v in violations), violations


def test_resilience_section_flag(tmp_path, resilience_base):
    """``--section resilience`` is what the resilience-smoke job runs;
    an unknown section on a resilience artifact is a clean failure."""
    assert check_artifacts(copy.deepcopy(resilience_base),
                           resilience_base, section="resilience") == []
    violations = check_artifacts(copy.deepcopy(resilience_base),
                                 resilience_base, section="nope")
    assert violations == ["unknown resilience section 'nope'"]
    baseline = str(BASELINES / "BENCH_resilience.json")
    good = tmp_path / "res.json"
    good.write_text(json.dumps(resilience_base))
    assert main([str(good), baseline, "--section", "resilience"]) == 0
    bad_art = copy.deepcopy(resilience_base)
    bad_art["seu"]["silently_corrupted"] = 3
    bad = tmp_path / "res_bad.json"
    bad.write_text(json.dumps(bad_art))
    assert main([str(bad), baseline, "--section", "resilience"]) == 1


def test_compiler_tuned_cycle_regression_fails(compiler_base):
    """An injected tuned-cycle regression trips BOTH compiler gates: the
    absolute never-worse-than-default invariant and the exact baseline
    comparison — the satellite demonstration the compiler-smoke job
    relies on."""
    fresh = copy.deepcopy(compiler_base)
    name = next(iter(fresh["autotune"]["benches"]))
    row = fresh["autotune"]["benches"][name]
    row["tuned_cycles"] = row["default_cycles"] + 8
    violations = check_artifacts(fresh, compiler_base)
    assert any(f"autotune {name}: tuned" in v for v in violations)
    assert any(f"autotune.{name}.tuned_cycles" in v for v in violations)


def test_compiler_strictly_better_invariant(compiler_base):
    """The committed baseline itself has a strict win, and flattening
    every tuned result to its default fails the gate."""
    b = compiler_base["autotune"]["benches"]
    assert any(r["tuned_cycles"] < r["default_cycles"] for r in b.values())
    fresh = copy.deepcopy(compiler_base)
    for name, row in fresh["autotune"]["benches"].items():
        row["tuned_cycles"] = row["default_cycles"]
        row["tuned_vs_default"] = 1.0
    violations = check_artifacts(fresh, compiler_base)
    assert any("strictly faster" in v for v in violations), violations


def test_compiler_schedule_choice_and_parity_exact(compiler_base):
    """The deterministic schedule pick, suite-parity cycles, and the
    co-design frontier are all exact-compared."""
    fresh = copy.deepcopy(compiler_base)
    name = next(iter(fresh["autotune"]["benches"]))
    fresh["autotune"]["benches"][name]["best_schedule"] = "c512"
    assert any("best_schedule" in v
               for v in check_artifacts(fresh, compiler_base))
    fresh = copy.deepcopy(compiler_base)
    pname = next(iter(fresh["suite_parity"]))
    fresh["suite_parity"][pname]["cycles_dsl"] += 4
    assert any("cycles_dsl" in v
               for v in check_artifacts(fresh, compiler_base))
    fresh = copy.deepcopy(compiler_base)
    fresh["codesign"]["frontier"] = []
    violations = check_artifacts(fresh, compiler_base)
    assert any("codesign" in v for v in violations), violations


def test_compiler_baseline_invariants_hold(compiler_base):
    """The committed artifact satisfies the absolute autotune invariants
    and its co-design frontier carries (DesignPoint, Schedule) pairs."""
    from benchmarks.compiler_bench import autotune_invariants
    assert autotune_invariants(compiler_base["autotune"]) == []
    front = compiler_base["codesign"]["frontier"]
    assert front and all("schedule" in r and "|" in r["label"]
                         for r in front)
    assert compiler_base["dse"]["schema"] == "ggpu-dse/1"


def test_unknown_schema_rejected(dse_base):
    base = copy.deepcopy(dse_base)
    base["schema"] = "ggpu-mystery/9"
    assert check_artifacts(copy.deepcopy(base), base)


def test_cli_exit_codes(tmp_path, dse_base):
    good = tmp_path / "fresh.json"
    good.write_text(json.dumps(dse_base))
    baseline = str(BASELINES / "BENCH_dse.json")
    assert main([str(good), baseline]) == 0
    bad_art = copy.deepcopy(dse_base)
    bench = next(iter(bad_art["benches"]))
    bad_art["benches"][bench]["cycles"] *= 2
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_art))
    assert main([str(bad), baseline]) == 1


def test_unknown_section_rejected_gracefully(tmp_path, serve_base):
    """An unknown ``--section`` is a clean gate failure (violation list +
    exit 1), not a traceback — a registry section without a check_bench
    restriction must not silently pass the serve gate."""
    violations = check_artifacts(copy.deepcopy(serve_base), serve_base,
                                 section="nope")
    assert violations == ["unknown serve section 'nope'"]
    fresh = tmp_path / "fresh.json"
    fresh.write_text(json.dumps(serve_base))
    baseline = str(BASELINES / "BENCH_serve.json")
    assert main([str(fresh), baseline, "--section", "nope"]) == 1


def test_ci_wires_the_gate():
    """CI runs every smoke leg through one matrix job whose rows come
    from the scenario registry (``python -m repro.registry``), gating
    each artifact against the matrix-supplied baseline; the smoke matrix
    itself must reproduce the five legacy smoke legs."""
    ci = (ROOT / ".github" / "workflows" / "ci.yml").read_text()
    # the matrix is generated by the registry CLI, then consumed via
    # fromJSON — no per-section job definitions remain
    assert "repro.registry --ci-matrix smoke" in ci
    assert "fromJSON(needs.registry-enumerate.outputs.smoke)" in ci
    assert "benchmarks.check_bench ${{ matrix.artifact }}" in ci
    assert "${{ matrix.baseline }}" in ci and "${{ matrix.check_args }}" in ci
    # the PR-blocking plugin-health job
    assert "repro.registry --selfcheck" in ci
    assert "repro.registry --smoke" in ci
    assert "cancel-in-progress" in ci
    # only the tier-1 8-device leg hard-codes XLA flags now; the fleet
    # leg's flags travel in the registry matrix
    assert ci.count("--xla_force_host_platform_device_count=8") == 1

    from repro.registry.__main__ import smoke_matrix
    rows = {e["section"]: e for e in smoke_matrix()["include"]}
    assert {"dse", "serve", "graph", "compiler", "fleet"} <= set(rows)
    assert rows["dse"]["baseline"] == "benchmarks/baselines/BENCH_dse.json"
    assert rows["compiler"]["baseline"] \
        == "benchmarks/baselines/BENCH_compiler.json"
    assert sum(e["baseline"] == "benchmarks/baselines/BENCH_serve.json"
               for e in rows.values()) == 3
    assert rows["graph"]["run_args"] == "--graph --fast"
    assert rows["graph"]["check_args"] == "--section graph"
    assert rows["compiler"]["run_args"] == "--compiler --fast"
    assert "device_count=8" in rows["fleet"]["xla_flags"]

    nightly = (ROOT / ".github" / "workflows" / "nightly.yml").read_text()
    assert "schedule" in nightly
    assert "repro.registry --ci-matrix nightly" in nightly
    assert "repro.registry --run-cell" in nightly
    from repro.registry.__main__ import nightly_matrix
    sweeps = [e for e in nightly_matrix()["include"]
              if e["kind"] == "sweep"]
    # the nightly sweeps keep the full grids (no --fast) and include the
    # legacy compiler sweep, artifact upload intact
    assert any(e["run_args"] == "--compiler"
               and e["artifact"] == "BENCH_compiler.json" for e in sweeps)
    assert all("--fast" not in e["run_args"] for e in sweeps)
