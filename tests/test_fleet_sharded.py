"""Sharded fleet: the mesh-sharded scheduler must be bit-exact against
the single-device path on every bench (including at one device, where
every sharded entry point degrades gracefully), cohort bucketing must
follow the power-of-two discipline the envelope cache depends on, the
open-loop load generator must be deterministic per seed, and the fleet's
mesh slicing and load report must hold their invariants. One subprocess
test forces ``--xla_force_host_platform_device_count=8`` so real 8-way
``shard_map`` execution is exercised even when the host suite runs on a
single device."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.ggpu import programs
from repro.ggpu.engine import (GGPUConfig, cohort_rows, launch_shards,
                               run_kernel)
from repro.launch.mesh import make_launch_mesh
from repro.serve import (Fleet, Request, Scheduler, bursty_arrivals,
                         poisson_arrivals, replay)
from repro.serve.fleet import _mesh_slices

CFG = GGPUConfig(n_cus=2)
STAT_KEYS = ("cycles", "instrs", "mem_ops", "hits", "misses", "steps")

SMALL = {
    "copy": lambda: programs._copy(16, 128),
    "vec_mul": lambda: programs._vec_mul(16, 128),
    "mat_mul": lambda: programs._mat_mul(4, 8),
    "fir": lambda: programs._fir(16, 64),
    "div_int": lambda: programs._div_int(16, 64),
    "xcorr": lambda: programs._xcorr(16, 64),
    "parallel_sel": lambda: programs._parallel_sel(16, 64),
    "reduction": lambda: programs._reduction(64, 256),
}


def _variant_mem(b, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(-20, 20, b.gpu_mem.shape[0]).astype(np.int32)


def _check(result, direct):
    mem, info = result
    dmem, dinfo = direct
    np.testing.assert_array_equal(mem, dmem)
    for k in STAT_KEYS:
        assert info[k] == dinfo[k], k


# -- bit-exactness through the sharded scheduler ----------------------------

@pytest.mark.parametrize("name", sorted(SMALL))
def test_sharded_scheduler_bit_exact(name):
    """A mesh-placed scheduler returns the same bits, cycles, and stats as
    direct ``run_kernel`` on every bench — cohort and vmap-batch chunks,
    monolithic flush and budgeted drain. At one device this is the
    graceful-fallback path; under the 8-device CI leg it runs real
    ``shard_map`` dispatches."""
    b = SMALL[name]()
    progA = b.gpu_prog
    progB = np.vstack([progA, np.zeros((1, progA.shape[1]), np.int32)])
    mems = [b.gpu_mem] + [_variant_mem(b, s) for s in range(1, 5)]
    # 0..3 = A over four mems (cohort), 4 = B (batched with nothing: single)
    launches = [(progA, m) for m in mems[:4]] + [(progB, mems[4])]
    direct = [run_kernel(p, m, b.gpu_items, CFG) for p, m in launches]

    sched = Scheduler(CFG, max_batch=4, mesh=make_launch_mesh())
    assert sched.executor.shards == jax.device_count()
    assert sched.plan_batch == 4 * jax.device_count()
    for p, m in launches:
        sched.submit(p, m, b.gpu_items)
    got = {r.info["ticket"]: r for r in sched.flush()}
    assert sorted(got) == list(range(len(launches)))
    for t, d in enumerate(direct):
        _check(got[t], d)

    # budgeted drain through the same mesh placement
    sched2 = Scheduler(CFG, max_batch=2, mesh=make_launch_mesh())
    for p, m in launches:
        sched2.submit(p, m, b.gpu_items)
    out = []
    while len(sched2) or sched2.inflight_chunks:
        out += sched2.drain(budget=2)
    assert not sched2.quarantined
    got2 = {r.info["ticket"]: r for r in out}
    for t, d in enumerate(direct):
        _check(got2[t], d)


def test_sharded_matches_unsharded_scheduler():
    """Sharded and plain schedulers serve an identical submission stream
    to identical per-ticket bits (placement moves arrays, never the
    traced computation)."""
    b = SMALL["vec_mul"]()
    mems = [b.gpu_mem] + [_variant_mem(b, s) for s in range(1, 7)]
    plain = Scheduler(CFG, max_batch=4)
    shard = Scheduler(CFG, max_batch=4, mesh=make_launch_mesh())
    for m in mems:
        plain.submit(b.gpu_prog, m, b.gpu_items)
        shard.submit(b.gpu_prog, m, b.gpu_items)
    want = {r.info["ticket"]: r for r in plain.flush()}
    got = {r.info["ticket"]: r for r in shard.flush()}
    assert sorted(want) == sorted(got)
    for t in want:
        _check(got[t], want[t])


def test_scheduler_rejects_executor_plus_placement():
    with pytest.raises(ValueError):
        Scheduler(CFG, executor=Scheduler(CFG).executor,
                  mesh=make_launch_mesh())


# -- cohort bucketing -------------------------------------------------------

def test_cohort_rows_pow2_buckets():
    """Bucketed cohort sizes: >= B, a multiple of shards, power-of-two per
    shard, and monotone in B — O(log B) distinct envelopes under open-loop
    traffic."""
    for shards in (1, 2, 8):
        prev = 0
        for B in range(1, 70):
            rows = cohort_rows(B, shards)
            per = rows // shards
            assert rows >= B and rows % shards == 0
            assert per & (per - 1) == 0          # power of two
            assert rows >= prev
            prev = rows
    assert cohort_rows(1) == 1
    assert cohort_rows(5) == 8
    assert cohort_rows(9, 8) == 16
    assert cohort_rows(17, 8) == 32
    # at most log2 buckets cover any range of cohort sizes
    assert len({cohort_rows(B, 8) for B in range(1, 257)}) <= 7


def test_launch_shards_matches_device_count():
    assert launch_shards(None) == 1
    assert launch_shards(make_launch_mesh()) == jax.device_count()
    assert launch_shards(make_launch_mesh(1)) == 1


# -- open-loop load generator -----------------------------------------------

def test_loadgen_deterministic_per_seed():
    a = poisson_arrivals(100.0, 64, seed=7)
    b = poisson_arrivals(100.0, 64, seed=7)
    np.testing.assert_array_equal(a, b)
    c = poisson_arrivals(100.0, 64, seed=8)
    assert not np.array_equal(a, c)
    assert a.shape == (64,) and np.all(np.diff(a) > 0)
    # mean rate lands near the requested one
    assert 50.0 < 64 / a[-1] < 200.0

    x = bursty_arrivals(4, 8, 0.01, seed=3)
    np.testing.assert_array_equal(x, bursty_arrivals(4, 8, 0.01, seed=3))
    assert x.shape == (32,) and np.all(np.diff(x) >= 0)
    # each burst is simultaneous: only n_bursts distinct times
    assert len(np.unique(x)) == 4

    with pytest.raises(ValueError):
        poisson_arrivals(0.0, 4)
    with pytest.raises(ValueError):
        bursty_arrivals(2, 2, 0.0)


def test_replay_scheduler_open_loop():
    """Replaying a Poisson trace against a sharded scheduler serves every
    arrival with positive latency, and the report carries the percentile
    fields ``BENCH_serve.json`` (schema ggpu-serve/3) records."""
    b = SMALL["copy"]()
    mems = [_variant_mem(b, s) for s in range(8)]
    sched = Scheduler(CFG, max_batch=4, mesh=make_launch_mesh())
    arrivals = poisson_arrivals(2000.0, 8, seed=11)
    res = replay(sched, arrivals,
                 lambda i: Request(b.gpu_prog, mems[i], b.gpu_items))
    assert res.served == 8 and res.quarantined == 0
    lat = res.latencies
    assert lat.shape == (8,) and not np.isnan(lat).any()
    assert np.all(lat > 0)
    rep = res.report()
    assert 0 < rep["p50_ms"] <= rep["p99_ms"]
    assert rep["rate_per_s"] > 0


# -- fleet placement and report ---------------------------------------------

def test_mesh_slices_partition():
    """Contiguous proportional slices: cover all devices exactly once, in
    order, with empty slices only when the fleet outnumbers the mesh."""
    mesh = make_launch_mesh()
    devs = list(np.ravel(mesh.devices))
    for n in (1, 2, 3, len(devs), len(devs) + 2):
        slices = _mesh_slices(mesh, n)
        assert len(slices) == n
        flat = [d for s in slices for d in s]
        assert flat == devs                      # partition, order kept
        sizes = [len(s) for s in slices]
        nonzero = [s for s in sizes if s]
        assert max(nonzero) - min(nonzero) <= 1  # proportional
        assert sizes == sorted(sizes, reverse=True)   # largest first


def test_fleet_report_utilization_and_queue_depth():
    b = SMALL["fir"]()
    fast = GGPUConfig(n_cus=1, freq_mhz=800.0)
    wide = GGPUConfig(n_cus=8, freq_mhz=500.0)
    fleet = Fleet([("fast", fast), ("wide", wide)], max_batch=4,
                  mesh=make_launch_mesh())
    rep0 = fleet.report()
    assert set(rep0["utilization"]) == {"fast", "wide"}
    assert all(v == 0.0 for v in rep0["utilization"].values())
    assert all(v == 0 for v in rep0["queue_depth"].values())
    assert sum(rep0["shards"].values()) >= 2 or jax.device_count() == 1

    for s in range(6):
        fleet.submit(b.gpu_prog, _variant_mem(b, s), b.gpu_items)
    rep1 = fleet.report()
    assert sum(rep1["queue_depth"].values()) == 6
    out = fleet.drain()
    assert len(out) == 6 and not fleet.quarantined
    rep2 = fleet.report()
    assert all(v == 0 for v in rep2["queue_depth"].values())
    util = rep2["utilization"]
    assert max(util.values()) == 1.0             # critical-path device
    assert all(0.0 <= v <= 1.0 for v in util.values())
    assert sum(rep2["placement"].values()) == 6
    # routed results are bit-exact vs direct execution on their device
    cfg_of = {"fast": fast, "wide": wide}
    for r in out:
        i = r.info["ticket"]
        d = run_kernel(b.gpu_prog, _variant_mem(b, i), b.gpu_items,
                       cfg_of[r.info["device"]])
        np.testing.assert_array_equal(r.mem, d[0])


def test_replay_drives_fleet():
    b = SMALL["copy"]()
    mems = [_variant_mem(b, s) for s in range(6)]
    fleet = Fleet([("a", CFG), ("b", GGPUConfig(n_cus=4))], max_batch=4,
                  mesh=make_launch_mesh())
    res = replay(fleet, bursty_arrivals(2, 3, 0.002, seed=5),
                 lambda i: Request(b.gpu_prog, mems[i], b.gpu_items))
    assert res.served == 6 and res.quarantined == 0
    assert res.p99_ms >= res.p50_ms > 0


# -- real 8-way sharding in a subprocess ------------------------------------

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import jax
    import numpy as np
    assert jax.device_count() == 8, jax.device_count()
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig, run_kernel
    from repro.launch.mesh import make_launch_mesh
    from repro.serve import Scheduler
    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(16, 64)
    rng = np.random.default_rng(0)
    mems = [rng.integers(-20, 20, b.gpu_mem.shape[0]).astype(np.int32)
            for _ in range(16)]
    sched = Scheduler(cfg, max_batch=2, mesh=make_launch_mesh())
    assert sched.executor.shards == 8, sched.executor.shards
    assert sched.plan_batch == 16
    for m in mems:
        sched.submit(b.gpu_prog, m, b.gpu_items)
    got = {r.info["ticket"]: r for r in sched.flush()}
    assert sched.executor.stats.dispatches == 1   # one 16-wide dispatch
    for t, m in enumerate(mems):
        dmem, dinfo = run_kernel(b.gpu_prog, m, b.gpu_items, cfg)
        np.testing.assert_array_equal(got[t].mem, dmem)
        assert got[t].info["cycles"] == dinfo["cycles"]
    print("OK8")
""")


def test_eight_device_sharding_subprocess():
    """Force 8 host devices in a clean interpreter and assert a 16-launch
    stream resolves bit-exactly through ONE 8-way sharded dispatch."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    assert "OK8" in proc.stdout
