"""Serving subsystem: facade compatibility, bit-exact round-trips through
every launch path (cohort / vmap-batch / singleton) under both monolithic
flush and incremental drain on all 8 benches, scheduler quarantine and
priority planning, the shared executor cache, and the fleet router."""
import numpy as np
import pytest

from repro.ggpu import programs
from repro.ggpu.engine import GGPUConfig, run_kernel
from repro.ggpu.isa import Assembler
from repro.serve import (AdmissionError, Fleet, LaunchQueue, Request,
                         Scheduler, plan_chunks, plan_waves, pinned_makespan)

CFG = GGPUConfig(n_cus=2)
STAT_KEYS = ("cycles", "instrs", "mem_ops", "hits", "misses", "steps")

# reduced-size builders for all 8 benches (7 paper + reduction)
SMALL = {
    "copy": lambda: programs._copy(16, 128),
    "vec_mul": lambda: programs._vec_mul(16, 128),
    "mat_mul": lambda: programs._mat_mul(4, 8),
    "fir": lambda: programs._fir(16, 64),
    "div_int": lambda: programs._div_int(16, 64),
    "xcorr": lambda: programs._xcorr(16, 64),
    "parallel_sel": lambda: programs._parallel_sel(16, 64),
    "reduction": lambda: programs._reduction(64, 256),
}


def _pad_prog(prog, rows):
    """Append unreachable HALT rows: a distinct program (new kernel key,
    new cohort identity) with identical behavior."""
    return np.vstack([prog, np.zeros((rows, prog.shape[1]), np.int32)])


def _variant_mem(b, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(-20, 20, b.gpu_mem.shape[0]).astype(np.int32)
    return m


def _check(result, direct):
    mem, info = result
    dmem, dinfo = direct
    np.testing.assert_array_equal(mem, dmem)
    for k in STAT_KEYS:
        assert info[k] == dinfo[k], k


def test_facade_imports_unchanged():
    from repro.serve.engine import (Engine, EngineConfig,  # noqa: F401
                                    KernelLaunch, LaunchQueue)
    q = LaunchQueue(CFG)
    assert len(q) == 0
    kl = KernelLaunch(np.zeros((1, 5), np.int32), np.zeros(4, np.int32), 1,
                      "t")
    assert kl.tag == "t" and kl.priority == 0


@pytest.mark.parametrize("name", sorted(SMALL))
def test_roundtrip_all_paths_flush_and_drain(name):
    """All three launch paths, monolithic flush AND incremental drain, are
    bit-exact vs direct ``run_kernel`` — results, cycles, and stats — on
    every bench."""
    b = SMALL[name]()
    progA = b.gpu_prog
    progB = _pad_prog(progA, 1)
    progC = _pad_prog(progA, 2)
    m0, m1, m2 = b.gpu_mem, _variant_mem(b, 1), _variant_mem(b, 2)
    # tickets: 0 = B/m1 and 3 = C/m0 share a wavefront bucket (vmap batch);
    # 1, 2 = A over two mems (cohort)
    launches = [(progB, m1), (progA, m0), (progA, m2), (progC, m0)]
    direct = [run_kernel(p, m, b.gpu_items, CFG) for p, m in launches]

    q = LaunchQueue(CFG)
    for p, m in launches:
        q.submit(p, m, b.gpu_items)
    flushed = q.flush()
    assert [r.info["batch_size"] for r in flushed] == [2, 2, 2, 2]
    for res, d in zip(flushed, direct):
        _check(res, d)

    # singleton path
    q.submit(progA, m0, b.gpu_items)
    (single,) = q.flush()
    assert single.info["batch_size"] == 1
    _check(single, direct[1])

    # incremental drain with interleaved submissions
    s = Scheduler(CFG)
    s.submit(progB, m1, b.gpu_items)        # ticket 0
    s.submit(progA, m0, b.gpu_items)        # ticket 1
    first = s.drain(budget=1)               # serves only ticket 0's chunk
    s.submit(progA, m2, b.gpu_items)        # ticket 2
    s.submit(progC, m0, b.gpu_items)        # ticket 3
    rest = s.drain()
    assert len(s) == 0 and not s.quarantined
    got = {r.info["ticket"]: r for r in first + rest}
    assert sorted(got) == [0, 1, 2, 3]
    assert [r.info["ticket"] for r in rest] == sorted(
        r.info["ticket"] for r in rest)
    for t, d in enumerate(direct):
        _check(got[t], d)


def test_interleaved_drain_matches_monolithic_flush():
    """Any submit/drain interleaving returns the same per-ticket bits as
    one monolithic flush of the same submission sequence."""
    b = SMALL["copy"]()
    mems = [b.gpu_mem] + [_variant_mem(b, s) for s in range(1, 5)]
    sub = [(b.gpu_prog, m, b.gpu_items) for m in mems]

    mono = Scheduler(CFG)
    for p, m, n in sub:
        mono.submit(p, m, n)
    expect = {r.info["ticket"]: r for r in mono.flush()}

    inc = Scheduler(CFG)
    inc.submit(*sub[0])
    inc.submit(*sub[1])
    out = inc.drain()                        # cohort of 2
    inc.submit(*sub[2])
    out += inc.drain(budget=1)               # singleton
    inc.submit(*sub[3])
    inc.submit(*sub[4])
    out += inc.drain()                       # cohort of 2
    assert sorted(r.info["ticket"] for r in out) == sorted(expect)
    for r in out:
        _check(r, expect[r.info["ticket"]])


def _spinner():
    a = Assembler()
    a.label("spin").beq(0, 0, "spin")
    return a.assemble()


def test_scheduler_quarantines_poisoned_launch():
    """A launch that never halts is isolated into ``quarantined``; the
    rest of its chunk (and the drain) completes in the same call."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(16, 128)
    c2 = programs._copy(8, 64)               # W=1: shares spinner's bucket
    s = Scheduler(cfg)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, tag="good0")
    t_bad = s.submit(_spinner(), np.zeros(8, np.int32), 8, tag="spinner")
    t2 = s.submit(c2.gpu_prog, c2.gpu_mem, c2.gpu_items, tag="good2")
    t3 = s.submit(b.gpu_prog, _variant_mem(b, 3), b.gpu_items, tag="good3")
    results = s.drain()
    assert len(s) == 0
    assert [r.info["ticket"] for r in results] == [t0, t2, t3]
    assert set(s.quarantined) == {t_bad}
    assert s.quarantined[t_bad].request.tag == "spinner"
    assert "max_steps" in str(s.quarantined[t_bad].error)
    # survivors are still bit-exact
    _check(results[1], run_kernel(c2.gpu_prog, c2.gpu_mem, c2.gpu_items,
                                  cfg))
    # the scheduler remains serviceable
    s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    assert len(s.drain()) == 1
    # stats stay coherent through the failure path
    st = s.executor.stats
    assert st.trace_hits + st.trace_misses == st.dispatches


def test_fleet_surfaces_quarantined_launches():
    """A launch quarantined on its routed device appears in
    ``Fleet.quarantined`` under its *fleet* ticket; the drain still
    returns every healthy result."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(16, 128)
    fleet = Fleet([("only", cfg)])
    t0 = fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    t_bad = fleet.submit(_spinner(), np.zeros(8, np.int32), 8, tag="spin")
    results = fleet.drain()
    assert [r.info["ticket"] for r in results] == [t0]
    assert set(fleet.quarantined) == {t_bad}
    assert fleet.quarantined[t_bad].request.tag == "spin"
    assert fleet.report()["quarantined"] == [t_bad]


def test_scheduler_drain_loses_nothing_on_unexpected_failure():
    """A non-launch failure mid-drain (not a max_steps quarantine) must
    not lose work: in-flight and unexecuted requests stay pending, and
    results already computed in the same drain are buffered for the next
    one."""
    b = SMALL["copy"]()
    fir = SMALL["fir"]()
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)        # cohort of 2
    t1 = s.submit(b.gpu_prog, _variant_mem(b, 1), b.gpu_items)
    t2 = s.submit(fir.gpu_prog, fir.gpu_mem, fir.gpu_items)  # later single
    real_collect = s.executor.collect
    calls = []

    def explode_on_second(pending):
        calls.append(pending.kind)
        if len(calls) == 2:
            raise ValueError("malformed launch")
        return real_collect(pending)

    s.executor.collect = explode_on_second
    with pytest.raises(ValueError):
        s.drain()
    # the cohort completed (buffered); the single — already dispatched and
    # in flight when the failure hit — is abandoned back to pending
    assert s.pending_tickets == [t2]
    s.executor.collect = real_collect
    results = s.drain()
    assert [r.info["ticket"] for r in results] == [t0, t1, t2]
    for t, (p, m, n) in [(t0, (b.gpu_prog, b.gpu_mem, b.gpu_items)),
                         (t2, (fir.gpu_prog, fir.gpu_mem, fir.gpu_items))]:
        _check(results[[r.info["ticket"] for r in results].index(t)],
               run_kernel(p, m, n, CFG))


def test_fleet_rejects_duplicate_device_names():
    with pytest.raises(ValueError):
        Fleet([("dev", GGPUConfig(n_cus=1)), ("dev", GGPUConfig(n_cus=2))])


def test_scheduler_quarantines_whole_poisoned_cohort():
    cfg = GGPUConfig(max_steps=50)
    s = Scheduler(cfg)
    for _ in range(2):
        s.submit(_spinner(), np.zeros(8, np.int32), 8)
    assert s.drain() == []
    assert sorted(s.quarantined) == [0, 1]


def test_plan_chunks_priority_and_deadline_order():
    b = SMALL["copy"]()
    fir = SMALL["fir"]()
    reqs = [
        Request(b.gpu_prog, b.gpu_mem, b.gpu_items),                # 0
        Request(fir.gpu_prog, fir.gpu_mem, fir.gpu_items,
                priority=1),                                        # 1
        Request(b.gpu_prog, _variant_mem(b, 1), b.gpu_items),       # 2
    ]
    chunks = plan_chunks(reqs, CFG)
    # the priority-1 single jumps ahead of the earlier-ticket cohort
    assert [c.members for c in chunks] == [(1,), (0, 2)]
    # deadlines break ties within a priority class
    reqs[0].deadline_us = reqs[2].deadline_us = 5.0
    assert [c.members for c in plan_chunks(reqs, CFG)] == [(1,), (0, 2)]
    reqs[1].priority = 0
    reqs[1].deadline_us = 1.0
    assert [c.members for c in plan_chunks(reqs, CFG)] == [(1,), (0, 2)]
    # defaults reproduce the legacy first-ticket order exactly
    legacy = [Request(r.prog, r.mem0, r.n_items) for r in reqs]
    assert [c.members for c in plan_chunks(legacy, CFG)] == [(0, 2), (1,)]


def test_scheduler_admission_limit():
    b = SMALL["copy"]()
    s = Scheduler(CFG, max_pending=1)
    s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    with pytest.raises(AdmissionError):
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    s.drain()
    s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)   # freed by the drain


def test_plan_waves_slots():
    assert plan_waves(range(5), 2) == [[0, 1], [2, 3], [4]]
    assert plan_waves([], 3) == []
    with pytest.raises(ValueError):
        plan_waves([1], 0)


def test_executor_envelope_cache_hits_on_repeat_traffic():
    """Repeat traffic with the same envelope is a trace-cache hit; the
    stats expose the hit rate BENCH_serve.json reports."""
    b = SMALL["vec_mul"]()
    s = Scheduler(CFG)
    for seed in (1, 2):
        s.submit(b.gpu_prog, _variant_mem(b, seed), b.gpu_items)
    s.drain()
    stats0 = s.executor.stats
    assert stats0.dispatches == 1 and stats0.trace_misses == 1
    for seed in (3, 4):
        s.submit(b.gpu_prog, _variant_mem(b, seed), b.gpu_items)
    s.drain()
    assert s.executor.stats.trace_hits == 1
    assert s.executor.stats.batch_occupancy == 2.0
    assert 0 < s.executor.stats.hit_rate <= 0.5


def test_fleet_routes_mixed_trace_and_beats_pinning():
    """Mixed trace over two complementary configs: wide launches land on
    the many-CU device, narrow ones on the high-clock device, results stay
    bit-exact, and the fleet's modeled makespan beats pinning the whole
    trace to either config."""
    small_cfg = GGPUConfig(n_cus=1, freq_mhz=667.0)
    wide_cfg = GGPUConfig(n_cus=8, freq_mhz=500.0)
    wide_b = programs._copy(16, 1024)        # W=16: wants CUs
    narrow_b = programs._reduction(64, 256)  # W=1: wants clock
    trace = []
    for seed in range(3):
        m = np.random.default_rng(seed).integers(
            -50, 50, wide_b.gpu_mem.shape[0]).astype(np.int32)
        trace.append((wide_b.gpu_prog, m, wide_b.gpu_items))
        m = np.random.default_rng(10 + seed).integers(
            -50, 50, narrow_b.gpu_mem.shape[0]).astype(np.int32)
        trace.append((narrow_b.gpu_prog, m, narrow_b.gpu_items))

    fleet = Fleet([("small", small_cfg), ("wide", wide_cfg)])
    tickets = [fleet.submit(p, m, n) for p, m, n in trace]
    results = fleet.drain()
    assert [r.info["ticket"] for r in results] == tickets
    report = fleet.report()
    assert all(report["placement"][d] > 0 for d in ("small", "wide"))
    # routed results are bit-exact on their device's config
    by_cfg = {"small": small_cfg, "wide": wide_cfg}
    for (p, m, n), res in zip(trace, results):
        _check(res, run_kernel(p, m, n, by_cfg[res.info["device"]]))
    # the routed fleet beats both pinned placements on modeled wall-clock
    for cfg in (small_cfg, wide_cfg):
        assert fleet.makespan_us() < pinned_makespan(cfg, trace)


def test_fleet_shard_width_wins_large_cohorts():
    """Two identical configs, one backed by a 4-wide physical mesh slice
    (stubbed via ``Executor.shards`` — real meshes are covered by the
    sharding subprocess tests): the router discounts the wide device's
    backlog by its shard width, so a large same-shape cohort
    overwhelmingly lands there, while modeled compute (busy_us /
    makespan) stays shard-agnostic."""
    b = programs._copy(16, 128)
    fleet = Fleet([("narrow", CFG), ("wide", CFG)], max_batch=4)
    wide = next(d for d in fleet.devices if d.name == "wide")
    wide.scheduler.executor.shards = 4          # stub the physical width

    for seed in range(16):
        fleet.submit(b.gpu_prog, _variant_mem(b, seed), b.gpu_items)
    rep = fleet.report()
    assert rep["placement"]["wide"] > rep["placement"]["narrow"]
    # estimate_us itself is shard-agnostic; only the finish model differs
    req = Request(b.gpu_prog, b.gpu_mem, b.gpu_items)
    narrow = next(d for d in fleet.devices if d.name == "narrow")
    assert fleet.estimate_us(wide, req) == fleet.estimate_us(narrow, req)
    assert fleet.finish_us(wide, req) < fleet.finish_us(narrow, req)

    results = fleet.drain()
    assert len(results) == 16
    for res in results:
        _check(res, run_kernel(b.gpu_prog,
                               _variant_mem(b, res.info["ticket"]),
                               b.gpu_items, CFG))
    # modeled compute accounting is unchanged by the routing discount
    rep = fleet.report()
    assert rep["busy_us"]["wide"] >= rep["busy_us"]["narrow"]
    assert fleet.makespan_us() == max(rep["busy_us"].values())


def test_engine_prefill_eos_regression():
    """A sequence whose *first* generated token (sampled from prefill) is
    EOS must stop immediately instead of decoding for max_new steps."""
    import jax

    from repro.configs import get_smoke
    from repro.models.schema import init_params
    from repro.serve.engine import Engine, EngineConfig

    cfg = get_smoke("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    free = Engine(cfg, params, EngineConfig(slots=1, temperature=0.0)) \
        .generate([[1, 2]], max_new=6)[0]
    first = free[2]                       # the prefill-sampled token
    out = Engine(cfg, params,
                 EngineConfig(slots=1, temperature=0.0, eos_id=int(first))) \
        .generate([[1, 2]], max_new=6)[0]
    assert out == [1, 2, int(first)]
