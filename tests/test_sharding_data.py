"""Sharding rules (divisibility invariants), MeshPlanner, data pipeline
determinism, checkpoint roundtrip + resharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.core.meshplanner import Knobs, estimate, plan
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import SHAPES
from repro.models.schema import abstract_params, init_params, param_axes
from repro.optim import adamw
from repro.train import checkpoint


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class FakeRules:
    """Divisibility-check logic without a real 256-device mesh."""

    def __init__(self, sizes):
        from repro.sharding.rules import ShardingRules
        self._sizes = sizes
        self.dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
        self.tp_axis = "model"
        self.fsdp = True
        self.seq_shard = True
        self.param_rules = dict(
            __import__("repro.sharding.rules", fromlist=["DEFAULT_PARAM_RULES"]
                       ).DEFAULT_PARAM_RULES)
        self.axes_size = ShardingRules.axes_size.__get__(self)
        self._fits = ShardingRules._fits.__get__(self)
        self.param_spec = ShardingRules.param_spec.__get__(self)
        self.activation_spec = ShardingRules.activation_spec.__get__(self)


RULES = FakeRules({"data": 16, "model": 16})


@given(st.integers(1, 2048), st.integers(1, 2048),
       st.sampled_from(["vocab", "ffn", "qkv", "kv", "embed", "experts"]))
@settings(max_examples=60, deadline=None)
def test_param_spec_always_divides(dim0, dim1, ax):
    """Whatever the shape, the chosen PartitionSpec divides every dim —
    the invariant that makes every arch lower on every mesh."""
    spec = RULES.param_spec((dim0, dim1), (ax, "embed"))
    for dim, s in zip((dim0, dim1), spec):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        n = 1
        for a in axes:
            n *= RULES._sizes[a]
        assert dim % n == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_match_schema(arch):
    """Every param has a logical-axes tuple of matching rank."""
    cfg = get_config(arch)
    ab = abstract_params(cfg)
    ax = param_axes(cfg)
    flat_p = jax.tree.leaves(ab)
    flat_a = jax.tree.leaves(ax, is_leaf=lambda x: isinstance(x, tuple))
    assert len(flat_p) == len(flat_a)
    for p, a in zip(flat_p, flat_a):
        assert len(p.shape) == len(a), (p.shape, a)


def test_activation_spec_fallbacks():
    # batch 1 cannot shard over dp -> None
    s = RULES.activation_spec("acts", (1, 4096, 1024))
    assert s[0] is None
    # seq not divisible -> no SP
    s = RULES.activation_spec("acts", (256, 4095, 1024))
    assert s[1] is None
    # kv heads below axis size -> sequence-sharded cache
    s = RULES.activation_spec("kv_cache", (128, 32768, 8, 128))
    assert s[1] == "model" and s[2] is None
    # kv heads divisible -> head-sharded cache
    s = RULES.activation_spec("kv_cache", (128, 32768, 16, 128))
    assert s[2] == "model"


# ---------------------------------------------------------------------------
# MeshPlanner
# ---------------------------------------------------------------------------

def test_meshplanner_all_cells_fit_or_explain():
    for a in ARCH_IDS:
        for sname, s in SHAPES.items():
            p = plan(get_config(a), s)
            assert p.fits or p.reason, (a, sname)


def test_meshplanner_memory_actions_monotone():
    """Each division action reduces estimated activation memory."""
    cfg = get_config("qwen2-vl-72b")
    s = SHAPES["train_4k"]
    base = estimate(cfg, s, Knobs(remat="none", seq_shard=False, fsdp=False))
    remat = estimate(cfg, s, Knobs(remat="full", seq_shard=False, fsdp=False))
    sp = estimate(cfg, s, Knobs(remat="full", seq_shard=True, fsdp=False))
    fsdp = estimate(cfg, s, Knobs(remat="full", seq_shard=True, fsdp=True))
    assert remat.act_bytes < base.act_bytes
    assert sp.act_bytes < remat.act_bytes
    assert fsdp.params_bytes < sp.params_bytes
    assert fsdp.total_bytes < base.total_bytes


def test_meshplanner_flash_kernel_cuts_memory_term():
    cfg = get_config("granite-8b")
    s = SHAPES["train_4k"]
    off = estimate(cfg, s, Knobs(use_flash_kernel=False))
    on = estimate(cfg, s, Knobs(use_flash_kernel=True))
    assert on.compute_s < off.compute_s     # no masked-pair waste


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_seekable():
    dc = DataConfig(vocab_size=1000, seq_len=64, global_batch=8)
    src = SyntheticLM(dc)
    b1 = src.batch_at(7)
    b2 = src.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are the shifted stream
    assert b1["tokens"].shape == b1["labels"].shape == (8, 64)


def test_data_host_sharding_disjoint():
    base = DataConfig(vocab_size=1000, seq_len=32, global_batch=8,
                      host_count=2)
    h0 = SyntheticLM(DataConfig(**{**base.__dict__, "host_index": 0}))
    h1 = SyntheticLM(DataConfig(**{**base.__dict__, "host_index": 1}))
    b0, b1 = h0.batch_at(3), h1.batch_at(3)
    assert b0["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_data_tokens_in_range(step):
    dc = DataConfig(vocab_size=503, seq_len=16, global_batch=2)
    b = SyntheticLM(dc).batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 503


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("granite-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    checkpoint.save(tmp_path, 5, params, opt)
    assert checkpoint.latest_step(tmp_path) == 5
    p2, o2, man = checkpoint.restore(
        tmp_path, 5, abstract_params(cfg),
        adamw.AdamWState(m=abstract_params(cfg), v=abstract_params(cfg),
                         step=jax.ShapeDtypeStruct((), jnp.int32)))
    assert man["step"] == 5
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2.step) == 0


def test_checkpoint_elastic_restore_single_device(tmp_path):
    """A checkpoint restores onto a different device layout (here: the
    trivial 1-device mesh) — the elastic-restart mechanism."""
    import numpy as _np
    cfg = get_smoke("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(1))
    checkpoint.save(tmp_path, 1, params)
    mesh = jax.sharding.Mesh(
        _np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    from repro.sharding.rules import make_rules, param_shardings
    rules = make_rules(mesh)
    p2, _, _ = checkpoint.restore(tmp_path, 1, abstract_params(cfg),
                                  shardings=param_shardings(rules, cfg))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir from a crashed save is never picked up."""
    cfg = get_smoke("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    checkpoint.save(tmp_path, 1, params)
    (tmp_path / "step_00000009.tmp").mkdir()
    assert checkpoint.latest_step(tmp_path) == 1


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback():
    """Error feedback: the accumulated quantization error stays bounded and
    compressed-grad sums track true-grad sums over steps."""
    from repro.optim import compress
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 0.01, (64, 64)), jnp.float32)
    grads = {"w": g_true}
    st_ = compress.init(grads)
    total_deq = jnp.zeros_like(g_true)
    for _ in range(10):
        deq, st_, stats = compress.compress_grads(grads, st_)
        total_deq = total_deq + deq["w"]
    # with error feedback the cumulative compressed signal converges to the
    # cumulative true signal
    err = float(jnp.max(jnp.abs(total_deq - 10 * g_true)))
    assert err < float(jnp.max(jnp.abs(g_true)))
    assert stats["ratio"] == 4.0
