"""Optional-``hypothesis`` shim so the suite runs on a bare jax+numpy env.

``from _hypothesis_compat import given, settings, st`` is a drop-in for
``from hypothesis import given, settings, strategies as st``: when
hypothesis is installed the real objects are re-exported; when it is not,
``@given(...)`` turns the property test into a single pytest skip and the
``st`` stub absorbs any strategy expression at decoration time.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs every strategy construction (st.integers(...).filter(...)
        etc.) without evaluating anything."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # deliberately zero-arg (no functools.wraps): pytest must not
            # mistake the strategy-filled parameters for fixtures
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(property test; pip install hypothesis)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn
        return decorate
