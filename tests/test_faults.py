"""Deterministic fault injection (``repro.faults``): the seed-keyed
FaultPlan draw primitive, the FAULTS registry axis, injector
transparency with an inactive plan (injection-off is byte-identical),
and full-run determinism — same seed + plan => identical decision logs
and byte-identical results across runs, including through a drain that
returns with an abandoned hedge-loser chunk still in flight."""
import numpy as np
import pytest

from repro.faults import FaultInjector, FaultPlan
from repro.ggpu import programs
from repro.ggpu.engine import GGPUConfig, run_kernel
from repro.registry import FAULTS
from repro.serve import Fleet, Request, Scheduler
from repro.serve.request import result_checksum

CFG = GGPUConfig(n_cus=2)


def _copy_bench():
    return programs._copy(16, 128)


def _mems(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-30, 30, b.gpu_mem.shape[0]).astype(np.int32)
            for _ in range(n)]


# ------------------------------------------------------------- FaultPlan

def test_plan_draws_are_pure_and_seed_keyed():
    """Decisions are pure functions of (seed, kind, ticket, attempt):
    two plan instances agree, different seeds disagree somewhere, and a
    retry (attempt+1) is a fresh draw."""
    a = FaultPlan(seed=7, seu_rate=0.5)
    b = FaultPlan(seed=7, seu_rate=0.5)
    hits = [a.seu_hit(t, 0) for t in range(64)]
    assert hits == [b.seu_hit(t, 0) for t in range(64)]
    assert any(hits) and not all(hits)          # rate 0.5 lands both ways
    other = FaultPlan(seed=8, seu_rate=0.5)
    assert hits != [other.seu_hit(t, 0) for t in range(64)]
    assert hits != [a.seu_hit(t, 1) for t in range(64)]  # attempt-aware


def test_plan_rate_monotone_and_extremes():
    never = FaultPlan(seed=3, seu_rate=0.0, seu_post_rate=0.0)
    always = FaultPlan(seed=3, seu_rate=1.0, seu_post_rate=1.0)
    some = FaultPlan(seed=3, seu_rate=0.4)
    for t in range(32):
        assert not never.seu_hit(t, 0) and not never.post_hit(t, 0)
        assert always.seu_hit(t, 0) and always.post_hit(t, 0)
        # a launch hit at rate r stays hit at any higher rate (the draw
        # is shared; only the threshold moves)
        if some.seu_hit(t, 0):
            assert always.seu_hit(t, 0)


def test_plan_flip_coordinates_in_range():
    plan = FaultPlan(seed=1, seu_rate=1.0, seu_post_rate=1.0)
    for t in range(32):
        word, bit = plan.seu_flip(t, 0, msize=17)
        assert 0 <= word < 17
        assert 0 <= bit < 31          # int32 sign bit is never drawn
        word, bit = plan.post_flip(t, 0, msize=5)
        assert 0 <= word < 5 and 0 <= bit < 31


def test_plan_inactive_flag_and_stuck():
    assert not FaultPlan().active
    assert FaultPlan(seu_rate=0.1).active
    assert FaultPlan(stuck_devices=("d",)).active
    plan = FaultPlan(stuck_devices=("dev0",), stuck_after=2)
    assert not plan.stuck("dev0", 1)
    assert plan.stuck("dev0", 2) and plan.stuck("dev0", 5)
    assert not plan.stuck("dev1", 99)


# ----------------------------------------------------- FAULTS axis

def test_faults_axis_builtins():
    assert {"none", "seu", "straggler", "device-loss"} \
        <= set(FAULTS.names())
    sc = FAULTS.get("none")(seed=3)
    assert not sc.plan.active and sc.resilience is None and not sc.audit
    sc = FAULTS.get("seu")(seed=3)
    assert sc.plan.active and sc.audit and sc.retry is not None
    sc = FAULTS.get("straggler")(seed=3)
    assert sc.resilience.hedge is not None and sc.timeout_s
    sc = FAULTS.get("device-loss")(seed=3)
    assert sc.plan.stuck_devices == ("dev0",)


# --------------------------------------- injector off == byte-identical

def test_inactive_injector_is_byte_identical_passthrough():
    """An interposed injector with an inactive plan changes nothing:
    same bits, same stats, empty decision log — the committed-baseline
    byte-identity guarantee."""
    b = _copy_bench()
    mems = _mems(b, 4)

    plain = Scheduler(CFG, max_batch=2)
    for m in mems:
        plain.submit(b.gpu_prog, m, b.gpu_items)
    expect = plain.flush()

    wrapped = Scheduler(CFG, max_batch=2)
    inj = FaultInjector("d", wrapped.executor, FaultPlan(seed=5))
    wrapped.executor = inj
    for m in mems:
        wrapped.submit(b.gpu_prog, m, b.gpu_items)
    got = wrapped.flush()

    assert inj.injected == []
    assert inj.cfg is CFG                 # protocol passthrough
    assert len(got) == len(expect) == 4
    for g, e in zip(got, expect):
        np.testing.assert_array_equal(g.mem, e.mem)
        assert g.info["cycles"] == e.info["cycles"]


# --------------------------------------------- full-run determinism

def _chaos_run(seed: int, n: int = 10):
    """One audited chaos serve under the ``seu`` scenario; returns every
    determinism-relevant surface for cross-run comparison."""
    b = _copy_bench()
    mems = _mems(b, n)
    refs = [run_kernel(b.gpu_prog, m, b.gpu_items, CFG) for m in mems]
    sc = FAULTS.get("seu")(seed=seed, rate=0.6, max_retries=4)
    fleet = Fleet([("dev0", CFG), ("dev1", GGPUConfig(n_cus=1))],
                  max_batch=4, **sc.fleet_kwargs())
    for m, ref in zip(mems, refs):
        fleet.submit_request(Request(
            b.gpu_prog, m, b.gpu_items, audit=result_checksum(ref[0])))
    results = fleet.drain()
    return (sc.decision_log(),
            tuple(r.info["ticket"] for r in results),
            tuple(np.asarray(r.mem, np.int32).tobytes() for r in results),
            tuple(sorted(fleet.quarantined)),
            refs)


def test_same_seed_same_decisions_and_bits():
    """Two runs at one seed are indistinguishable: identical injection
    decision logs (the determinism surface), identical served tickets,
    byte-identical result memories, identical quarantine sets — through
    retry interleaving and checksum-audit re-dispatches."""
    log1, served1, bits1, quar1, refs = _chaos_run(seed=0)
    log2, served2, bits2, quar2, _ = _chaos_run(seed=0)
    assert log1 == log2
    assert len(log1) > 0                  # chaos actually happened
    assert served1 == served2
    assert bits1 == bits2
    assert quar1 == quar2
    # and the audit held: every served result is bit-exact (corruption
    # was retried, never silently returned)
    for t, raw in zip(served1, bits1):
        np.testing.assert_array_equal(
            np.frombuffer(raw, np.int32), refs[t][0])


def test_different_seed_different_decisions():
    log0 = _chaos_run(seed=0)[0]
    log9 = _chaos_run(seed=9)[0]
    assert log0 != log9


def test_determinism_through_abandoned_drain():
    """Same-seed determinism holds through the abandoned-loser path: a
    resilient drain that returns while a hedge-loser chunk is still in
    flight (discarded by a later drain's collect) serves the same bits
    both runs."""
    def run():
        b = _copy_bench()
        mems = _mems(b, 3, seed=2)
        plan = FaultPlan(seed=0, straggler_rate=1.0, straggler_delay_s=0.4)

        def wrap(name, ex):
            # only dev0 straggles; dev1 is the clean hedge target
            return FaultInjector(name, ex, plan) if name == "dev0" else ex

        from repro.serve.fleet import FleetResilience, HedgePolicy
        fleet = Fleet([("dev0", CFG), ("dev1", CFG)], max_batch=1,
                      resilience=FleetResilience(
                          hedge=HedgePolicy(after_s=0.03)),
                      timeout_s=5.0, executor_wrap=wrap)
        for m in mems:
            fleet.submit(b.gpu_prog, m, b.gpu_items)
        out = fleet.drain()
        injector = fleet.devices[0].scheduler.executor
        log = tuple(sorted(injector.injected))
        import time
        time.sleep(0.5)                   # let the abandoned holds expire
        late = fleet.drain()              # losers collected and discarded
        assert late == []
        return (log, tuple(r.info["ticket"] for r in out),
                tuple(np.asarray(r.mem, np.int32).tobytes() for r in out))

    log1, served1, bits1 = run()
    log2, served2, bits2 = run()
    assert log1 == log2 and len(log1) >= 1
    assert served1 == served2 == (0, 1, 2)
    assert bits1 == bits2


def test_seu_flip_lands_in_staged_memory():
    """A pre-dispatch SEU really flips the staged bit: the result is
    bit-exact with running the kernel over the host-side image with the
    drawn bit flipped — the corruption is in the staged input, not a
    host-side fiction."""
    b = _copy_bench()
    plan = FaultPlan(seed=4, seu_rate=1.0)
    s = Scheduler(CFG)
    inj = FaultInjector("d", s.executor, plan)
    s.executor = inj
    m = _mems(b, 1)[0]
    s.submit(b.gpu_prog, m, b.gpu_items)
    (res,) = s.flush()
    assert [e[0] for e in inj.injected] == ["seu"]
    word, bit = plan.seu_flip(0, 0, int(m.shape[0]))
    flipped = m.copy()
    flipped[word] ^= np.int32(1) << bit
    expect = run_kernel(b.gpu_prog, flipped, b.gpu_items, CFG)
    np.testing.assert_array_equal(res.mem, expect[0])


def test_stuck_device_never_ready():
    plan = FaultPlan(seed=0, stuck_devices=("d",), stuck_after=0)
    from repro.serve.executors import DeviceTimeout, Executor
    ex = Executor(CFG, timeout_s=0.05)
    inj = FaultInjector("d", ex, plan)
    b = _copy_bench()
    pending = inj.submit("single",
                         [Request(b.gpu_prog, b.gpu_mem, b.gpu_items)])
    assert not inj.chunk_ready(pending)
    with pytest.raises(DeviceTimeout):
        inj.collect(pending)
