"""Roofline HLO-walker unit tests (synthetic HLO), serving-engine
behaviours, and the BinCorpus file-backed data path."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.roofline.hlo_parse import HloCost, split_computations


SYNTH = """\
HloModule m

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups=[4,4]<=[16], to_apply=%add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_walker_trip_count_multiplies():
    cost = HloCost(SYNTH).entry_cost()
    # one 8x8x8 dot per iteration x 5 trips
    assert cost.flops == 5 * 2 * 8 * 8 * 8
    assert cost.coll_counts["all-reduce"] == 5
    # ring all-reduce over groups of 4: 2*(4-1)/4 * 256 bytes, x5
    assert abs(cost.coll_ring - 5 * 2 * 0.75 * 256) < 1e-6


def test_hlo_walker_splits_computations():
    comps = split_computations(SYNTH)
    assert {"body", "cond", "main", "__entry__"} <= set(comps)
    assert comps["__entry__"] is comps["main"]


def test_hlo_walker_comment_immunity():
    txt = SYNTH.replace("%w = (s32[], f32[8,8])",
                        "%w = (s32[], /*index=1*/f32[8,8])")
    cost = HloCost(txt).entry_cost()
    assert cost.flops == 5 * 2 * 8 * 8 * 8


def test_engine_eos_stops_early():
    from repro.configs import get_smoke
    from repro.models.schema import init_params
    from repro.serve.engine import Engine, EngineConfig

    cfg = get_smoke("qwen1.5-0.5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    # find the token the greedy head emits, then use it as EOS
    e0 = Engine(cfg, params, EngineConfig(slots=1, temperature=0.0))
    free = e0.generate([[1, 2]], max_new=4)[0]
    eos = free[3]                         # second generated token
    e1 = Engine(cfg, params, EngineConfig(slots=1, temperature=0.0,
                                          eos_id=int(eos)))
    out = e1.generate([[1, 2]], max_new=8)[0]
    assert out[-1] == eos and len(out) <= len(free) + 4


def test_bincorpus_deterministic(tmp_path):
    from repro.data.pipeline import BinCorpus, DataConfig
    toks = np.arange(10_000, dtype=np.int32)
    p1 = tmp_path / "s1.bin"
    p2 = tmp_path / "s2.bin"
    toks[:6000].tofile(p1)
    toks[6000:].tofile(p2)
    dc = DataConfig(vocab_size=50_000, seq_len=32, global_batch=4)
    src = BinCorpus(dc, [p1, p2])
    b1 = src.batch_at(3)
    b2 = src.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are the +1 shift of tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    # crosses the file boundary without corruption
    row = b1["tokens"][0]
    diffs = np.diff(row.astype(np.int64))
    assert np.all((diffs == 1) | (diffs < 0))   # contiguous or wrapped
