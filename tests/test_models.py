"""Model-math unit tests: attention variants, recurrent mixers, MoE, loss
chunking — each against an independent naive reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import recurrent as R
from repro.models.attention import (KVCache, blocked_attention,
                                    decode_attention, windowed_attention)
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, apply_mrope, cross_entropy
from repro.models.schema import init_params, layer_groups

RNG = jax.random.PRNGKey(0)


def _naive_attn(q, k, v, causal, window, scale):
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    qf = q.reshape(b, sq, hkv, h // hkv, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, hd)


@pytest.mark.parametrize("s,cq,ck,causal", [
    (64, 16, 16, True), (100, 32, 16, True), (64, 64, 64, False),
    (37, 8, 16, True),
])
def test_blocked_attention_vs_naive(s, cq, ck, causal):
    q = jax.random.normal(RNG, (2, s, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, 2, 16))
    out = blocked_attention(q, k, v, causal=causal, window=0, q_offset=0,
                            chunk_q=cq, chunk_kv=ck, scale=0.25)
    ref = _naive_attn(q, k, v, causal, 0, 0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("s,w", [(64, 16), (100, 32), (48, 48)])
def test_windowed_attention_vs_naive(s, w):
    q = jax.random.normal(RNG, (1, s, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, s, 2, 16))
    out = windowed_attention(q, k, v, window=w, chunk_q=16, scale=0.25)
    ref = _naive_attn(q, k, v, True, w, 0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_mlstm_chunk_size_invariance():
    b, s, h, hd = 1, 48, 2, 8
    ks = jax.random.split(RNG, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd)) * hd ** -0.5
    v = jax.random.normal(ks[2], (b, s, h, hd))
    logi = jax.random.normal(ks[3], (b, s, h))
    logf = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, h)))
    st_ = R.mlstm_state_init(b, h, hd, 2 * 16)
    outs = []
    for chunk in (4, 12, 48):
        hseq, _ = R.mlstm_scan(q, k, v, logi, logf, st_, chunk)
        outs.append(hseq)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]),
                               atol=1e-4)


def test_mlstm_matches_stepwise_recurrence():
    """Chunkwise-parallel form == the xLSTM per-step recurrent definition."""
    b, s, h, hd = 1, 12, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, hd))
    logi = jax.random.normal(ks[3], (b, s, h))
    logf = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, h)))
    st_ = R.mlstm_state_init(b, h, hd, 8)
    hs, _ = R.mlstm_scan(q, k, v, logi, logf, st_, chunk=s)
    # naive per-step
    C = np.zeros((hd, hd)); n = np.zeros(hd); m = -30.0
    for t in range(s):
        mt = max(float(logf[0, t, 0]) + m, float(logi[0, t, 0]))
        fw = np.exp(float(logf[0, t, 0]) + m - mt)
        iw = np.exp(float(logi[0, t, 0]) - mt)
        kt = np.asarray(k[0, t, 0]); vt = np.asarray(v[0, t, 0])
        C = fw * C + iw * np.outer(kt, vt)
        n = fw * n + iw * kt
        m = mt
        qt = np.asarray(q[0, t, 0])
        denom = max(abs(float(qt @ n)), np.exp(-m))
        expect = (qt @ C) / denom
        np.testing.assert_allclose(np.asarray(hs[0, t, 0]), expect,
                                   atol=1e-4)


def test_rglru_linear_scan_vs_loop():
    b, s, d = 2, 33, 8
    a = jax.nn.sigmoid(jax.random.normal(RNG, (b, s, d)))
    bb = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    h0 = jax.random.normal(jax.random.PRNGKey(2), (b, d))
    hs, hf = R.linear_scan(a, bb, h0)
    h = h0
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h),
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), atol=1e-5)


def test_conv_state_consistency():
    """Streaming causal conv (with state) == full-sequence conv."""
    p = {"w": jax.random.normal(RNG, (4, 8)) * 0.3,
         "b": jnp.zeros(8)}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 8))
    y_full, _ = R.causal_conv(p, x, None)
    y1, st_ = R.causal_conv(p, x[:, :13], None)
    y2, _ = R.causal_conv(p, x[:, 13:], st_)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)


def test_moe_routing_mass_conserved():
    """With enough capacity, every token's gate mass reaches the output:
    MoE(x) with identity-ish experts stays bounded; and aux loss ~ 1 for
    uniform routing."""
    from repro.models.moe import apply_moe
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      n_experts=4, topk=2, capacity_factor=4.0)
    params = init_params(cfg, RNG)
    p = params["groups"]["0"]["0"]["mlp"]
    p = jax.tree.map(lambda x: x[0], p)       # unstack layer dim
    x = jax.random.normal(RNG, (2, 8, 16))
    out, aux = apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert 0.0 < float(aux) < 1.0             # coef 0.01, balance ~1


def test_moe_capacity_drops_tokens():
    """Tiny capacity drops tokens (output scales down) — the documented
    train/serve inconsistency of capacity-based MoE."""
    from repro.models.moe import apply_moe
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      n_experts=4, topk=1, capacity_factor=8.0)
    params = init_params(cfg, RNG)
    p = jax.tree.map(lambda x: x[0], params["groups"]["0"]["0"]["mlp"])
    x = jax.random.normal(RNG, (1, 32, 16))
    full, _ = apply_moe(p, x, cfg)
    tiny, _ = apply_moe(p, x, cfg.replace(capacity_factor=0.1))
    assert float(jnp.linalg.norm(tiny)) < float(jnp.linalg.norm(full))


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative positions: <rope(q,i), rope(k,j)>
    depends only on i - j."""
    hd = 8
    q = jax.random.normal(RNG, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 10_000.0)
        kj = apply_rope(k, jnp.array([[j]]), 10_000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(0, 0) - float(jnp.sum(q * k))) < 1e-4


def test_mrope_equals_rope_for_equal_streams():
    """M-RoPE with identical (t, h, w) positions reduces to plain RoPE."""
    hd = 16
    x = jax.random.normal(RNG, (1, 6, 2, hd))
    pos = jnp.arange(6)[None]
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, jnp.broadcast_to(pos, (3, 1, 6)), 1e4, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_loss_matches_full():
    from repro.configs import get_smoke
    from repro.models import model as M
    cfg = get_smoke("granite-8b")
    params = init_params(cfg, RNG)
    x = jax.random.normal(RNG, (2, 32, cfg.d_model), jnp.bfloat16)
    labels = jax.random.randint(RNG, (2, 32), 0, cfg.vocab_size)
    full_logits = M.unembed(params, x, cfg) if False else None
    from repro.models.layers import unembed
    logits = unembed(params, x, cfg)
    full = cross_entropy(logits, labels)
    chunked = M.chunked_lm_loss(params, cfg, x, labels, chunk=8)
    assert abs(float(full) - float(chunked)) < 1e-3


def test_layer_groups_cover_all_layers():
    for unit, nl in [(("rglru", "rglru", "local"), 26),
                     (("mlstm", "mlstm", "mlstm", "slstm"), 24),
                     (("attn",), 48)]:
        cfg = ModelConfig(name="t", family="x", n_layers=nl, d_model=8,
                          n_heads=1, n_kv_heads=1, d_ff=16, vocab_size=32,
                          pattern_unit=unit)
        total = sum(len(u) * r for u, r in layer_groups(cfg))
        assert total == nl
