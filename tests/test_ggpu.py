"""G-GPU SIMT simulator: functional correctness of all seven paper
benchmarks on GPU + scalar machines, divergence handling, and the paper's
scaling trends.

Simulations are memoized per (bench, machine, CU count) behind the
session-scoped ``sim`` fixture so compiled steppers and results are reused
across tests. ``GGPU_FAST_TESTS=1`` downscales the bench inputs for the
correctness assertions; the Table-III trend assertions always use the
paper's sizes (they are cheap — the quadratic kernels are not involved)."""
import functools
import os

import numpy as np
import pytest

from repro.ggpu import programs
from repro.ggpu.isa import Assembler
from repro.ggpu.machine import GGPUConfig, ScalarConfig, run_kernel
from repro.ggpu.programs import all_benches

FAST = os.environ.get("GGPU_FAST_TESTS", "0") not in ("", "0")
FAST_TESTS = ["copy", "vec_mul", "div_int", "mat_mul", "fir", "parallel_sel",
              "reduction"]


@functools.lru_cache(maxsize=1)
def _paper_benches():
    return all_benches()


@functools.lru_cache(maxsize=1)
def _correctness_benches():
    if not FAST:
        return _paper_benches()
    small = [programs._mat_mul(8, 32), programs._copy(128, 4096),
             programs._vec_mul(128, 8192), programs._fir(32, 1024),
             programs._div_int(64, 1024), programs._xcorr(32, 256),
             programs._parallel_sel(32, 512), programs._reduction(256, 4096)]
    return {b.name: b for b in small}


BENCHES = _correctness_benches()


def _sim(name, kind="gpu", ncu=1, paper_size=False):
    # normalize before the cache key: without FAST the sizes coincide, so
    # paper_size=True must hit the same memoized entry
    return _sim_cached(name, kind, ncu, paper_size and FAST)


@functools.lru_cache(maxsize=None)
def _sim_cached(name, kind, ncu, paper_size):
    """Memoized kernel simulation; results (and the stepper compiled for
    the shape) are shared by every test in the session."""
    b = _paper_benches()[name] if paper_size else BENCHES[name]
    if kind == "gpu":
        return run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                          GGPUConfig(n_cus=ncu)) + (b,)
    return run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig()) + (b,)


@pytest.fixture(scope="session")
def sim():
    return _sim


@pytest.mark.parametrize("name", list(BENCHES))
def test_gpu_kernel_correct(name, sim):
    if name == "xcorr" and not FAST:
        # keep CI time bounded at paper size: covered by test_xcorr_small
        pytest.skip("covered by test_xcorr_small")
    mem, info, b = sim(name, "gpu", 2)
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, b.gpu_n))
    assert info["cycles"] > 0


@pytest.mark.parametrize("name", FAST_TESTS)
def test_scalar_kernel_correct(name, sim):
    mem, info, b = sim(name, "scalar")
    np.testing.assert_array_equal(mem[b.scalar_out],
                                  b.ref(b.scalar_mem, b.scalar_n))


def test_xcorr_small():
    """xcorr correctness on a reduced size (full size runs in benchmarks)."""
    b = programs._xcorr(n_scalar=64, n_gpu=256)
    mem, _ = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, GGPUConfig())
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, 256))
    mem, _ = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    np.testing.assert_array_equal(mem[b.scalar_out], b.ref(b.scalar_mem, 64))


def test_divergence_serializes_correctly():
    """Work-items taking different branches all produce correct results
    (full thread divergence, min-PC reconvergence)."""
    n = 128
    a = Assembler()
    a.tid(1)
    a.andi(2, 1, 1)                       # odd/even
    a.beq(2, 0, "even")
    a.mul(3, 1, 1).sw(3, 1, n).beq(0, 0, "end")   # odd: tid^2
    a.label("even").slli(3, 1, 1).sw(3, 1, n)     # even: 2*tid
    a.label("end").halt()
    mem0 = np.zeros(2 * n, np.int32)
    mem, _ = run_kernel(a.assemble(), mem0, n, GGPUConfig())
    tid = np.arange(n)
    expect = np.where(tid % 2 == 1, tid * tid, 2 * tid).astype(np.int32)
    np.testing.assert_array_equal(mem[n:2 * n], expect)


def test_cu_scaling_parallel_kernel(sim):
    """mat_mul scales near-linearly 1 -> 8 CUs (the paper's headline).
    Always at the paper's Table-III input size."""
    cycles = {}
    for ncu in (1, 2, 8):
        _, info, _ = sim("mat_mul", "gpu", ncu, paper_size=True)
        cycles[ncu] = info["cycles"]
    assert cycles[1] / cycles[2] > 1.8
    assert cycles[1] / cycles[8] > 6.0


def test_streaming_kernel_saturates(sim):
    """copy is DRAM-bound: 8 CUs buy little (paper Table III trend)."""
    _, c1, _ = sim("copy", "gpu", 1, paper_size=True)
    _, c8, _ = sim("copy", "gpu", 8, paper_size=True)
    assert c1["cycles"] / c8["cycles"] < 4.0       # far from linear


def test_divider_weakness(sim):
    """div_int per-element cost is much worse on the G-GPU than the scalar
    core (FGPU lacks a native divider; Fig. 5's weakest kernel)."""
    _, g, b = sim("div_int", "gpu", 1, paper_size=True)
    _, s, _ = sim("div_int", "scalar", paper_size=True)
    gpu_per_elem = g["cycles"] / b.gpu_n
    scalar_per_elem = s["cycles"] / b.scalar_n
    _, gc, copy_b = sim("copy", "gpu", 1, paper_size=True)
    _, sc, _ = sim("copy", "scalar", paper_size=True)
    # relative advantage on div is much smaller than on copy
    adv_div = scalar_per_elem / gpu_per_elem
    adv_copy = (sc["cycles"] / copy_b.scalar_n) / (gc["cycles"] / copy_b.gpu_n)
    assert adv_div < adv_copy


def test_store_load_roundtrip():
    a = Assembler()
    a.tid(1).slli(2, 1, 2).sw(2, 1, 0).lw(3, 1, 0).addi(3, 3, 7) \
     .sw(3, 1, 64).halt()
    mem, _ = run_kernel(a.assemble(), np.zeros(128, np.int32), 64,
                        GGPUConfig())
    np.testing.assert_array_equal(mem[64:128], np.arange(64) * 4 + 7)


def test_halts_and_stats():
    a = Assembler()
    a.tid(1).halt()
    mem, info = run_kernel(a.assemble(), np.zeros(4, np.int32), 64,
                           GGPUConfig())
    assert info["steps"] >= 2
    assert info["cycles"] >= 16
