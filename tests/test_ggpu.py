"""G-GPU SIMT simulator: functional correctness of all seven paper
benchmarks on GPU + scalar machines, divergence handling, and the paper's
scaling trends."""
import numpy as np
import pytest

from repro.ggpu.isa import Assembler
from repro.ggpu.machine import GGPUConfig, ScalarConfig, run_kernel
from repro.ggpu.programs import all_benches

BENCHES = all_benches()
FAST = ["copy", "vec_mul", "div_int", "mat_mul", "fir", "parallel_sel"]


@pytest.mark.parametrize("name", list(BENCHES))
def test_gpu_kernel_correct(name):
    b = BENCHES[name]
    cfg = GGPUConfig(n_cus=2)
    if name == "xcorr":    # keep CI time bounded: shrink via slicing inputs
        pytest.skip("covered by test_xcorr_small")
    mem, info = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg)
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, b.gpu_n))
    assert info["cycles"] > 0


@pytest.mark.parametrize("name", FAST)
def test_scalar_kernel_correct(name):
    b = BENCHES[name]
    mem, info = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    np.testing.assert_array_equal(mem[b.scalar_out],
                                  b.ref(b.scalar_mem, b.scalar_n))


def test_xcorr_small():
    """xcorr correctness on a reduced size (full size runs in benchmarks)."""
    from repro.ggpu.programs import _xcorr
    b = _xcorr(n_scalar=64, n_gpu=256)
    mem, _ = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, GGPUConfig())
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, 256))
    mem, _ = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    np.testing.assert_array_equal(mem[b.scalar_out], b.ref(b.scalar_mem, 64))


def test_divergence_serializes_correctly():
    """Work-items taking different branches all produce correct results
    (full thread divergence, min-PC reconvergence)."""
    n = 128
    a = Assembler()
    a.tid(1)
    a.andi(2, 1, 1)                       # odd/even
    a.beq(2, 0, "even")
    a.mul(3, 1, 1).sw(3, 1, n).beq(0, 0, "end")   # odd: tid^2
    a.label("even").slli(3, 1, 1).sw(3, 1, n)     # even: 2*tid
    a.label("end").halt()
    mem0 = np.zeros(2 * n, np.int32)
    mem, _ = run_kernel(a.assemble(), mem0, n, GGPUConfig())
    tid = np.arange(n)
    expect = np.where(tid % 2 == 1, tid * tid, 2 * tid).astype(np.int32)
    np.testing.assert_array_equal(mem[n:2 * n], expect)


def test_cu_scaling_parallel_kernel():
    """mat_mul scales near-linearly 1 -> 8 CUs (the paper's headline)."""
    b = BENCHES["mat_mul"]
    cycles = {}
    for ncu in (1, 2, 8):
        _, info = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                             GGPUConfig(n_cus=ncu))
        cycles[ncu] = info["cycles"]
    assert cycles[1] / cycles[2] > 1.8
    assert cycles[1] / cycles[8] > 6.0


def test_streaming_kernel_saturates():
    """copy is DRAM-bound: 8 CUs buy little (paper Table III trend)."""
    b = BENCHES["copy"]
    _, c1 = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, GGPUConfig(n_cus=1))
    _, c8 = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, GGPUConfig(n_cus=8))
    assert c1["cycles"] / c8["cycles"] < 4.0       # far from linear


def test_divider_weakness():
    """div_int per-element cost is much worse on the G-GPU than the scalar
    core (FGPU lacks a native divider; Fig. 5's weakest kernel)."""
    b = BENCHES["div_int"]
    _, g = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, GGPUConfig(n_cus=1))
    _, s = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    gpu_per_elem = g["cycles"] / b.gpu_n
    scalar_per_elem = s["cycles"] / b.scalar_n
    copy_b = BENCHES["copy"]
    _, gc = run_kernel(copy_b.gpu_prog, copy_b.gpu_mem, copy_b.gpu_items,
                       GGPUConfig(n_cus=1))
    _, sc = run_kernel(copy_b.scalar_prog, copy_b.scalar_mem, 1,
                       ScalarConfig())
    # relative advantage on div is much smaller than on copy
    adv_div = scalar_per_elem / gpu_per_elem
    adv_copy = (sc["cycles"] / copy_b.scalar_n) / (gc["cycles"] / copy_b.gpu_n)
    assert adv_div < adv_copy


def test_store_load_roundtrip():
    a = Assembler()
    a.tid(1).slli(2, 1, 2).sw(2, 1, 0).lw(3, 1, 0).addi(3, 3, 7) \
     .sw(3, 1, 64).halt()
    mem, _ = run_kernel(a.assemble(), np.zeros(128, np.int32), 64,
                        GGPUConfig())
    np.testing.assert_array_equal(mem[64:128], np.arange(64) * 4 + 7)


def test_halts_and_stats():
    a = Assembler()
    a.tid(1).halt()
    mem, info = run_kernel(a.assemble(), np.zeros(4, np.int32), 64,
                           GGPUConfig())
    assert info["steps"] >= 2
    assert info["cycles"] >= 16
