"""Execution-engine invariants: fused dispatch and batched launches are
bit-exact vs the legacy single-launch path; the pluggable memory systems
preserve functional results; the LaunchQueue groups and orders correctly."""
import numpy as np
import pytest

from repro.ggpu import programs
from repro.ggpu.engine import (MEMSYS_REGISTRY, GGPUConfig, ScalarConfig,
                               get_memsys, run_kernel, run_kernel_batch,
                               run_kernel_cohort)
from repro.ggpu.isa import Assembler
from repro.serve.engine import LaunchQueue


def _divergent_prog(n):
    a = Assembler()
    a.tid(1).andi(2, 1, 1).beq(2, 0, "even")
    a.mul(3, 1, 1).sw(3, 1, n).beq(0, 0, "end")
    a.label("even").slli(3, 1, 1).sw(3, 1, n)
    a.label("end").halt()
    return a.assemble()


def test_fused_dispatch_bit_exact():
    """fuse=1 (legacy, memsys every round) and fuse=8 (fused fast path)
    agree on results, cycles, stats, and step count."""
    b = programs._xcorr(32, 256)
    runs = {}
    for fuse in (1, 8):
        cfg = GGPUConfig(n_cus=2, fuse=fuse)
        runs[fuse] = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg)
    mem1, i1 = runs[1]
    mem8, i8 = runs[8]
    np.testing.assert_array_equal(mem1, mem8)
    for k in ("cycles", "instrs", "mem_ops", "hits", "misses", "steps"):
        assert i1[k] == i8[k], k


def test_batch_matches_single_mixed_shapes():
    """A batch of different programs/memory sizes/item counts reproduces
    each single launch bit-exactly (results AND cycle counts)."""
    cfg = GGPUConfig(n_cus=2)
    c = programs._copy(64, 1024)
    n = 128
    launches = [
        (c.gpu_prog, c.gpu_mem, c.gpu_items),
        (_divergent_prog(n), np.zeros(2 * n, np.int32), n),
    ]
    singles = [run_kernel(p, m, k, cfg) for p, m, k in launches]
    batch = run_kernel_batch([p for p, _, _ in launches],
                             [m for _, m, _ in launches],
                             [k for _, _, k in launches], cfg)
    for (ms, is_), (mb, ib) in zip(singles, batch):
        np.testing.assert_array_equal(ms, mb)
        for key in ("cycles", "instrs", "mem_ops", "hits", "misses",
                    "steps"):
            assert is_[key] == ib[key], key


def test_legacy_reference_bit_exact():
    """The seed-faithful legacy stepper and the optimized engine agree on
    everything observable."""
    b = programs._xcorr(32, 256)
    cfg = GGPUConfig(n_cus=2)
    mem_n, i_n = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg)
    mem_l, i_l = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg,
                            legacy=True)
    np.testing.assert_array_equal(mem_n, mem_l)
    for k in ("cycles", "instrs", "mem_ops", "hits", "misses", "steps"):
        assert i_n[k] == i_l[k], k


def test_cohort_matches_single():
    """A same-kernel cohort (folded into the wavefront axis) reproduces
    each single launch bit-exactly, including cycles."""
    b = programs._xcorr(32, 256)
    cfg = GGPUConfig(n_cus=2)
    rng = np.random.default_rng(11)
    mems = [np.concatenate([rng.integers(-20, 20, 512).astype(np.int32),
                            np.zeros(256, np.int32)]) for _ in range(3)]
    singles = [run_kernel(b.gpu_prog, m, b.gpu_items, cfg) for m in mems]
    cohort = run_kernel_cohort(b.gpu_prog, mems, b.gpu_items, cfg)
    for (ms, is_), (mc, ic) in zip(singles, cohort):
        np.testing.assert_array_equal(ms, mc)
        for key in ("cycles", "instrs", "mem_ops", "hits", "misses",
                    "steps"):
            assert is_[key] == ic[key], key
        assert ic["batch_size"] == 3


def test_cohort_rejects_mixed_mem_shapes():
    b = programs._copy(64, 256)
    with pytest.raises(ValueError):
        run_kernel_cohort(b.gpu_prog,
                          [b.gpu_mem, np.zeros(7, np.int32)],
                          b.gpu_items, GGPUConfig())


def test_batch_clips_at_each_launchs_own_memory_size():
    """In a mixed-size batch, an out-of-range address must clip at the
    launch's own memory boundary (reading its last word), not at the
    padded batch envelope (which would read padding zeros)."""
    n = 64
    a = Assembler()
    a.tid(1).li(2, 5000).lw(3, 2, 0).sw(3, 1, n).halt()   # read way OOB
    prog = a.assemble()
    mem_small = np.arange(2 * n, dtype=np.int32)          # last word: 127
    big = programs._copy(64, 1024)                        # forces padding
    single = run_kernel(prog, mem_small, n, GGPUConfig())
    batch = run_kernel_batch([prog, big.gpu_prog],
                             [mem_small, big.gpu_mem],
                             [n, big.gpu_items], GGPUConfig())
    np.testing.assert_array_equal(single[0], batch[0][0])
    assert batch[0][0][n] == 2 * n - 1                    # clipped in-image
    assert single[1]["cycles"] == batch[0][1]["cycles"]


def test_batch_empty_and_single():
    assert run_kernel_batch([], [], [], GGPUConfig()) == []
    b = programs._copy(64, 256)
    (mem_b, info_b), = run_kernel_batch([b.gpu_prog], [b.gpu_mem],
                                        [b.gpu_items], GGPUConfig())
    mem_s, info_s = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                               GGPUConfig())
    np.testing.assert_array_equal(mem_b, mem_s)
    assert info_b["cycles"] == info_s["cycles"]


@pytest.mark.parametrize("memsys", sorted(MEMSYS_REGISTRY))
def test_memsys_functional_results_identical(memsys):
    """The memory system only changes cycle accounting — functional results
    are identical across organizations."""
    b = programs._xcorr(32, 256)
    cfg = GGPUConfig(n_cus=2, memsys=memsys)
    mem, info = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, cfg)
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, 256))
    assert info["cycles"] > 0
    assert info["memsys"] == memsys


def test_banked_1cu_equals_shared():
    """With one CU and full-size banks the banked organization degenerates
    to the shared cache: cycles must match exactly."""
    b = programs._xcorr(32, 256)
    _, shared = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                           GGPUConfig(n_cus=1, memsys="shared"))
    _, banked = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                           GGPUConfig(n_cus=1, memsys="banked"))
    for k in ("cycles", "hits", "misses"):
        assert shared[k] == banked[k], k


def test_banked_8cu_model_properties():
    """At 8 CUs on a working set that fits every organization: banks fill
    independently (no cross-CU MSHR coalescing), so the banked cache pays
    at least the shared cache's compulsory misses — while hits split across
    banks. The DSE sweep (table_memsys) reports which effect wins."""
    b = programs._xcorr(32, 512)
    _, shared = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                           GGPUConfig(n_cus=8, memsys="shared"))
    _, banked = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items,
                           GGPUConfig(n_cus=8, memsys="banked"))
    assert banked["misses"] >= shared["misses"]
    assert banked["hits"] + banked["misses"] == shared["hits"] + shared["misses"]
    assert banked["cycles"] > 0


def test_get_memsys_unknown_name():
    with pytest.raises(KeyError):
        get_memsys("l3-victim")


def test_launch_queue_orders_and_groups():
    """Tickets come back in submission order; same-wavefront launches share
    one batch, odd shapes fall back to singletons."""
    cfg = GGPUConfig(n_cus=2)
    q = LaunchQueue(cfg)
    c1 = programs._copy(64, 1024)       # W = 16
    c2 = programs._copy(64, 256)        # W = 4
    rng = np.random.default_rng(3)
    mems = [np.concatenate([rng.integers(-50, 50, 1024).astype(np.int32),
                            np.zeros(1024, np.int32)]) for _ in range(3)]
    t0 = q.submit(c1.gpu_prog, mems[0], c1.gpu_items)
    t1 = q.submit(c2.gpu_prog, c2.gpu_mem, c2.gpu_items)
    t2 = q.submit(c1.gpu_prog, mems[1], c1.gpu_items)
    t3 = q.submit(c1.gpu_prog, mems[2], c1.gpu_items)
    assert [t0, t1, t2, t3] == [0, 1, 2, 3]
    assert len(q) == 4
    results = q.flush()
    assert len(q) == 0 and len(results) == 4
    for t, m in zip((t0, t2, t3), mems):
        mem, info = results[t]
        np.testing.assert_array_equal(mem[c1.gpu_out], m[:1024])
        assert info["batch_size"] == 3          # grouped by wavefront count
    mem, info = results[t1]
    np.testing.assert_array_equal(mem[c2.gpu_out], c2.gpu_mem[:256])
    assert info["batch_size"] == 1              # singleton fallback


def test_launch_queue_restores_on_failure_and_surfaces_tags():
    """A failed flush re-queues every launch (retryable after dropping the
    bad request); submission tags come back in info['tag']."""
    q = LaunchQueue(GGPUConfig(max_steps=50))
    b = programs._copy(64, 256)
    spin = Assembler()
    spin.label("spin").beq(0, 0, "spin")        # never halts
    q.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, tag="good")
    t_bad = q.submit(spin.assemble(), np.zeros(8, np.int32), 8,
                     tag="spinner")
    with pytest.raises(RuntimeError):
        q.flush()
    assert len(q) == 2                           # nothing lost
    assert q.discard(t_bad).tag == "spinner"     # drop the poisoned launch
    (_, info), = q.flush()                       # rest of the burst retries
    assert info["tag"] == "good"


def test_launch_queue_drains_in_ticket_order(monkeypatch):
    """Regression: chunks must execute ordered by their earliest ticket —
    a pure function of submission order — not by cohort-dict/group
    iteration order (which used to run all cohorts before any batch or
    singleton, regardless of when they were submitted)."""
    import repro.serve.executors as sx
    order = []

    def spy(name, fn):
        def wrapper(*args, **kw):
            order.append(name)
            return fn(*args, **kw)
        return wrapper

    monkeypatch.setattr(sx, "run_kernel_async",
                        spy("single", sx.run_kernel_async))
    monkeypatch.setattr(sx, "run_kernel_cohort_async",
                        spy("cohort", sx.run_kernel_cohort_async))
    monkeypatch.setattr(sx, "run_kernel_batch_async",
                        spy("batch", sx.run_kernel_batch_async))

    cfg = GGPUConfig(n_cus=2)
    q = LaunchQueue(cfg)
    big = programs._copy(64, 1024)       # W=16: singleton bucket
    small = programs._copy(64, 256)      # W=4: cohort group
    t0 = q.submit(big.gpu_prog, big.gpu_mem, big.gpu_items)      # single
    t1 = q.submit(small.gpu_prog, small.gpu_mem, small.gpu_items)
    t2 = q.submit(small.gpu_prog, small.gpu_mem, small.gpu_items)
    results = q.flush()
    # ticket 0's singleton chunk must run before ticket 1's cohort
    assert order == ["single", "cohort"]
    assert [info["batch_size"] for _, info in results] == [1, 2, 2]
    for t in (t0, t1, t2):
        assert results[t] is not None


def test_launch_queue_chunk_plan_is_submission_deterministic():
    """The drain plan is identical for identical submission sequences and
    orders chunks by first ticket."""
    cfg = GGPUConfig()
    b1 = programs._copy(64, 256)
    b2 = programs._copy(64, 1024)

    def build():
        q = LaunchQueue(cfg, max_batch=2)
        q.submit(b2.gpu_prog, b2.gpu_mem, b2.gpu_items)   # 0: singleton
        for _ in range(3):                                # 1-3: cohort x2
            q.submit(b1.gpu_prog, b1.gpu_mem, b1.gpu_items)
        return q

    plan_a = build()._plan_chunks(build()._pending)
    plan_b = build()._plan_chunks(build()._pending)
    assert plan_a == plan_b
    firsts = [chunk[0] for _, chunk in plan_a]
    assert firsts == sorted(firsts)
    assert [k for k, _ in plan_a] == ["single", "cohort", "cohort"]


def test_launch_queue_respects_max_batch():
    cfg = GGPUConfig()
    q = LaunchQueue(cfg, max_batch=2)
    b = programs._copy(64, 256)
    for _ in range(3):
        q.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    results = q.flush()
    assert [info["batch_size"] for _, info in results] == [2, 2, 1]


def test_scalar_runs_on_engine():
    """The scalar baseline flows through the same engine stages."""
    b = programs._copy(64, 256)
    mem, info = run_kernel(b.scalar_prog, b.scalar_mem, 1, ScalarConfig())
    np.testing.assert_array_equal(mem[b.scalar_out],
                                  b.ref(b.scalar_mem, b.scalar_n))
    assert info["cycles"] > 0


def test_planner_memsys_sweep():
    from repro.dse import sweep_memsys
    sweep = sweep_memsys(bench="xcorr", n_cus=(1,), sizes=(32, 128))
    # defaults must track the engine registry (single source of truth)
    assert set(sweep) == {(1, ms) for ms in MEMSYS_REGISTRY}
    for info in sweep.values():
        assert info["cycles"] > 0
