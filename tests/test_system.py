"""End-to-end behaviour tests: every assigned architecture trains a step on
a reduced config (CPU), serving is consistent with training-mode forward,
and the fault-tolerance loop resumes bit-identically."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.data.pipeline import DataConfig
from repro.models import model as M
from repro.models.config import SHAPES, cell_supported
from repro.models.schema import count_params, init_params
from repro.models.steps import make_train_step
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainConfig

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.frontend:
        out = {"embeds": jax.random.normal(RNG, (b, s, cfg.d_frontend)),
               "labels": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)}
        if cfg.mrope:
            out["positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, None, :], (3, b, s))
        return out
    return {"tokens": jax.random.randint(RNG, (b, s + 1), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One train step on the reduced config: finite loss, params update,
    correct output structure."""
    cfg = get_smoke(arch)
    params = init_params(cfg, RNG)
    hp = adamw.AdamWConfig(warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(cfg, hp))
    opt = adamw.init(params)
    batch = _batch(cfg)
    p2, o2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: NaN loss"
    assert float(metrics["loss"]) > 0
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: no parameter update"
    assert int(o2.step) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, RNG)
    b, s = 2, 16
    if cfg.frontend:
        logits = M.encode(params, cfg, jax.random.normal(
            RNG, (b, s, cfg.d_frontend)))
    else:
        x, _, _ = M.forward(params, cfg, tokens=jnp.zeros((b, s), jnp.int32))
        logits = M.lm_logits(params, cfg, x)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


DECODE_ARCHS = [a for a in ARCH_IDS
                if not get_smoke(a).is_encoder_only
                and get_smoke(a).frontend is None]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_consistency(arch):
    """Gold test: decode(prefill(S-1), token) == full forward at position S."""
    cfg = get_smoke(arch)
    if cfg.n_experts:
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))  # no drops
    params = init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab_size)
    ref_logits, _ = M.prefill(params, cfg, tokens=tokens)
    _, cache = M.prefill(params, cfg, tokens=tokens[:, :s - 1], pad_to=s + 4)
    dec_logits, _ = M.decode_step(params, cfg, cache, tokens[:, s - 1:s],
                                  jnp.array(s - 1, jnp.int32))
    err = float(jnp.max(jnp.abs(ref_logits - dec_logits)))
    scale = float(jnp.max(jnp.abs(ref_logits)))
    assert err / max(scale, 1e-9) < 0.05, f"{arch}: decode diverges ({err})"


def test_full_configs_match_spec():
    """The full (dry-run) configs carry the exact published dimensions."""
    spec = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (l, d, h, kv, ff, v), arch


def test_cell_support_matrix():
    """40 cells; the documented 8 skips and 32 live cells."""
    live = skips = 0
    for a in ARCH_IDS:
        for s in SHAPES.values():
            ok, reason = cell_supported(get_config(a), s)
            live += ok
            skips += not ok
            if not ok:
                assert reason
    assert live == 32 and skips == 8


def test_trainer_resume_bit_identical(tmp_path):
    cfg = get_smoke("qwen1.5-0.5b")
    hp = adamw.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=12)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    a_dir, b_dir = tmp_path / "a", tmp_path / "b"
    r1 = Trainer(cfg, hp, TrainConfig(steps=8, save_every=4,
                                      ckpt_dir=str(a_dir)), dc).run()
    with pytest.raises(RuntimeError):
        Trainer(cfg, hp, TrainConfig(steps=8, save_every=4,
                                     ckpt_dir=str(b_dir), fail_at_step=6),
                dc).run()
    r2 = Trainer(cfg, hp, TrainConfig(steps=8, save_every=4,
                                      ckpt_dir=str(b_dir)), dc).run()
    for x, y in zip(jax.tree.leaves(r1["params"]),
                    jax.tree.leaves(r2["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_training_loss_decreases(tmp_path):
    cfg = get_smoke("smollm-360m")
    hp = adamw.AdamWConfig(lr=1e-2, warmup_steps=3, total_steps=25)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    t = Trainer(cfg, hp, TrainConfig(steps=20, save_every=20,
                                     ckpt_dir=str(tmp_path)), dc)
    t.run()
    losses = [m["loss"] for m in t.metrics_log]
    assert losses[-1] < losses[0] * 0.9


def test_param_counts_reasonable():
    """Full-config parameter counts land near the published sizes."""
    approx = {"mixtral-8x7b": 46.7e9, "granite-8b": 8.1e9,
              "qwen1.5-0.5b": 0.62e9, "smollm-360m": 0.36e9,
              "recurrentgemma-2b": 2.7e9, "qwen2-vl-72b": 72.7e9,
              "xlstm-350m": 0.35e9}
    for arch, expect in approx.items():
        n = count_params(get_config(arch))
        assert 0.6 * expect < n < 1.55 * expect, (arch, n, expect)
