"""GPUPlanner + PPA model: the paper's 12 versions, map behaviour, and
hypothesis properties of the memory-division strategy."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.planner import enumerate_versions, plan
from repro.core.ppa import PAPER_TABLE1, GGPUVersion, baseline_inventory
from repro.core.sram import Macro, divided_path_delay


def test_baseline_is_500mhz_no_divisions():
    """The 'standard version without optimizations' runs at ~500 MHz."""
    v = GGPUVersion(1, 500.0, baseline_inventory())
    assert 490 <= v.fmax_mhz() <= 530
    assert all(m.divided == 0 for m in v.inventory)


@pytest.mark.parametrize("n_cus", [1, 2, 4])
def test_667_closes_below_8cus(n_cus):
    p = plan(n_cus, 667.0)
    assert p.achieved, p.reason


def test_8cu_667_interconnect_bound():
    """The paper's headline physical-design finding: 8CU@667 only reaches
    ~600 MHz, and pipelining cannot fix it."""
    p = plan(8, 667.0)
    assert not p.achieved
    assert "interconnect" in p.reason
    assert 580 <= p.version.fmax_mhz() <= 620
    assert p.map_log[-1].bottleneck == "interconnect"


def test_map_divides_then_pipelines():
    """The map's action sequence mirrors the paper: memory divisions with
    on-demand pipeline insertion when the critical path moves to logic."""
    p = plan(1, 667.0)
    assert p.achieved
    actions = [e.action for e in p.map_log]
    assert any(a.startswith("divide") for a in actions)
    assert any("pipeline" in a for a in actions)


def test_twelve_versions_ppa_error():
    """Mean relative error vs Table I (area, #mem, power) under 25%."""
    errs = []
    plans = enumerate_versions()
    assert len(plans) == 12
    freqs = [500, 500, 500, 500, 590, 590, 590, 590, 667, 667, 667, 667]
    for p, f in zip(plans, freqs):
        r = p.version.report()
        pap = PAPER_TABLE1[(r["n_cus"], f)]
        errs += [abs(r["total_area_mm2"] - pap["area"]) / pap["area"],
                 abs(r["total_w"] - pap["total"]) / pap["total"]]
    assert sum(errs) / len(errs) < 0.25


def test_area_grows_linearly_with_cus():
    areas = [plan(c, 500.0).version.total_area_mm2() for c in (1, 2, 4, 8)]
    # paper: "the G-GPU size grows linearly with the number of CUs"
    slope1 = (areas[1] - areas[0])
    slope3 = (areas[3] - areas[2]) / 4
    assert abs(slope1 - slope3) / slope1 < 0.1


@given(st.integers(5, 14), st.integers(2, 7))
@settings(max_examples=25, deadline=None)
def test_division_property(words_log2, bits_log2):
    """Dividing a macro never increases its access delay and always
    increases its area (the paper's core trade-off)."""
    m = Macro("m", 2 ** words_log2, 2 ** bits_log2)
    d = m.divide_words()
    assert divided_path_delay(d) <= divided_path_delay(m) + 1e-9
    assert d.area_mm2() > m.area_mm2()
    assert d.count == 2 * m.count


@given(st.integers(1, 8), st.sampled_from([400.0, 500.0, 590.0, 667.0]))
@settings(max_examples=20, deadline=None)
def test_plan_postconditions(n_cus, freq):
    """Achieved plans meet their target; failed plans explain themselves."""
    p = plan(n_cus, freq)
    if p.achieved:
        assert p.version.fmax_mhz() >= freq - 1
    else:
        assert p.reason
        assert p.map_log[-1].action.startswith("STOP")


def test_division_limit_stops():
    """A absurd target fails gracefully at the division/pipeline limits."""
    p = plan(1, 2000.0)
    assert not p.achieved


def test_map_log_is_the_dynamic_spreadsheet():
    """The map log carries everything the paper's 'dynamic spreadsheet'
    shows a designer: per-iteration fmax, the named bottleneck, the action
    taken, and all three candidate critical paths."""
    p = plan(1, 667.0)
    assert p.achieved
    its = [e.iteration for e in p.map_log]
    assert its == sorted(its) and len(set(its)) == len(its)
    for e in p.map_log:
        assert set(e.paths) == {"memory", "logic", "interconnect"}
        assert e.fmax_mhz > 0
    # memory is the baseline bottleneck, so the map divides first
    assert p.map_log[0].bottleneck.startswith("memory:")
    assert p.map_log[0].action.startswith("divide")
    assert p.map_log[-1].action == "target met"
    # fmax never degrades along the map (division/pipelining only help)
    fmaxes = [e.fmax_mhz for e in p.map_log]
    assert all(b >= a - 1e-9 for a, b in zip(fmaxes, fmaxes[1:]))


def test_twelve_versions_table1_anchor_points():
    """Table I anchors: 12 versions in freq-major order; the 500 MHz
    baseline is the paper's 51-block memory map; only 8CU@667 misses its
    target and lands at the ~600 MHz interconnect stop."""
    plans = enumerate_versions()
    assert len(plans) == 12
    reqs = [(f, c) for f in (500.0, 590.0, 667.0) for c in (1, 2, 4, 8)]
    for p, (f, c) in zip(plans, reqs):
        assert p.version.n_cus == c
        if (c, f) != (8, 667.0):
            assert p.achieved, (c, f, p.reason)
            assert p.version.freq_mhz == f
    base = plans[0].version
    # the modeled inventory: 28 per-CU + 9 fixed blocks (coarser than the
    # paper's 51 — block counts scale linearly with CUs like Table I's)
    assert base.n_memories() == 37
    assert plans[3].version.n_memories() == 28 * 8 + 9
    assert base.pipelines == 0
    # Table I trend: higher-frequency versions divide more memories
    assert plans[8].version.n_memories() > base.n_memories()
    stop = plans[-1]
    assert not stop.achieved
    assert stop.map_log[-1].bottleneck == "interconnect"
    assert 595 <= stop.version.freq_mhz <= 605   # the paper's ~600 derate
    # higher-frequency versions pay the paper's area trade-off
    for c_ix in range(4):
        a500 = plans[c_ix].version.total_area_mm2()
        a667 = plans[8 + c_ix].version.total_area_mm2()
        assert a667 > a500
