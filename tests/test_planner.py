"""GPUPlanner + PPA model: the paper's 12 versions, map behaviour, and
hypothesis properties of the memory-division strategy."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.planner import enumerate_versions, plan
from repro.core.ppa import PAPER_TABLE1, GGPUVersion, baseline_inventory
from repro.core.sram import Macro, divided_path_delay


def test_baseline_is_500mhz_no_divisions():
    """The 'standard version without optimizations' runs at ~500 MHz."""
    v = GGPUVersion(1, 500.0, baseline_inventory())
    assert 490 <= v.fmax_mhz() <= 530
    assert all(m.divided == 0 for m in v.inventory)


@pytest.mark.parametrize("n_cus", [1, 2, 4])
def test_667_closes_below_8cus(n_cus):
    p = plan(n_cus, 667.0)
    assert p.achieved, p.reason


def test_8cu_667_interconnect_bound():
    """The paper's headline physical-design finding: 8CU@667 only reaches
    ~600 MHz, and pipelining cannot fix it."""
    p = plan(8, 667.0)
    assert not p.achieved
    assert "interconnect" in p.reason
    assert 580 <= p.version.fmax_mhz() <= 620
    assert p.map_log[-1].bottleneck == "interconnect"


def test_map_divides_then_pipelines():
    """The map's action sequence mirrors the paper: memory divisions with
    on-demand pipeline insertion when the critical path moves to logic."""
    p = plan(1, 667.0)
    assert p.achieved
    actions = [e.action for e in p.map_log]
    assert any(a.startswith("divide") for a in actions)
    assert any("pipeline" in a for a in actions)


def test_twelve_versions_ppa_error():
    """Mean relative error vs Table I (area, #mem, power) under 25%."""
    errs = []
    plans = enumerate_versions()
    assert len(plans) == 12
    freqs = [500, 500, 500, 500, 590, 590, 590, 590, 667, 667, 667, 667]
    for p, f in zip(plans, freqs):
        r = p.version.report()
        pap = PAPER_TABLE1[(r["n_cus"], f)]
        errs += [abs(r["total_area_mm2"] - pap["area"]) / pap["area"],
                 abs(r["total_w"] - pap["total"]) / pap["total"]]
    assert sum(errs) / len(errs) < 0.25


def test_area_grows_linearly_with_cus():
    areas = [plan(c, 500.0).version.total_area_mm2() for c in (1, 2, 4, 8)]
    # paper: "the G-GPU size grows linearly with the number of CUs"
    slope1 = (areas[1] - areas[0])
    slope3 = (areas[3] - areas[2]) / 4
    assert abs(slope1 - slope3) / slope1 < 0.1


@given(st.integers(5, 14), st.integers(2, 7))
@settings(max_examples=25, deadline=None)
def test_division_property(words_log2, bits_log2):
    """Dividing a macro never increases its access delay and always
    increases its area (the paper's core trade-off)."""
    m = Macro("m", 2 ** words_log2, 2 ** bits_log2)
    d = m.divide_words()
    assert divided_path_delay(d) <= divided_path_delay(m) + 1e-9
    assert d.area_mm2() > m.area_mm2()
    assert d.count == 2 * m.count


@given(st.integers(1, 8), st.sampled_from([400.0, 500.0, 590.0, 667.0]))
@settings(max_examples=20, deadline=None)
def test_plan_postconditions(n_cus, freq):
    """Achieved plans meet their target; failed plans explain themselves."""
    p = plan(n_cus, freq)
    if p.achieved:
        assert p.version.fmax_mhz() >= freq - 1
    else:
        assert p.reason
        assert p.map_log[-1].action.startswith("STOP")


def test_division_limit_stops():
    """A absurd target fails gracefully at the division/pipeline limits."""
    p = plan(1, 2000.0)
    assert not p.achieved
