"""Autotuner: schedule space, search determinism, never-worse guarantee,
and the co-design joint frontier.

Small sizes keep every test interactive; the cycle-level sweep quality is
enforced in CI by ``benchmarks.run --compiler --fast`` against the
committed ``BENCH_compiler.json`` baseline.
"""
import numpy as np
import pytest

from repro.compiler import (DEFAULT_SCHEDULE, CompileError, Schedule,
                            ScheduleSpace, autotune, autotune_suite,
                            codesign, compile_kernel, kernel_def)
from repro.ggpu.engine import GGPUConfig

CFG = GGPUConfig(n_cus=2)
SMALL = ScheduleSpace(coarsen=(1, 2), hoist=(True,), branchy=(True, False),
                      peel=(True,))


# ---------------------------------------------------------------------------
# schedule + space
# ---------------------------------------------------------------------------

def test_schedule_validation():
    with pytest.raises(CompileError):
        Schedule(coarsen=0)
    assert DEFAULT_SCHEDULE.label() == "c1"
    assert Schedule(coarsen=2, branchy=False).label() == "c2+select"


def test_schedule_space_candidates_valid_and_default_first():
    """Candidates are filtered to valid coarsen divisors, always include
    the default, and come in deterministic default-first order."""
    space = ScheduleSpace(coarsen=(1, 2, 3, 4))
    cands = space.candidates(out_len=8)
    assert cands[0] == DEFAULT_SCHEDULE
    assert all(8 % s.coarsen == 0 for s in cands)
    assert not any(s.coarsen == 3 for s in cands)
    # even a space that omits coarsen=1 keeps the default candidate
    assert DEFAULT_SCHEDULE in ScheduleSpace(coarsen=(2,)).candidates(8)
    assert cands == space.candidates(out_len=8)


def test_schedule_conflicts_with_legacy_coarsen_arg():
    fn, shapes = kernel_def("copy", 16)
    with pytest.raises(CompileError):
        compile_kernel(fn, shapes, coarsen=4, schedule=Schedule(coarsen=2))
    # agreeing values are fine
    k = compile_kernel(fn, shapes, coarsen=2, schedule=Schedule(coarsen=2))
    assert k.schedule.coarsen == 2


def test_compiled_kernel_records_its_schedule():
    fn, shapes = kernel_def("vec_mul", 16)
    sched = Schedule(coarsen=4, branchy=False)
    k = compile_kernel(fn, shapes, schedule=sched)
    assert k.schedule == sched
    assert compile_kernel(fn, shapes).schedule == DEFAULT_SCHEDULE


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------

def test_autotune_never_worse_and_deterministic():
    """The default schedule is in every candidate set, so tuned <=
    default by construction; and the same (fn, shapes, space, config)
    always picks the same schedule."""
    fn, shapes = kernel_def("vec_mul", 64)
    r1 = autotune(fn, shapes, CFG, space=SMALL, name="vec_mul")
    r2 = autotune(fn, shapes, CFG, space=SMALL, name="vec_mul")
    assert r1.best_cycles <= r1.default_cycles
    assert r1.speedup >= 1.0
    assert r1.best_schedule == r2.best_schedule
    assert [c.report() for c in r1.candidates] \
        == [c.report() for c in r2.candidates]
    assert sum(c.best for c in r1.candidates) == 1


def test_autotune_candidates_are_verified_bit_exact():
    """Every candidate is costed with Evaluator(check=True) against the
    DEFAULT kernel's oracle output — a bad schedule cannot win by being
    wrong. All report rows carry verified=True."""
    fn, shapes = kernel_def("fir", 32, 4)
    r = autotune(fn, shapes, CFG, space=ScheduleSpace(coarsen=(1, 2)),
                 name="fir")
    assert r.candidates and all(c.verified for c in r.candidates)
    # the chosen kernel really is bit-exact end to end
    ins = r.best.random_inputs(seed=0)
    r.best.verify(ins, CFG)


def test_autotune_finds_strict_win_on_elementwise():
    """Coarsening amortizes the per-item TID/address overhead on the
    elementwise benches at serving sizes — the strictly-faster witness
    the CI invariant relies on."""
    fn, shapes = kernel_def("copy", 512)
    r = autotune(fn, shapes, CFG, space=SMALL, name="copy")
    assert r.best_cycles < r.default_cycles
    assert r.best_schedule.coarsen > 1


def test_autotune_report_shape():
    fn, shapes = kernel_def("copy", 16)
    rep = autotune(fn, shapes, CFG, space=SMALL, name="copy").report()
    assert rep["name"] == "copy"
    assert rep["tuned_cycles"] <= rep["default_cycles"]
    assert rep["n_candidates"] == len(rep["candidates"]) >= 2
    assert {"schedule", "cycles", "prog_len", "verified",
            "best"} <= set(rep["candidates"][0])


def test_autotune_suite_runs_by_name():
    out = autotune_suite(("copy", "vec_mul"), CFG,
                         sizes={"copy": (16, 64), "vec_mul": (16, 64)},
                         space=SMALL)
    assert sorted(out) == ["copy", "vec_mul"]
    assert all(r.best_cycles <= r.default_cycles for r in out.values())


# ---------------------------------------------------------------------------
# co-design
# ---------------------------------------------------------------------------

def test_codesign_joint_frontier_over_pairs():
    """The joint frontier ranks (DesignPoint, Schedule) pairs: every
    frontier entry carries a schedule label, the population is
    |schedules| x |specs|, and no frontier pair is dominated by any
    other pair."""
    from repro.dse import dominates, enumerate_specs

    defs = {n: kernel_def(n, 64) for n in ("copy", "vec_mul")}
    specs = enumerate_specs(cus=(1, 2), freq_targets=(500.0,))
    res = codesign(defs, specs, space=SMALL)
    assert res.frontier
    labels = sorted(res.results)
    assert DEFAULT_SCHEDULE.label() in labels
    assert res.joint.points and len(res.joint.points) \
        == len(labels) * len(specs)
    vecs = [(jp.point.time_us, jp.point.area_mm2)
            for jp in res.joint.points]
    for jp in res.frontier:
        v = (jp.point.time_us, jp.point.area_mm2)
        assert jp.variant in labels
        assert not any(dominates(w, v) for w in vecs)
    rows = res.report()
    assert all("schedule" in r and "on_frontier" in r for r in rows)
    assert any(r["on_frontier"] for r in rows)


def test_codesign_rejects_empty_defs():
    with pytest.raises(CompileError):
        codesign({}, None)


def test_autotune_cycle_cache_shared_across_calls():
    """Re-running the same search is near-free: candidate programs are
    content-addressed on the shared per-config executors, so the second
    call starts with every (IR, schedule, config) cycle memoized."""
    fn, shapes = kernel_def("vec_mul", 32)
    r1 = autotune(fn, shapes, CFG, space=SMALL, name="vm_cache")
    r2 = autotune(fn, shapes, CFG, space=SMALL, name="vm_cache")
    assert r2.cache_hits >= len(r2.candidates)
    assert [c.report() for c in r2.candidates] \
        == [c.report() for c in r1.candidates]
