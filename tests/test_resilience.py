"""Serve-side resilience: checksum audits + bounded retry, stuck-device
timeouts, fleet health/eviction/re-routing/probation, deadline-aware
hedged dispatch with first-result-wins (abandoned losers), settle-time
stamping, and the preemptive ``deadline-drop`` scheduling policy."""
import time

import numpy as np
import pytest

from repro.faults import FaultInjector, FaultPlan
from repro.registry import FAULTS
from repro.ggpu import programs
from repro.ggpu.engine import GGPUConfig, run_kernel
from repro.serve import Fleet, Request, Scheduler
from repro.serve.fleet import FleetResilience, HedgePolicy
from repro.serve.request import result_checksum
from repro.serve.scheduler import (ChecksumError, DeadlineExceeded,
                                   RetryPolicy)

CFG = GGPUConfig(n_cus=2)


def _bench():
    return programs._copy(16, 128)


def _mems(b, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(-30, 30, b.gpu_mem.shape[0]).astype(np.int32)
            for _ in range(n)]


def _audited(b, m):
    ref = run_kernel(b.gpu_prog, m, b.gpu_items, CFG)
    return Request(b.gpu_prog, m, b.gpu_items,
                   audit=result_checksum(ref[0])), ref


# ------------------------------------------- audit + retry (scheduler)

def _transient_seed():
    """A seed where ticket 0's first attempt takes post-compute SDC and
    its retry doesn't — the transient-fault shape a real SEU has."""
    for seed in range(200):
        p = FaultPlan(seed=seed, seu_post_rate=0.5)
        if p.post_hit(0, 0) and not p.post_hit(0, 1):
            return seed
    raise AssertionError("no transient seed in range")


def test_audit_catches_sdc_and_retry_serves_clean_result():
    b = _bench()
    m = _mems(b, 1)[0]
    req, ref = _audited(b, m)
    plan = FaultPlan(seed=_transient_seed(), seu_post_rate=0.5)
    s = Scheduler(CFG, retry=RetryPolicy(max_retries=2))
    inj = FaultInjector("d", s.executor, plan)
    s.executor = inj
    s.submit_request(req)
    (res,) = s.flush()
    np.testing.assert_array_equal(res.mem, ref[0])  # clean after retry
    assert req.attempts == 1
    assert [e[0] for e in inj.injected] == ["sdc"]
    assert not s.quarantined


def test_hard_corruption_quarantined_never_served():
    """Rate-1.0 SDC corrupts every attempt: with an audit the launch is
    quarantined as ChecksumError after exhausting retries — a corrupted
    result is never returned."""
    b = _bench()
    m = _mems(b, 1)[0]
    req, _ = _audited(b, m)
    plan = FaultPlan(seed=0, seu_post_rate=1.0)
    s = Scheduler(CFG, retry=RetryPolicy(max_retries=2))
    s.executor = FaultInjector("d", s.executor, plan)
    s.submit_request(req)
    assert s.flush() == []
    (q,) = s.quarantined.values()
    assert isinstance(q.error, ChecksumError)
    assert type(q.error).device_fault       # blamed on the device
    assert req.attempts == 2                # budget was really spent


def test_without_audit_corruption_is_silent():
    """The same rate-1.0 SDC with no audit sails through — the failure
    mode the checksum machinery exists for."""
    b = _bench()
    m = _mems(b, 1)[0]
    ref = run_kernel(b.gpu_prog, m, b.gpu_items, CFG)
    s = Scheduler(CFG)
    s.executor = FaultInjector("d", s.executor,
                               FaultPlan(seed=0, seu_post_rate=1.0))
    s.submit(b.gpu_prog, m, b.gpu_items)
    (res,) = s.flush()
    assert not np.array_equal(res.mem, ref[0])   # silently corrupted


# -------------------------------------------------- stuck device (timeout)

def test_stuck_device_quarantines_via_timeout():
    from repro.serve.executors import DeviceTimeout
    b = _bench()
    plan = FaultPlan(seed=0, stuck_devices=("d",), stuck_after=0)
    s = Scheduler(GGPUConfig(n_cus=2), max_batch=4)
    s.executor.timeout_s = 0.05
    s.executor = FaultInjector("d", s.executor, plan)
    t = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    assert s.flush() == []
    assert isinstance(s.quarantined[t].error, DeviceTimeout)


# ------------------------------------------------- fleet self-healing

def _loss_fleet(n=6, timeout_s=0.15, router="earliest-finish"):
    sc = FAULTS.get("device-loss")(seed=0, stuck_after=0,
                                   timeout_s=timeout_s)
    fleet = Fleet([("dev0", GGPUConfig(n_cus=1)), ("dev1", CFG)],
                  max_batch=4, router=router, **sc.fleet_kwargs())
    b = _bench()
    refs = {}
    for m in _mems(b, n):
        req, ref = _audited(b, m)
        refs[fleet.submit_request(req)] = ref
    return fleet, refs, sc


def test_device_loss_evicts_and_reroutes_backlog():
    """dev0 wedges on its first dispatch: timeouts exhaust the retry
    budget, consecutive faults evict it, and its backlog re-routes to
    dev1 — everything is served bit-exact, nothing quarantined."""
    fleet, refs, _ = _loss_fleet()
    results = fleet.drain()
    assert fleet.devices[0].state == "evicted"
    assert not fleet.quarantined
    assert sorted(r.info["ticket"] for r in results) == sorted(refs)
    for res in results:
        assert res.info["device"] == "dev1"
        np.testing.assert_array_equal(res.mem,
                                      refs[res.info["ticket"]][0])
    rep = fleet.report()
    assert rep["device_state"] == {"dev0": "evicted", "dev1": "active"}
    assert rep["reroutes"] > 0
    assert rep["faults"]["dev0"] > 0 and rep["faults"]["dev1"] == 0
    assert rep["health"]["dev0"] < rep["health"]["dev1"]


def test_probation_readmission_and_promotion():
    """An evicted device is re-admitted on probation after the cooldown;
    still-faulty, it is re-evicted on its first new fault; healed (the
    plan swapped for an inactive one), a clean probation drain promotes
    it back to active."""
    fleet, _, sc = _loss_fleet(router="round-robin")
    fleet.drain()
    dev0 = fleet.devices[0]
    assert dev0.state == "evicted"
    b = _bench()

    def serve_pair(seed):
        # two requests per drain: round-robin lands one on each routable
        # device, so a probation dev0 always sees real work
        for i in range(2):
            req, _ = _audited(b, _mems(b, 1, seed=seed + 100 * i)[0])
            fleet.submit_request(req)
        fleet.drain()

    # cooldown: probation_after drains must pass before re-admission
    cooldown = fleet.resilience.probation_after
    for i in range(cooldown):
        serve_pair(seed=10 + i)
        assert dev0.state == "evicted"
    # routing happens at submit time, so re-admission must land before
    # the next submissions: an empty drain flips dev0 to probation
    fleet.drain()
    assert dev0.state == "probation" and dev0.probation_left > 0
    # it is still stuck, so its first probation fault re-evicts it
    # (probation tolerates exactly zero faults)
    serve_pair(seed=20)
    assert dev0.state == "evicted"
    assert dev0.faults >= 3
    assert not fleet.quarantined        # every re-route still served
    # heal the device: swap every injector to an inactive plan
    for inj in sc.injectors:
        inj.plan = FaultPlan(seed=0)
    for i in range(cooldown):
        serve_pair(seed=30 + i)
    fleet.drain()                       # re-admission drain
    assert dev0.state == "probation"
    # probation again — a clean served result promotes dev0 to active
    serve_pair(seed=40)
    assert dev0.state == "active"
    assert dev0.consecutive_faults == 0 and dev0.served > 0


# ---------------------------------------------------- hedged dispatch

def test_hedge_wins_and_loser_is_abandoned_then_discarded():
    """A straggling chunk is hedged onto the idle clean device; the
    hedge result wins the fleet ticket, the drain returns *before* the
    straggler's hold expires (the loser is abandoned in flight), and a
    later drain discards the loser's result."""
    b = _bench()
    m = _mems(b, 1)[0]
    ref = run_kernel(b.gpu_prog, m, b.gpu_items, CFG)
    plan = FaultPlan(seed=0, straggler_rate=1.0, straggler_delay_s=0.6)

    def wrap(name, ex):
        return FaultInjector(name, ex, plan) if name == "dev0" else ex

    fleet = Fleet([("dev0", CFG), ("dev1", CFG)], max_batch=1,
                  resilience=FleetResilience(
                      hedge=HedgePolicy(after_s=0.03)),
                  timeout_s=5.0, executor_wrap=wrap)
    t = fleet.submit(b.gpu_prog, m, b.gpu_items)
    t0 = time.monotonic()
    (res,) = fleet.drain()
    elapsed = time.monotonic() - t0
    assert res.info["ticket"] == t
    assert res.info["device"] == "dev1"       # the hedge won
    np.testing.assert_array_equal(res.mem, ref[0])
    assert elapsed < 0.5                      # did not wait out the hold
    assert "settled_s" in res.info            # open-loop settle stamp
    assert res.info["settled_s"] <= time.monotonic()
    assert fleet.report()["hedged"] == 1
    # the loser is still in flight, abandoned
    assert fleet.devices[0].scheduler.inflight_chunks == 1
    time.sleep(0.7)                           # hold expires
    assert fleet.drain() == []                # loser collected, discarded
    assert fleet.devices[0].scheduler.inflight_chunks == 0
    assert not fleet.quarantined


def test_hedge_fires_at_most_once_per_ticket():
    b = _bench()
    plan = FaultPlan(seed=0, straggler_rate=1.0, straggler_delay_s=0.3)

    def wrap(name, ex):
        return FaultInjector(name, ex, plan) if name == "dev0" else ex

    fleet = Fleet([("dev0", CFG), ("dev1", CFG)], max_batch=1,
                  resilience=FleetResilience(
                      hedge=HedgePolicy(after_s=0.02)),
                  timeout_s=5.0, executor_wrap=wrap)
    tickets = [fleet.submit(b.gpu_prog, m, b.gpu_items)
               for m in _mems(b, 3)]
    results = fleet.drain()
    assert sorted(r.info["ticket"] for r in results) == tickets
    assert fleet.report()["hedged"] <= len(tickets)
    assert len(fleet._hedged) == len(set(fleet._hedged))


# ------------------------------------------------ deadline-drop policy

def test_deadline_drop_plans_expired_requests_out():
    from repro.registry import SCHEDULERS
    plan = SCHEDULERS.get("deadline-drop")
    b = _bench()
    fresh = Request(b.gpu_prog, b.gpu_mem, b.gpu_items)
    fresh.arrival_s = time.monotonic()
    expired = Request(b.gpu_prog, _mems(b, 1)[0], b.gpu_items,
                      deadline_us=1.0)
    expired.arrival_s = time.monotonic() - 1.0   # 1s ago >> 1us budget
    chunks = plan([fresh, expired], CFG, 4)
    assert chunks[0].kind == "drop" and chunks[0].members == (1,)
    assert [c.members for c in chunks[1:]] == [(0,)]
    # without deadlines the plan is exactly the cohort plan
    from repro.serve import plan_chunks
    reqs = [Request(b.gpu_prog, m, b.gpu_items) for m in _mems(b, 3)]
    assert [(c.kind, c.members) for c in plan(reqs, CFG, 4)] \
        == [(c.kind, c.members) for c in plan_chunks(reqs, CFG, 4)]


def test_deadline_drop_scheduler_quarantines_expired():
    b = _bench()
    s = Scheduler(CFG, policy="deadline-drop")
    t_ok = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    t_late = s.submit(b.gpu_prog, _mems(b, 1)[0], b.gpu_items,
                      deadline_us=50.0)
    time.sleep(0.01)                        # 10ms >> the 50us budget
    results = s.flush()
    assert [r.info["ticket"] for r in results] == [t_ok]
    assert isinstance(s.quarantined[t_late].error, DeadlineExceeded)
    # no-deadline traffic is never dropped, however stale
    t2 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    time.sleep(0.01)
    assert [r.info["ticket"] for r in s.flush()] == [t2]


def test_deadline_drop_in_fleet():
    b = _bench()
    fleet = Fleet([("dev0", CFG)], policy="deadline-drop")
    t = fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, deadline_us=50.0)
    t2 = fleet.submit(b.gpu_prog, _mems(b, 1)[0], b.gpu_items)
    time.sleep(0.01)
    results = fleet.drain()
    assert [r.info["ticket"] for r in results] == [t2]
    assert isinstance(fleet.quarantined[t].error, DeadlineExceeded)
