"""Scenario registry (``repro.registry``): decorator registration,
duplicate-name rejection, lazy provider discovery, deterministic
enumeration, the Mapping-compatible legacy views, and the CI matrix
surface the workflows consume."""
import json
import os
import subprocess
import sys

import pytest

from repro.registry import (AXES, BENCHES, FAULTS, MEMSYS, ROUTERS,
                            SCHEDULERS, SECTIONS, TRAFFIC)
from repro.registry.core import (Axis, DuplicateNameError, RegistryError,
                                 UnknownPluginError, resolve)


# ---------------------------------------------------------------- core

def _axis():
    return Axis("thing", providers=(), scan_plugins=False)


def test_decorator_and_direct_registration():
    ax = _axis()

    @ax.register("deco")
    def plugin():
        return 1

    ax.register("direct", plugin)
    assert ax.get("deco") is plugin          # decorator returns the obj
    assert ax.get("direct") is plugin
    assert plugin() == 1


def test_duplicate_name_rejected():
    ax = _axis()
    ax.register("dup", object())
    with pytest.raises(DuplicateNameError, match="dup"):
        ax.register("dup", object())


@pytest.mark.parametrize("bad", ["", None, 3])
def test_invalid_names_rejected(bad):
    with pytest.raises(RegistryError):
        _axis().register(bad, object())


def test_unknown_name_is_keyerror_listing_choices():
    ax = _axis()
    ax.register("a", 1)
    ax.register("b", 2)
    with pytest.raises(UnknownPluginError) as exc:
        ax.get("c")
    assert isinstance(exc.value, KeyError)
    assert "'a'" in str(exc.value) and "'b'" in str(exc.value)


def test_enumeration_is_sorted_not_insertion_ordered():
    ax = _axis()
    for name in ("zeta", "alpha", "mid"):
        ax.register(name, name.upper())
    assert ax.names() == ["alpha", "mid", "zeta"]
    assert [n for n, _ in ax.items()] == ["alpha", "mid", "zeta"]
    assert "mid" in ax and len(ax) == 3


def test_discovery_failure_reraises_on_retry():
    ax = Axis("broken", providers=("no_such_provider_module_xyz",),
              scan_plugins=False)
    with pytest.raises(ModuleNotFoundError):
        ax.names()
    # the failed discovery must roll back, not latch an empty axis
    with pytest.raises(ModuleNotFoundError):
        ax.names()


def test_resolve_module_function_spec():
    fn = resolve("json:dumps")
    assert fn([1]) == "[1]"
    with pytest.raises(RegistryError):
        resolve("json:no_such_attr")


def test_provider_import_is_lazy():
    """Importing repro.registry must not import the provider modules;
    the first axis query must. (Subprocess: this process's sys.modules
    is already polluted by other tests.)"""
    code = (
        "import sys\n"
        "import repro.registry\n"
        "assert 'repro.serve.loadgen' not in sys.modules, 'eager import'\n"
        "repro.registry.TRAFFIC.names()\n"
        "assert 'repro.serve.loadgen' in sys.modules, 'discovery missed'\n"
    )
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


# ------------------------------------------------------------- the axes

def test_all_axes_discover_builtins():
    assert BENCHES.names() == sorted(["mat_mul", "copy", "vec_mul", "fir",
                                      "div_int", "xcorr", "parallel_sel",
                                      "reduction"])
    assert MEMSYS.names() == ["banked", "banked-iso", "shared"]
    assert {"cohort", "fifo"} <= set(SCHEDULERS.names())
    assert {"earliest-finish", "round-robin"} <= set(ROUTERS.names())
    assert {"poisson", "bursty"} <= set(TRAFFIC.names())
    assert {"none", "seu", "straggler", "device-loss"} \
        <= set(FAULTS.names())
    assert {"dse", "serve", "compiler", "graph", "fleet",
            "engine", "resilience"} <= set(SECTIONS.names())
    for name, axis in AXES.items():
        assert len(axis) > 0, f"axis {name} is empty"


def test_dropin_plugin_discovered():
    """The one-file plugin package entry is visible on its axis and in
    the machine-readable enumeration CI consumes."""
    from repro.registry.__main__ import full_enumeration

    assert "heavy-tail" in TRAFFIC.names()
    arr = TRAFFIC.get("heavy-tail")(16, 3)
    assert len(arr) == 16 and all(b >= a for a, b in zip(arr, arr[1:]))
    enum = full_enumeration()
    assert enum["schema"] == "ggpu-registry/1"
    assert "heavy-tail" in enum["axes"]["traffic"]["names"]


def test_memsys_registry_view_tracks_axis():
    from repro.ggpu.engine.memsys import MEMSYS_REGISTRY, get_memsys

    assert sorted(MEMSYS_REGISTRY) == MEMSYS.names()
    assert len(MEMSYS_REGISTRY) == len(MEMSYS)
    assert "shared" in MEMSYS_REGISTRY
    assert MEMSYS_REGISTRY["banked"] is MEMSYS.get("banked")
    with pytest.raises(KeyError):
        get_memsys("l3-victim")


def test_bench_axis_serves_all_benches():
    from repro.ggpu import programs

    table = programs.all_benches()
    # legacy insertion order is preserved for table/CSV stability
    assert list(table) == ["mat_mul", "copy", "vec_mul", "fir", "div_int",
                           "xcorr", "parallel_sel", "reduction"]
    spec = BENCHES.get("copy")
    b = spec.build(*spec.smoke_sizes)
    assert b.name == "copy"


def test_isa_only_bench_rejected_by_suite():
    from repro.compiler.suite import kernel_def, suite_names

    assert set(suite_names()) <= set(BENCHES.names())
    with pytest.raises(KeyError):
        kernel_def("no_such_bench")


# ------------------------------------------- policies/routers behavior

def _mixed_requests():
    """A same-kernel copy pair, an odd shape, and a priority-1 request."""
    from repro.serve import Request

    reqs = []
    for i, name in enumerate(("copy", "copy", "vec_mul", "div_int")):
        spec = BENCHES.get(name)
        b = spec.build(*spec.smoke_sizes)
        reqs.append(Request(b.gpu_prog, b.gpu_mem, b.gpu_items, f"r{i}",
                            priority=(1 if i == 3 else 0)))
    return reqs


def test_fifo_policy_preserves_submission_order():
    """fifo ignores priority: strict submission order, folding only the
    consecutive same-kernel pair into one cohort chunk."""
    from repro.ggpu.engine import GGPUConfig

    chunks = SCHEDULERS.get("fifo")(_mixed_requests(), GGPUConfig(), 4)
    assert [tuple(c.members) for c in chunks] == [(0, 1), (2,), (3,)]


def test_cohort_policy_is_default_and_priority_aware():
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler, plan_chunks

    sched = Scheduler(GGPUConfig(), max_batch=4)
    assert sched.policy == "cohort"
    chunks = plan_chunks(_mixed_requests(), GGPUConfig(), 4)
    # cohort plans by (priority desc, ...): the priority-1 request leads
    assert tuple(chunks[0].members) == (3,)
    # a policy may also be passed as a callable, bypassing the registry
    assert Scheduler(GGPUConfig(), policy=plan_chunks)._plan is plan_chunks


def test_round_robin_router_alternates_devices():
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Fleet

    fleet = Fleet([("a", GGPUConfig(n_cus=1)), ("b", GGPUConfig(n_cus=1))],
                  router="round-robin")
    spec = BENCHES.get("copy")
    b = spec.build(*spec.smoke_sizes)
    for i in range(4):
        fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, tag=f"t{i}")
    results = fleet.drain()
    placed = [r.info["device"] for r in results]
    assert sorted(placed) == ["a", "a", "b", "b"]


def test_router_accepts_instance_and_unknown_name_fails():
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Fleet, RoundRobinRouter

    fleet = Fleet([("a", GGPUConfig())], router=RoundRobinRouter())
    assert isinstance(fleet.router, RoundRobinRouter)
    with pytest.raises(UnknownPluginError):
        Fleet([("a", GGPUConfig())], router="no-such-router")


# ------------------------------------------------------- CI matrices

def test_smoke_matrix_covers_legacy_smoke_jobs():
    from repro.registry.__main__ import smoke_matrix

    m = smoke_matrix()
    rows = {e["section"]: e for e in m["include"]}
    assert {"dse", "serve", "compiler", "graph", "fleet",
            "resilience"} <= set(rows)
    assert "engine" not in rows                 # ci_smoke=False
    assert rows["graph"]["check_args"] == "--section graph"
    assert rows["graph"]["baseline"].endswith("BENCH_serve.json")
    assert rows["resilience"]["check_args"] == "--section resilience"
    assert rows["resilience"]["baseline"].endswith("BENCH_resilience.json")
    assert "device_count=8" in rows["fleet"]["xla_flags"]
    assert rows["fleet"]["artifact_name"] == "BENCH_serve-sharded"
    for e in m["include"]:
        assert e["run_args"] and e["artifact"] and e["baseline"]
    json.dumps(m)                               # must be JSON-clean


def test_nightly_matrix_is_full_cross_product():
    from repro.registry.__main__ import nightly_matrix

    m = nightly_matrix()
    cells = [e for e in m["include"] if e["kind"] == "cell"]
    combos = {(e["memsys"], e["policy"], e["router"], e["fault"])
              for e in cells}
    want = len(MEMSYS) * len(SCHEDULERS) * len(ROUTERS) * len(FAULTS)
    assert len(cells) == len(combos) == want
    sweeps = [e for e in m["include"] if e["kind"] == "sweep"]
    assert any("--compiler" in e["run_args"] for e in sweeps)
    assert all("--fast" not in e["run_args"] for e in sweeps)
    json.dumps(m)


def test_cli_selfcheck_passes():
    from repro.registry.__main__ import main

    assert main(["--selfcheck"]) == 0
