"""Per-kernel correctness: Pallas (interpret mode) vs the pure-jnp oracles
in ``repro.kernels.ref`` across shape/dtype sweeps, plus hypothesis
property tests on the kernels' invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ggpu import isa
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pe_simd import pe_execute
from repro.kernels.rglru_scan import rglru_scan


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (bh, bhkv, sq, skv, hd, causal, window, dtype)
    (4, 2, 256, 256, 64, True, 0, jnp.float32),
    (4, 4, 128, 128, 32, False, 0, jnp.float32),      # bidirectional
    (8, 2, 200, 200, 64, True, 64, jnp.float32),      # ragged + SWA
    (2, 1, 384, 384, 128, True, 128, jnp.float32),    # deep GQA + window
    (2, 2, 128, 128, 64, True, 0, jnp.bfloat16),
    (6, 3, 96, 160, 64, False, 0, jnp.float32),       # cross lengths
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_ref(case):
    bh, bhkv, sq, skv, hd, causal, window, dtype = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (bh, sq, hd), dtype)
    k = jax.random.normal(k2, (bhkv, skv, hd), dtype)
    v = jax.random.normal(k3, (bhkv, skv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window,
                               scale=hd ** -0.5)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_flash_block_size_invariance():
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(3), (2, 256, 64))
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = flash_attention(q, k, v, causal=True, block_q=128, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,d", [(1, 64, 128), (3, 100, 96), (2, 17, 40)])
def test_rglru_vs_ref(b, s, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d)))
    bb = jax.random.normal(k2, (b, s, d))
    h0 = jax.random.normal(k3, (b, d))
    h, hf = rglru_scan(a, bb, h0, block_d=64, chunk=16, interpret=True)
    hr, hfr = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), atol=1e-5)


@given(st.integers(2, 30), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_rglru_composition_property(s, b):
    """Scanning [0:k) then [k:S) with the carried state == scanning [0:S)."""
    d = 16
    key = jax.random.PRNGKey(s * 7 + b)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, d)))
    bb = jax.random.normal(k2, (b, s, d))
    h0 = jax.random.normal(k3, (b, d))
    cut = max(1, s // 2)
    h_full, hf_full = ref.rglru_scan_ref(a, bb, h0)
    _, hf1 = rglru_scan(a[:, :cut], bb[:, :cut], h0, chunk=8)
    h2, hf2 = rglru_scan(a[:, cut:], bb[:, cut:], hf1, chunk=8)
    np.testing.assert_allclose(np.asarray(hf2), np.asarray(hf_full),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full[:, cut:]),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# pe_simd
# ---------------------------------------------------------------------------

def test_pe_simd_exact_all_ops():
    """Every ALU opcode, bit-exact vs the oracle."""
    ops_list = [isa.ADD, isa.SUB, isa.MUL, isa.MULH, isa.DIV, isa.REM, isa.AND, isa.OR,
                isa.XOR, isa.SLL, isa.SRL, isa.SRA, isa.SLT, isa.ADDI,
                isa.ANDI, isa.ORI, isa.XORI, isa.SLLI, isa.SRLI, isa.SRAI,
                isa.SLTI, isa.LUI]
    w, l = len(ops_list), 64
    op = jnp.asarray(ops_list, jnp.int32)[:, None]
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-10_000, 10_000, (w, l)), jnp.int32)
    b = jnp.asarray(rng.integers(-64, 64, (w, l)), jnp.int32)
    imm = jnp.asarray(rng.integers(0, 31, (w, 1)), jnp.int32)
    out = pe_execute(op, imm, a, b, interpret=True)
    expect = ref.pe_alu_ref(op, a, b, imm)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@given(st.integers(1, 40), st.integers(1, 128), st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_pe_simd_property_random(w, l, seed):
    rng = np.random.default_rng(seed)
    op = jnp.asarray(rng.integers(1, 23, (w, 1)), jnp.int32)
    a = jnp.asarray(rng.integers(-2**20, 2**20, (w, l)), jnp.int32)
    b = jnp.asarray(rng.integers(-100, 100, (w, l)), jnp.int32)
    imm = jnp.asarray(rng.integers(-2048, 2048, (w, 1)), jnp.int32)
    out = pe_execute(op, imm, a, b, interpret=True)
    expect = ref.pe_alu_ref(op, a, b, imm)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_pe_simd_matches_machine_alu():
    """The Pallas kernel and the simulator's exec_alu agree (the kernel is
    the TPU twin of the machine's hot loop)."""
    from repro.ggpu.engine.alu import exec_alu
    rng = np.random.default_rng(3)
    w, l = 16, 64
    op = jnp.asarray(rng.integers(1, 23, (w, 1)), jnp.int32)
    a = jnp.asarray(rng.integers(-1000, 1000, (w, l)), jnp.int32)
    b = jnp.asarray(rng.integers(-50, 50, (w, l)), jnp.int32)
    imm = jnp.asarray(rng.integers(-100, 100, (w, 1)), jnp.int32)
    kern = pe_execute(op, imm, a, b, interpret=True)
    sim = exec_alu(op, a, b, imm, None)
    # exclude MULH (int64 emulation differs on x64-disabled CPU)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(sim))


def test_mulh_vs_bigint():
    """The int32-only MULH decomposition is exact vs python big ints."""
    from repro.ggpu.engine.alu import _mulh32
    rng = np.random.default_rng(7)
    a = rng.integers(-2**31, 2**31, 10000).astype(np.int32)
    b = rng.integers(-2**31, 2**31, 10000).astype(np.int32)
    got = np.asarray(_mulh32(jnp.asarray(a), jnp.asarray(b)))
    exp = ((a.astype(object) * b.astype(object)) >> 32).astype(np.int64)
    np.testing.assert_array_equal(got, exp.astype(np.int32))
