"""Compiler differential suite: randomized DSL expressions executed on
the engine vs the NumPy oracle.

Two layers:

  * a seeded generator that always runs (fixed seeds, so the tier-1 suite
    is deterministic), covering random elementwise/reduction expression
    trees on a 1-CU machine plus one fixed expression across the full
    {scalar, 1/2/4 CU} x {shared, banked} machine matrix;
  * a hypothesis property (via ``tests/_hypothesis_compat``) that widens
    the same generator when hypothesis is installed, and degrades to a
    skip when it is not.

``GGPU_FAST_TESTS=1`` trims the seed count and the machine matrix.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.compiler import Schedule, compile_kernel, dsl  # noqa: E402
from repro.ggpu.engine import GGPUConfig, ScalarConfig  # noqa: E402

FAST = os.environ.get("GGPU_FAST_TESTS", "0") not in ("", "0")

N = 64
#: binary operators safe at any operand value (engine semantics mirrored
#: exactly by the oracle, including division by zero)
BIN_FNS = [
    lambda a, b: a + b,
    lambda a, b: a - b,
    lambda a, b: a * b,
    lambda a, b: a // b,
    lambda a, b: a % b,
    lambda a, b: a & b,
    lambda a, b: a | b,
    lambda a, b: a ^ b,
    lambda a, b: a < b,
]
UNARY_FNS = [
    lambda a: a >> 2,
    lambda a: a << 1,
    lambda a: a * 3,
    lambda a: a + 17,
    lambda a: -a,
    lambda a: a % 5,
]


def _random_exprfn(rng):
    """A random 2-input elementwise/reduction kernel body."""
    def build(depth):
        r = rng.integers(0, 4)
        if depth <= 0 or r == 0:
            return lambda a, b: (a, b)[rng.integers(0, 2)]
        if r == 1:
            f, sub = rng.choice(UNARY_FNS), build(depth - 1)
            return lambda a, b: f(sub(a, b))
        f = BIN_FNS[rng.integers(0, len(BIN_FNS))]
        l, rr = build(depth - 1), build(depth - 1)
        return lambda a, b: f(l(a, b), rr(a, b))

    body = build(int(rng.integers(1, 4)))
    if rng.integers(0, 2):
        seg = int(rng.choice([4, 8, 16]))
        return lambda a, b: body(a, b).seg_sum(seg)
    return body


def _check(fn, seed, cfg, scalar=False, lo=-100, hi=100, schedule=None):
    k = compile_kernel(fn, dict(a=N, b=N), name=f"rand{seed}",
                       schedule=schedule)
    ins = k.random_inputs(lo=lo, hi=hi, seed=seed)
    k.verify(ins, cfg, scalar=scalar)


def _random_schedule(rng, out_len):
    """A random valid lowering schedule for a kernel with ``out_len``
    outputs (coarsen drawn from the valid divisors)."""
    divs = [d for d in (1, 2, 4, 8) if out_len % d == 0]
    return Schedule(coarsen=int(rng.choice(divs)),
                    hoist=bool(rng.integers(0, 2)),
                    branchy=bool(rng.integers(0, 2)),
                    peel=bool(rng.integers(0, 2)))


@pytest.mark.parametrize("seed", range(3 if FAST else 6))
def test_random_expressions_bit_exact(seed):
    rng = np.random.default_rng(100 + seed)
    _check(_random_exprfn(rng), seed, GGPUConfig(n_cus=1))


def test_random_expression_edge_values():
    """Extreme operands: wraparound, INT32 edges, zero divisors."""
    fn = (lambda a, b: ((a * b) ^ (a // b)) + (a % b))
    k = compile_kernel(fn, dict(a=N, b=N), name="edges")
    rng = np.random.default_rng(0)
    ins = {
        "a": rng.choice(np.array([0, 1, -1, 2 ** 31 - 1, -2 ** 31,
                                  12345, -54321], np.int32), N),
        # -1 is excluded: INT32_MIN // -1 overflows int32 and XLA's CPU
        # lowering of that single case is platform-defined
        "b": rng.choice(np.array([0, 1, 3, -3, 2 ** 31 - 1],
                                 np.int32), N),
    }
    k.verify(ins, GGPUConfig(n_cus=1))


MACHINES = [("scalar", None), ("1cu", 1), ("2cu", 2), ("4cu", 4)]
MEMSYS = ["shared", "banked"]
if FAST:
    MACHINES = [("scalar", None), ("2cu", 2)]


@pytest.mark.parametrize("memsys", MEMSYS)
@pytest.mark.parametrize("machine,cus", MACHINES)
def test_fixed_expression_machine_matrix(machine, cus, memsys):
    """One mixed expression (fused elementwise + segmented reduction +
    guarded stencil) across the machine x memory-system matrix."""
    def fn(a, b):
        return (dsl.stencil(a, [1, 1], [-1, 1]) * b + 3).seg_sum(8)

    if cus is None:
        if memsys != "shared":
            pytest.skip("scalar baseline models the shared cache")
        _check(fn, 42, ScalarConfig(), scalar=True)
    else:
        _check(fn, 42, GGPUConfig(n_cus=cus, memsys=memsys))


@pytest.mark.parametrize("memsys", MEMSYS)
@pytest.mark.parametrize("machine,cus", MACHINES)
def test_random_schedules_machine_matrix(machine, cus, memsys):
    """Randomized lowering schedules (the autotuner's candidate axes:
    coarsen x hoist x branchy x peel) on the guarded mixed expression,
    each differentially verified vs the IR oracle across the machine x
    memory-system matrix."""
    def fn(a, b):
        return (dsl.stencil(a, [1, 1], [-1, 1]) * b + 3).seg_sum(8)

    if cus is None and memsys != "shared":
        pytest.skip("scalar baseline models the shared cache")
    rng = np.random.default_rng(1234)
    for i in range(2 if FAST else 4):
        sched = _random_schedule(rng, out_len=N // 8)
        if cus is None:
            _check(fn, 50 + i, ScalarConfig(), scalar=True,
                   schedule=sched)
        else:
            _check(fn, 50 + i, GGPUConfig(n_cus=cus, memsys=memsys),
                   schedule=sched)


@pytest.mark.parametrize("seed", range(2 if FAST else 4))
def test_random_expression_random_schedule(seed):
    """Random expression trees under random schedules stay bit-exact —
    the coverage claim behind autotuning: ANY candidate the search can
    emit is oracle-verified."""
    rng = np.random.default_rng(500 + seed)
    fn = _random_exprfn(rng)
    k0 = compile_kernel(fn, dict(a=N, b=N), name=f"sched{seed}")
    sched = _random_schedule(rng, k0.kernel.out_len)
    _check(fn, seed, GGPUConfig(n_cus=2), schedule=sched)


@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=10, deadline=None)
def test_property_random_expressions(seed, depth):
    """Hypothesis-driven widening of the seeded generator (skips without
    hypothesis installed)."""
    rng = np.random.default_rng(seed)
    fn = _random_exprfn(rng)
    _check(fn, seed % 97, GGPUConfig(n_cus=1))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="documentation marker")
def test_property_suite_is_live():
    """Guards against the property test silently degrading when
    hypothesis IS available."""
    assert HAVE_HYPOTHESIS
