"""Async launch pipeline: LaunchHandle futures are bit-exact vs the sync
entry points on all 8 benches across {single, cohort, batch} and through
interleaved pipelined drains; donation never invalidates caller data and
is never read back after dispatch; failures surface through the handle;
the executor registry is frequency-faithful; opcode sets are
content-cached on requests."""
import numpy as np
import pytest

from repro.ggpu import programs
from repro.ggpu.engine import (GGPUConfig, KernelLaunchError, run_kernel,
                               run_kernel_async, run_kernel_batch,
                               run_kernel_batch_async, run_kernel_cohort,
                               run_kernel_cohort_async)
from repro.ggpu.engine.stepper import _static_ops
from repro.ggpu.isa import Assembler
from repro.serve import Request, Scheduler, get_executor, sim_key

CFG = GGPUConfig(n_cus=2)
STAT_KEYS = ("cycles", "instrs", "mem_ops", "hits", "misses", "steps")

SMALL = {
    "copy": lambda: programs._copy(16, 128),
    "vec_mul": lambda: programs._vec_mul(16, 128),
    "mat_mul": lambda: programs._mat_mul(4, 8),
    "fir": lambda: programs._fir(16, 64),
    "div_int": lambda: programs._div_int(16, 64),
    "xcorr": lambda: programs._xcorr(16, 64),
    "parallel_sel": lambda: programs._parallel_sel(16, 64),
    "reduction": lambda: programs._reduction(64, 256),
}


def _pad_prog(prog, rows):
    return np.vstack([prog, np.zeros((rows, prog.shape[1]), np.int32)])


def _variant_mem(b, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(-20, 20, b.gpu_mem.shape[0]).astype(np.int32)


def _check(result, direct):
    mem, info = result
    dmem, dinfo = direct
    np.testing.assert_array_equal(mem, dmem)
    for k in STAT_KEYS:
        assert info[k] == dinfo[k], k


@pytest.mark.parametrize("name", sorted(SMALL))
def test_async_bitexact_all_paths_and_interleaved_drain(name):
    """Handles from all three async entry points, and a pipelined
    scheduler drain interleaved under a budget, return the same bits
    (mem, cycles, stats) as direct sync ``run_kernel`` on every bench."""
    b = SMALL[name]()
    progA = b.gpu_prog
    progB = _pad_prog(progA, 1)
    progC = _pad_prog(progA, 2)
    m0, m1, m2 = b.gpu_mem, _variant_mem(b, 1), _variant_mem(b, 2)
    launches = [(progB, m1), (progA, m0), (progA, m2), (progC, m0)]
    direct = [run_kernel(p, m, b.gpu_items, CFG) for p, m in launches]

    # engine-level async handles: single / cohort / batch
    _check(run_kernel_async(progA, m0, b.gpu_items, CFG).result(),
           direct[1])
    hc = run_kernel_cohort_async(progA, [m0, m2], b.gpu_items, CFG)
    for out, d in zip(hc.results(), (direct[1], direct[2])):
        _check(out, d)
    hb = run_kernel_batch_async([progB, progC], [m1, m0],
                                [b.gpu_items, b.gpu_items], CFG)
    for out, d in zip(hb.results(), (direct[0], direct[3])):
        _check(out, d)

    # pipelined scheduler: cohort + batch chunks in flight together,
    # drains interleaved under a budget
    s = Scheduler(CFG, max_inflight=2)
    for p, m in launches:
        s.submit(p, m, b.gpu_items)
    out = s.drain(budget=1)
    out += s.drain()
    assert len(s) == 0 and not s.quarantined
    got = {r.info["ticket"]: r for r in out}
    assert sorted(got) == [0, 1, 2, 3]
    for t, d in enumerate(direct):
        _check(got[t], d)


def test_out_region_sliced_download():
    """A declared out_region downloads exactly that slice of the final
    image — on every path, including through the scheduler — and (0, 0)
    transfers nothing while cycles stay exact."""
    b = SMALL["vec_mul"]()
    lo, hi = b.gpu_out.start, b.gpu_out.stop
    full, dinfo = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)

    h = run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG,
                         out_region=(lo, hi))
    mem, info = h.result()
    np.testing.assert_array_equal(mem, full[lo:hi])
    assert info["cycles"] == dinfo["cycles"]

    m2 = _variant_mem(b, 5)
    full2, _ = run_kernel(b.gpu_prog, m2, b.gpu_items, CFG)
    hc = run_kernel_cohort_async(b.gpu_prog, [b.gpu_mem, m2], b.gpu_items,
                                 CFG, out_regions=[(lo, hi), None])
    outs = hc.results()
    np.testing.assert_array_equal(outs[0][0], full[lo:hi])
    np.testing.assert_array_equal(outs[1][0], full2)   # None: full image

    hb = run_kernel_batch_async(
        [b.gpu_prog, _pad_prog(b.gpu_prog, 1)], [b.gpu_mem, m2],
        [b.gpu_items] * 2, CFG, out_regions=[(lo, hi), (0, 0)])
    outs = hb.results()
    np.testing.assert_array_equal(outs[0][0], full[lo:hi])
    assert outs[1][0].shape == (0,)                    # cycles-only
    assert outs[1][1]["cycles"] == dinfo["cycles"]

    s = Scheduler(CFG)
    s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, out_region=(lo, hi))
    s.submit(b.gpu_prog, m2, b.gpu_items, out_region=(0, 0))
    r0, r1 = s.drain()
    np.testing.assert_array_equal(r0.mem, full[lo:hi])
    assert r1.mem.shape == (0,) and r1.info["cycles"] == dinfo["cycles"]

    with pytest.raises(ValueError):
        run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG,
                         out_region=(0, b.gpu_mem.shape[0] + 1))


def test_donation_safety():
    """The staged device buffer is donated at dispatch (XLA invalidates
    it — proof nothing reads it afterwards), while the caller's host
    array is never touched; results stay correct after donation, and a
    sync re-run from the same host array is unaffected."""
    b = SMALL["copy"]()
    before = b.gpu_mem.copy()
    h = run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)
    assert h.donated.is_deleted()            # donated, not merely unused
    np.testing.assert_array_equal(b.gpu_mem, before)   # caller untouched
    mem, _ = h.result()
    np.testing.assert_array_equal(mem[b.gpu_out], b.ref(b.gpu_mem, b.gpu_n))

    hc = run_kernel_cohort_async(b.gpu_prog, [b.gpu_mem, b.gpu_mem],
                                 b.gpu_items, CFG)
    assert hc.donated.is_deleted()
    hb = run_kernel_batch_async([b.gpu_prog, _pad_prog(b.gpu_prog, 1)],
                                [b.gpu_mem, b.gpu_mem],
                                [b.gpu_items] * 2, CFG)
    assert hb.donated.is_deleted()
    np.testing.assert_array_equal(b.gpu_mem, before)
    # the same host image dispatches again cleanly (fresh staging copy)
    _check(run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items,
                            CFG).result(),
           run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG))


def _spinner():
    a = Assembler()
    a.label("spin").beq(0, 0, "spin")
    return a.assemble()


def test_launch_handle_surfaces_failure():
    """A launch that hits max_steps raises KernelLaunchError out of the
    handle at resolution time, naming the failing position — on every
    path — and the error repeats on re-resolution."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(8, 64)
    h = run_kernel_async(_spinner(), np.zeros(8, np.int32), 8, cfg)
    with pytest.raises(KernelLaunchError) as exc:
        h.result()
    assert exc.value.index == 0
    with pytest.raises(KernelLaunchError):   # sticky: wait() re-raises
        h.wait()

    hc = run_kernel_cohort_async(_spinner(), [np.zeros(8, np.int32)] * 2,
                                 8, cfg)
    with pytest.raises(KernelLaunchError):
        hc.results()

    hb = run_kernel_batch_async(
        [b.gpu_prog, _spinner()], [b.gpu_mem, np.zeros(8, np.int32)],
        [b.gpu_items, 8], cfg)
    with pytest.raises(KernelLaunchError) as exc:
        hb.results()
    assert exc.value.index == 1


@pytest.mark.parametrize("max_inflight", (1, 8))
def test_pipelined_drain_quarantines_at_any_depth(max_inflight):
    """Pipeline depth never changes results or quarantine behavior: a
    poisoned launch in a deep in-flight queue is isolated, survivors
    complete bit-exact, and stats stay coherent."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(16, 128)
    c2 = programs._copy(8, 64)               # W=1: shares spinner's bucket
    s = Scheduler(cfg, max_inflight=max_inflight)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    t_bad = s.submit(_spinner(), np.zeros(8, np.int32), 8)
    t2 = s.submit(c2.gpu_prog, c2.gpu_mem, c2.gpu_items)
    t3 = s.submit(b.gpu_prog, _variant_mem(b, 3), b.gpu_items)
    results = s.drain()
    assert len(s) == 0
    assert [r.info["ticket"] for r in results] == [t0, t2, t3]
    assert set(s.quarantined) == {t_bad}
    _check(results[1],
           run_kernel(c2.gpu_prog, c2.gpu_mem, c2.gpu_items, cfg))
    st = s.executor.stats
    assert st.trace_hits + st.trace_misses == st.dispatches


def test_registry_is_frequency_faithful():
    """get_executor at a non-default frequency returns a view sharing the
    canonical executor's compiled-envelope cache, stats, and memo — but
    its Results report time_us rescaled from cycles at the TRUE freq_mhz
    (the PR-3 registry reported it at the normalized 500 MHz)."""
    cfg667 = GGPUConfig(n_cus=4, freq_mhz=667.0)
    ex = get_executor(cfg667)
    assert ex.cfg.freq_mhz == 667.0
    assert ex.sim_cfg == sim_key(cfg667)
    canon = get_executor(sim_key(cfg667))
    assert canon is not ex
    assert ex.memo is canon.memo and ex.stats is canon.stats
    assert ex._envelopes is canon._envelopes
    assert get_executor(cfg667) is ex        # views are cached too

    b = SMALL["copy"]()
    (res,) = ex.run("single", [Request(b.gpu_prog, b.gpu_mem, b.gpu_items)])
    assert res.info["time_us"] == pytest.approx(
        res.info["cycles"] / 667.0)
    # same envelope through the canonical executor: shared trace cache hits
    (res500,) = canon.run("single",
                          [Request(b.gpu_prog, b.gpu_mem, b.gpu_items)])
    assert res500.info["cycles"] == res.info["cycles"]
    assert res500.info["time_us"] == pytest.approx(
        res.info["cycles"] / 500.0)
    assert canon.stats.trace_hits >= 1


def test_bad_out_region_bounces_at_admission():
    """A malformed out_region raises at submit (per-request,
    handleable) — it must never be admitted, where it would poison every
    later drain from inside the dispatch path."""
    b = SMALL["copy"]()
    s = Scheduler(CFG)
    with pytest.raises(ValueError):
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                 out_region=(0, b.gpu_mem.shape[0] + 1))
    with pytest.raises(ValueError):
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, out_region=(-1, 0))
    assert len(s) == 0                       # nothing admitted
    s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    assert len(s.drain()) == 1               # scheduler unharmed


def test_trace_hits_counted_across_pipeline_window():
    """Identical-envelope chunks dispatched ahead in one pipeline window
    are trace hits: the jit trace is paid at dispatch, so only the first
    chunk is a miss even before anything is collected."""
    b = SMALL["vec_mul"]()
    s = Scheduler(CFG, max_batch=2, max_inflight=8)
    for seed in range(8):                    # 4 identical cohort envelopes
        s.submit(b.gpu_prog, _variant_mem(b, seed), b.gpu_items)
    assert len(s.drain()) == 8
    st = s.executor.stats
    assert st.dispatches == 4
    assert st.trace_misses == 1 and st.trace_hits == 3
    assert st.trace_hits + st.trace_misses == st.dispatches


def test_sync_entries_accept_iterators():
    """run_kernel_cohort/batch materialize sequence inputs exactly once —
    a generator argument is not consumed by the emptiness guard."""
    b = SMALL["copy"]()
    direct = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)
    outs = run_kernel_cohort(b.gpu_prog,
                             (m for m in [b.gpu_mem, _variant_mem(b, 1)]),
                             b.gpu_items, CFG)
    assert len(outs) == 2
    _check(outs[0], direct)
    assert run_kernel_cohort(b.gpu_prog, iter([]), b.gpu_items, CFG) == []
    outs = run_kernel_batch((p for p in [b.gpu_prog]),
                            (m for m in [b.gpu_mem]),
                            (n for n in [b.gpu_items]), CFG)
    _check(outs[0], direct)
    assert run_kernel_batch(iter([]), iter([]), iter([]), CFG) == []


def test_request_static_ops_content_cached():
    """Opcode sets are cached by program *content*: two distinct Request
    objects over equal programs share one cached tuple, and it matches
    the engine's own scan."""
    b = SMALL["fir"]()
    r1 = Request(b.gpu_prog, b.gpu_mem, b.gpu_items)
    r2 = Request(b.gpu_prog.copy(), _variant_mem(b, 1), b.gpu_items)
    assert r1.static_ops() == _static_ops(b.gpu_prog)
    assert r1.static_ops() is r2.static_ops()   # one cache entry


def test_fleet_dispatches_all_devices_before_collecting():
    """Fleet.drain puts every device's chunks in flight before resolving
    any: each device's scheduler dispatch precedes every collect."""
    from repro.serve import Fleet
    events = []
    b = SMALL["copy"]()
    fleet = Fleet([("a", GGPUConfig(n_cus=1)), ("b", GGPUConfig(n_cus=2))])
    for dev in fleet.devices:
        sched = dev.scheduler

        def spy(kind, fn, name):
            def wrapper(*a, **k):
                events.append((kind, name))
                return fn(*a, **k)
            return wrapper
        sched.dispatch = spy("dispatch", sched.dispatch, dev.name)
        sched.collect = spy("collect", sched.collect, dev.name)
    # one launch per device (wide vs narrow routing not needed here)
    fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    fleet.submit(b.gpu_prog, _variant_mem(b, 1), b.gpu_items)
    fleet.drain()
    kinds = [k for k, _ in events]
    assert kinds == ["dispatch", "dispatch", "collect", "collect"]
