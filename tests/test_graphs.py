"""Device-resident kernel graphs: ``Request.deps`` edges through the
dependency-aware scheduler, engine-level staged-buffer patches,
``compile_graph`` reduction-boundary splitting, the ``serve.graphs``
program surface, fleet co-location with learned (kernel, schedule)
service times, cascade quarantine, and the open-loop load generator
replayed against a ``Fleet``."""
import numpy as np
import pytest

from repro.compiler import compile_graph
from repro.ggpu import programs
from repro.ggpu.engine import (BlockPatch, GGPUConfig, run_kernel,
                               run_kernel_async, run_kernel_cohort_async)
from repro.ggpu.isa import Assembler
from repro.serve import (Dep, DependencyError, Fleet, Request, Scheduler,
                         bursty_arrivals, extract_outputs, replay,
                         run_chains_host_staged, run_program,
                         run_program_host_staged, run_programs_host_staged,
                         submit_program, submit_programs)

CFG = GGPUConfig(n_cus=2)
N, SEG = 64, 16


@pytest.fixture(scope="module")
def program():
    """3-stage map -> segmented reduce -> scale chain."""
    return compile_graph(lambda a, b: (a * b).seg_sum(SEG) * 3 + 1,
                         {"a": N, "b": N}, name="mrs")


def _inputs(seed):
    rng = np.random.default_rng(seed)
    return {"a": rng.integers(-50, 50, N).astype(np.int32),
            "b": rng.integers(-50, 50, N).astype(np.int32)}


def _spinner():
    a = Assembler()
    a.label("spin").beq(0, 0, "spin")
    return a.assemble()


# -- engine: staged-buffer patches ---------------------------------------


def test_single_launch_patch_matches_host_patch():
    """A device patch applied to the staged buffer is bit-exact with
    patching the host image before launch."""
    import jax.numpy as jnp
    b = programs._copy(16, 128)
    lo, hi = 3, 19
    src = np.arange(lo, hi, dtype=np.int32) * 7
    patched = b.gpu_mem.copy()
    patched[lo:hi] = src
    direct = run_kernel(b.gpu_prog, patched, b.gpu_items, CFG)
    h = run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG,
                         patches=[(lo, hi, jnp.asarray(src))])
    mem, info = h.result()
    np.testing.assert_array_equal(mem, direct[0])
    assert info["cycles"] == direct[1]["cycles"]


def test_cohort_patch_block_and_per_launch_match():
    """Cohort dispatch with a fused ``BlockPatch`` and with per-launch
    patch lists both reproduce host-side patching, member by member."""
    import jax.numpy as jnp
    b = programs._copy(16, 128)
    B, lo, hi = 3, 8, 24
    rng = np.random.default_rng(0)
    mems = [rng.integers(-20, 20, b.gpu_mem.shape[0]).astype(np.int32)
            for _ in range(B)]
    block = rng.integers(-99, 99, (B, hi - lo)).astype(np.int32)
    direct = []
    for m, row in zip(mems, block):
        p = m.copy()
        p[lo:hi] = row
        direct.append(run_kernel(b.gpu_prog, p, b.gpu_items, CFG))
    fused = run_kernel_cohort_async(
        b.gpu_prog, mems, b.gpu_items, CFG,
        patches=BlockPatch(lo, hi, jnp.asarray(block))).results()
    per = run_kernel_cohort_async(
        b.gpu_prog, mems, b.gpu_items, CFG,
        patches=[[(lo, hi, jnp.asarray(row))] for row in block]).results()
    for (dm, di), (fm, fi), (pm, pi) in zip(direct, fused, per):
        np.testing.assert_array_equal(fm, dm)
        np.testing.assert_array_equal(pm, dm)
        assert fi["cycles"] == di["cycles"] == pi["cycles"]


def test_patch_validation():
    b = programs._copy(16, 128)
    with pytest.raises(ValueError):
        run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG,
                         patches=[(5, 2, np.zeros(0, np.int32))])
    with pytest.raises(ValueError):
        run_kernel_async(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG,
                         patches=[(0, 4, np.zeros(3, np.int32))])


# -- scheduler: dependency edges -----------------------------------------


def test_manual_dep_chain_bit_exact():
    """A hand-built producer->consumer edge: the consumer's window is
    overwritten with the producer's output on the device, matching the
    host-composed run exactly — and both serve in ONE drain call."""
    b = programs._copy(16, 128)
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    lo, hi = b.gpu_out.start, b.gpu_out.stop
    consumer_mem = b.gpu_mem.copy()
    consumer_mem[lo:hi] = 0                          # placeholder words
    t1 = s.submit(b.gpu_prog, consumer_mem, b.gpu_items,
                  deps=[Dep(t0, (lo, hi), (lo, hi))])
    results = {r.info["ticket"]: r for r in s.drain()}
    assert set(results) == {t0, t1}
    prod_mem, _ = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)
    host = consumer_mem.copy()
    host[lo:hi] = np.asarray(prod_mem)[lo:hi]
    cons_mem, _ = run_kernel(b.gpu_prog, host, b.gpu_items, CFG)
    np.testing.assert_array_equal(results[t1].mem, cons_mem)
    # residency released once the last consumer collected
    assert s._resident == {} and s._dep_waiters == {}


def test_dep_src_defaults_to_producer_out_region():
    """``Dep.src=None`` pins to the producer's declared out_region."""
    b = programs._copy(16, 128)
    lo, hi = b.gpu_out.start, b.gpu_out.stop
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, out_region=(lo, hi))
    t1 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                  deps=[Dep(t0, (lo, hi))])
    req = s._pending[t1]
    assert req.deps[0].src == (lo, hi)
    assert len(s.drain()) == 2


def test_dep_validation_bounces_at_admission():
    b = programs._copy(16, 128)
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    with pytest.raises(ValueError):                  # unknown producer
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                 deps=[Dep(999, (0, 4), (0, 4))])
    with pytest.raises(ValueError):                  # width mismatch
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                 deps=[Dep(t0, (0, 4), (0, 8))])
    with pytest.raises(ValueError):                  # src out of bounds
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                 deps=[Dep(t0, (0, 4), (10 ** 6, 10 ** 6 + 4))])
    # producer with the empty out_region needs an explicit src
    t2 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, out_region=(0, 0))
    with pytest.raises(ValueError):
        s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items, deps=[Dep(t2, (0, 4))])
    # a consumer cannot cancel a producer out from under its waiters
    t3 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                  deps=[Dep(t0, (0, 4), (0, 4))])
    with pytest.raises(ValueError):
        s.cancel(t0)
    s.cancel(t3)
    assert len(s.drain()) == 2                   # t0 and t2 still pending


def test_residency_survives_across_drains():
    """A producer collected in an earlier drain stays resident (its
    device buffer sliceable) while consumers admitted before that drain
    are still pending — the consumer completes bit-exactly later."""
    b = programs._copy(16, 128)
    lo, hi = b.gpu_out.start, b.gpu_out.stop
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    t1 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                  deps=[Dep(t0, (lo, hi), (lo, hi))])
    first = s.drain(budget=1)                    # serves only the producer
    assert [r.info["ticket"] for r in first] == [t0]
    assert t0 in s._resident                     # held for the consumer
    prod_mem, _ = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)
    host = b.gpu_mem.copy()
    host[lo:hi] = np.asarray(prod_mem)[lo:hi]
    (res,) = s.drain()
    assert res.info["ticket"] == t1
    np.testing.assert_array_equal(
        res.mem, run_kernel(b.gpu_prog, host, b.gpu_items, CFG)[0])
    assert s._resident == {}


def test_dependency_cascade_quarantine():
    """A poisoned producer quarantines its consumers transitively with
    ``DependencyError`` — they never compute on placeholder zeros."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(16, 128)
    s = Scheduler(cfg)
    t_bad = s.submit(_spinner(), np.zeros(8, np.int32), 8)
    t_mid = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                     deps=[Dep(t_bad, (0, 4), (0, 4))])
    t_leaf = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                      deps=[Dep(t_mid, (0, 4), (0, 4))])
    t_ok = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    results = s.drain()
    assert [r.info["ticket"] for r in results] == [t_ok]
    assert set(s.quarantined) == {t_bad, t_mid, t_leaf}
    assert "max_steps" in str(s.quarantined[t_bad].error)
    for t in (t_mid, t_leaf):
        assert isinstance(s.quarantined[t].error, DependencyError)
    assert len(s) == 0 and s.inflight_chunks == 0
    assert s._resident == {} and s._dep_waiters == {} and s._poisoned == {}


def test_drain_abandons_cleanly_through_repeated_failures():
    """Regression: two successive unexpected mid-drain failures must not
    double-count ``inflight_chunks`` or double-serve — abandoned chunks
    go back to pending exactly once, and the final drain returns every
    ticket exactly once (dep chains included)."""
    b = programs._copy(16, 128)
    fir = programs._fir(16, 64)
    lo, hi = b.gpu_out.start, b.gpu_out.stop
    s = Scheduler(CFG)
    t0 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    t1 = s.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                  deps=[Dep(t0, (lo, hi), (lo, hi))])
    t2 = s.submit(fir.gpu_prog, fir.gpu_mem, fir.gpu_items)
    real_collect = s.executor.collect
    boom = {"armed": True}

    def exploding(pending):
        if boom["armed"]:
            raise ValueError("malformed launch")
        return real_collect(pending)

    s.executor.collect = exploding
    for attempt in range(2):
        with pytest.raises(ValueError):
            s.drain()
        assert s.inflight_chunks == 0, "abandoned chunks must not linger"
        assert sorted(s.pending_tickets) == [t0, t1, t2]
        assert s._completed == [] or attempt == 0
    s.executor.collect = real_collect
    boom["armed"] = False
    results = s.drain()
    assert [r.info["ticket"] for r in results] == [t0, t1, t2]
    assert s.drain() == []                       # nothing double-served
    assert s.inflight_chunks == 0 and len(s) == 0
    # the dep chain still executed device-resident and bit-exact
    prod_mem, _ = run_kernel(b.gpu_prog, b.gpu_mem, b.gpu_items, CFG)
    host = b.gpu_mem.copy()
    host[lo:hi] = np.asarray(prod_mem)[lo:hi]
    np.testing.assert_array_equal(
        results[1].mem, run_kernel(b.gpu_prog, host, b.gpu_items, CFG)[0])


# -- compiler: reduction-boundary splitting ------------------------------


def test_compile_graph_splits_at_reduction(program):
    assert [ck.name for ck in program.stages] == ["mrs_s0", "mrs_s1",
                                                  "mrs_s2"]
    kinds = [sorted(k for k, _ in program.sources[i].values())
             for i in range(3)]
    assert kinds[0] == ["input", "input"]        # map: a, b
    assert kinds[1] == ["stage"]                 # reduce feeds on the map
    assert kinds[2] == ["stage"]                 # scale feeds on the reduce
    ins = _inputs(0)
    expect = ((ins["a"].astype(np.int64) * ins["b"])
              .reshape(-1, SEG).sum(axis=1) * 3 + 1).astype(np.int32)
    np.testing.assert_array_equal(program.reference(ins), expect)
    np.testing.assert_array_equal(program.run_host(ins, CFG), expect)


def test_compile_graph_single_stage_when_no_reduction():
    prog = compile_graph(lambda a, b: a * b + 1, {"a": 16, "b": 16})
    assert len(prog.stages) == 1
    ins = {"a": np.arange(16, dtype=np.int32),
           "b": np.full(16, 3, np.int32)}
    sched = Scheduler(CFG)
    np.testing.assert_array_equal(run_program(sched, prog, ins),
                                  prog.reference(ins))


def test_compile_graph_chained_reductions():
    prog = compile_graph(lambda a: (a * 2).seg_sum(8).seg_sum(4),
                         {"a": 64})
    assert len(prog.stages) >= 3                 # map, reduce, reduce
    ins = {"a": np.arange(64, dtype=np.int32)}
    sched = Scheduler(CFG)
    np.testing.assert_array_equal(run_program(sched, prog, ins),
                                  prog.reference(ins))


# -- serve.graphs: programs end to end -----------------------------------


def test_run_program_matches_reference_and_host_staged(program):
    ins = _inputs(1)
    sched = Scheduler(CFG)
    out = run_program(sched, program, ins)
    ref = program.reference(ins)
    np.testing.assert_array_equal(out, ref)
    np.testing.assert_array_equal(
        run_program_host_staged(Scheduler(CFG), program, ins), ref)
    assert sched.quarantined == {}
    # interior stages never declared a download
    assert sched._resident == {} and len(sched) == 0


def test_submit_programs_folds_stage_major(program):
    """N instances stage-major: every stage folds into one cohort
    dispatch, every output is bit-exact, and both host-staged references
    agree."""
    n_inst = 4
    ins = [_inputs(10 + i) for i in range(n_inst)]
    refs = [program.reference(i) for i in ins]
    sched = Scheduler(CFG, max_batch=n_inst)
    d0 = sched.executor.stats.dispatches
    handles = submit_programs(sched, program, ins)
    outs = extract_outputs(sched.drain(), handles)
    assert sched.executor.stats.dispatches - d0 == len(program.stages)
    for o, r in zip(outs, refs):
        np.testing.assert_array_equal(o, r)
    for o, r in zip(run_chains_host_staged(Scheduler(CFG), program, ins),
                    refs):
        np.testing.assert_array_equal(o, r)
    for o, r in zip(run_programs_host_staged(Scheduler(CFG), program, ins),
                    refs):
        np.testing.assert_array_equal(o, r)


def test_submit_program_interleaves_with_other_traffic(program):
    """Graph requests coexist with plain launches in one drain."""
    b = programs._copy(16, 128)
    sched = Scheduler(CFG)
    t_plain = sched.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    ins = _inputs(2)
    handle = submit_program(sched, program, ins, tag="g")
    results = sched.drain()
    tickets = [r.info["ticket"] for r in results]
    assert t_plain in tickets and handle.final in tickets
    np.testing.assert_array_equal(
        extract_outputs(results, [handle])[0], program.reference(ins))
    tags = {r.info.get("tag") for r in results}
    assert f"g:{program.stages[-1].name}" in tags


def test_graph_quarantine_surfaces_as_none(program):
    """A quarantined ancestor leaves that chain's final output as ``None``
    in ``extract_outputs`` while an independent healthy instance of the
    same program completes in the same drain."""
    from repro.serve import GraphTickets
    # generous step budget: the real program must complete — only the
    # spinner (which never halts) trips the bound
    cfg = GGPUConfig(n_cus=2, max_steps=5000)
    b = programs._copy(16, 128)
    sched = Scheduler(cfg)
    t_bad = sched.submit(_spinner(), np.zeros(8, np.int32), 8)
    t_leaf = sched.submit(b.gpu_prog, b.gpu_mem, b.gpu_items,
                          deps=[Dep(t_bad, (0, 4), (0, 4))])
    poisoned_chain = GraphTickets([t_bad, t_leaf])
    ins = _inputs(3)
    healthy = submit_program(sched, program, ins)
    outs = extract_outputs(sched.drain(), [poisoned_chain, healthy])
    assert outs[0] is None
    np.testing.assert_array_equal(outs[1], program.reference(ins))
    assert isinstance(sched.quarantined[t_leaf].error, DependencyError)


# -- fleet: co-location + learned service times --------------------------


def test_fleet_colocates_graph_and_learns_schedules(program):
    fleet = Fleet([("wide", GGPUConfig(n_cus=8)),
                   ("narrow", GGPUConfig(n_cus=1))])
    ins = _inputs(4)
    out = run_program(fleet, program, ins)
    np.testing.assert_array_equal(out, program.reference(ins))
    # all stages landed on one device
    assert len(set(fleet.placement.values())) == 1
    # learned table keys: (device, content-addressed kernel, schedule)
    assert fleet._learned
    for dev, kk, sched in fleet._learned:
        assert dev in ("wide", "narrow")
        assert isinstance(kk, tuple) and isinstance(sched, str)
    # a dep on a ticket this fleet never issued is rejected
    b = programs._copy(16, 128)
    with pytest.raises(ValueError):
        fleet.submit_request(Request(b.gpu_prog, b.gpu_mem, b.gpu_items,
                                     deps=(Dep(10 ** 6, (0, 4), (0, 4)),)))


def test_fleet_learned_table_updates_routing():
    """Learned service times are keyed per (kernel, schedule) and feed
    ``estimate_us``: after serving, the estimate for that exact kernel
    reflects the measured time, not the generic model."""
    b = programs._copy(16, 128)
    fleet = Fleet([("only", CFG)])
    fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    fleet.drain()
    (key,) = [k for k in fleet._learned]
    assert key[0] == "only" and key[2] == ""     # untuned: empty schedule
    assert fleet._learned[key] > 0


# -- loadgen: bursty arrivals against a Fleet ----------------------------


def test_bursty_arrivals_deterministic_per_seed():
    a = bursty_arrivals(3, 4, 0.002, seed=5)
    b = bursty_arrivals(3, 4, 0.002, seed=5)
    c = bursty_arrivals(3, 4, 0.002, seed=6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (12,) and not np.array_equal(a, c)
    # bursts are simultaneous: 3 distinct start times
    assert len(np.unique(a)) == 3


def test_bursty_replay_against_fleet_populates_latency():
    b = programs._copy(16, 128)
    fleet = Fleet([("wide", GGPUConfig(n_cus=4)), ("narrow", CFG)])
    # warm both devices so the replay measures steady state
    for _ in range(2):
        fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    fleet.drain()
    trace = bursty_arrivals(2, 3, 0.001, seed=9)
    res = replay(fleet, trace,
                 lambda i: Request(b.gpu_prog, b.gpu_mem, b.gpu_items))
    assert res.served == trace.size and res.quarantined == 0
    assert not np.isnan(res.latencies).any()
    assert (res.latencies > 0).all() and res.duration_s > 0
    rep = res.report()
    assert rep["p50_ms"] <= rep["p99_ms"] and rep["rate_per_s"] > 0


def test_bursty_replay_propagates_quarantine():
    """A spinner inside the burst is quarantined by its device scheduler,
    surfaces through ``Fleet.quarantined``, and the replay marks it nan
    without stalling the open loop."""
    cfg = GGPUConfig(max_steps=50)
    b = programs._copy(16, 128)
    fleet = Fleet([("only", cfg)])
    fleet.submit(b.gpu_prog, b.gpu_mem, b.gpu_items)
    fleet.drain()
    trace = bursty_arrivals(2, 2, 0.001, seed=3)
    bad = 2

    def make(i):
        if i == bad:
            return Request(_spinner(), np.zeros(8, np.int32), 8)
        return Request(b.gpu_prog, b.gpu_mem, b.gpu_items)

    res = replay(fleet, trace, make)
    assert res.quarantined == 1 and res.served == trace.size - 1
    assert np.isnan(res.latencies).sum() == 1
    assert len(fleet.quarantined) == 1
