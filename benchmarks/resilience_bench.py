"""Resilience benchmark (``python -m benchmarks.run --resilience``).

Chaos-under-injection gates for the self-healing serving stack: every
leg builds a two-device fleet from a registered ``FAULTS`` scenario
(``repro.faults``) and proves the resilience machinery the scenario
bundles actually absorbs the injected faults. Three legs, recorded in
the standardized ``BENCH_resilience.json`` artifact (schema
``ggpu-resilience/1``, path overridable via ``GGPU_RESILIENCE_OUT``):

  * **seu** — a seeded trace under pre- and post-compute single-event
    upsets, every request carrying an output-checksum audit. The gates:
    the served-correctly fraction must stay >= ``MIN_SERVED_CORRECT``
    (0.999), **zero** corrupted results may be served silently (every
    corruption is retried or quarantined — the audit + ``ChecksumError``
    retry path), and goodput under chaos must stay within
    ``MIN_GOODPUT_RATIO`` of the same trace served fault-free (the
    retry tax is bounded).
  * **device_loss** — one device wedges permanently from its first
    dispatch; the executor timeout surfaces it as ``DeviceTimeout``,
    retries exhaust, the fleet evicts it and re-routes its backlog.
    Gates: the device is evicted, nothing is lost, and every result is
    bit-exact with the fault-free oracle (served entirely by the
    survivor).
  * **straggler** — the same open-loop Poisson trace replayed twice
    under identical straggler injection: once with deadline-aware
    hedging (duplicates fired onto the healthiest idle device after
    ``hedge.after_s``, first result wins, the loser is abandoned in
    flight) and once with hedging off. Gate: the hedged p99 beats the
    unhedged p99 — tail insurance must actually pay.

Every fault decision is a pure hash of ``(seed, kind, ticket,
attempt)`` (``repro.faults.plan``), so the seu/device-loss counts in
the artifact are deterministic at the committed seed and ``check_bench``
compares them exactly; wall-clock metrics (goodput, p99s) get the usual
host ratio bands. ``--fast`` shrinks the traces (the CI
``resilience-smoke`` job, gated by ``check_bench --section
resilience`` against ``benchmarks/baselines/BENCH_resilience.json``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA = "ggpu-resilience/1"
SEED = 0
# fraction of the chaos trace that must be served with bit-correct
# results (quarantines and silent corruption both count against it)
MIN_SERVED_CORRECT = 0.999
# goodput under SEU chaos vs the same trace fault-free: the retry tax
# must stay bounded (generous — host wall-clock on shared CI runners)
MIN_GOODPUT_RATIO = 0.2


def _fresh_mems(b, k, rng):
    """k fresh memory images for bench ``b`` (same envelope, new data)."""
    n = b.gpu_mem.shape[0]
    return [np.concatenate([rng.integers(-100, 100,
                                         2 * b.gpu_n).astype(np.int32),
                            np.zeros(n - 2 * b.gpu_n, np.int32)])
            for _ in range(k)]


def _ref_scheduler():
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler

    return Scheduler(GGPUConfig(n_cus=2), max_batch=8)


def _reference(sched, b, mems):
    """Fault-free oracle results for ``mems``, in submission order (one
    shared scheduler so its compiled envelopes are reused)."""
    tickets = [sched.submit(b.gpu_prog, m, b.gpu_items) for m in mems]
    by = {r.info["ticket"]: r for r in sched.flush()}
    return [by[t] for t in tickets]


def _devices():
    from repro.ggpu.engine import GGPUConfig
    return [("dev0", GGPUConfig(n_cus=1)), ("dev1", GGPUConfig(n_cus=2))]


def _serve_trace(fleet, b, mems, refs, audit):
    """Serve ``mems`` through ``fleet`` (audited when asked), timing the
    drain; returns (wall_s, correctness accounting vs ``refs``)."""
    from repro.serve import Request, result_checksum

    tickets = [fleet.submit_request(Request(
        b.gpu_prog, m, b.gpu_items,
        audit=result_checksum(ref.mem) if audit else None))
        for m, ref in zip(mems, refs)]
    t0 = time.perf_counter()
    out = fleet.drain()
    wall = time.perf_counter() - t0
    by = {r.info["ticket"]: r for r in out}
    correct = sum(
        1 for t, ref in zip(tickets, refs) if t in by
        and np.array_equal(np.asarray(by[t].mem), np.asarray(ref.mem)))
    return {
        "wall_s": wall,
        "served": len(out),
        "served_correct": correct,
        "silently_corrupted": len(out) - correct,
        "quarantined": len(fleet.quarantined),
    }


def bench_seu(emit, fast: bool) -> dict:
    """SEU chaos vs the fault-free control over one identical trace."""
    from repro.registry import FAULTS
    from repro.ggpu import programs
    from repro.serve import Fleet

    b = programs._vec_mul(16, 128)
    n = 24 if fast else 64
    reps, warm_reps = 3, 2         # goodput: best of reps (host noise);
    #                                warm passes retire the one-time jit
    #                                compiles of the injected-path
    #                                envelopes (patched cohorts, retry
    #                                chunk sizes) before timing starts
    rng = np.random.default_rng(11)
    ref_sched = _ref_scheduler()

    def trace():
        m = _fresh_mems(b, n, rng)
        return m, _reference(ref_sched, b, m)

    def run(scenario):
        fleet = Fleet(_devices(), max_batch=8,
                      **scenario.fleet_kwargs())
        for _ in range(warm_reps):
            mems, refs = trace()
            _serve_trace(fleet, b, mems, refs, scenario.audit)
        total = {"served": 0, "served_correct": 0,
                 "silently_corrupted": 0, "goodput_per_s": 0.0}
        for _ in range(reps):
            mems, refs = trace()
            stats = _serve_trace(fleet, b, mems, refs, scenario.audit)
            total["goodput_per_s"] = max(total["goodput_per_s"],
                                         n / stats.pop("wall_s"))
            for key in ("served", "served_correct", "silently_corrupted"):
                total[key] += stats[key]
        total["quarantined"] = len(fleet.quarantined)
        return fleet, total

    # the goodput control runs the SAME resilience machinery (resilient
    # drain, audits, retry policy) under an inactive plan, so the ratio
    # isolates the injection + retry tax rather than the cost of turning
    # the machinery on (the default fast path is gated by the serve
    # bench; injection-off fleets leave it byte-identical)
    from repro.faults import FaultPlan

    control_sc = FAULTS.get("seu")(seed=SEED)
    control_sc.plan = FaultPlan(seed=SEED)
    _, clean = run(control_sc)
    chaos_sc = FAULTS.get("seu")(seed=SEED)
    fleet, chaos = run(chaos_sc)
    offered = reps * n
    row = {
        "kernel": b.name,
        "n": offered,
        "seed": SEED,
        "injections": len(chaos_sc.decision_log()),
        "served": chaos["served"],
        "served_correct": chaos["served_correct"],
        "served_correct_fraction": round(chaos["served_correct"] / offered,
                                         6),
        "silently_corrupted": chaos["silently_corrupted"],
        "quarantined": chaos["quarantined"],
        "clean_goodput_per_s": round(clean["goodput_per_s"], 2),
        "chaos_goodput_per_s": round(chaos["goodput_per_s"], 2),
        "goodput_ratio": round(chaos["goodput_per_s"]
                               / clean["goodput_per_s"], 3),
        "health": fleet.report()["health"],
    }
    emit("resilience/seu", 1e6 / row["chaos_goodput_per_s"],
         f"served_correct={row['served_correct']}/{offered} "
         f"injections={row['injections']} "
         f"goodput_ratio={row['goodput_ratio']} "
         f"quarantined={row['quarantined']}")
    return row


def bench_device_loss(emit, fast: bool) -> dict:
    """Permanent device wedge: timeout -> eviction -> backlog re-route,
    bit-exact completion on the survivor."""
    from repro.registry import FAULTS
    from repro.ggpu import programs
    from repro.serve import Fleet

    b = programs._vec_mul(16, 128)
    n = 8 if fast else 16
    rng = np.random.default_rng(13)
    mems = _fresh_mems(b, n, rng)
    refs = _reference(_ref_scheduler(), b, mems)
    # stuck_after=0: wedged from the very first dispatch (uniform traffic
    # folds into few cohorts, so a later wedge may never fire in a short
    # trace); timeout_s is the detection latency the wall time pays
    sc = FAULTS.get("device-loss")(seed=SEED, stuck_after=0,
                                   timeout_s=0.2)
    fleet = Fleet(_devices(), max_batch=8, **sc.fleet_kwargs())
    t0 = time.perf_counter()
    stats = _serve_trace(fleet, b, mems, refs, sc.audit)
    wall = time.perf_counter() - t0
    rep = fleet.report()
    row = {
        "kernel": b.name,
        "n": n,
        "seed": SEED,
        "timeout_s": 0.2,
        "served": stats["served"],
        "bit_exact": stats["served_correct"] == stats["served"],
        "lost": n - stats["served"] - stats["quarantined"],
        "quarantined": stats["quarantined"],
        "evicted": rep["device_state"]["dev0"] == "evicted",
        "device_state": rep["device_state"],
        "reroutes": rep["reroutes"],
        "faults": rep["faults"],
        "wall_s": round(wall, 4),
    }
    emit("resilience/device_loss", wall * 1e6 / n,
         f"served={row['served']}/{n} evicted={row['evicted']} "
         f"reroutes={row['reroutes']} bit_exact={row['bit_exact']}")
    return row


def bench_straggler(emit, fast: bool) -> dict:
    """Hedged vs unhedged p99 over one open-loop trace under identical
    straggler injection (module doc)."""
    from repro.registry import FAULTS
    from repro.ggpu import programs
    from repro.serve import (Fleet, FleetResilience, Request,
                             poisson_arrivals, replay)

    b = programs._vec_mul(16, 128)
    n = 24 if fast else 48
    delay_s = 0.25
    rng = np.random.default_rng(17)
    mems = _fresh_mems(b, 16, rng)
    arrivals = poisson_arrivals(60.0, n, seed=5)

    def run(scenario):
        fleet = Fleet(_devices(), max_batch=8,
                      **scenario.fleet_kwargs())
        # warm every cohort envelope open-loop traffic can produce so
        # the replay never pays a jit compile (powers of two down to 1)
        k = 8
        while k >= 1:
            for m in _fresh_mems(b, k, rng):
                fleet.submit_request(Request(b.gpu_prog, m, b.gpu_items))
            fleet.drain()
            k //= 2
        res = replay(fleet, arrivals,
                     lambda i: Request(b.gpu_prog, mems[i % len(mems)],
                                       b.gpu_items))
        return fleet, res

    hedged_sc = FAULTS.get("straggler")(seed=SEED, delay_s=delay_s)
    unhedged_sc = FAULTS.get("straggler")(seed=SEED, delay_s=delay_s)
    unhedged_sc.resilience = FleetResilience()   # same machinery, no hedge
    hedged_fleet, hedged = run(hedged_sc)
    _, unhedged = run(unhedged_sc)
    row = {
        "kernel": b.name,
        "n": n,
        "seed": SEED,
        "arrivals": "poisson",
        "straggler_delay_s": delay_s,
        "hedges_fired": hedged_fleet.report()["hedged"],
        "hedged": hedged.report(),
        "unhedged": unhedged.report(),
        "hedge_p99_speedup": round(unhedged.p99_ms / hedged.p99_ms, 3)
        if hedged.p99_ms else 0.0,
    }
    emit("resilience/straggler/hedged", hedged.p99_ms * 1e3,
         f"p99={hedged.p99_ms:.1f}ms hedges={row['hedges_fired']} "
         f"served={hedged.served}/{n}")
    emit("resilience/straggler/unhedged", unhedged.p99_ms * 1e3,
         f"p99={unhedged.p99_ms:.1f}ms "
         f"hedge_p99_speedup={row['hedge_p99_speedup']}x")
    return row


def invariant_problems(art: dict) -> list:
    """Absolute health invariants of a resilience run — checked by
    ``benchmarks.run`` after the artifact is written and re-enforced on
    the fresh artifact by ``check_bench``."""
    problems = []
    s = art.get("seu", {})
    frac = s.get("served_correct_fraction", 0)
    if frac < MIN_SERVED_CORRECT:
        problems.append(
            f"seu.served_correct_fraction {frac} < {MIN_SERVED_CORRECT}: "
            "the audit+retry machinery is not absorbing SEU chaos")
    if s.get("silently_corrupted", 1):
        problems.append(
            f"seu.silently_corrupted {s.get('silently_corrupted')}: "
            "corrupted results were served without being caught — the "
            "checksum audit path is broken")
    ratio = s.get("goodput_ratio", 0)
    if ratio < MIN_GOODPUT_RATIO:
        problems.append(
            f"seu.goodput_ratio {ratio} < {MIN_GOODPUT_RATIO}: the retry "
            "tax under chaos is unbounded")
    d = art.get("device_loss", {})
    if not d.get("evicted"):
        problems.append(
            "device_loss.evicted: the wedged device was never evicted — "
            "timeout/eviction machinery is not firing")
    if d.get("lost", 1):
        problems.append(
            f"device_loss.lost {d.get('lost')}: requests vanished during "
            "eviction instead of being re-routed or quarantined")
    if not d.get("bit_exact"):
        problems.append(
            "device_loss.bit_exact: results served around an eviction "
            "diverge from the fault-free oracle")
    st = art.get("straggler", {})
    hp = st.get("hedged", {}).get("p99_ms", float("inf"))
    up = st.get("unhedged", {}).get("p99_ms", 0)
    if not hp < up:
        problems.append(
            f"straggler: hedged p99 {hp}ms is not below unhedged p99 "
            f"{up}ms — hedging is not insuring the tail")
    if not st.get("hedges_fired"):
        problems.append("straggler.hedges_fired is 0: hedging never "
                        "engaged under straggler injection")
    for leg in ("hedged", "unhedged"):
        served = st.get(leg, {}).get("served", 0)
        if served != st.get("n", -1):
            problems.append(
                f"straggler.{leg}: served {served} != offered "
                f"{st.get('n')} — the chaos replay lost requests")
    return problems


def bench_resilience(emit, fast: bool = False, out: str = None) -> dict:
    """Run all three legs and write ``BENCH_resilience.json``; returns
    the artifact dict."""
    import jax

    out = out or os.environ.get("GGPU_RESILIENCE_OUT",
                                "BENCH_resilience.json")
    seu = bench_seu(emit, fast)
    device_loss = bench_device_loss(emit, fast)
    straggler = bench_straggler(emit, fast)
    art = {
        "schema": SCHEMA,
        "n_devices": jax.device_count(),
        "seed": SEED,
        "served_correct_fraction": seu["served_correct_fraction"],
        "silently_corrupted": seu["silently_corrupted"]
        + (0 if device_loss["bit_exact"] else 1),
        "goodput_ratio": seu["goodput_ratio"],
        "hedge_p99_speedup": straggler["hedge_p99_speedup"],
        "seu": seu,
        "device_loss": device_loss,
        "straggler": straggler,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("resilience/artifact", 0.0, f"wrote {out}")
    return art


def run_resilience_section(emit, fast: bool = False) -> list:
    """Registry section runner (``repro.registry`` SECTIONS
    ``resilience``): run the chaos legs, return invariant violations."""
    return invariant_problems(bench_resilience(emit, fast=fast))
