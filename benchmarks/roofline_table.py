"""§Roofline table: read the dry-run JSON records and emit the per-cell
three-term roofline (the EXPERIMENTS.md source of truth)."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path("experiments/dryrun")


def load_records(mesh="16x16"):
    recs = []
    if not DRYRUN_DIR.exists():
        return recs
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def roofline_table(emit, mesh="16x16"):
    recs = load_records(mesh)
    if not recs:
        emit("roofline/missing", 0.0,
             "run `python -m repro.launch.dryrun --all --both-meshes` first")
        return
    for r in recs:
        name = f"roofline/{r['arch']}/{r['shape']}"
        if not r.get("supported", True):
            emit(name, 0.0, f"skipped: {r['reason']}")
            continue
        step_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e3
        emit(name, step_ms * 1e3,
             f"compute_ms={r['compute_s']*1e3:.2f} "
             f"memory_ms={r['memory_s']*1e3:.2f} "
             f"collective_ms={r['collective_s']*1e3:.2f} "
             f"bound={r['bound']} useful_ratio={r['useful_ratio']:.2f} "
             f"mem_dev_GiB={r['total_dev_bytes']/2**30:.2f} "
             f"fits={r['fits_hbm']}")


def summary(emit, mesh="16x16"):
    recs = [r for r in load_records(mesh) if r.get("supported", True)]
    if not recs:
        return
    bounds = {}
    fits = 0
    for r in recs:
        bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
        fits += bool(r["fits_hbm"])
    emit("roofline/summary", 0.0,
         f"cells={len(recs)} fits_hbm={fits} bound_histogram={bounds}")
