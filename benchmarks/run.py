"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Usage:
  PYTHONPATH=src python -m benchmarks.run [--fast] [--engine] [--dse] \
      [--serve] [--compiler]

Section flags are dispatched through the scenario registry
(``repro.registry`` SECTIONS axis): each registered ``BenchSection``
carries its CLI flag and a ``module:function`` runner spec, and the CI
smoke/nightly matrices are generated from the same axis by
``python -m repro.registry --ci-matrix {smoke,nightly}``.
``--fast`` skips the O(n^2) cycle simulations (xcorr/parallel_sel) and
shrinks the engine/DSE grids.
``--engine`` runs only the simulator-engine micro-benchmarks (fused
dispatch, batched launch queue, memory-system DSE sweep, unified DSE
search) and writes the ``BENCH_dse.json`` artifact.
``--dse`` runs only the unified DSE Pareto sweep + artifact
(``--dse --fast`` is the 2-point CI smoke).
``--serve`` runs the serving-subsystem benchmark — throughput,
mesh-sharded scheduler vs single-device, open-loop Poisson tail latency,
fleet routing, and device-resident kernel graphs — and writes the
``BENCH_serve.json`` artifact (schema ``ggpu-serve/4``; ``--serve
--fast`` is the CI ``serve-smoke`` job, and the ``fleet-smoke`` job runs
it again under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise real 8-way sharding).
``--graph`` runs only the kernel-graph section (device-resident
pipelined vs host-staged chain execution, the CI ``graph-smoke`` job)
and writes the partial ``BENCH_graph.json`` artifact that ``check_bench
--section graph`` gates against the full serve baseline.
``--compiler`` runs the tensor-DSL compiler sweep (suite parity vs the
hand-written benches + a compiled-workload DSE search) and writes
``BENCH_compiler.json`` (the nightly ``compiler-sweep`` job).

Smoke invariants (fleet routing must beat both pins, the executor cache
must be hitting, sharded results must be bit-exact — and >= 1.5x faster
at >= 8 simulated devices — DSE frontiers must be non-empty, compiled
kernels must be bit-exact) are re-checked after each artifact-producing
mode; any
violation exits non-zero so CI fails instead of uploading a broken
artifact.
"""
from __future__ import annotations

import sys
from typing import List


def _fail(problems: List[str]) -> None:
    if problems:
        for p in problems:
            print(f"INVARIANT FAILED: {p}", file=sys.stderr)
        sys.exit(1)


def main() -> None:
    fast = "--fast" in sys.argv

    def emit(name, us, derived=""):
        print(f"{name},{us:.3f},{derived}")

    print("name,us_per_call,derived")
    # flag-bearing sections dispatch through the scenario registry: each
    # BenchSection names its runner as a "module:function" spec, so a
    # section added in one file is reachable here with no edit
    from repro.registry import SECTIONS
    from repro.registry.core import resolve
    for name in SECTIONS.names():
        sec = SECTIONS.get(name)
        if sec.flag and sec.flag in sys.argv:
            _fail(resolve(sec.runner)(emit, fast=fast))
            return
    from benchmarks import ggpu_tables, roofline_table
    ggpu_tables.table1_ppa(emit)
    ggpu_tables.table2_wires(emit)
    if not fast:
        ggpu_tables.simulate_all(verbose=False)
    if fast:
        # shrink the quadratic kernels for a quick pass
        from repro.ggpu import programs
        b = programs.all_benches()
        small = programs._xcorr(64, 512)
        b["xcorr"] = small
        ggpu_tables._cycle_cache.clear()
    ggpu_tables.table3_cycles(emit)
    ggpu_tables.fig5_speedup(emit)
    ggpu_tables.fig6_area_derated(emit)
    # the memsys sweep simulates the quadratic xcorr: shrink it under --fast
    ggpu_tables.table_memsys(emit, sizes=(32, 256) if fast else (64, 1024))
    import benchmarks.roofline_table as rt
    rt.DRYRUN_DIR = __import__("pathlib").Path("experiments/dryrun")
    emit("roofline/baseline", 0.0, "paper-faithful baseline sweep")
    roofline_table.roofline_table(emit)
    roofline_table.summary(emit)
    rt.DRYRUN_DIR = __import__("pathlib").Path("experiments/dryrun_opt")
    emit("roofline/optimized", 0.0,
         "optimized sweep (EXPERIMENTS.md §Perf)")
    roofline_table.roofline_table(emit)
    roofline_table.summary(emit)


if __name__ == "__main__":
    main()
