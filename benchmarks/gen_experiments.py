"""Generate the EXPERIMENTS.md roofline/dry-run tables from sweep JSONs.

    PYTHONPATH=src python -m benchmarks.gen_experiments
prints markdown tables for the baseline (experiments/dryrun) and optimized
(experiments/dryrun_opt) sweeps.
"""
from __future__ import annotations

import json
from pathlib import Path

ARCH_ORDER = ["llama4-scout-17b-a16e", "mixtral-8x7b", "xlstm-350m",
              "qwen1.5-4b", "granite-8b", "qwen1.5-0.5b", "smollm-360m",
              "recurrentgemma-2b", "hubert-xlarge", "qwen2-vl-72b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname, mesh):
    out = {}
    d = Path(dirname)
    if not d.exists():
        return out
    for f in d.glob("*.json"):
        r = json.loads(f.read_text())
        if r.get("mesh") == mesh:
            out[(r["arch"], r["shape"])] = r
    return out


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.1f}s"
    return f"{x*1e3:.1f}ms"


def table(dirname, mesh="16x16", title=""):
    recs = load(dirname, mesh)
    lines = [f"\n#### {title} ({mesh} mesh)\n",
             "| arch | shape | compute | memory | collective | bound | "
             "useful | GiB/dev | fits |",
             "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s))
            if r is None:
                continue
            if not r.get("supported", True):
                lines.append(f"| {a} | {s} | — | — | — | skipped | — | — | "
                             f"{r['reason'][:46]} |")
                continue
            lines.append(
                f"| {a} | {s} | {fmt_s(r['compute_s'])} | "
                f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                f"{r['bound']} | {r['useful_ratio']:.2f} | "
                f"{r['total_dev_bytes']/2**30:.1f} | "
                f"{'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def multi_pod_deltas(dirname):
    base = load(dirname, "16x16")
    multi = load(dirname, "2x16x16")
    lines = ["\n#### multi-pod (2x16x16) vs single-pod: collective term\n",
             "| arch | shape | coll 1-pod | coll 2-pod | ratio |",
             "|---|---|---|---|---|"]
    for key in sorted(base):
        b, m = base[key], multi.get(key)
        if m is None or not b.get("supported", True):
            continue
        r = m["collective_s"] / max(b["collective_s"], 1e-12)
        lines.append(f"| {key[0]} | {key[1]} | {fmt_s(b['collective_s'])} | "
                     f"{fmt_s(m['collective_s'])} | {r:.2f}x |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table("experiments/dryrun", title="Paper-faithful baseline"))
    print(table("experiments/dryrun_opt",
                title="Optimized (grouped MoE dispatch + attention "
                      "checkpointing + GQA head sharding)"))
    print(multi_pod_deltas("experiments/dryrun_opt"))
