"""Simulator engine micro-benchmark (``python -m benchmarks.run --engine``).

Measures the execution-engine refactor itself, not the simulated machine:

  * fused dispatch   — wall-clock of the inner-loop-heavy xcorr bench under
    the seed-faithful legacy reference stepper (``legacy=True``: one round
    per iteration, memory pipeline every round, one-hot scatter cache
    accounting) vs fused dispatch (``fuse=8``); cycles are bit-identical,
    only simulator speed changes.
  * batched launches — N same-kernel launches sequentially vs one
    ``LaunchQueue`` flush (cohort-folded into a single stepper call).
  * async launches   — N single launches serially (each ``run_kernel``
    blocks on its own download) vs N ``run_kernel_async`` dispatches
    resolved after the last one is in flight; the cold trace (first
    call, pays the jit compile) is reported separately from both
    steady-state rates.
  * memsys sweep     — the cache-organization DSE on the bench the paper
    flags as cache-thrashing (xcorr at 8 CUs).
  * dse sweep        — the unified analytic+cycle-accurate Pareto search
    (``repro.dse``); writes the standardized ``BENCH_dse.json`` artifact.

Warm timings exclude compilation (each variant runs once to compile).
"""
from __future__ import annotations

import time

import numpy as np


def _time(fn, reps: int = 1):
    """Warm (compile) then time; returns (seconds_per_rep, last_result)."""
    fn()                                    # compile + warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps, out


def bench_fused_dispatch(emit, n_gpu: int = 1024, n_cus: int = 2) -> float:
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig, run_kernel

    b = programs._xcorr(64, n_gpu)
    # the legacy point runs the seed-faithful reference stepper: one round
    # per iteration, memory pipeline engaged every round, one-hot scatter
    # cache accounting, dense writeback, full unpruned datapath
    variants = [
        ("legacy", GGPUConfig(n_cus=n_cus, fuse=1), True),
        ("fused_fuse8", GGPUConfig(n_cus=n_cus, fuse=8), False),
    ]
    times, cycles = {}, {}
    for name, cfg, legacy in variants:
        times[name], (_, info) = _time(
            lambda cfg=cfg, legacy=legacy: run_kernel(
                b.gpu_prog, b.gpu_mem, b.gpu_items, cfg, legacy=legacy))
        cycles[name] = info["cycles"]
        emit(f"engine/xcorr{n_gpu}/{name}", times[name] * 1e6,
             f"cycles={info['cycles']} steps={info['steps']}")
    speedup = times["legacy"] / times["fused_fuse8"]
    assert cycles["legacy"] == cycles["fused_fuse8"], \
        "fused dispatch changed the cycle count"
    emit(f"engine/xcorr{n_gpu}/fused_speedup", 0.0,
         f"speedup={speedup:.2f}x (target >=2x) bit_exact_cycles=True")
    return speedup


def bench_batched_launch(emit, n_launches: int = 8, n: int = 512) -> float:
    from repro.ggpu import programs
    from repro.ggpu.engine import ScalarConfig, run_kernel
    from repro.serve import LaunchQueue

    # same-kernel launch burst over distinct memory images: the RISC-V
    # baseline div_int program (tiny 1-lane machine, thousands of rounds —
    # the case where folding launches into one stepper amortizes the most;
    # this is exactly the serial workload the Table III harness runs)
    cfg = ScalarConfig()
    b = programs._div_int(n, 2 * n)
    rng = np.random.default_rng(7)
    mems = [np.concatenate([rng.integers(-1000, 1000, n).astype(np.int32),
                            rng.integers(1, 50, n).astype(np.int32),
                            np.zeros(n, np.int32)])
            for _ in range(n_launches)]

    def sequential():
        return [run_kernel(b.scalar_prog, m, 1, cfg) for m in mems]

    def batched():
        q = LaunchQueue(cfg)
        for m in mems:
            q.submit(b.scalar_prog, m, 1)
        return q.flush()

    t_seq, seq_out = _time(sequential)
    t_bat, bat_out = _time(batched)
    exact = all(np.array_equal(ms, mb) and is_["cycles"] == ib["cycles"]
                for (ms, is_), (mb, ib) in zip(seq_out, bat_out))
    emit(f"engine/batch{n_launches}x_div_int{n}/sequential", t_seq * 1e6, "")
    emit(f"engine/batch{n_launches}x_div_int{n}/launch_queue", t_bat * 1e6,
         f"speedup={t_seq / t_bat:.2f}x bit_exact={exact}")
    return t_seq / t_bat


def bench_async_launch(emit, n_launches: int = 16, n: int = 512) -> float:
    """Sync-vs-async single-launch streams at the engine level: the async
    path dispatches every launch before resolving any, so staging and
    download of launch k+1 overlap launch k's device compute. Results are
    asserted bit-exact; returns the steady-state speedup."""
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig, run_kernel, run_kernel_async

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(32, n)
    rng = np.random.default_rng(3)
    nm = b.gpu_mem.shape[0]
    mems = [np.concatenate([rng.integers(-100, 100,
                                         2 * b.gpu_n).astype(np.int32),
                            np.zeros(nm - 2 * b.gpu_n, np.int32)])
            for _ in range(n_launches)]

    t0 = time.perf_counter()
    run_kernel(b.gpu_prog, mems[0], b.gpu_items, cfg)   # cold: jit compile
    cold_s = time.perf_counter() - t0
    emit(f"engine/async{n_launches}x_vec_mul{n}/cold_trace", cold_s * 1e6,
         "first launch incl. jit compile")

    def sync():
        return [run_kernel(b.gpu_prog, m, b.gpu_items, cfg) for m in mems]

    def asy():
        handles = [run_kernel_async(b.gpu_prog, m, b.gpu_items, cfg)
                   for m in mems]
        return [h.result() for h in handles]

    t_sync, sync_out = _time(sync, reps=3)
    t_async, async_out = _time(asy, reps=3)
    exact = all(np.array_equal(ms, ma) and is_["cycles"] == ia["cycles"]
                for (ms, is_), (ma, ia) in zip(sync_out, async_out))
    assert exact, "async launch path diverged from sync results"
    emit(f"engine/async{n_launches}x_vec_mul{n}/sync", t_sync * 1e6,
         f"launches_per_sec={n_launches / t_sync:.0f}")
    emit(f"engine/async{n_launches}x_vec_mul{n}/async", t_async * 1e6,
         f"launches_per_sec={n_launches / t_async:.0f} "
         f"speedup={t_sync / t_async:.2f}x bit_exact={exact}")
    return t_sync / t_async


def bench_memsys_sweep(emit, sizes=(64, 1024)) -> None:
    from repro.dse import sweep_memsys

    sweep = sweep_memsys(bench="xcorr", n_cus=(1, 8), sizes=sizes)
    for (c, ms), info in sweep.items():
        emit(f"engine/memsys/{ms}/{c}cu", info["time_us"],
             f"cycles={info['cycles']} hits={info['hits']} "
             f"misses={info['misses']}")


def bench_dse(emit, fast: bool = False, out: str = None):
    """The unified DSE sweep: plan + cycle-evaluate a design grid, emit the
    Pareto frontier, and write the standardized ``BENCH_dse.json`` artifact
    (path overridable via ``GGPU_DSE_OUT``). ``fast`` runs the 2-point
    smoke grid CI uses. Returns (artifact dict, problems list) so
    ``benchmarks.run`` can fail the build on a broken sweep."""
    import os

    from repro import dse

    out = out or os.environ.get("GGPU_DSE_OUT", "BENCH_dse.json")
    if fast:
        specs = dse.enumerate_specs(cus=(1,),
                                    freq_targets=(500.0, 667.0))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (16, 128)})
    else:
        specs = dse.enumerate_specs(
            cus=(1, 2, 4, 8), freq_targets=(500.0, 590.0, 667.0, 750.0),
            memsys=("shared", "banked", "banked-iso"))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (64, 1024)})
    res = dse.search(specs=specs, evaluator=ev)
    for row in res.report():
        emit(f"dse/point/{row['label']}", row["time_us"],
             f"area={row['area_mm2']:.2f} "
             f"analytic_us={row['analytic_time_us']:.1f} "
             f"frontier={row['on_frontier']}")
    emit("dse/frontier", 0.0,
         " ".join(p.label() for p in res.frontier))
    emit("dse/excluded_analytic", 0.0,
         " ".join(p.label() for p in res.excluded_analytic) or "-")
    problems = []
    if not res.frontier:
        problems.append("DSE Pareto frontier is empty")
    reference = min(res.frontier or res.points, key=lambda p: p.time_us)
    path = dse.write_artifact(out, reference, res)
    emit("dse/artifact", 0.0, f"wrote {path} reference={reference.label()}")
    return dse.dse_artifact(reference, res), problems


def main(emit, fast: bool = False) -> None:
    if fast:
        bench_fused_dispatch(emit, n_gpu=256)
        bench_batched_launch(emit, n_launches=4, n=128)
        bench_async_launch(emit, n_launches=8)
        bench_memsys_sweep(emit, sizes=(32, 256))
    else:
        bench_fused_dispatch(emit)
        bench_batched_launch(emit)
        bench_async_launch(emit)
        bench_memsys_sweep(emit)
    bench_dse(emit, fast=fast)


def run_dse_section(emit, fast: bool = False) -> list:
    """Registry section runner (``repro.registry`` SECTIONS ``dse``)."""
    _art, problems = bench_dse(emit, fast=fast)
    return problems


def run_engine_section(emit, fast: bool = False) -> list:
    """Registry section runner (``engine``): micro-benches, no gate."""
    main(emit, fast=fast)
    return []
