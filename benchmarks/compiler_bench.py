"""Compiler sweep (``python -m benchmarks.run --compiler``).

Exercises the tensor-expression DSL end to end:

  * **suite parity** — compiles the eight bench kernels, runs each against
    its hand-written twin on one engine config, and reports the cycle
    ratio (cycle-identical for everything except the branch-free
    ``parallel_sel``, see ``repro.compiler.suite``); every compiled
    result is differentially checked against both the hand-written NumPy
    reference and the compiler's own oracle.
  * **generated-workload DSE** — a ``repro.dse.search`` Pareto sweep whose
    evaluator runs *compiled* workloads (a suite sample plus a
    user-style kernel that exists in no hand-written form), writing the
    standard ``ggpu-dse/1`` artifact to ``BENCH_compiler.json`` (path
    overridable via ``GGPU_COMPILER_OUT``).

``--fast`` shrinks sizes and the spec grid; the nightly ``compiler-sweep``
workflow runs the full version and uploads the artifact.

Returns (artifact dict, problems list) — ``benchmarks.run`` exits
non-zero when any invariant fails.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import numpy as np

#: reduced bench sizes for the fast (CI-smoke-adjacent) variant
FAST_SIZES = {
    "copy": (64, 512), "vec_mul": (64, 512), "div_int": (64, 512),
    "reduction": (64, 512, 8), "fir": (64, 512), "mat_mul": (8, 16),
    "xcorr": (32, 128), "parallel_sel": (32, 128),
}
#: full-sweep sizes: paper Table III except the O(n^2) kernels, which are
#: trimmed to keep the nightly run under the job timeout
FULL_SIZES = {
    "xcorr": (64, 1024), "parallel_sel": (64, 1024),
}


def _user_kernel(n: int, seg: int):
    """A segmented-reduction workload no hand-written bench covers."""
    from repro.compiler import compile_kernel
    return compile_kernel(lambda a, b: ((a - b) * a).seg_sum(seg),
                          dict(a=n, b=n), name="user_segred")


def bench_suite_parity(emit, fast: bool):
    """Compile the eight benches, verify bit-exactness vs the hand-written
    programs, and report cycles + compile times. Returns (rows, problems,
    compiled) so the DSE section reuses the compiled suite."""
    from repro.compiler import dsl_benches, hand_benches
    from repro.ggpu.engine import GGPUConfig, run_kernel

    sizes = dict(FAST_SIZES) if fast else dict(FULL_SIZES)
    cfg = GGPUConfig(n_cus=2)
    hands = hand_benches(sizes)
    t0 = time.perf_counter()
    compiled = dsl_benches(sizes, hands=hands)
    compile_s = time.perf_counter() - t0
    emit("compiler/suite/compile", compile_s * 1e6,
         f"kernels={len(compiled)}")
    rows: Dict[str, dict] = {}
    problems: List[str] = []
    for name in sorted(compiled):
        base = name[len("dsl_"):]
        hand = hands[base]
        d = compiled[name]
        mh, ih = run_kernel(hand.gpu_prog, hand.gpu_mem, hand.gpu_items,
                            cfg)
        md, idd = run_kernel(d.gpu_prog, d.gpu_mem, d.gpu_items, cfg)
        exact = bool(np.array_equal(mh[hand.gpu_out], md[d.gpu_out]))
        ref_ok = bool(np.array_equal(
            md[d.gpu_out], hand.ref(hand.gpu_mem, hand.gpu_n)))
        if not (exact and ref_ok):
            problems.append(f"compiled {base} is not bit-exact")
        ratio = idd["cycles"] / ih["cycles"]
        rows[base] = {
            "cycles_hand": ih["cycles"], "cycles_dsl": idd["cycles"],
            "cycle_ratio": round(ratio, 3), "bit_exact": exact and ref_ok,
            "prog_len": int(d.gpu_prog.shape[0]),
        }
        emit(f"compiler/suite/{base}", 0.0,
             f"cycles={idd['cycles']} hand={ih['cycles']} "
             f"ratio={ratio:.3f} bit_exact={exact and ref_ok}")
    return rows, problems, compiled


def bench_compiled_dse(emit, fast: bool,
                       compiled: Dict[str, object]) -> Tuple[dict,
                                                             List[str]]:
    """Pareto sweep over compiled workloads (``compiled`` is the suite
    ``bench_suite_parity`` already built); returns (artifact, problems).
    """
    from repro import dse

    problems: List[str] = []
    if fast:
        specs = dse.enumerate_specs(cus=(1, 2),
                                    freq_targets=(500.0, 667.0))
        user = _user_kernel(512, 32)
        sample = ("vec_mul", "reduction")
    else:
        specs = dse.enumerate_specs(
            cus=(1, 2, 4, 8), freq_targets=(500.0, 590.0, 667.0, 750.0),
            memsys=("shared", "banked", "banked-iso"))
        user = _user_kernel(8192, 64)
        sample = ("vec_mul", "reduction", "xcorr")
    workloads = {n: b for n, b in compiled.items()
                 if n[len("dsl_"):] in sample}
    workloads["dsl_user_segred"] = user.as_bench(seed=11)
    ev = dse.Evaluator(benches=(), workloads=workloads, check=True)
    res = dse.search(specs=specs, evaluator=ev)
    for row in res.report():
        emit(f"compiler/dse/{row['label']}", row["time_us"],
             f"area={row['area_mm2']:.2f} frontier={row['on_frontier']}")
    if not res.frontier:
        problems.append("compiled-workload DSE frontier is empty")
    reference = min(res.frontier, key=lambda p: p.time_us) \
        if res.frontier else res.points[0]
    art = dse.dse_artifact(reference, res)
    art["workloads"] = sorted(workloads)
    return art, problems


def bench_compiler(emit, fast: bool = False,
                   out: str = None) -> Tuple[dict, List[str]]:
    """Run both sections and write the ``BENCH_compiler.json`` artifact."""
    import json

    out = out or os.environ.get("GGPU_COMPILER_OUT", "BENCH_compiler.json")
    rows, problems, compiled = bench_suite_parity(emit, fast)
    art, p2 = bench_compiled_dse(emit, fast, compiled)
    problems += p2
    art["suite_parity"] = rows
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("compiler/artifact", 0.0, f"wrote {out}")
    return art, problems
