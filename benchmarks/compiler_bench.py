"""Compiler sweep (``python -m benchmarks.run --compiler``).

Exercises the tensor-expression DSL end to end and writes the
``ggpu-compiler/2`` artifact:

  * **suite parity** — compiles the eight bench kernels, runs each against
    its hand-written twin on one engine config, and reports the cycle
    ratio (cycle-identical for everything except the branch-free
    ``parallel_sel``, see ``repro.compiler.suite``); every compiled
    result is differentially checked against both the hand-written NumPy
    reference and the compiler's own oracle.
  * **autotune** — ``repro.compiler.autotune`` schedule search per bench
    (fast: 2 benches x ``SMOKE_SPACE``; full: all 8 x ``DEFAULT_SPACE``),
    reporting tuned-vs-default and tuned-vs-hand cycle ratios. Absolute
    invariants (also re-enforced by ``check_bench`` on the fresh
    artifact): the tuned schedule is **never worse than the default on
    any bench and strictly better on at least one**, and every candidate
    was bit-exact vs the IR oracle.
  * **codesign** — ``(DesignPoint, Schedule)`` pairs ranked on one joint
    Pareto frontier (``autotune.codesign``); the frontier must be
    non-empty.
  * **generated-workload DSE** — a ``repro.dse.search`` Pareto sweep whose
    evaluator runs *compiled* workloads (a suite sample plus a
    user-style kernel that exists in no hand-written form), nested under
    ``"dse"`` as a standard ``ggpu-dse/1`` artifact.

``--fast`` shrinks sizes, the spec grid, and the schedule space; the
PR-blocking ``compiler-smoke`` job runs it and gates the artifact against
``benchmarks/baselines/BENCH_compiler.json``, while the nightly
``compiler-sweep`` workflow runs the full version. Both upload the
artifact (path overridable via ``GGPU_COMPILER_OUT``).

Returns (artifact dict, problems list) — ``benchmarks.run`` exits
non-zero when any invariant fails.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Tuple

import numpy as np

SCHEMA = "ggpu-compiler/2"

#: reduced bench sizes for the fast (CI-smoke-adjacent) variant
FAST_SIZES = {
    "copy": (64, 512), "vec_mul": (64, 512), "div_int": (64, 512),
    "reduction": (64, 512, 8), "fir": (64, 512), "mat_mul": (8, 16),
    "xcorr": (32, 128), "parallel_sel": (32, 128),
}
#: full-sweep sizes: paper Table III except the O(n^2) kernels, which are
#: trimmed to keep the nightly run under the job timeout
FULL_SIZES = {
    "xcorr": (64, 1024), "parallel_sel": (64, 1024),
}

#: benches in the fast autotune/codesign sample: elementwise kernels where
#: coarsening provably moves cycles, so the strictly-better invariant has
#: a real witness in the smoke space
FAST_TUNE_BENCHES = ("copy", "vec_mul")
ALL_BENCHES = ("copy", "vec_mul", "div_int", "reduction", "fir",
               "mat_mul", "xcorr", "parallel_sel")


def _user_kernel(n: int, seg: int):
    """A segmented-reduction workload no hand-written bench covers."""
    from repro.compiler import compile_kernel
    return compile_kernel(lambda a, b: ((a - b) * a).seg_sum(seg),
                          dict(a=n, b=n), name="user_segred")


def bench_suite_parity(emit, fast: bool):
    """Compile the eight benches, verify bit-exactness vs the hand-written
    programs, and report cycles + compile times. Returns (rows, problems,
    compiled) so the DSE section reuses the compiled suite."""
    from repro.compiler import dsl_benches, hand_benches
    from repro.ggpu.engine import GGPUConfig, run_kernel

    sizes = dict(FAST_SIZES) if fast else dict(FULL_SIZES)
    cfg = GGPUConfig(n_cus=2)
    hands = hand_benches(sizes)
    t0 = time.perf_counter()
    compiled = dsl_benches(sizes, hands=hands)
    compile_s = time.perf_counter() - t0
    emit("compiler/suite/compile", compile_s * 1e6,
         f"kernels={len(compiled)}")
    rows: Dict[str, dict] = {}
    problems: List[str] = []
    for name in sorted(compiled):
        base = name[len("dsl_"):]
        hand = hands[base]
        d = compiled[name]
        mh, ih = run_kernel(hand.gpu_prog, hand.gpu_mem, hand.gpu_items,
                            cfg)
        md, idd = run_kernel(d.gpu_prog, d.gpu_mem, d.gpu_items, cfg)
        exact = bool(np.array_equal(mh[hand.gpu_out], md[d.gpu_out]))
        ref_ok = bool(np.array_equal(
            md[d.gpu_out], hand.ref(hand.gpu_mem, hand.gpu_n)))
        if not (exact and ref_ok):
            problems.append(f"compiled {base} is not bit-exact")
        ratio = idd["cycles"] / ih["cycles"]
        rows[base] = {
            "cycles_hand": ih["cycles"], "cycles_dsl": idd["cycles"],
            "cycle_ratio": round(ratio, 3), "bit_exact": exact and ref_ok,
            "prog_len": int(d.gpu_prog.shape[0]),
        }
        emit(f"compiler/suite/{base}", 0.0,
             f"cycles={idd['cycles']} hand={ih['cycles']} "
             f"ratio={ratio:.3f} bit_exact={exact and ref_ok}")
    return rows, problems, compiled


def autotune_invariants(section: dict) -> List[str]:
    """Absolute autotune health invariants, shared between the benchmark
    harness's own exit-code check and ``check_bench`` on a fresh
    artifact: tuned never worse than default on ANY bench, strictly
    better on >= 1, every candidate verified bit-exact."""
    problems: List[str] = []
    benches = section.get("benches", {})
    if not benches:
        return ["autotune section has no benches"]
    strict = 0
    for name, row in sorted(benches.items()):
        tuned, default = row.get("tuned_cycles"), row.get("default_cycles")
        if tuned is None or default is None:
            problems.append(f"autotune {name}: missing cycle fields")
            continue
        if tuned > default:
            problems.append(
                f"autotune {name}: tuned {tuned} > default {default}")
        elif tuned < default:
            strict += 1
        if not row.get("verified", False):
            problems.append(f"autotune {name}: candidates not verified")
    if strict == 0:
        problems.append(
            "autotune: no bench strictly faster than the default schedule")
    return problems


def bench_autotune(emit, fast: bool) -> Tuple[dict, List[str]]:
    """Schedule search per bench through ``repro.compiler.autotune``;
    returns (section, problems)."""
    from repro.compiler.autotune import (DEFAULT_SPACE, SMOKE_SPACE,
                                         autotune_suite)
    from repro.ggpu.engine import GGPUConfig

    cfg = GGPUConfig(n_cus=2)
    if fast:
        names, space = FAST_TUNE_BENCHES, SMOKE_SPACE
        sizes = dict(FAST_SIZES)
    else:
        names, space = ALL_BENCHES, DEFAULT_SPACE
        sizes = dict(FULL_SIZES)
    t0 = time.perf_counter()
    results = autotune_suite(names, cfg, sizes=sizes, space=space)
    wall = time.perf_counter() - t0
    benches = {}
    from repro.compiler.suite import hand_benches
    from repro.ggpu.engine import run_kernel
    hands = hand_benches(sizes)
    for name, r in results.items():
        hand = hands[name]
        _, ih = run_kernel(hand.gpu_prog, hand.gpu_mem, hand.gpu_items,
                           cfg)
        row = r.report()
        row["verified"] = all(c.verified for c in r.candidates)
        row["cycles_hand"] = int(ih["cycles"])
        row["tuned_vs_hand"] = round(r.best_cycles / ih["cycles"], 4)
        del row["name"]
        benches[name] = row
        emit(f"compiler/autotune/{name}", 0.0,
             f"best={r.best_schedule.label()} tuned={r.best_cycles} "
             f"default={r.default_cycles} hand={ih['cycles']} "
             f"speedup={r.speedup:.3f}")
    section = {
        "config": "cu2-shared",
        "space": {
            "coarsen": sorted(space.coarsen),
            "hoist": sorted(space.hoist),
            "branchy": sorted(space.branchy),
            "peel": sorted(space.peel),
        },
        "benches": benches,
        "wall_s": round(wall, 3),
    }
    return section, autotune_invariants(section)


def bench_codesign(emit, fast: bool) -> Tuple[dict, List[str]]:
    """(DesignPoint, Schedule) co-design sweep; returns (section,
    problems)."""
    from repro.compiler.autotune import SMOKE_SPACE, ScheduleSpace, codesign
    from repro.compiler.suite import def_args, hand_benches, kernel_def
    from repro.dse.search import enumerate_specs

    if fast:
        space = SMOKE_SPACE
        specs = enumerate_specs(cus=(1, 2), freq_targets=(500.0, 667.0))
        sizes = dict(FAST_SIZES)
    else:
        space = ScheduleSpace(coarsen=(1, 2, 4), hoist=(True,),
                              branchy=(True, False), peel=(True,))
        specs = enumerate_specs(cus=(1, 2, 4),
                                freq_targets=(500.0, 667.0))
        sizes = dict(FULL_SIZES)
    hands = hand_benches(sizes)
    defs = {n: kernel_def(n, *def_args(n, hands[n]))
            for n in FAST_TUNE_BENCHES}
    t0 = time.perf_counter()
    res = codesign(defs, specs, space=space)
    wall = time.perf_counter() - t0
    problems: List[str] = []
    if not res.frontier:
        problems.append("codesign frontier is empty")
    frontier_rows = [{"label": jp.label(), "schedule": jp.variant,
                      "time_us": round(jp.point.time_us, 3),
                      "area_mm2": round(jp.point.area_mm2, 2)}
                     for jp in res.frontier]
    for row in frontier_rows:
        emit(f"compiler/codesign/{row['label']}", row["time_us"],
             f"area={row['area_mm2']:.2f} schedule={row['schedule']}")
    section = {
        "workloads": sorted(defs),
        "schedules": sorted(res.results),
        "n_points": sum(len(r.points) for r in res.results.values()),
        "frontier": sorted(frontier_rows, key=lambda r: r["label"]),
        "wall_s": round(wall, 3),
    }
    return section, problems


def bench_compiled_dse(emit, fast: bool,
                       compiled: Dict[str, object]) -> Tuple[dict,
                                                             List[str]]:
    """Pareto sweep over compiled workloads (``compiled`` is the suite
    ``bench_suite_parity`` already built); returns (artifact, problems).
    """
    from repro import dse

    problems: List[str] = []
    if fast:
        specs = dse.enumerate_specs(cus=(1, 2),
                                    freq_targets=(500.0, 667.0))
        user = _user_kernel(512, 32)
        sample = ("vec_mul", "reduction")
    else:
        specs = dse.enumerate_specs(
            cus=(1, 2, 4, 8), freq_targets=(500.0, 590.0, 667.0, 750.0),
            memsys=("shared", "banked", "banked-iso"))
        user = _user_kernel(8192, 64)
        sample = ("vec_mul", "reduction", "xcorr")
    workloads = {n: b for n, b in compiled.items()
                 if n[len("dsl_"):] in sample}
    workloads["dsl_user_segred"] = user.as_bench(seed=11)
    ev = dse.Evaluator(benches=(), workloads=workloads, check=True)
    res = dse.search(specs=specs, evaluator=ev)
    for row in res.report():
        emit(f"compiler/dse/{row['label']}", row["time_us"],
             f"area={row['area_mm2']:.2f} frontier={row['on_frontier']}")
    if not res.frontier:
        problems.append("compiled-workload DSE frontier is empty")
    reference = min(res.frontier, key=lambda p: p.time_us) \
        if res.frontier else res.points[0]
    art = dse.dse_artifact(reference, res)
    art["workloads"] = sorted(workloads)
    return art, problems


def bench_compiler(emit, fast: bool = False,
                   out: str = None) -> Tuple[dict, List[str]]:
    """Run all sections and write the ``BENCH_compiler.json`` artifact."""
    import json

    out = out or os.environ.get("GGPU_COMPILER_OUT", "BENCH_compiler.json")
    rows, problems, compiled = bench_suite_parity(emit, fast)
    tune, p2 = bench_autotune(emit, fast)
    co, p3 = bench_codesign(emit, fast)
    dse_art, p4 = bench_compiled_dse(emit, fast, compiled)
    problems += p2 + p3 + p4
    art = {
        "schema": SCHEMA,
        "fast": bool(fast),
        "suite_parity": rows,
        "autotune": tune,
        "codesign": co,
        "dse": dse_art,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("compiler/artifact", 0.0, f"wrote {out}")
    return art, problems


def run_compiler_section(emit, fast: bool = False) -> list:
    """Registry section runner (``repro.registry`` SECTIONS ``compiler``)."""
    _art, problems = bench_compiler(emit, fast=fast)
    return problems
