"""Serving-subsystem benchmark (``python -m benchmarks.run --serve``).

Two sections, both recorded in the standardized ``BENCH_serve.json``
artifact (schema ``ggpu-serve/2``, path overridable via
``GGPU_SERVE_OUT``):

  * **throughput** — a bursty same-kernel trace served through the
    continuous-batching ``Scheduler`` (submit interleaved with
    incremental drains), measured twice over identical traffic: a **sync
    serial** drain (``max_inflight=1``: every chunk is collected before
    the next is staged — the pre-async behavior) and the **pipelined
    async** drain (chunks dispatched ahead of collection). The cold
    trace (first drain, which pays the jit compile) is reported
    separately from the steady-state rates; ``async_speedup`` is the
    steady-state ratio and must stay >= ``ASYNC_MIN_SPEEDUP`` (a smoke
    invariant ``check_bench`` also enforces). Batch occupancy (launches
    per compiled-stepper dispatch) and the executor trace-cache hit rate
    are measured on the async scheduler — repeat traffic must not
    re-trace.
  * **fleet** — the routing demo connecting the DSE output to the serving
    path: a mixed wide+narrow trace is served across two configs picked
    from a ``repro.dse.search`` Pareto front (every device dispatched
    before any is collected), and the routed fleet's modeled makespan is
    compared against pinning the whole trace to either single config.

``--fast`` shrinks the trace and the DSE grid (the CI ``serve-smoke``
job).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA = "ggpu-serve/2"
# pipelined async drain must beat the sync serial drain by this factor
ASYNC_MIN_SPEEDUP = 1.5


def _bursty_mems(b, k, rng):
    """k fresh memory images for bench ``b`` (same envelope, new data)."""
    n = b.gpu_mem.shape[0]
    return [np.concatenate([rng.integers(-100, 100,
                                         2 * b.gpu_n).astype(np.int32),
                            np.zeros(n - 2 * b.gpu_n, np.int32)])
            for _ in range(k)]


def bench_throughput(emit, fast: bool) -> dict:
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(32, 512)
    burst, max_batch = 16, 2                 # 8 same-kernel chunks per drain
    n_bursts = 3 if fast else 8
    reps = 3                                 # steady state: best of reps
    rng = np.random.default_rng(0)

    def steady(sched):
        """Best-of-``reps`` steady-state launches/sec over identical
        traffic: bursts of submissions interleaved with drains."""
        best, served = 0.0, 0
        for _ in range(reps):
            t0 = time.perf_counter()
            served = 0
            for _ in range(n_bursts):
                for m in _bursty_mems(b, burst, rng):
                    sched.submit(b.gpu_prog, m, b.gpu_items)
                served += len(sched.drain())
            best = max(best, served / (time.perf_counter() - t0))
        return best, served

    # sync serial reference: every chunk collected before the next one is
    # staged (the pre-async launch path). Its first drain pays the jit
    # compile for the chunk envelopes — the cold trace, reported apart
    # from every steady-state number.
    sync_sched = Scheduler(cfg, max_batch=max_batch, max_inflight=1)
    for m in _bursty_mems(b, burst, rng):
        sync_sched.submit(b.gpu_prog, m, b.gpu_items)
    t0 = time.perf_counter()
    sync_sched.drain()
    cold_trace_s = time.perf_counter() - t0
    sync_rate, served = steady(sync_sched)

    # pipelined async drain over the same traffic shape
    async_sched = Scheduler(cfg, max_batch=max_batch, max_inflight=8)
    for m in _bursty_mems(b, burst, rng):
        async_sched.submit(b.gpu_prog, m, b.gpu_items)
    async_sched.drain()                      # own envelope-cache warm-up
    st = async_sched.executor.stats
    l0, d0 = st.launches, st.dispatches
    h0, m0 = st.trace_hits, st.trace_misses
    async_rate, _ = steady(async_sched)
    hits = st.trace_hits - h0
    misses = st.trace_misses - m0
    speedup = async_rate / sync_rate
    row = {
        "device": f"{cfg.n_cus}cu/{cfg.memsys}",
        "kernel": b.name,
        "burst": burst,
        "max_batch": max_batch,
        "launches": served,
        "cold_trace_s": round(cold_trace_s, 4),
        "sync": {"launches_per_sec": round(sync_rate, 2),
                 "wall_s": round(served / sync_rate, 4)},
        "async": {"launches_per_sec": round(async_rate, 2),
                  "wall_s": round(served / async_rate, 4),
                  "max_inflight": 8},
        "async_speedup": round(speedup, 3),
        "launches_per_sec": round(async_rate, 2),
        "batch_occupancy": round((st.launches - l0)
                                 / (st.dispatches - d0), 3),
        "executor_cache": {"hits": hits, "misses": misses,
                           "hit_rate": round(hits / (hits + misses), 3)
                           if hits + misses else 0.0},
    }
    emit("serve/throughput/cold_trace", cold_trace_s * 1e6,
         "first drain incl. jit compile")
    emit("serve/throughput/sync", 1e6 / sync_rate,
         f"launches_per_sec={row['sync']['launches_per_sec']} "
         "(serial drain, max_inflight=1)")
    emit("serve/throughput/async", 1e6 / async_rate,
         f"launches_per_sec={row['async']['launches_per_sec']} "
         f"speedup={row['async_speedup']}x "
         f"occupancy={row['batch_occupancy']} "
         f"cache_hit_rate={row['executor_cache']['hit_rate']}")
    return row


def bench_fleet(emit, fast: bool) -> dict:
    from repro import dse
    from repro.ggpu import programs
    from repro.serve import Fleet, pinned_makespan

    # DSE-selected devices: the (fastest, smallest) ends of a Pareto front
    if fast:
        specs = dse.enumerate_specs(cus=(1, 8), freq_targets=(667.0,))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (16, 128)})
    else:
        specs = dse.enumerate_specs(cus=(1, 2, 4, 8),
                                    freq_targets=(500.0, 667.0))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (32, 256)})
    res = dse.search(specs=specs, evaluator=ev)
    frontier = sorted(res.frontier, key=lambda p: p.time_us)
    picks = [frontier[0], frontier[-1]]
    if picks[0] is picks[1]:
        raise RuntimeError("DSE frontier collapsed to one design: nothing "
                           "to route across — widen the spec grid")
    devices = [(p.label(), p.point.config) for p in picks]

    wide = programs._copy(16, 1024 if fast else 4096)      # many wavefronts
    narrow = programs._reduction(64, 256 if fast else 1024)  # W=1
    rng = np.random.default_rng(1)
    trace = []
    for _ in range(3 if fast else 8):
        trace.append((wide.gpu_prog, _bursty_mems(wide, 1, rng)[0],
                      wide.gpu_items))
        trace.append((narrow.gpu_prog, _bursty_mems(narrow, 1, rng)[0],
                      narrow.gpu_items))

    fleet = Fleet(devices)
    for prog, mem0, n_items in trace:
        fleet.submit(prog, mem0, n_items)
    fleet.drain()
    rep = fleet.report()
    pinned = {name: round(pinned_makespan(cfg, trace), 3)
              for name, cfg in devices}
    best_pin = min(pinned.values())
    rep.update({
        "pinned_us": pinned,
        "speedup_vs_best_pin": round(best_pin / rep["makespan_us"], 3),
        "beats_both_pins": rep["makespan_us"] < best_pin,
    })
    emit("serve/fleet/makespan", rep["makespan_us"],
         f"devices={'+'.join(rep['devices'])} "
         f"placement={rep['placement']} "
         f"pinned_us={pinned} speedup={rep['speedup_vs_best_pin']}x")
    return rep


def invariant_problems(art: dict) -> list:
    """Smoke invariants a healthy serve run must satisfy — checked by
    ``benchmarks.run`` after the artifact is written so a broken result
    fails the build instead of uploading quietly."""
    problems = []
    fleet = art.get("fleet", {})
    if not fleet.get("beats_both_pins"):
        problems.append(
            "fleet.beats_both_pins: routing does not beat both pinned "
            f"configs (makespan={fleet.get('makespan_us')} "
            f"pinned={fleet.get('pinned_us')})")
    if art.get("cache_hit_rate", 0) <= 0:
        problems.append("cache_hit_rate: executor trace-cache hit rate "
                        "is 0 — repeat traffic is re-tracing")
    if art.get("batch_occupancy", 0) <= 1:
        problems.append(
            f"batch occupancy {art.get('batch_occupancy')} <= 1: the "
            "scheduler is not folding same-kernel launches")
    spd = art.get("async_speedup", 0)
    if spd < ASYNC_MIN_SPEEDUP:
        problems.append(
            f"async_speedup {spd} < {ASYNC_MIN_SPEEDUP}: the pipelined "
            "async drain must beat the sync serial drain")
    if fleet.get("quarantined"):
        problems.append(
            f"fleet quarantined launches: {fleet['quarantined']}")
    return problems


def bench_serve(emit, fast: bool = False, out: str = None) -> dict:
    """Run both sections and write the ``BENCH_serve.json`` artifact;
    returns the artifact dict."""
    out = out or os.environ.get("GGPU_SERVE_OUT", "BENCH_serve.json")
    throughput = bench_throughput(emit, fast)
    fleet = bench_fleet(emit, fast)
    art = {
        "schema": SCHEMA,
        "launches_per_sec": throughput["launches_per_sec"],
        "sync_launches_per_sec": throughput["sync"]["launches_per_sec"],
        "async_speedup": throughput["async_speedup"],
        "cold_trace_s": throughput["cold_trace_s"],
        "batch_occupancy": throughput["batch_occupancy"],
        "cache_hit_rate": throughput["executor_cache"]["hit_rate"],
        "throughput": throughput,
        "fleet": fleet,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("serve/artifact", 0.0, f"wrote {out}")
    return art
