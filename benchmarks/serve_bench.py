"""Serving-subsystem benchmark (``python -m benchmarks.run --serve``).

Five sections, all recorded in the standardized ``BENCH_serve.json``
artifact (schema ``ggpu-serve/4``, path overridable via
``GGPU_SERVE_OUT``):

  * **throughput** — a bursty same-kernel trace served through the
    continuous-batching ``Scheduler`` (submit interleaved with
    incremental drains), measured twice over identical traffic: a **sync
    serial** drain (``max_inflight=1``: every chunk is collected before
    the next is staged — the pre-async behavior) and the **pipelined
    async** drain (chunks dispatched ahead of collection). The cold
    trace (first drain, which pays the jit compile) is reported
    separately from the steady-state rates; ``async_speedup`` is the
    steady-state ratio and must stay >= ``ASYNC_MIN_SPEEDUP`` (a smoke
    invariant ``check_bench`` also enforces — on hosts with >= 2 CPUs
    only: with a single core there is no second core to overlap onto,
    so the artifact records the ratio and ``host_cpus`` but the gate is
    report-only). Batch occupancy (launches
    per compiled-stepper dispatch) and the executor trace-cache hit rate
    are measured on the async scheduler — repeat traffic must not
    re-trace.
  * **sharded** — the same bursty trace served through a data-parallel
    scheduler whose chunks shard their launch axis over every JAX device
    (``mesh=make_launch_mesh()``; CPU CI simulates 8 devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), compared
    against the single-device async scheduler over identical traffic.
    The sharded scheduler plans ``max_batch * shards``-wide chunks, so
    one dispatch covers what the single-device path pipelines as
    ``shards`` dispatches. Results are checked bit-exact against direct
    ``run_kernel``; at >= 8 devices ``speedup`` must clear
    ``SHARDED_MIN_SPEEDUP`` (enforced by the invariants below).
  * **latency** — open-loop tail latency: a Poisson arrival trace
    (``repro.serve.loadgen``, deterministic seed) offered at a fixed
    fraction of the measured async capacity, replayed against the
    sharded scheduler; reports p50/p99/mean launch latency and the
    sustained rate.
  * **fleet** — the routing demo connecting the DSE output to the serving
    path: a mixed wide+narrow trace is served across two configs picked
    from a ``repro.dse.search`` Pareto front (every device dispatched
    before any is collected), and the routed fleet's modeled makespan is
    compared against pinning the whole trace to either single config.
  * **graph** — device-resident kernel graphs: N instances of a 3-stage
    map→reduce→scale chain (split out of one traced expression by
    ``repro.compiler.compile_graph``) served three ways. **pipelined**
    submits every stage up front with dependency edges and drains once —
    the dependency-aware scheduler folds each stage across instances
    into one cohort dispatch and feeds producers into consumers entirely
    on the device (``BlockPatch``), zero host round-trips between
    stages. **host_staged** is the gated baseline: the pre-graph DAG
    idiom, each chain executed stage-by-stage with a full
    ``LaunchHandle`` download and host re-staging per edge (without
    dependency edges the per-chain barrier structure also hides
    cross-chain folding from the scheduler). **host_folded** is reported
    for calibration: the strongest manual workaround, stage-major
    submission with one drain barrier + download + re-stage per stage —
    it recovers cohort folding, so the residual vs pipelined isolates
    the pure round-trip/overlap cost (parity on a single-core host
    where simulator compute dominates). ``speedup`` (pipelined vs
    host_staged) must clear ``GRAPH_MIN_SPEEDUP``, the pipelined run
    must execute in at most one dispatch per stage, and all three paths
    must be bit-exact against the ``Program`` oracle — all enforced by
    the invariants below and by ``check_bench``.

``--fast`` shrinks the traces and the DSE grid (the CI ``serve-smoke``,
``fleet-smoke``, and ``graph-smoke`` jobs; ``benchmarks.run --graph``
runs the graph section alone and writes a partial ``BENCH_graph.json``
that ``check_bench --section graph`` gates against the full baseline).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

SCHEMA = "ggpu-serve/4"
# pipelined async drain must beat the sync serial drain by this factor
ASYNC_MIN_SPEEDUP = 1.5
# device-resident pipelined graph execution must beat the host-staged
# per-chain baseline by this factor (the win is structural — folding plus
# zero host round-trips — so it holds even on a single-core host)
GRAPH_MIN_SPEEDUP = 1.5
# sharded scheduler must beat the single-device async scheduler by this
# factor when >= this many devices are simulated (dispatch amortization
# alone clears it on one core; real parallel hardware adds more)
SHARDED_MIN_SPEEDUP = 1.5
SHARDED_MIN_DEVICES = 8
# offered Poisson load as a fraction of measured async capacity
LATENCY_LOAD_FRACTION = 0.6


def _bursty_mems(b, k, rng):
    """k fresh memory images for bench ``b`` (same envelope, new data)."""
    n = b.gpu_mem.shape[0]
    return [np.concatenate([rng.integers(-100, 100,
                                         2 * b.gpu_n).astype(np.int32),
                            np.zeros(n - 2 * b.gpu_n, np.int32)])
            for _ in range(k)]


def bench_throughput(emit, fast: bool) -> dict:
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import Scheduler

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(32, 512)
    burst, max_batch = 16, 2                 # 8 same-kernel chunks per drain
    n_bursts = 3 if fast else 8
    reps = 3                                 # steady state: best of reps
    rng = np.random.default_rng(0)

    def steady(sched):
        """Best-of-``reps`` steady-state launches/sec over identical
        traffic: bursts of submissions interleaved with drains."""
        best, served = 0.0, 0
        for _ in range(reps):
            t0 = time.perf_counter()
            served = 0
            for _ in range(n_bursts):
                for m in _bursty_mems(b, burst, rng):
                    sched.submit(b.gpu_prog, m, b.gpu_items)
                served += len(sched.drain())
            best = max(best, served / (time.perf_counter() - t0))
        return best, served

    # sync serial reference: every chunk collected before the next one is
    # staged (the pre-async launch path). Its first drain pays the jit
    # compile for the chunk envelopes — the cold trace, reported apart
    # from every steady-state number.
    sync_sched = Scheduler(cfg, max_batch=max_batch, max_inflight=1)
    for m in _bursty_mems(b, burst, rng):
        sync_sched.submit(b.gpu_prog, m, b.gpu_items)
    t0 = time.perf_counter()
    sync_sched.drain()
    cold_trace_s = time.perf_counter() - t0
    sync_rate, served = steady(sync_sched)

    # pipelined async drain over the same traffic shape
    async_sched = Scheduler(cfg, max_batch=max_batch, max_inflight=8)
    for m in _bursty_mems(b, burst, rng):
        async_sched.submit(b.gpu_prog, m, b.gpu_items)
    async_sched.drain()                      # own envelope-cache warm-up
    st = async_sched.executor.stats
    l0, d0 = st.launches, st.dispatches
    h0, m0 = st.trace_hits, st.trace_misses
    async_rate, _ = steady(async_sched)
    hits = st.trace_hits - h0
    misses = st.trace_misses - m0
    speedup = async_rate / sync_rate
    row = {
        "device": f"{cfg.n_cus}cu/{cfg.memsys}",
        "kernel": b.name,
        "burst": burst,
        "max_batch": max_batch,
        "launches": served,
        "cold_trace_s": round(cold_trace_s, 4),
        "sync": {"launches_per_sec": round(sync_rate, 2),
                 "wall_s": round(served / sync_rate, 4)},
        "async": {"launches_per_sec": round(async_rate, 2),
                  "wall_s": round(served / async_rate, 4),
                  "max_inflight": 8},
        "async_speedup": round(speedup, 3),
        "launches_per_sec": round(async_rate, 2),
        "batch_occupancy": round((st.launches - l0)
                                 / (st.dispatches - d0), 3),
        "executor_cache": {"hits": hits, "misses": misses,
                           "hit_rate": round(hits / (hits + misses), 3)
                           if hits + misses else 0.0},
    }
    emit("serve/throughput/cold_trace", cold_trace_s * 1e6,
         "first drain incl. jit compile")
    emit("serve/throughput/sync", 1e6 / sync_rate,
         f"launches_per_sec={row['sync']['launches_per_sec']} "
         "(serial drain, max_inflight=1)")
    emit("serve/throughput/async", 1e6 / async_rate,
         f"launches_per_sec={row['async']['launches_per_sec']} "
         f"speedup={row['async_speedup']}x "
         f"occupancy={row['batch_occupancy']} "
         f"cache_hit_rate={row['executor_cache']['hit_rate']}")
    return row


def bench_sharded(emit, fast: bool) -> dict:
    """Sharded vs single-device scheduler over identical bursty traffic,
    plus a bit-exactness audit of the sharded results."""
    import jax

    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig, run_kernel
    from repro.launch.mesh import make_launch_mesh
    from repro.serve import Scheduler

    cfg = GGPUConfig(n_cus=2)
    # the smallest suite kernel at a high burst: the dispatch-bound regime
    # sharding targets. One sharded dispatch plans max_batch*shards
    # launches, replacing `shards` pipelined dispatches — on a single host
    # core the win is pure dispatch amortization (~1.6x at 8 shards);
    # real parallel devices add compute concurrency on top.
    b = programs._vec_mul(16, 64)
    burst, max_batch = 32, 2
    n_bursts = 3 if fast else 8
    reps = 3
    rng = np.random.default_rng(2)
    mesh = make_launch_mesh()
    n_devices = jax.device_count()

    def steady(sched):
        best, served = 0.0, 0
        for _ in range(reps):
            t0 = time.perf_counter()
            served = 0
            for _ in range(n_bursts):
                for m in _bursty_mems(b, burst, rng):
                    sched.submit(b.gpu_prog, m, b.gpu_items)
                served += len(sched.drain())
            best = max(best, served / (time.perf_counter() - t0))
        return best, served

    def warm(sched):
        for m in _bursty_mems(b, burst, rng):
            sched.submit(b.gpu_prog, m, b.gpu_items)
        sched.drain()

    single = Scheduler(cfg, max_batch=max_batch, max_inflight=8)
    warm(single)
    single_rate, served = steady(single)

    sharded = Scheduler(cfg, max_batch=max_batch, max_inflight=8, mesh=mesh)
    warm(sharded)
    sharded_rate, _ = steady(sharded)

    # bit-exactness: one burst through the sharded scheduler vs direct
    # single-launch execution of every member
    audit = _bursty_mems(b, burst, rng)
    tickets = [sharded.submit(b.gpu_prog, m, b.gpu_items) for m in audit]
    by_ticket = {r.info["ticket"]: r for r in sharded.drain()}
    bit_exact = True
    for tk, m in zip(tickets, audit):
        mem, info = run_kernel(b.gpu_prog, m, b.gpu_items, cfg)
        r = by_ticket[tk]
        if not (np.array_equal(r.mem, mem)
                and r.info["cycles"] == info["cycles"]):
            bit_exact = False

    speedup = sharded_rate / single_rate
    row = {
        "device": f"{cfg.n_cus}cu/{cfg.memsys}",
        "kernel": b.name,
        "burst": burst,
        "max_batch": max_batch,
        "n_devices": n_devices,
        "shards": sharded.executor.shards,
        "plan_batch": sharded.plan_batch,
        "launches": served,
        "single": {"launches_per_sec": round(single_rate, 2)},
        "sharded": {"launches_per_sec": round(sharded_rate, 2)},
        "speedup": round(speedup, 3),
        "bit_exact": bit_exact,
    }
    emit("serve/sharded", 1e6 / sharded_rate,
         f"launches_per_sec={row['sharded']['launches_per_sec']} "
         f"speedup={row['speedup']}x over single-device "
         f"(shards={row['shards']}, n_devices={n_devices}, "
         f"bit_exact={bit_exact})")
    return row


def bench_latency(emit, fast: bool, capacity_per_s: float) -> dict:
    """Open-loop Poisson tail latency at a fixed fraction of measured
    capacity, against the sharded scheduler (falls back to single-device
    with one JAX device)."""
    from repro.ggpu import programs
    from repro.ggpu.engine import GGPUConfig
    from repro.launch.mesh import make_launch_mesh
    from repro.serve import Request, Scheduler, poisson_arrivals, replay

    cfg = GGPUConfig(n_cus=2)
    b = programs._vec_mul(32, 512)
    rng = np.random.default_rng(3)
    n = 48 if fast else 200
    rate = LATENCY_LOAD_FRACTION * capacity_per_s
    arrivals = poisson_arrivals(rate, n, seed=42)
    mems = _bursty_mems(b, 32, rng)

    sched = Scheduler(cfg, max_batch=2, max_inflight=8,
                      mesh=make_launch_mesh())
    # warm every chunk envelope open-loop traffic can produce: cohort
    # sizes are bucketed to powers of two (engine ``cohort_rows``), so
    # draining bursts of plan_batch, plan_batch/2, ... 2, and 1 covers
    # them all — the replay itself then never pays a jit compile
    k = sched.plan_batch
    while k >= 1:
        for m in _bursty_mems(b, k, rng):
            sched.submit(b.gpu_prog, m, b.gpu_items)
        sched.drain()
        k //= 2

    res = replay(sched, arrivals,
                 lambda i: Request(b.gpu_prog, mems[i % len(mems)],
                                   b.gpu_items))
    row = {
        "arrivals": "poisson",
        "seed": 42,
        "n": n,
        "offered_rate_per_s": round(rate, 2),
        "load_fraction": LATENCY_LOAD_FRACTION,
        "shards": sched.executor.shards,
        **res.report(),
    }
    emit("serve/latency/p50", row["p50_ms"] * 1e3,
         f"open-loop poisson @ {row['offered_rate_per_s']}/s "
         f"({LATENCY_LOAD_FRACTION:.0%} of capacity), n={n}")
    emit("serve/latency/p99", row["p99_ms"] * 1e3,
         f"p50={row['p50_ms']}ms mean={row['mean_ms']}ms "
         f"sustained={row['rate_per_s']}/s served={row['served']}")
    return row


def bench_fleet(emit, fast: bool) -> dict:
    from repro import dse
    from repro.ggpu import programs
    from repro.serve import Fleet, pinned_makespan

    # DSE-selected devices: the (fastest, smallest) ends of a Pareto front
    if fast:
        specs = dse.enumerate_specs(cus=(1, 8), freq_targets=(667.0,))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (16, 128)})
    else:
        specs = dse.enumerate_specs(cus=(1, 2, 4, 8),
                                    freq_targets=(500.0, 667.0))
        ev = dse.Evaluator(benches=("xcorr",), sizes={"xcorr": (32, 256)})
    res = dse.search(specs=specs, evaluator=ev)
    frontier = sorted(res.frontier, key=lambda p: p.time_us)
    picks = [frontier[0], frontier[-1]]
    if picks[0] is picks[1]:
        raise RuntimeError("DSE frontier collapsed to one design: nothing "
                           "to route across — widen the spec grid")
    devices = [(p.label(), p.point.config) for p in picks]

    wide = programs._copy(16, 1024 if fast else 4096)      # many wavefronts
    narrow = programs._reduction(64, 256 if fast else 1024)  # W=1
    rng = np.random.default_rng(1)
    trace = []
    for _ in range(3 if fast else 8):
        trace.append((wide.gpu_prog, _bursty_mems(wide, 1, rng)[0],
                      wide.gpu_items))
        trace.append((narrow.gpu_prog, _bursty_mems(narrow, 1, rng)[0],
                      narrow.gpu_items))

    fleet = Fleet(devices)
    for prog, mem0, n_items in trace:
        fleet.submit(prog, mem0, n_items)
    fleet.drain()
    rep = fleet.report()
    pinned = {name: round(pinned_makespan(cfg, trace), 3)
              for name, cfg in devices}
    best_pin = min(pinned.values())
    rep.update({
        "pinned_us": pinned,
        "speedup_vs_best_pin": round(best_pin / rep["makespan_us"], 3),
        "beats_both_pins": rep["makespan_us"] < best_pin,
    })
    emit("serve/fleet/makespan", rep["makespan_us"],
         f"devices={'+'.join(rep['devices'])} "
         f"placement={rep['placement']} "
         f"pinned_us={pinned} speedup={rep['speedup_vs_best_pin']}x")
    return rep


def bench_graph(emit, fast: bool) -> dict:
    """Device-resident pipelined kernel-graph execution vs the
    host-staged baselines (module doc) on a 3-stage map→reduce→scale
    chain, bit-exact against the ``Program`` oracle."""
    from repro.compiler import compile_graph
    from repro.ggpu.engine import GGPUConfig
    from repro.serve import (Scheduler, extract_outputs,
                             run_chains_host_staged,
                             run_programs_host_staged, submit_programs)

    cfg = GGPUConfig(n_cus=2)
    n, seg = 256, 64
    n_inst = 8 if fast else 16
    reps = 3
    program = compile_graph(
        lambda a, b: (a * b).seg_sum(seg) * 3 + 1,
        {"a": n, "b": n}, name="map_reduce_scale")
    rng = np.random.default_rng(7)

    def instances():
        return [{"a": rng.integers(-100, 100, n).astype(np.int32),
                 "b": rng.integers(-100, 100, n).astype(np.int32)}
                for _ in range(n_inst)]

    # one scheduler per path, same config (shared executor/envelope
    # cache); max_batch = n_inst so each stage folds into one cohort
    pipe = Scheduler(cfg, max_batch=n_inst, max_inflight=8)
    staged = Scheduler(cfg, max_batch=n_inst, max_inflight=8)
    folded = Scheduler(cfg, max_batch=n_inst, max_inflight=8)

    # warm every path's chunk envelopes so steady state never re-traces
    submit_programs(pipe, program, instances())
    pipe.drain()
    run_chains_host_staged(staged, program, instances())
    run_programs_host_staged(folded, program, instances())

    best_pipe = best_staged = best_folded = float("inf")
    outs = staged_outs = None
    dispatches = 0
    for _ in range(reps):
        ins = instances()
        st = pipe.executor.stats
        d0 = st.dispatches
        t0 = time.perf_counter()
        handles = submit_programs(pipe, program, ins)
        outs = extract_outputs(pipe.drain(), handles)
        best_pipe = min(best_pipe, time.perf_counter() - t0)
        dispatches = st.dispatches - d0
        t0 = time.perf_counter()
        staged_outs = run_chains_host_staged(staged, program, ins)
        best_staged = min(best_staged, time.perf_counter() - t0)
        t0 = time.perf_counter()
        folded_outs = run_programs_host_staged(folded, program, ins)
        best_folded = min(best_folded, time.perf_counter() - t0)
    # audit the last rep's results against the NumPy oracle: all three
    # execution paths must agree bit-for-bit
    refs = [program.reference(i) for i in ins]
    bit_exact = all(
        np.array_equal(o, r) and np.array_equal(s, r)
        and np.array_equal(f, r)
        for o, s, f, r in zip(outs, staged_outs, folded_outs, refs))

    speedup = best_staged / best_pipe
    row = {
        "device": f"{cfg.n_cus}cu/{cfg.memsys}",
        "program": program.name,
        "stages": [ck.name for ck in program.stages],
        "n": n,
        "seg": seg,
        "instances": n_inst,
        "launches": 3 * n_inst,
        "pipelined": {"wall_s": round(best_pipe, 4),
                      "chains_per_sec": round(n_inst / best_pipe, 2),
                      "dispatches": dispatches},
        "host_staged": {"wall_s": round(best_staged, 4),
                        "chains_per_sec": round(n_inst / best_staged, 2)},
        "host_folded": {"wall_s": round(best_folded, 4),
                        "chains_per_sec": round(n_inst / best_folded, 2)},
        "speedup": round(speedup, 3),
        "folded_speedup": round(best_folded / best_pipe, 3),
        "bit_exact": bit_exact,
    }
    emit("serve/graph/pipelined", best_pipe * 1e6 / n_inst,
         f"chains_per_sec={row['pipelined']['chains_per_sec']} "
         f"dispatches={dispatches} for {3 * n_inst} launches")
    emit("serve/graph/host_staged", best_staged * 1e6 / n_inst,
         f"speedup={row['speedup']}x pipelined over per-chain host "
         f"staging (folded_speedup={row['folded_speedup']}x, "
         f"bit_exact={bit_exact})")
    return row


def graph_invariant_problems(art: dict) -> list:
    """Absolute health invariants of the ``graph`` section (shared by the
    full-artifact check and the partial ``--graph`` smoke run)."""
    problems = []
    g = art.get("graph", {})
    if not g:
        return ["graph section missing from artifact"]
    if not g.get("bit_exact"):
        problems.append(
            "graph.bit_exact: device-resident pipelined results diverge "
            "from the Program oracle / independently-run stages")
    spd = g.get("speedup", 0)
    if spd < GRAPH_MIN_SPEEDUP:
        problems.append(
            f"graph.speedup {spd} < {GRAPH_MIN_SPEEDUP}: device-resident "
            "pipelined execution must beat the host-staged baseline")
    stages = len(g.get("stages", ())) or 3
    disp = g.get("pipelined", {}).get("dispatches", -1)
    if not 0 < disp <= stages:
        problems.append(
            f"graph.pipelined.dispatches {disp}: {g.get('instances')} "
            f"chains x {stages} stages must fold into at most "
            f"{stages} dispatches (one cohort per stage)")
    return problems


def invariant_problems(art: dict) -> list:
    """Smoke invariants a healthy serve run must satisfy — checked by
    ``benchmarks.run`` after the artifact is written so a broken result
    fails the build instead of uploading quietly."""
    problems = []
    fleet = art.get("fleet", {})
    if not fleet.get("beats_both_pins"):
        problems.append(
            "fleet.beats_both_pins: routing does not beat both pinned "
            f"configs (makespan={fleet.get('makespan_us')} "
            f"pinned={fleet.get('pinned_us')})")
    if art.get("cache_hit_rate", 0) <= 0:
        problems.append("cache_hit_rate: executor trace-cache hit rate "
                        "is 0 — repeat traffic is re-tracing")
    if art.get("batch_occupancy", 0) <= 1:
        problems.append(
            f"batch occupancy {art.get('batch_occupancy')} <= 1: the "
            "scheduler is not folding same-kernel launches")
    spd = art.get("async_speedup", 0)
    if art.get("n_devices", 1) == 1 and art.get("host_cpus", 2) >= 2 \
            and spd < ASYNC_MIN_SPEEDUP:
        # the async-vs-sync comparison measures host-pipelining overlap;
        # forcing multiple host devices (the fleet-smoke job) partitions
        # XLA's thread pool and perturbs exactly that overlap, so the
        # gate binds on the single-device job only — the multi-device
        # job is gated on the sharded speedup instead. Below 2 host CPUs
        # there is no second core to overlap onto, so the speedup is
        # recorded in the artifact but not gated (report-only)
        problems.append(
            f"async_speedup {spd} < {ASYNC_MIN_SPEEDUP}: the pipelined "
            "async drain must beat the sync serial drain")
    sharded = art.get("sharded", {})
    if not sharded.get("bit_exact"):
        problems.append("sharded.bit_exact: sharded scheduler results "
                        "diverge from direct run_kernel")
    if art.get("n_devices", 1) >= SHARDED_MIN_DEVICES \
            and sharded.get("speedup", 0) < SHARDED_MIN_SPEEDUP:
        problems.append(
            f"sharded.speedup {sharded.get('speedup')} < "
            f"{SHARDED_MIN_SPEEDUP} at {art.get('n_devices')} devices: "
            "the sharded scheduler must beat the single-device async one")
    lat = art.get("latency", {})
    if lat.get("served", 0) != lat.get("n", -1):
        problems.append(
            f"latency: served {lat.get('served')} != offered {lat.get('n')}"
            " — the open-loop replay dropped or quarantined requests")
    if not (0 < lat.get("p50_ms", 0) <= lat.get("p99_ms", 0)):
        problems.append(
            f"latency percentiles malformed: p50={lat.get('p50_ms')} "
            f"p99={lat.get('p99_ms')}")
    if fleet.get("quarantined"):
        problems.append(
            f"fleet quarantined launches: {fleet['quarantined']}")
    problems += graph_invariant_problems(art)
    return problems


def bench_serve(emit, fast: bool = False, out: str = None) -> dict:
    """Run all five sections and write the ``BENCH_serve.json`` artifact;
    returns the artifact dict."""
    import jax

    out = out or os.environ.get("GGPU_SERVE_OUT", "BENCH_serve.json")
    throughput = bench_throughput(emit, fast)
    sharded = bench_sharded(emit, fast)
    latency = bench_latency(emit, fast,
                            throughput["async"]["launches_per_sec"])
    fleet = bench_fleet(emit, fast)
    graph = bench_graph(emit, fast)
    art = {
        "schema": SCHEMA,
        "n_devices": jax.device_count(),
        "host_cpus": os.cpu_count(),
        "launches_per_sec": throughput["launches_per_sec"],
        "sync_launches_per_sec": throughput["sync"]["launches_per_sec"],
        "async_speedup": throughput["async_speedup"],
        "sharded_speedup": sharded["speedup"],
        "graph_speedup": graph["speedup"],
        "cold_trace_s": throughput["cold_trace_s"],
        "batch_occupancy": throughput["batch_occupancy"],
        "cache_hit_rate": throughput["executor_cache"]["hit_rate"],
        "throughput": throughput,
        "sharded": sharded,
        "latency": latency,
        "fleet": fleet,
        "graph": graph,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("serve/artifact", 0.0, f"wrote {out}")
    return art


def bench_graph_only(emit, fast: bool = False, out: str = None) -> dict:
    """Run just the graph section (the CI ``graph-smoke`` job) and write
    a partial ``BENCH_graph.json`` artifact — same schema tag plus a
    ``sections`` marker so ``check_bench --section graph`` knows it is
    gating a subset against the full committed baseline."""
    import jax

    out = out or os.environ.get("GGPU_GRAPH_OUT", "BENCH_graph.json")
    graph = bench_graph(emit, fast)
    art = {
        "schema": SCHEMA,
        "sections": ["graph"],
        "n_devices": jax.device_count(),
        "graph_speedup": graph["speedup"],
        "graph": graph,
    }
    with open(out, "w") as f:
        json.dump(art, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("serve/artifact", 0.0, f"wrote {out}")
    return art


def run_serve_section(emit, fast: bool = False) -> list:
    """Registry section runner (``repro.registry`` SECTIONS ``serve`` and
    ``fleet``): run the serve suite, return invariant violations."""
    return invariant_problems(bench_serve(emit, fast=fast))


def run_graph_section(emit, fast: bool = False) -> list:
    """Registry section runner (``graph``): partial-artifact variant."""
    return graph_invariant_problems(bench_graph_only(emit, fast=fast))
